//! Cluster acceptance tests (ISSUE 1): a mixed-topology workload on a
//! 4-device fleet must be (a) bit-identical to single-device serving,
//! (b) strictly faster in modeled aggregate throughput, and (c) cheaper
//! in reconfigurations per request than one coordinator seeing the same
//! interleaved stream.

use famous::accel::FamousAccelerator;
use famous::cluster::{Cluster, ClusterConfig, DeviceSpec, ShardPlan, WorkloadProfile};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Coordinator, Request, SchedulerConfig};
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;

fn mixed_workload() -> Vec<Topology> {
    vec![
        Topology::new(64, 768, 8, 64),
        Topology::new(32, 768, 8, 64),
        Topology::new(64, 512, 8, 64),
    ]
}

/// Same scheduler tuning for the lone coordinator and every cluster
/// device: an online-serving window (bounded reordering), so neither
/// side gets an offline-batching advantage.
fn serving_sched() -> SchedulerConfig {
    SchedulerConfig { max_batch: 4, policy: BatchPolicy::GroupByTopology, fairness_window: 4 }
}

#[test]
fn four_device_cluster_acceptance() {
    let topos = mixed_workload();
    let n = 24usize;
    let requests: Vec<Request> = (0..n)
        .map(|i| {
            let t = topos[i % topos.len()].clone();
            Request::new(i as u64, t.clone(), MhaInputs::generate(&t))
        })
        .collect();

    // --- Single device: one coordinator, interleaved arrival order. ---
    let mut single = Coordinator::new(
        FamousAccelerator::with_sim_datapath(SimConfig::u55c()),
        serving_sched(),
    );
    for r in &requests {
        single.submit(r.clone()).unwrap();
    }
    let single_responses = single.serve_all().unwrap();
    assert_eq!(single_responses.len(), n);
    // Same occupancy convention as the fleet: Σ per-batch makespan
    // (max-of-batch), so the comparison is like-for-like.
    let single_busy_ms: f64 = single.stats.batch_makespan_ms;
    let single_reconfigs = single.stats.reconfigurations;

    // --- Cluster: 4 devices, same scheduler config, same stream. ---
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    let cluster = Cluster::start(
        devices,
        &WorkloadProfile::uniform(&topos),
        ClusterConfig { scheduler: serving_sched(), ..ClusterConfig::default() },
    )
    .unwrap();
    let h = cluster.handle();
    let mut cluster_outputs = Vec::new();
    for r in &requests {
        let resp = h.call(r.clone()).unwrap();
        assert!(!resp.sharded);
        cluster_outputs.push((resp.id, resp.output));
    }
    let fleet = cluster.shutdown();

    // (a) Every response bit-identical to the single-device output.
    for (id, out) in &cluster_outputs {
        let reference = single_responses.iter().find(|r| r.id == *id).unwrap();
        assert_eq!(out, &reference.output, "request {id} diverged from single-device run");
    }

    // (b) Strictly higher modeled aggregate throughput: same total GOP
    // over a strictly smaller makespan (the busiest device's fabric
    // occupancy vs the lone device serving everything).
    let makespan = fleet.makespan_ms();
    assert!(makespan > 0.0);
    assert!(
        makespan < single_busy_ms,
        "cluster makespan {makespan:.2} ms !< single-device busy {single_busy_ms:.2} ms"
    );
    let cluster_gops = fleet.cluster_gops();
    let single_gops = fleet.totals.total_gop / (single_busy_ms * 1e-3);
    assert!(
        cluster_gops > single_gops,
        "cluster {cluster_gops:.0} GOPS !> single {single_gops:.0} GOPS"
    );

    // (c) Fewer reconfigurations per request: affinity gives each device
    // a homogeneous stream (one reprogram per topology-device pair),
    // while the lone coordinator flips topologies inside its window.
    let cluster_reconfigs = fleet.reconfigurations();
    assert!(
        cluster_reconfigs < single_reconfigs,
        "cluster {cluster_reconfigs} reconfigs !< single {single_reconfigs}"
    );
    assert_eq!(fleet.totals.completed as usize, n);
    assert!(fleet.reconfigs_per_request() < single_reconfigs as f64 / n as f64);
    // Affinity should be near-perfect on a stable mix.
    assert!(fleet.affinity_hit_rate() > 0.9, "hit rate {}", fleet.affinity_hit_rate());
}

#[test]
fn cluster_shards_bert_large_on_heterogeneous_fleet() {
    // Mixed U55C + U200 fleet; BERT-large (d_model 1024, h 16) fits no
    // single build and must be head-sharded across two devices.
    let large = Topology::new(64, 1024, 16, 64);
    let base = Topology::new(64, 768, 6, 64);
    let cluster = Cluster::start(
        vec![
            DeviceSpec::u55c(0),
            DeviceSpec::u55c(1),
            DeviceSpec::u200(2),
            DeviceSpec::u200(3),
        ],
        &WorkloadProfile::uniform(&[large.clone(), base.clone()]),
        ClusterConfig::default(),
    )
    .unwrap();
    let h = cluster.handle();

    let inputs = MhaInputs::generate(&large);
    let resp =
        h.call(Request::new(1, large.clone(), inputs.clone())).unwrap();
    assert!(resp.sharded);
    assert_eq!(resp.output.len(), 64 * 1024);
    // The halves are h=8 shapes, so only the U55Cs can serve them.
    assert!(resp.devices.iter().all(|&d| d < 2), "halves on {:?}", resp.devices);

    // Bit-identical to the same split served by one local accelerator.
    let plan = ShardPlan::plan(&large).unwrap();
    let (lo, hi) = plan.split_inputs(&inputs).unwrap();
    let mut accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let want = plan
        .concat_outputs(
            &accel.run(&plan.half, &lo).unwrap().output,
            &accel.run(&plan.half, &hi).unwrap().output,
        )
        .unwrap();
    assert_eq!(resp.output, want);

    // The h=6 shape is servable fleet-wide, including the U200s.
    let r2 = h
        .call(Request::new(2, base.clone(), MhaInputs::generate(&base)))
        .unwrap();
    assert!(!r2.sharded);

    let fleet = cluster.shutdown();
    assert_eq!(fleet.totals.sharded, 1);
    assert_eq!(fleet.totals.completed, 2);
    assert_eq!(fleet.served(), 3, "two half-invocations plus one whole");
    assert!(fleet.render().contains("Fleet report"));
}

#[test]
fn cluster_survives_backpressure_saturation() {
    // Tiny ingress queues + concurrent clients: requests bounce between
    // devices (or block) but none are lost or duplicated.
    let topos = mixed_workload();
    let cluster = Cluster::start(
        (0..2).map(DeviceSpec::u55c).collect(),
        &WorkloadProfile::uniform(&topos),
        ClusterConfig {
            scheduler: serving_sched(),
            server: famous::coordinator::ServerConfig { queue_capacity: 1, ingest_burst: 1 },
            max_retries: 2,
            ..ClusterConfig::default()
        },
    )
    .unwrap();
    let mut joins = Vec::new();
    for i in 0..16u64 {
        let h = cluster.handle();
        let t = topos[i as usize % topos.len()].clone();
        joins.push(std::thread::spawn(move || {
            let inputs = MhaInputs::generate(&t);
            h.call(Request::new(i, t, inputs)).unwrap().id
        }));
    }
    let mut ids: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    ids.sort();
    assert_eq!(ids, (0..16).collect::<Vec<_>>());
    let fleet = cluster.shutdown();
    assert_eq!(fleet.totals.completed, 16);
    assert_eq!(fleet.served(), 16);
    assert_eq!(fleet.totals.rejected, 0);
}
