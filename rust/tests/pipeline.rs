//! Program/execute split acceptance (ISSUE 2): batched execution is
//! bit-identical to sequential, a warm `ProgramCache` runs zero timing
//! sims on repeat topologies, and the cache evicts LRU at capacity —
//! end-to-end through the coordinator, not just the accelerator.

use famous::accel::{FamousAccelerator, ProgramCache};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Coordinator, Request, SchedulerConfig};
use famous::sim::SimConfig;
use famous::testdata::{gen_matrix, MhaInputs};

fn topo() -> Topology {
    Topology::new(16, 768, 8, 64)
}

/// Distinct-input requests of one topology (shared weights — the
/// serving-a-model case), with one weight-divergent straggler.
fn mixed_weight_requests(topo: &Topology, n: u64) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let mut inputs = MhaInputs::generate(topo);
            inputs.x = gen_matrix(2000 + i, topo.seq_len, topo.d_model);
            if i == n - 1 {
                inputs.wk[3] = -inputs.wk[3] + 0.5;
            }
            Request::new(i, topo.clone(), inputs)
        })
        .collect()
}

#[test]
fn batched_bit_identical_to_sequential() {
    let topo = topo();
    let requests = mixed_weight_requests(&topo, 6);

    // Sequential reference: one run() per request on a fresh device.
    let mut serial = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let want: Vec<Vec<f32>> = requests
        .iter()
        .map(|r| serial.run(&topo, &r.inputs).unwrap().output)
        .collect();

    // Batched path through the coordinator (GroupByTopology pulls all six
    // into one batch).
    let mut coord = Coordinator::new(
        FamousAccelerator::with_sim_datapath(SimConfig::u55c()),
        SchedulerConfig {
            max_batch: 16,
            policy: BatchPolicy::GroupByTopology,
            fairness_window: 64,
        },
    );
    for r in &requests {
        coord.submit(r.clone()).unwrap();
    }
    let responses = coord.serve_all().unwrap();
    assert_eq!(responses.len(), requests.len());
    assert_eq!(coord.stats.batches, 1, "one batch for one topology");

    for resp in &responses {
        let reference = &want[resp.id as usize];
        // Byte-for-byte: compare f32 bit patterns, not approximate values.
        let got: Vec<u32> = resp.output.iter().map(|v| v.to_bits()).collect();
        let exp: Vec<u32> = reference.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, exp, "request {} diverged from the sequential path", resp.id);
    }
}

#[test]
fn warm_cache_batch_runs_exactly_one_timing_sim() {
    let topo = topo();
    let mut coord = Coordinator::new(
        FamousAccelerator::with_sim_datapath(SimConfig::u55c()),
        SchedulerConfig {
            max_batch: 8,
            policy: BatchPolicy::GroupByTopology,
            fairness_window: 64,
        },
    );
    for r in mixed_weight_requests(&topo, 5) {
        coord.submit(r).unwrap();
    }
    coord.serve_all().unwrap();
    assert_eq!(coord.stats.timing_sims, 1, "cold batch: one program, one sim");
    assert_eq!(coord.accel.timing_sims_run, 1);

    // Second same-topology batch: warm cache, zero new timing sims.
    for r in mixed_weight_requests(&topo, 5) {
        let r = Request { id: r.id + 100, ..r };
        coord.submit(r).unwrap();
    }
    coord.serve_all().unwrap();
    assert_eq!(coord.stats.served, 10);
    assert_eq!(coord.stats.timing_sims, 1, "warm batch must run zero timing sims");
    assert!(coord.stats.program_cache_hits >= 1);
}

#[test]
fn program_cache_evicts_lru_at_capacity() {
    let mut accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    accel.programs = ProgramCache::new(2);
    let t1 = Topology::new(16, 768, 8, 64);
    let t2 = Topology::new(32, 768, 8, 64);
    let t3 = Topology::new(64, 768, 8, 64);

    accel.program(&t1).unwrap();
    accel.program(&t2).unwrap();
    assert_eq!(accel.timing_sims_run, 2);
    assert_eq!(accel.programs.len(), 2);

    // t3 evicts the least recently used entry (t1).
    accel.program(&t3).unwrap();
    assert_eq!(accel.timing_sims_run, 3);
    assert_eq!(accel.programs.len(), 2);
    assert_eq!(accel.programs.topologies(), vec![t2.clone(), t3.clone()]);

    // t2 is still cached; t1 must re-sim.
    accel.program(&t2).unwrap();
    assert_eq!(accel.timing_sims_run, 3);
    accel.program(&t1).unwrap();
    assert_eq!(accel.timing_sims_run, 4);
}

#[test]
fn cached_timing_matches_fresh_simulation() {
    // The cached image must report the same timing the simulator would
    // produce fresh — the cache is a memo, not an approximation.
    let mut accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let t = Topology::new(64, 768, 8, 64);
    let first = accel.program(&t).unwrap();
    let cached = accel.program(&t).unwrap();
    assert_eq!(first.cycles(), cached.cycles());
    let fresh = famous::sim::Simulator::new(SimConfig::u55c()).run_timing(&t).unwrap();
    assert_eq!(cached.cycles(), fresh.cycles);
    assert_eq!(cached.sim.trace.total(), fresh.trace.total());
    assert_eq!(accel.timing_sims_run, 1);
}
