//! Property-based tests over the system invariants (DESIGN.md §7),
//! using the in-crate proptest_lite harness.

use famous::analytical::LatencyModel;
use famous::config::Topology;
use famous::fixed::{matmul_i32, matmul_i32_tiled, Dsp48Mac, FxMatrix, Quantizer};
use famous::fpga::hls::{LoopNest, PipelinedLoop};
use famous::fpga::ResourceModel;
use famous::jsonlite::{parse, Json};
use famous::proptest_lite::{run, Gen};
use famous::sim::{SimConfig, Simulator};

// ------------------------------------------------------------ fixed point

#[test]
fn prop_tiled_gemm_equals_direct() {
    // The FAMOUS tiling invariant: column-tiled accumulation is exactly
    // the direct product in integer arithmetic, any shape, any tile —
    // including tiles that do not divide the reduction dim (tail tile).
    run("tiled gemm == direct", 300, |g: &mut Gen| {
        let m = g.usize_in(1, 8);
        let n = g.usize_in(1, 8);
        let ts = g.usize_in(1, 9);
        let k = g.usize_in(1, 48);
        let a = FxMatrix { rows: m, cols: k, data: g.vec_i8(m * k) };
        let b = FxMatrix { rows: n, cols: k, data: g.vec_i8(n * k) };
        assert_eq!(matmul_i32_tiled(&a, &b, ts), matmul_i32(&a, &b));
    });
}

#[test]
fn prop_mac_never_overflows_for_model_scale_reductions() {
    // d_model <= 4096 int8 reductions stay far inside the 48-bit
    // accumulator: the no-rounding-inside-dot-products guarantee.
    run("mac headroom", 200, |g: &mut Gen| {
        let len = g.usize_in(1, 4096);
        let mut mac = Dsp48Mac::new();
        for _ in 0..len {
            mac.mac(g.i8_any(), g.i8_any());
        }
        assert!(!mac.overflowed());
        assert!(mac.value().abs() <= len as i64 * 128 * 128);
    });
}

#[test]
fn prop_quantizer_roundtrip_and_bounds() {
    run("quantizer", 300, |g: &mut Gen| {
        let scale = g.f64_in(1e-3, 2.0) as f32;
        let q = Quantizer::new(scale);
        let v = g.f64_in(-500.0, 500.0) as f32;
        let level = q.quantize(v);
        // In-range values round-trip within half a step.
        if v.abs() <= 127.0 * scale {
            assert!((q.fake_quant(v) - v).abs() <= scale / 2.0 + 1e-5);
        }
        // Grid values are fixed points.
        let gv = level as f32 * scale;
        assert_eq!(q.quantize(gv), level);
    });
}

// --------------------------------------------------------------- HLS / sim

#[test]
fn prop_loop_latency_monotone() {
    run("PLL monotonicity", 300, |g: &mut Gen| {
        let tc = g.usize_in(1, 1000) as u64;
        let ii = g.usize_in(1, 4) as u64;
        let pd = g.usize_in(1, 64) as u64;
        let outer = g.usize_in(1, 64) as u64;
        let base = LoopNest::new(PipelinedLoop::new(tc, ii, pd), outer).latency();
        assert!(LoopNest::new(PipelinedLoop::new(tc + 1, ii, pd), outer).latency() > base);
        assert!(LoopNest::new(PipelinedLoop::new(tc, ii, pd + 1), outer).latency() > base);
        assert!(LoopNest::new(PipelinedLoop::new(tc, ii, pd), outer + 1).latency() > base);
        // Eq. 3 exactly.
        assert_eq!(
            PipelinedLoop::new(tc, ii, pd).latency(),
            (tc - 1) * ii + pd
        );
    });
}

fn random_admitted_topology(g: &mut Gen) -> Topology {
    // Topologies admitted by the U55C TS=64 build.
    let sl = *g.pick(&[16usize, 32, 64, 128]);
    let dm = *g.pick(&[256usize, 512, 768]);
    let h_candidates: Vec<usize> = [1usize, 2, 4, 8]
        .iter()
        .copied()
        .filter(|h| dm % h == 0)
        .collect();
    let h = *g.pick(&h_candidates);
    Topology::new(sl, dm, h, 64)
}

#[test]
fn prop_sim_equals_analytical_everywhere() {
    // Not just on Table I rows: on every admitted topology.
    let model = LatencyModel::default();
    run("sim == analytical", 60, |g: &mut Gen| {
        let topo = random_admitted_topology(g);
        let sim_cc = Simulator::new(SimConfig::u55c()).run_timing(&topo).unwrap().cycles;
        assert_eq!(sim_cc, model.predict(&topo).total_cycles(), "{topo}");
    });
}

#[test]
fn prop_latency_monotone_in_workload() {
    // More sequence/embedding is never faster; more heads never slower
    // (at fixed d_model the per-head width shrinks).
    let model = LatencyModel::default();
    run("latency monotonicity", 100, |g: &mut Gen| {
        let topo = random_admitted_topology(g);
        let base = model.predict(&topo).total_cycles();
        if topo.seq_len < 128 {
            let mut t = topo.clone();
            t.seq_len *= 2;
            assert!(model.predict(&t).total_cycles() > base, "{topo}");
        }
        if topo.heads < 8 && topo.d_model % (topo.heads * 2) == 0 {
            let mut t = topo.clone();
            t.heads *= 2;
            assert!(model.predict(&t).total_cycles() < base, "{topo}");
        }
    });
}

#[test]
fn prop_double_buffer_bounded_speedup() {
    // Overlap can only help, and never beyond hiding all loads.
    run("double buffer bounds", 40, |g: &mut Gen| {
        let topo = random_admitted_topology(g);
        let seq = Simulator::new(SimConfig::u55c()).run_timing(&topo).unwrap();
        let mut cfg = SimConfig::u55c();
        cfg.double_buffer = true;
        let db = Simulator::new(cfg).run_timing(&topo).unwrap();
        assert!(db.cycles <= seq.cycles, "{topo}");
        let loads: u64 = seq.trace.phase_cycles("LIA") + seq.trace.phase_cycles("LWA");
        assert!(db.cycles + loads >= seq.cycles, "{topo}: overlap hid more than the loads");
    });
}

#[test]
fn prop_resource_estimate_monotone_in_heads_and_ts() {
    let rm = ResourceModel::default();
    run("resources monotone", 100, |g: &mut Gen| {
        let dm = 768usize;
        let h = *g.pick(&[2usize, 4, 6, 8]);
        let ts = *g.pick(&[16usize, 32, 64]);
        let base = rm.estimate(&Topology::new(64, dm, h, ts));
        if h < 12 {
            let more_heads = rm.estimate(&Topology::new(64, dm, h + if dm % (h + 1) == 0 { 1 } else { h }, ts));
            assert!(more_heads.dsp >= base.dsp);
        }
        if ts < 128 {
            let bigger_tile = rm.estimate(&Topology::new(64, dm, h, ts * 2));
            assert!(bigger_tile.dsp > base.dsp);
            assert!(bigger_tile.lut > base.lut);
        }
    });
}

// ------------------------------------------------------------------- JSON

fn random_json(g: &mut Gen, depth: usize) -> Json {
    match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.i64_in(-1_000_000, 1_000_000) as f64) / 64.0),
        3 => {
            let n = g.usize_in(0, 8);
            Json::Str((0..n).map(|_| *g.pick(&['a', 'b', '"', '\\', 'π', '\n'])).collect())
        }
        4 => Json::arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1))),
        _ => Json::obj(
            (0..g.usize_in(0, 4))
                .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                .collect::<Vec<_>>(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    run("json roundtrip", 300, |g: &mut Gen| {
        let doc = random_json(g, 3);
        let text = doc.to_string();
        let parsed = parse(&text).unwrap_or_else(|e| panic!("parse failed on {text}: {e}"));
        assert_eq!(parsed, doc, "roundtrip mismatch for {text}");
    });
}

// ----------------------------------------------------------- admission

#[test]
fn prop_admission_is_exactly_the_box() {
    // admits() accepts exactly the topologies inside the synthesized box
    // with matching tile size and divisibility.
    let build = famous::config::AcceleratorConfig::u55c_ts64();
    run("admission box", 300, |g: &mut Gen| {
        let sl = g.usize_in(1, 256);
        let dm = g.usize_in(1, 16) * 64;
        let h = g.usize_in(1, 16);
        let ts = *g.pick(&[16usize, 32, 64]);
        let topo = Topology::new(sl, dm, h, ts);
        let valid = dm % h == 0 && dm % ts == 0;
        let inside = sl <= 128 && dm <= 768 && h <= 8 && ts == 64;
        assert_eq!(build.admits(&topo).is_ok(), valid && inside, "{topo}");
    });
}

// ------------------------------------------------ scheduling QoS (PR 4)

#[test]
fn prop_edf_serving_bit_identical_to_fifo() {
    // The EDF policy reorders *scheduling*, never numerics: serving the
    // same request set under EdfWithinWindow and under Fifo must yield
    // bit-identical per-request outputs, whatever the priority/deadline
    // mix.  Small topologies keep the datapath cheap — the invariant is
    // about batching, not arithmetic.
    use famous::accel::FamousAccelerator;
    use famous::coordinator::{BatchPolicy, Coordinator, Priority, Request, SchedulerConfig};
    use famous::testdata::MhaInputs;
    run("edf == fifo outputs", 8, |g: &mut Gen| {
        let topos = [Topology::new(8, 256, 4, 64), Topology::new(16, 256, 4, 64)];
        let n = g.usize_in(1, 10);
        let mut reqs = Vec::new();
        for i in 0..n {
            let t = (*g.pick(&topos)).clone();
            let priority = *g.pick(&Priority::ALL);
            let deadline = if g.bool() { Some(g.f64_in(0.0, 50.0)) } else { None };
            reqs.push(
                Request::new(i as u64, t.clone(), MhaInputs::generate(&t))
                    .with_qos(priority, 0.0, deadline),
            );
        }
        let serve = |policy: BatchPolicy| {
            let mut c = Coordinator::new(
                FamousAccelerator::with_sim_datapath(SimConfig::u55c()),
                SchedulerConfig { max_batch: 4, policy, fairness_window: 4 },
            );
            for r in &reqs {
                c.submit(r.clone()).unwrap();
            }
            let mut out: Vec<(u64, Vec<u32>)> = c
                .serve_all()
                .unwrap()
                .into_iter()
                .map(|r| (r.id, bits(&r.output)))
                .collect();
            out.sort_by_key(|(id, _)| *id);
            out
        };
        assert_eq!(serve(BatchPolicy::EdfWithinWindow), serve(BatchPolicy::Fifo));
    });
}

// ------------------------------------------------ execute path (PR 3)

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_head_parallel_workspace_bit_identical_to_serial() {
    // The PR-3 invariant: workspace reuse and head parallelism (any lane
    // count, any pool size including a 1-thread pool) never change a
    // single output bit vs the allocating serial path, across random
    // topologies, weights, numerics configs and thread counts.
    use famous::exec::ThreadPool;
    use famous::sim::{PreparedWeights, Workspace};
    use famous::testdata::MhaInputs;
    run("head-parallel == serial", 30, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 3, 4]);
        let dk = *g.pick(&[4usize, 8, 16]);
        let sl = g.usize_in(2, 12);
        let dm = heads * dk;
        let topo = Topology::new(sl, dm, heads, dm);
        let mut inputs = MhaInputs::generate(&topo);
        for _ in 0..4 {
            let i = g.usize_in(0, inputs.x.len() - 1);
            inputs.x[i] = g.f64_in(-1.0, 1.0) as f32;
            let j = g.usize_in(0, inputs.wq.len() - 1);
            inputs.wq[j] = g.f64_in(-1.0, 1.0) as f32;
        }
        let mut cfg = SimConfig::u55c();
        cfg.causal = g.bool();
        if g.bool() {
            cfg.softmax_lut_bits = Some(8);
        }
        let prepared = PreparedWeights::prepare(&cfg, &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let want = prepared.execute(&x);

        let mut ws = Workspace::new();
        prepared.execute_into(&x, &mut ws);
        assert_eq!(bits(ws.output()), bits(&want), "workspace serial diverged ({topo})");

        let threads = g.usize_in(1, 3);
        let lanes = g.usize_in(1, heads + 1);
        let pool = ThreadPool::new(threads);
        let mut wsp = Workspace::new();
        prepared.execute_parallel(&x, &mut wsp, &pool.handle(), lanes);
        assert_eq!(
            bits(wsp.output()),
            bits(&want),
            "head-parallel diverged ({topo}, threads={threads}, lanes={lanes})"
        );
        // Warm re-run on the same workspaces: still identical.
        prepared.execute_parallel(&x, &mut wsp, &pool.handle(), lanes);
        assert_eq!(bits(wsp.output()), bits(&want), "warm head-parallel diverged ({topo})");
    });
}

// ------------------------------------------ fused streaming path (PR 5)

#[test]
fn prop_fused_tiled_within_tolerance_of_reference() {
    // The PR-5 numerics policy (DESIGN.md §12): the fused tile-streaming
    // path is tolerance-equivalent to the reference oracle across random
    // topologies (tile residues included), both softmax realizations,
    // causal and dense — and bit-deterministic per path across flavors.
    use famous::exec::ThreadPool;
    use famous::sim::{fused, ExecPath, PreparedWeights, Workspace};
    use famous::testdata::MhaInputs;
    run("fused ~= reference", 30, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 3, 4]);
        let dk = *g.pick(&[4usize, 8, 16]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 24);
        // Any tile width dividing d_model is a valid build TS; small
        // ones force multi-tile streaming with tail tiles.
        let ts_candidates: Vec<usize> =
            [2usize, 4, 8, 16, dm].iter().copied().filter(|t| dm % t == 0).collect();
        let ts = *g.pick(&ts_candidates);
        let topo = Topology::new(sl, dm, heads, ts);
        let mut inputs = MhaInputs::generate(&topo);
        for _ in 0..4 {
            let i = g.usize_in(0, inputs.x.len() - 1);
            inputs.x[i] = g.f64_in(-1.0, 1.0) as f32;
            let j = g.usize_in(0, inputs.wv.len() - 1);
            inputs.wv[j] = g.f64_in(-1.0, 1.0) as f32;
        }
        let mut cfg = SimConfig::u55c();
        cfg.causal = g.bool();
        let kind = if g.bool() {
            cfg.softmax_lut_bits = Some(8);
            famous::sim::SoftmaxKind::Lut { bits: 8 }
        } else {
            famous::sim::SoftmaxKind::Exact
        };
        let prepared = PreparedWeights::prepare(&cfg, &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let want = prepared.execute(&x); // reference oracle
        let got = prepared.execute_path(&x, ExecPath::FusedTiled);
        fused::assert_within_tolerance(kind, sl, &want, &got, &format!("{topo} ts={ts}"));

        // Per-path bit-determinism: serial workspace and head-parallel
        // fused runs reproduce the allocating fused run exactly.
        let mut ws = Workspace::new();
        prepared.execute_into_path(&x, &mut ws, ExecPath::FusedTiled);
        assert_eq!(bits(ws.output()), bits(&got), "fused workspace diverged ({topo})");
        assert_eq!(
            ws.reference_score_capacity(),
            0,
            "fused workspace materialized an SL×SL buffer ({topo})"
        );
        let threads = g.usize_in(1, 3);
        let lanes = g.usize_in(1, heads + 1);
        let pool = ThreadPool::new(threads);
        let mut wsp = Workspace::new();
        prepared.execute_parallel_path(&x, &mut wsp, &pool.handle(), lanes, {
            ExecPath::FusedTiled
        });
        assert_eq!(
            bits(wsp.output()),
            bits(&got),
            "fused head-parallel diverged ({topo}, threads={threads}, lanes={lanes})"
        );
    });
}

#[test]
fn fused_workspace_footprint_is_sl_times_ts() {
    // The acceptance contract: fused workspaces carry SL×TS score
    // stripes, never SL×SL — footprint scales linearly in SL at fixed
    // TS, and warm fused requests allocate nothing.
    use famous::sim::{ExecPath, PreparedWeights, Workspace};
    use famous::testdata::MhaInputs;
    let bytes_at = |sl: usize| -> (usize, usize) {
        let topo = Topology::new(sl, 128, 2, 64);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&SimConfig::u55c_long(), &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let mut fused_ws = Workspace::new();
        prepared.execute_into_path(&x, &mut fused_ws, ExecPath::FusedTiled);
        assert_eq!(fused_ws.reference_score_capacity(), 0);
        let fp = fused_ws.footprint();
        prepared.execute_into_path(&x, &mut fused_ws, ExecPath::FusedTiled);
        assert_eq!(fused_ws.footprint(), fp, "warm fused request reallocated (SL={sl})");
        let mut ref_ws = Workspace::new();
        prepared.execute_into_path(&x, &mut ref_ws, ExecPath::Reference);
        (fused_ws.footprint_bytes(), ref_ws.footprint_bytes())
    };
    let (f128, r128) = bytes_at(128);
    let (f256, r256) = bytes_at(256);
    assert!(f128 < r128 && f256 < r256, "fused must retain less than reference");
    // The reference−fused gap is exactly the score scratch: SL²·4 vs
    // SL·TS·4 + SL·8.  Doubling SL quadruples the former and doubles
    // the latter, so the gap must more than triple — the O(SL²) vs
    // O(SL×TS) scaling the fused path exists for.
    let (gap128, gap256) = (r128 - f128, r256 - f256);
    assert!(
        gap256 > 3 * gap128,
        "score-scratch gap {gap128} → {gap256} is not scaling quadratically"
    );
}

// --------------------------------------- kernel tiers / int8 GEMM (PR 7)

#[test]
fn prop_int8_gemm_bit_identical_across_tiers() {
    // DESIGN.md §14: every integer GEMM tier computes the same exact
    // i32 accumulators — the true int8×int8 kernel, its AVX2 version,
    // and both widened-i16 kernels all equal the direct product — over
    // random shapes (k/n tails off the 16- and 4-lane grids) and random
    // sub-slice offsets (unaligned SIMD loads).
    use famous::fixed::{
        matmul_i32_i8_blocked_into, matmul_i32_i8_into, matmul_i32_i8_scalar_into,
        matmul_i32_widened_blocked_into, matmul_i32_widened_into, matmul_i32_widened_simd_into,
        widen_i16, PackedBi16, PackedBi8,
    };
    run("int8 gemm == widened == direct", 200, |g: &mut Gen| {
        let m = g.usize_in(1, 6);
        let k = g.usize_in(1, 70);
        let n = g.usize_in(1, 9);
        let off_a = g.usize_in(0, 3);
        let off_b = g.usize_in(0, 3);
        let a_buf = g.vec_i8(off_a + m * k);
        let b_buf = g.vec_i8(off_b + n * k);
        let (a8, b8) = (&a_buf[off_a..], &b_buf[off_b..]);
        let want = matmul_i32(
            &FxMatrix { rows: m, cols: k, data: a8.to_vec() },
            &FxMatrix { rows: n, cols: k, data: b8.to_vec() },
        );
        let shape = format!("m={m} k={k} n={n} off=({off_a},{off_b})");
        let mut got = vec![0i32; m * n];
        matmul_i32_i8_scalar_into(a8, b8, m, k, n, &mut got);
        assert_eq!(got, want, "i8 scalar diverged ({shape})");
        got.fill(0);
        matmul_i32_i8_into(a8, b8, m, k, n, &mut got);
        assert_eq!(got, want, "i8 dispatched diverged ({shape})");
        let (a16, b16) = (widen_i16(a8), widen_i16(b8));
        got.fill(0);
        matmul_i32_widened_into(&a16, &b16, m, k, n, &mut got);
        assert_eq!(got, want, "widened scalar diverged ({shape})");
        got.fill(0);
        matmul_i32_widened_simd_into(&a16, &b16, m, k, n, &mut got);
        assert_eq!(got, want, "widened simd diverged ({shape})");
        // PR-10 cache-blocked drivers over pre-packed block-major B:
        // integer partial sums commute, so any jc/pc/MC blocking — tail
        // panels included — reproduces the flat product bit-for-bit.
        let pb8 = PackedBi8::pack(b8, k, n);
        got.fill(0);
        matmul_i32_i8_blocked_into(a8, &pb8, m, &mut got);
        assert_eq!(got, want, "i8 blocked diverged ({shape})");
        let pb16 = PackedBi16::pack(&b16, k, n);
        got.fill(0);
        matmul_i32_widened_blocked_into(&a16, &pb16, m, &mut got);
        assert_eq!(got, want, "widened blocked diverged ({shape})");
    });
}

#[test]
fn prop_i8_saturation_roundtrip() {
    // The operand snap saturates instead of wrapping: values past the
    // grid edges land exactly on ±extreme levels, grid extremes
    // round-trip exactly, and fake-quantization is idempotent (the
    // datapath sees a fixed point of the snap).
    run("i8 saturation", 300, |g: &mut Gen| {
        let scale = g.f64_in(1e-3, 2.0) as f32;
        let q = Quantizer::new(scale);
        let v = g.f64_in(-600.0, 600.0) as f32;
        if v >= 128.0 * scale {
            assert_eq!(q.quantize(v), 127, "positive overflow must saturate (v={v})");
        }
        if v <= -129.0 * scale {
            assert_eq!(q.quantize(v), -128, "negative overflow must saturate (v={v})");
        }
        assert_eq!(q.fake_quant(127.0 * scale), 127.0 * scale);
        assert_eq!(q.fake_quant(-128.0 * scale), -128.0 * scale);
        let fq = q.fake_quant(v);
        assert_eq!(q.fake_quant(fq), fq, "fake_quant must be idempotent (v={v})");
        assert!(fq.abs() <= 128.0 * scale);
    });
}

#[test]
fn prop_kernel_tiers_agree_end_to_end() {
    // DESIGN.md §14 on random topologies: the scalar oracle and the
    // SIMD tiers agree within the documented tier tolerance on both
    // attention paths; the two AVX2 tiers (identical integer
    // projections, same f32 code) are bit-identical to each other; and
    // every tier is bit-deterministic across repeat runs.
    use famous::sim::{fused, ExecPath, KernelTier, PreparedWeights};
    use famous::testdata::MhaInputs;
    run("tiers agree end-to-end", 20, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 4]);
        let dk = *g.pick(&[4usize, 8, 16]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 20);
        let topo = Topology::new(sl, dm, heads, dm);
        let mut inputs = MhaInputs::generate(&topo);
        for _ in 0..4 {
            let i = g.usize_in(0, inputs.x.len() - 1);
            inputs.x[i] = g.f64_in(-1.0, 1.0) as f32;
        }
        let mut cfg = SimConfig::u55c();
        cfg.causal = g.bool();
        let path = if g.bool() { ExecPath::FusedTiled } else { ExecPath::Reference };
        let prepared: Vec<_> = KernelTier::ALL
            .into_iter()
            .map(|t| PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, t))
            .collect();
        let x = prepared[0].quantize_input(&inputs.x);
        let outs: Vec<Vec<f32>> = prepared.iter().map(|p| p.execute_path(&x, path)).collect();
        let mag = outs[0].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let tol = fused::tier_tolerance(famous::sim::SoftmaxKind::Exact, sl, dk, mag);
        // The bit-exact tiers (indices 1..=2) stay within the tier
        // tolerance of the scalar oracle; simd-int8-attn is handled
        // below against its own contract (DESIGN.md §17).
        for (tier, out) in KernelTier::ALL.into_iter().zip(&outs).take(3).skip(1) {
            for (a, b) in outs[0].iter().zip(out) {
                assert!((a - b).abs() <= tol, "{topo} {tier}: {a} vs {b} (tol {tol:.2e})");
            }
        }
        if KernelTier::Simd.is_available() {
            assert_eq!(bits(&outs[1]), bits(&outs[2]), "{topo}: simd != simd-int8");
            // simd-int8-attn changes numerics only on the fused path —
            // int8 tile scores dequantized into the online softmax —
            // and only within the per-request quantization bound; on
            // the reference path it runs the same f32 modules as
            // simd-int8 and must be bit-identical.
            match path {
                ExecPath::Reference => {
                    assert_eq!(
                        bits(&outs[3]),
                        bits(&outs[2]),
                        "{topo}: reference int8-attn diverged from simd-int8"
                    );
                }
                ExecPath::FusedTiled => {
                    let bound = prepared[3].attn_quant_bound(&x);
                    assert!(bound.is_finite() && bound > 0.0, "{topo}: bad bound {bound}");
                    for (a, b) in outs[2].iter().zip(&outs[3]) {
                        assert!(
                            (a - b).abs() <= bound,
                            "{topo}: int8-attn {b} vs fused f32 {a} (bound {bound:.2e})"
                        );
                    }
                }
            }
        } else {
            // Clamped hosts run the scalar kernels under every label.
            assert_eq!(bits(&outs[0]), bits(&outs[1]), "{topo}: clamped simd");
            assert_eq!(bits(&outs[0]), bits(&outs[2]), "{topo}: clamped simd-int8");
            assert_eq!(bits(&outs[0]), bits(&outs[3]), "{topo}: clamped simd-int8-attn");
        }
        for (p, out) in prepared.iter().zip(&outs) {
            assert_eq!(
                bits(&p.execute_path(&x, path)),
                bits(out),
                "{topo} {}: tier not bit-deterministic",
                p.tier()
            );
        }
    });
}

// ------------------------------------------- int8 attention (PR 10)

#[test]
fn prop_int8_attn_within_quant_bound_of_f32_fused() {
    // DESIGN.md §17 on random topologies: the int8 attention datapath
    // (int8×int8→i32 tile scores dequantized into the online-softmax
    // absorb, dequantizing i8 SV axpy) stays within the per-request
    // quantization bound of the f32 fused path under the *same* staged
    // projections — tail tiles, both softmax realizations, causal and
    // dense.  On hosts without AVX2 both tiers clamp to Scalar and the
    // outputs must be bit-equal.
    use famous::sim::{ExecPath, KernelTier, PreparedWeights};
    use famous::testdata::MhaInputs;
    run("int8-attn ~= fused f32", 25, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 3, 4]);
        let dk = *g.pick(&[4usize, 8, 16]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 24);
        let ts_candidates: Vec<usize> =
            [2usize, 4, 8, 16, dm].iter().copied().filter(|t| dm % t == 0).collect();
        let ts = *g.pick(&ts_candidates);
        let topo = Topology::new(sl, dm, heads, ts);
        let mut inputs = MhaInputs::generate(&topo);
        for _ in 0..4 {
            let i = g.usize_in(0, inputs.x.len() - 1);
            inputs.x[i] = g.f64_in(-1.0, 1.0) as f32;
            let j = g.usize_in(0, inputs.wk.len() - 1);
            inputs.wk[j] = g.f64_in(-1.0, 1.0) as f32;
        }
        let mut cfg = SimConfig::u55c();
        cfg.causal = g.bool();
        if g.bool() {
            cfg.softmax_lut_bits = Some(8);
        }
        let f32_p = PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8);
        let attn_p =
            PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8Attn);
        let x = f32_p.quantize_input(&inputs.x);
        let want = f32_p.execute_path(&x, ExecPath::FusedTiled);
        let got = attn_p.execute_path(&x, ExecPath::FusedTiled);
        if KernelTier::SimdInt8Attn.is_available() {
            let bound = attn_p.attn_quant_bound(&x);
            assert!(bound.is_finite() && bound > 0.0, "{topo} ts={ts}: bad bound {bound}");
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    (a - b).abs() <= bound,
                    "{topo} ts={ts}: int8-attn {b} vs {a} at {i} (bound {bound:.2e})"
                );
            }
        } else {
            assert_eq!(bits(&want), bits(&got), "{topo} ts={ts}: clamped int8-attn diverged");
        }
    });
}

#[test]
fn prop_int8_attn_bit_deterministic_across_lanes_and_flavors() {
    // The serving contract extends to the new tier: the allocating,
    // warm-workspace and head-parallel fused flavors all reproduce the
    // same bits under simd-int8-attn (dynamic per-request activation
    // scales are a pure function of the inputs), across lane counts,
    // pool sizes and repeat runs — and a second identically-seeded
    // prepare reproduces them too.
    use famous::exec::ThreadPool;
    use famous::sim::{ExecPath, KernelTier, PreparedWeights, Workspace};
    use famous::testdata::MhaInputs;
    run("int8-attn bit-deterministic", 20, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 4]);
        let dk = *g.pick(&[4usize, 8, 16]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 20);
        let topo = Topology::new(sl, dm, heads, dm);
        let mut inputs = MhaInputs::generate(&topo);
        for _ in 0..4 {
            let i = g.usize_in(0, inputs.x.len() - 1);
            inputs.x[i] = g.f64_in(-1.0, 1.0) as f32;
        }
        let mut cfg = SimConfig::u55c();
        cfg.causal = g.bool();
        let prepared =
            PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8Attn);
        let x = prepared.quantize_input(&inputs.x);
        let got = prepared.execute_path(&x, ExecPath::FusedTiled);

        let mut ws = Workspace::new();
        prepared.execute_into_path(&x, &mut ws, ExecPath::FusedTiled);
        assert_eq!(bits(ws.output()), bits(&got), "int8-attn workspace diverged ({topo})");
        // Warm re-run: same buffers, same bits.
        prepared.execute_into_path(&x, &mut ws, ExecPath::FusedTiled);
        assert_eq!(bits(ws.output()), bits(&got), "warm int8-attn diverged ({topo})");

        let threads = g.usize_in(1, 3);
        let lanes = g.usize_in(1, heads + 1);
        let pool = ThreadPool::new(threads);
        let mut wsp = Workspace::new();
        prepared.execute_parallel_path(&x, &mut wsp, &pool.handle(), lanes, ExecPath::FusedTiled);
        assert_eq!(
            bits(wsp.output()),
            bits(&got),
            "int8-attn head-parallel diverged ({topo}, threads={threads}, lanes={lanes})"
        );

        let again =
            PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8Attn);
        assert_eq!(
            bits(&again.execute_path(&x, ExecPath::FusedTiled)),
            bits(&got),
            "re-prepared int8-attn diverged ({topo})"
        );
    });
}

#[test]
fn prop_int8_datapath_error_bounded_vs_f32_reference() {
    // The end-to-end quantization-error contract (DESIGN.md §14): on the
    // *same fake-quantized operands* the int8 datapath — whose integer
    // projections are exact, erring only in f32 dequant/softmax — stays
    // within the documented quant tolerance of a plain f32 attention
    // evaluated on those operands, for every kernel tier.
    use famous::sim::{KernelTier, PreparedWeights, SoftmaxUnit};
    use famous::testdata::MhaInputs;

    // f32 multi-head attention on fake-quantized operands, mirroring the
    // engine's semantics: per head q = fq(x)·fq(w)ᵀ + fq(b), exact
    // softmax over 1/√d_k-scaled scores, o = p·v, heads concatenated.
    fn mha_f32(topo: &Topology, inputs: &MhaInputs) -> Vec<f32> {
        let q = Quantizer::grid64();
        let (sl, dm, h, dk) = (topo.seq_len, topo.d_model, topo.heads, topo.d_k());
        let scale = 1.0 / (dk as f32).sqrt();
        let fq = |v: &[f32]| -> Vec<f32> { v.iter().map(|&x| q.fake_quant(x)).collect() };
        let x = fq(&inputs.x);
        let unit = SoftmaxUnit::exact();
        let mut out = vec![0f32; sl * dm];
        for head in 0..h {
            let proj = |w: &[f32], b: &[f32]| -> Vec<f32> {
                let w = fq(&w[head * dk * dm..(head + 1) * dk * dm]);
                let b = fq(&b[head * dk..(head + 1) * dk]);
                let mut m = vec![0f32; sl * dk];
                for i in 0..sl {
                    for c in 0..dk {
                        let mut acc = 0f32;
                        for l in 0..dm {
                            acc += x[i * dm + l] * w[c * dm + l];
                        }
                        m[i * dk + c] = acc + b[c];
                    }
                }
                m
            };
            let qm = proj(&inputs.wq, &inputs.bq);
            let km = proj(&inputs.wk, &inputs.bk);
            let vm = proj(&inputs.wv, &inputs.bv);
            let mut p = vec![0f32; sl * sl];
            for i in 0..sl {
                for j in 0..sl {
                    let mut acc = 0f32;
                    for c in 0..dk {
                        acc += qm[i * dk + c] * km[j * dk + c];
                    }
                    p[i * sl + j] = acc * scale;
                }
            }
            unit.rows(&mut p, sl, sl);
            for i in 0..sl {
                for c in 0..dk {
                    let mut acc = 0f32;
                    for j in 0..sl {
                        acc += p[i * sl + j] * vm[j * dk + c];
                    }
                    out[i * dm + head * dk + c] = acc;
                }
            }
        }
        out
    }

    run("int8 datapath ~= f32 reference", 15, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 4]);
        let dk = *g.pick(&[4usize, 8]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 16);
        let topo = Topology::new(sl, dm, heads, dm);
        let mut inputs = MhaInputs::generate(&topo);
        for _ in 0..4 {
            let i = g.usize_in(0, inputs.x.len() - 1);
            inputs.x[i] = g.f64_in(-1.5, 1.5) as f32;
            let j = g.usize_in(0, inputs.wq.len() - 1);
            inputs.wq[j] = g.f64_in(-1.0, 1.0) as f32;
        }
        let want = mha_f32(&topo, &inputs);
        let mag = want.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let kind = famous::sim::SoftmaxKind::Exact;
        let tol = famous::sim::tier_tolerance(kind, sl, dk, mag)
            .max(famous::sim::fused::quant_tolerance(kind, sl, dm, mag));
        for tier in KernelTier::ALL {
            let prepared =
                PreparedWeights::prepare_with_tier(&SimConfig::u55c(), &topo, &inputs, tier);
            let got = prepared.execute(&prepared.quantize_input(&inputs.x));
            for (i, (w, g2)) in want.iter().zip(&got).enumerate() {
                assert!(
                    (w - g2).abs() <= tol,
                    "{topo} {tier}: datapath {g2} vs f32 {w} at {i} (tol {tol:.2e})"
                );
            }
        }
    });
}

// ------------------------------------------- ABFT integrity (PR 8)

#[test]
fn prop_abft_catches_every_single_weight_fault() {
    // DESIGN.md §15: the Huang–Abraham column-sum check is exact in
    // integer arithmetic, so a single staged-weight corruption — random
    // head, projection, element and bit, on every kernel tier — is
    // always detected, while clean weights always verify clean.
    use famous::sim::{KernelTier, PreparedWeights, Workspace};
    use famous::testdata::MhaInputs;
    run("abft catches single faults", 40, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 4]);
        let dk = *g.pick(&[4usize, 8]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 12);
        let topo = Topology::new(sl, dm, heads, dm);
        let mut inputs = MhaInputs::generate(&topo);
        let head = g.usize_in(0, heads - 1);
        let proj = g.usize_in(0, 2);
        let pos = g.usize_in(0, dk * dm - 1);
        let bit = g.usize_in(0, 7) as u32;
        // Make the faulted weight column observable: the check is exact,
        // but a weight column whose input column quantizes to all-zero
        // cannot influence any accumulator — the corruption is dead code
        // and there is nothing to detect.
        inputs.x[pos % dm] = 1.0;
        for tier in KernelTier::ALL {
            let mut prepared =
                PreparedWeights::prepare_with_tier(&SimConfig::u55c(), &topo, &inputs, tier);
            let x = prepared.quantize_input(&inputs.x);
            let mut ws = Workspace::new();
            prepared.execute_into(&x, &mut ws);
            assert_eq!(ws.integrity_faults(), 0, "clean weights flagged ({topo} {tier})");
            prepared.inject_weight_fault(head, proj, pos, bit);
            prepared.execute_into(&x, &mut ws);
            assert!(
                ws.integrity_faults() > 0,
                "missed fault h={head} proj={proj} pos={pos} bit={bit} ({topo} {tier})"
            );
        }
    });
}

#[test]
fn prop_zero_rate_fault_plan_is_bit_transparent() {
    // A wired but zero-rate fault plan must be invisible: identical
    // staged weights, identical outputs, clean integrity — the harness
    // itself adds no perturbation (DESIGN.md §15 acceptance).
    use famous::sim::{FaultPlan, PreparedWeights, Workspace};
    use famous::testdata::MhaInputs;
    run("zero-rate plan == no plan", 20, |g: &mut Gen| {
        let heads = *g.pick(&[1usize, 2, 4]);
        let dk = *g.pick(&[4usize, 8]);
        let dm = heads * dk;
        let sl = g.usize_in(2, 12);
        let topo = Topology::new(sl, dm, heads, dm);
        let inputs = MhaInputs::generate(&topo);
        let cfg = SimConfig::u55c();
        let mut seeded = cfg.clone();
        seeded.fault_plan = Some(FaultPlan::seu(g.i64_in(0, 1 << 40) as u64, 0.0));
        let base = PreparedWeights::prepare(&cfg, &topo, &inputs);
        let planned = PreparedWeights::prepare(&seeded, &topo, &inputs);
        let x = base.quantize_input(&inputs.x);
        let mut ws = Workspace::new();
        planned.execute_into(&x, &mut ws);
        assert_eq!(ws.integrity_faults(), 0, "{topo}: zero-rate plan tripped the checksum");
        assert_eq!(
            bits(ws.output()),
            bits(&base.execute(&x)),
            "{topo}: zero-rate plan perturbed the output"
        );
    });
}

#[test]
fn warm_workspace_requests_allocate_nothing() {
    // A second same-topology request must leave every buffer pointer and
    // capacity untouched — the zero-allocation contract of the warm
    // execute path, for both the serial and the head-parallel flavor.
    use famous::exec::ThreadPool;
    use famous::sim::{PreparedWeights, Workspace};
    use famous::testdata::{gen_matrix, MhaInputs};
    let topo = Topology::new(16, 256, 4, 64);
    let inputs = MhaInputs::generate(&topo);
    let prepared = PreparedWeights::prepare(&SimConfig::u55c(), &topo, &inputs);
    let x1 = prepared.quantize_input(&inputs.x);
    let x2 = prepared.quantize_input(&gen_matrix(99, topo.seq_len, topo.d_model));

    let mut ws = Workspace::new();
    prepared.execute_into(&x1, &mut ws);
    let fp = ws.footprint();
    prepared.execute_into(&x2, &mut ws);
    assert_eq!(ws.footprint(), fp, "warm serial request reallocated a buffer");
    prepared.execute_into(&x1, &mut ws);
    assert_eq!(ws.footprint(), fp);
    assert_eq!(bits(ws.output()), bits(&prepared.execute(&x1)));

    let pool = ThreadPool::new(3);
    let mut wsp = Workspace::new();
    prepared.execute_parallel(&x1, &mut wsp, &pool.handle(), 4);
    let fpp = wsp.footprint();
    assert!(fpp.len() > fp.len(), "parallel workspace has one lane per head");
    prepared.execute_parallel(&x2, &mut wsp, &pool.handle(), 4);
    assert_eq!(wsp.footprint(), fpp, "warm parallel request reallocated a buffer");
    assert_eq!(bits(wsp.output()), bits(&prepared.execute(&x2)));
}

// ------------------------------------------------------------ cluster DES

#[test]
fn prop_des_event_heap_dispatches_in_timestamp_order() {
    // The discrete-event simulator's core invariant (DESIGN.md §16):
    // however events are pushed — duplicates, ties, interleaved with
    // pops — the heap hands them back in non-decreasing timestamp
    // order, FIFO among equal timestamps (push sequence breaks ties, so
    // replaying the same pushes replays the same dispatch order).
    use famous::cluster::EventQueue;
    run("event heap pops in time order", 200, |g: &mut Gen| {
        let mut q = EventQueue::new();
        let mut pushed: Vec<(f64, usize)> = Vec::new();
        let mut popped: Vec<(f64, usize)> = Vec::new();
        let mut now = 0.0f64;
        let mut seq = 0usize;
        let rounds = g.usize_in(1, 8);
        for _ in 0..rounds {
            // Quantized timestamps force plenty of exact ties; pushes
            // never schedule into the popped past, mirroring how the
            // DES only ever schedules at or after the current virtual
            // clock.
            for _ in 0..g.usize_in(0, 20) {
                let t = now + g.usize_in(0, 12) as f64 * 0.5;
                q.push(t, seq);
                pushed.push((t, seq));
                seq += 1;
            }
            for _ in 0..g.usize_in(0, 15) {
                let Some((t, v)) = q.pop() else { break };
                assert!(t >= now, "heap went backwards: {t} after {now}");
                now = t;
                popped.push((t, v));
            }
        }
        while let Some((t, v)) = q.pop() {
            assert!(t >= now, "heap went backwards in drain: {t} after {now}");
            now = t;
            popped.push((t, v));
        }
        assert!(q.is_empty());
        assert_eq!(popped.len(), pushed.len(), "events lost or duplicated");
        // FIFO among ties == stable sort by timestamp of the push log.
        // (Interleaving cannot break this: a pop only happens once every
        // not-yet-pushed event is strictly in its future.)
        let mut expect = pushed.clone();
        expect.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert_eq!(popped, expect, "dispatch order is not the stable time order");
    });
}
