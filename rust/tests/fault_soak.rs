//! Tier-2 soak: the fault-injection harness and the ABFT integrity
//! layer, fleet-wide (DESIGN.md §15).
//!
//! One device boots with a persistent seeded SEU plan (every weight
//! prepare corrupts its staged operands; local scrubbing re-draws the
//! identical flips, so it never helps).  The acceptance contract:
//!
//! * **100% detection, zero corrupted outputs served** — every response
//!   the faulty device produces is flagged by the checksum layer and
//!   re-executed on a clean device; no `Served` outcome ever carries a
//!   `Corrupt` verdict, and no `Clean` verdict ever names the faulty
//!   device.
//! * **Quarantine within K windows** — the per-device
//!   `IntegrityErrorRate` rule drains exactly the faulty device within a
//!   few telemetry windows of the first detection; the paired
//!   `UndrainDevice` rule restores it after consecutive clean windows
//!   (whereupon the persistent fault trips the re-armed drain again —
//!   the quarantine cycle is part of the contract).
//! * **Byte reproducibility** — the sealed frame export and the control
//!   action log are byte-identical across two runs of the same seed,
//!   real bounded-backoff sleeps notwithstanding (the virtual clock
//!   never reads the host clock).

use famous::cluster::loadgen::mean_service_ms;
use famous::cluster::{
    ActionRecord, Cluster, ClusterConfig, ControlAction, ControlRule, DeviceSpec, FleetStats,
    LoadGen, LoadGenConfig, QosOutcome, RuleScope, RuleSignal, TelemetryConfig, TelemetrySnapshot,
    WorkloadProfile,
};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, IntegrityVerdict, Priority, SchedulerConfig};
use famous::sim::FaultPlan;

const SOAK_SEED: u64 = 0x5eed_fa57;
const SEU_SEED: u64 = 0xBAD5_EED;

struct SoakRun {
    fleet: FleetStats,
    snap: TelemetrySnapshot,
    frames_jsonl: String,
    actions_jsonl: String,
    actions: Vec<ActionRecord>,
    served: u64,
    shed: u64,
    recovered_served: u64,
    corrupt_served: u64,
    clean_from_faulty: u64,
}

/// Replay `n` bursty arrivals through a 3-device fleet whose device 0
/// carries a persistent SEU plan, with the integrity quarantine/undrain
/// rule pair installed, pumping the control plane after every call.
fn run_seu_soak(n: usize) -> SoakRun {
    let mix = vec![(Topology::new(16, 256, 4, 64), 1.0)];
    let mut devices: Vec<DeviceSpec> = (0..3).map(DeviceSpec::u55c).collect();
    // Persistent stuck-at upsets: rate 1.0 corrupts every projection of
    // every prepare, so device 0 can never serve a clean response.
    devices[0] = DeviceSpec::u55c(0).with_fault_plan(FaultPlan::seu(SEU_SEED, 1.0));
    let base = mean_service_ms(&devices, &mix);
    let arrivals =
        LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix.clone(), 0.45, SOAK_SEED))
            .generate_n(n);
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let scheduler = SchedulerConfig {
        max_batch: 8,
        policy: BatchPolicy::EdfWithinWindow,
        fairness_window: 16,
    };
    let telemetry =
        TelemetryConfig { window_ms: 12.0 * base, grace_windows: 1, ring_capacity: 256 };
    let mut cluster = Cluster::start(
        devices,
        &workload,
        ClusterConfig { scheduler, telemetry, ..ClusterConfig::qos() },
    )
    .expect("cluster boot");
    cluster.add_control_rule(ControlRule {
        name: "integrity-quarantine".to_string(),
        scope: RuleScope::PerDevice,
        signal: RuleSignal::IntegrityErrorRate,
        threshold: 0.0,
        for_windows: 2,
        action: ControlAction::DrainDevice,
    });
    cluster.add_control_rule(ControlRule {
        name: "integrity-undrain".to_string(),
        scope: RuleScope::PerDevice,
        signal: RuleSignal::IntegrityErrorRate,
        threshold: 0.0,
        for_windows: 4,
        action: ControlAction::UndrainDevice,
    });
    let h = cluster.handle();
    let (mut served, mut shed) = (0u64, 0u64);
    let (mut recovered_served, mut corrupt_served, mut clean_from_faulty) = (0u64, 0u64, 0u64);
    let mut actions = Vec::new();
    for (i, a) in arrivals.iter().enumerate() {
        match h.call_qos(a.materialize(i as u64)).expect("call_qos") {
            QosOutcome::Served(resp) => {
                served += 1;
                match resp.verdict {
                    IntegrityVerdict::Clean => {
                        if resp.devices.contains(&0) {
                            clean_from_faulty += 1;
                        }
                    }
                    IntegrityVerdict::Recovered => recovered_served += 1,
                    IntegrityVerdict::Corrupt => corrupt_served += 1,
                }
            }
            QosOutcome::Shed(notice) => {
                assert_eq!(notice.priority, Priority::Low, "router may shed only Low");
                shed += 1;
            }
            QosOutcome::Saturated(_) => {
                unreachable!("Block saturation policy never returns Saturated")
            }
        }
        actions.extend(cluster.pump_control());
    }
    cluster.seal_telemetry();
    actions.extend(cluster.pump_control());
    let snap = cluster.telemetry();
    let frames_jsonl = snap.to_jsonl();
    let actions_jsonl = cluster.control_log_jsonl();
    SoakRun {
        fleet: cluster.shutdown(),
        snap,
        frames_jsonl,
        actions_jsonl,
        actions,
        served,
        shed,
        recovered_served,
        corrupt_served,
        clean_from_faulty,
    }
}

#[test]
fn seu_device_contained_quarantined_and_reproducible() {
    let n = 400;
    let run = run_seu_soak(n);

    // No accepted request is lost, and the frame ledger saw every one.
    assert_eq!(run.served + run.shed, n as u64);
    assert_eq!(run.snap.sealed.arrivals_total(), n as u64);
    assert_eq!(run.snap.sealed.completed, run.served);

    // Zero corrupted outputs served, 100% of the faulty device's output
    // flagged: no Corrupt verdict, and no Clean verdict names device 0.
    assert_eq!(run.corrupt_served, 0, "a corrupt response reached a client");
    assert_eq!(run.clean_from_faulty, 0, "device 0 served a response the checksums missed");
    assert!(run.recovered_served > 0, "the faulty device never got traffic — nothing was tested");

    // Router roll-up: detections happened, every one was healed by a
    // cross-device re-execute, none were abandoned.
    let totals = &run.fleet.totals;
    assert!(totals.integrity_detected > 0);
    assert!(totals.integrity_rerouted > 0);
    assert_eq!(totals.integrity_failed, 0, "a clean spare existed for every reroute");
    assert_eq!(
        totals.integrity_recovered, 0,
        "persistent flips re-draw identically at scrub — local retry must never succeed"
    );
    assert_eq!(
        totals.integrity_rerouted, run.recovered_served,
        "every recovered response is one cross-device re-execute, accounted exactly once"
    );
    // The telemetry ledger and the router agree on the detection count.
    assert_eq!(run.snap.sealed.integrity_detected, totals.integrity_detected);

    // Quarantine: the first control action drains exactly device 0,
    // within a handful of windows of the first detection.
    assert!(!run.actions.is_empty(), "integrity rule never fired");
    let first = &run.actions[0];
    assert_eq!(first.rule, "integrity-quarantine");
    assert_eq!(first.device, Some(0));
    assert!(matches!(first.action, ControlAction::DrainDevice));
    assert_eq!(first.outcome, "drained device 0");
    assert!(first.frame <= 12, "quarantine fired late, at frame {}", first.frame);

    // Every action in the log targets the faulty device, and the log
    // alternates drain / undrain: quarantine, restore after clean
    // windows, re-quarantine when the persistent fault trips again.
    for (i, act) in run.actions.iter().enumerate() {
        assert_eq!(act.device, Some(0), "action {i} targeted a healthy device: {act:?}");
        if i % 2 == 0 {
            assert!(matches!(act.action, ControlAction::DrainDevice), "action {i}: {act:?}");
        } else {
            assert!(matches!(act.action, ControlAction::UndrainDevice), "action {i}: {act:?}");
            assert_eq!(act.outcome, "restored device 0");
        }
    }
    assert!(
        run.actions.len() >= 2,
        "trace long enough for at least one undrain, got {:?}",
        run.actions
    );

    // The healthy devices were never drained and served the reroutes.
    for d in &run.fleet.devices[1..] {
        assert!(d.stats.served > 0, "healthy device {} sat idle", d.id);
    }

    // The fleet report names the incident.
    let rendered = run.fleet.render();
    assert!(rendered.contains("integrity"), "{rendered}");

    // Byte-for-byte reproducibility: counters, sealed frames and the
    // action log are identical across two runs of the same seeds.
    let again = run_seu_soak(n);
    assert_eq!(run.frames_jsonl, again.frames_jsonl, "frame export not reproducible");
    assert_eq!(run.actions_jsonl, again.actions_jsonl, "action log not reproducible");
    assert_eq!(run.served, again.served);
    assert_eq!(run.recovered_served, again.recovered_served);
    assert_eq!(again.fleet.totals.integrity_detected, totals.integrity_detected);
    assert_eq!(again.fleet.totals.integrity_rerouted, totals.integrity_rerouted);
}
