//! Virtual-time DES soak + fidelity cross-check (ISSUE 9, DESIGN.md §16).
//!
//! Two claims, each load-bearing for everything built on the simulator:
//!
//! * **Fidelity** — driven by the *same* seeded arrival trace and
//!   `ClusterConfig`, the DES reproduces a sequentially driven threaded
//!   [`Cluster`] exactly: identical conservation totals
//!   (offered = served + shed + rejected), identical per-class SLO
//!   counters down to the sojourn-sum bits, and a byte-identical sealed
//!   telemetry frame ledger.  Policies evaluated on the DES are then
//!   evaluated on the real router's semantics, not an approximation.
//! * **Scale** — a million-request virtual-hour trace simulates in
//!   wall-clock seconds and is bit-reproducible across runs, which is
//!   what makes capacity sweeps (`examples/capacity_study.rs`) and the
//!   CI `des-soak` job affordable.

use famous::cluster::{
    Cluster, ClusterConfig, DesConfig, DeviceSpec, FleetSim, LoadGen, LoadGenConfig, QosClass,
    QosOutcome, QosPolicy, WorkloadProfile,
};
use famous::cluster::{Arrival, ArrivalProcess};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Priority, SchedulerConfig};

const SOAK_SEED: u64 = 0x5eed_f0cc;

/// The qos_soak mix: small shapes, every one single-device admittable
/// (the sharded path spawns a concurrent half-request thread, whose
/// bookkeeping interleaving the threaded cluster does not pin down —
/// the cross-check stays on the path where the threaded run is itself
/// deterministic).
fn soak_mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(16, 256, 4, 64), 4.0),
        (Topology::new(32, 256, 4, 64), 2.0),
        (Topology::new(16, 512, 8, 64), 1.0),
    ]
}

fn workload(mix: &[(Topology, f64)]) -> WorkloadProfile {
    let mut w = WorkloadProfile::default();
    for (t, share) in mix {
        w.push(t.clone(), *share);
    }
    w
}

fn cluster_config(policy: QosPolicy) -> ClusterConfig {
    let scheduler = SchedulerConfig {
        max_batch: 8,
        policy: match policy {
            QosPolicy::SlackEdf => BatchPolicy::EdfWithinWindow,
            QosPolicy::Affinity => BatchPolicy::GroupByTopology,
        },
        fairness_window: 16,
    };
    ClusterConfig { scheduler, qos: policy, ..ClusterConfig::default() }
}

/// Bit-comparable roll-up shared by both harnesses.
#[derive(Debug, PartialEq, Eq)]
struct Ledger {
    served: u64,
    rejected: u64,
    met: [u64; 3],
    missed: [u64; 3],
    shed: [u64; 3],
    sojourn_sum_bits: [u64; 3],
    /// Sealed telemetry frames, serialized — the byte-identity witness.
    telemetry_jsonl: String,
}

/// Drive the real threaded cluster sequentially over `arrivals`.
fn run_threaded(arrivals: &[Arrival], policy: QosPolicy) -> Ledger {
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    let cluster =
        Cluster::start(devices, &workload(&soak_mix()), cluster_config(policy)).unwrap();
    let h = cluster.handle();
    for (i, a) in arrivals.iter().enumerate() {
        match h.call_qos(a.materialize(i as u64)).expect("accepted request must be served") {
            QosOutcome::Served(_) | QosOutcome::Shed(_) => {}
            QosOutcome::Saturated(_) => unreachable!("Block policy never saturates"),
        }
    }
    cluster.seal_telemetry();
    let telemetry_jsonl = cluster.telemetry().to_jsonl();
    let fleet = cluster.shutdown();
    let slo = &fleet.totals.slo;
    Ledger {
        served: fleet.totals.completed,
        rejected: fleet.totals.rejected,
        met: slo.met,
        missed: slo.missed,
        shed: slo.shed,
        sojourn_sum_bits: [
            slo.sojourn[0].sum().to_bits(),
            slo.sojourn[1].sum().to_bits(),
            slo.sojourn[2].sum().to_bits(),
        ],
        telemetry_jsonl,
    }
}

/// Replay the identical trace through the virtual-time simulator.
fn run_des(arrivals: &[Arrival], policy: QosPolicy) -> Ledger {
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    let config = DesConfig { cluster: cluster_config(policy), ..DesConfig::default() };
    let mut fs = FleetSim::new(devices, &workload(&soak_mix()), config).unwrap();
    let report = fs.run_trace(arrivals);
    fs.seal_telemetry();
    assert!(report.conserved(), "DES conservation failed: {report:?}");
    let slo = &report.totals.slo;
    Ledger {
        served: report.served,
        rejected: report.rejected,
        met: slo.met,
        missed: slo.missed,
        shed: slo.shed,
        sojourn_sum_bits: [
            slo.sojourn[0].sum().to_bits(),
            slo.sojourn[1].sum().to_bits(),
            slo.sojourn[2].sum().to_bits(),
        ],
        telemetry_jsonl: fs.telemetry().to_jsonl(),
    }
}

fn trace(n: usize, rho: f64, seed: u64) -> Vec<Arrival> {
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    LoadGen::new(LoadGenConfig::bursty_preset(&devices, soak_mix(), rho, seed)).generate_n(n)
}

#[test]
fn des_matches_threaded_soak_exactly_slack_edf() {
    let n = if cfg!(debug_assertions) { 120 } else { 400 };
    let arrivals = trace(n, 0.9, SOAK_SEED);
    let threaded = run_threaded(&arrivals, QosPolicy::SlackEdf);
    let des = run_des(&arrivals, QosPolicy::SlackEdf);
    // One assert over the whole ledger: counters AND the serialized
    // telemetry frames must agree byte for byte.
    assert_eq!(threaded, des, "DES diverged from the threaded cluster");
    // Conservation at equal offered load, both sides.
    let shed: u64 = des.shed.iter().sum();
    assert_eq!(des.served + shed + des.rejected, n as u64);
    // The trace actually exercised the QoS machinery.
    assert!(des.served > 0, "soak served nothing");
}

#[test]
fn des_matches_threaded_soak_exactly_affinity() {
    // The Affinity arm ranks on live ingress queue depth; a sequential
    // client always observes zero, which is exactly what the DES pins
    // `pending` to.  Cross-check that equivalence too.
    let n = if cfg!(debug_assertions) { 80 } else { 240 };
    let arrivals = trace(n, 0.7, SOAK_SEED ^ 0xa11);
    let threaded = run_threaded(&arrivals, QosPolicy::Affinity);
    let des = run_des(&arrivals, QosPolicy::Affinity);
    assert_eq!(threaded, des, "DES diverged from the threaded cluster (Affinity)");
}

/// Poisson trace sized to span one virtual hour: `n` arrivals at
/// `n / 3600` Hz.  Classes carry the 2:5:3 priority mix on fixed
/// deadline budgets so admission control stays exercised.
fn hour_trace_config(n: usize, seed: u64) -> LoadGenConfig {
    LoadGenConfig {
        process: ArrivalProcess::Poisson { rate_hz: n as f64 / 3600.0 },
        mix: soak_mix(),
        classes: vec![
            QosClass { priority: Priority::High, share: 2.0, deadline_budget_ms: Some(2.0) },
            QosClass { priority: Priority::Normal, share: 5.0, deadline_budget_ms: Some(4.0) },
            QosClass { priority: Priority::Low, share: 3.0, deadline_budget_ms: Some(6.0) },
        ],
        seed,
    }
}

#[test]
fn million_request_virtual_hour_simulates_in_wall_seconds() {
    // Debug builds keep CI affordable; the release-mode `des-soak` CI
    // job runs the full million.
    let n: usize = if cfg!(debug_assertions) { 50_000 } else { 1_000_000 };
    let run = || {
        let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        let mut fs = FleetSim::new(
            devices,
            &workload(&soak_mix()),
            DesConfig { cluster: cluster_config(QosPolicy::SlackEdf), ..DesConfig::default() },
        )
        .unwrap();
        let mut gen = LoadGen::new(hour_trace_config(n, SOAK_SEED));
        let report = fs.run(&mut gen, n);
        fs.seal_telemetry();
        (report, fs.telemetry().to_jsonl())
    };
    let (a, jsonl_a) = run();
    let (b, jsonl_b) = run();

    // Conservation + reproducibility, bit for bit.
    assert!(a.conserved(), "conservation failed: {a:?}");
    assert_eq!(a.offered, n as u64);
    assert_eq!(a.served, b.served);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.virtual_ms.to_bits(), b.virtual_ms.to_bits());
    assert_eq!(a.totals.slo.met, b.totals.slo.met);
    assert_eq!(a.totals.slo.missed, b.totals.slo.missed);
    for i in 0..3 {
        assert_eq!(
            a.totals.slo.sojourn[i].sum().to_bits(),
            b.totals.slo.sojourn[i].sum().to_bits(),
            "class {i} sojourn sum must be bit-identical"
        );
    }
    assert_eq!(jsonl_a, jsonl_b, "telemetry ledgers must be byte-identical");

    // The trace really spans on the order of a virtual hour (Poisson
    // jitter moves the last arrival, not the order of magnitude).
    assert!(
        a.virtual_ms > 3_000_000.0,
        "virtual span {} ms is far short of an hour",
        a.virtual_ms
    );

    // Wall budget (release only; debug timing is not meaningful): the
    // whole point of virtual time is that the hour costs seconds.
    if !cfg!(debug_assertions) {
        assert!(
            a.wall_ms < 60_000.0,
            "1M-request virtual hour took {:.1} s wall (budget 60 s)",
            a.wall_ms / 1000.0
        );
        println!(
            "des virtual hour: {} requests, {:.1} ms wall, {:.0}x real time",
            n,
            a.wall_ms,
            a.speedup()
        );
    }
}
