//! Integration tests across the full stack: PJRT runtime loading real
//! artifacts, golden-vector agreement with the python oracle, simulator
//! datapath cross-check, accelerator + coordinator end-to-end.
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise so
//! `cargo test` stays green on a fresh checkout).

use famous::accel::FamousAccelerator;
use famous::config::Topology;
use famous::coordinator::{
    BatchPolicy, Coordinator, Request, Scheduler, SchedulerConfig, Server, ServerConfig,
};
use famous::runtime::{Backend, Runtime, SimBackend, Variant};
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn manifest_covers_all_table1_topologies() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    for name in [
        "mha_sl64_d768_h8_ts64",
        "mha_sl64_d768_h4_ts64",
        "mha_sl64_d768_h2_ts64",
        "mha_sl64_d512_h8_ts64",
        "mha_sl64_d256_h8_ts64",
        "mha_sl128_d768_h8_ts64",
        "mha_sl32_d768_h8_ts64",
        "mha_sl16_d768_h8_ts64",
        "mha_sl64_d768_h6_ts64",
        "mha_sl64_d768_h12_ts64",
        "mha_sl64_d512_h4_ts64",
    ] {
        assert!(rt.manifest.entry(name).is_some(), "missing {name}");
    }
    assert!((rt.manifest.grid_scale - 1.0 / 64.0).abs() < 1e-12);
}

#[test]
fn pjrt_output_matches_python_golden_bitwise_class() {
    // The golden vectors were produced by the same HLO on the python side;
    // PJRT CPU should reproduce them to float-noise tolerance.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    for (name, topo) in [
        ("mha_sl64_d768_h8_ts64", Topology::new(64, 768, 8, 64)),
        ("mha_sl16_d768_h8_ts64", Topology::new(16, 768, 8, 64)),
        ("mha_sl64_d256_h8_ts64", Topology::new(64, 256, 8, 64)),
    ] {
        let golden = rt.golden(name).unwrap().expect("golden shipped");
        let out = rt.run_mha(&topo, &MhaInputs::generate(&topo)).unwrap();
        assert_eq!(out.len(), golden.len(), "{name}");
        let err = max_abs_diff(&out, &golden);
        assert!(err < 1e-5, "{name}: max abs diff {err}");
    }
}

#[test]
fn simulator_datapath_matches_pjrt() {
    // Independent implementations of the same math: the rust int8
    // datapath and the jax/Pallas artifact must agree to fp tolerance
    // (softmax exponentials differ in ulps; everything else is exact).
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut sim = SimBackend::new(SimConfig::u55c());
    for topo in [
        Topology::new(64, 768, 8, 64),
        Topology::new(64, 256, 8, 64),
        Topology::new(16, 768, 8, 64),
    ] {
        let inputs = MhaInputs::generate(&topo);
        let a = rt.run_mha(&topo, &inputs).unwrap();
        let b = sim.run_mha(&topo, &inputs).unwrap();
        let err = max_abs_diff(&a, &b);
        assert!(err < 1e-4, "{topo}: max abs diff {err}");
    }
}

#[test]
fn deploy_and_pallas_variants_agree() {
    // The XLA-fused deployment artifact and the Pallas kernel-structure
    // artifact are two lowerings of the same math; they must agree to
    // float tolerance (EXPERIMENTS.md §Perf documents why both exist).
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    for topo in [Topology::new(16, 768, 8, 64), Topology::new(64, 256, 8, 64)] {
        let inputs = MhaInputs::generate(&topo);
        let deploy = rt.run_mha_variant(&topo, &inputs, Variant::Deploy).unwrap();
        let pallas = rt.run_mha_variant(&topo, &inputs, Variant::Pallas).unwrap();
        let err = max_abs_diff(&deploy, &pallas);
        assert!(err < 1e-5, "{topo}: variants diverge by {err}");
    }
}

#[test]
fn executable_cache_compiles_once() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let topo = Topology::new(16, 768, 8, 64);
    let inputs = MhaInputs::generate(&topo);
    rt.run_mha(&topo, &inputs).unwrap();
    rt.run_mha(&topo, &inputs).unwrap();
    rt.run_mha(&topo, &inputs).unwrap();
    assert_eq!(rt.compilations, 1, "executable must be cached");
}

#[test]
fn accelerator_with_pjrt_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let mut accel = FamousAccelerator::with_pjrt(SimConfig::u55c(), &dir).unwrap();
    assert_eq!(accel.backend_name(), "pjrt");
    let topo = Topology::new(64, 768, 8, 64);
    let r = accel.run(&topo, &MhaInputs::generate(&topo)).unwrap();
    assert_eq!(r.output.len(), 64 * 768);
    assert!((r.latency_ms - 0.94).abs() < 0.01);
    assert!((r.gops - 328.0).abs() < 5.0);
}

#[test]
fn coordinator_over_pjrt_serves_mixed_topologies() {
    let Some(dir) = artifacts_dir() else { return };
    let accel = FamousAccelerator::with_pjrt(SimConfig::u55c(), &dir).unwrap();
    let mut coord = Coordinator::new(
        accel,
        SchedulerConfig { max_batch: 4, policy: BatchPolicy::GroupByTopology, fairness_window: 32 },
    );
    let topos = [
        Topology::new(64, 768, 8, 64),
        Topology::new(32, 768, 8, 64),
        Topology::new(16, 768, 8, 64),
    ];
    for i in 0..9 {
        let t = topos[i % 3].clone();
        let inputs = MhaInputs::generate(&t);
        coord.submit(Request::new(i as u64, t, inputs)).unwrap();
    }
    let responses = coord.serve_all().unwrap();
    assert_eq!(responses.len(), 9);
    // Grouping: 3 distinct topologies -> exactly 3 reconfigurations.
    assert_eq!(coord.stats.reconfigurations, 3);
    assert_eq!(coord.stats.served, 9);
}

#[test]
fn server_over_pjrt_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let srv = Server::start(
        move || {
            let accel = FamousAccelerator::with_pjrt(SimConfig::u55c(), &dir).unwrap();
            Coordinator::new(accel, SchedulerConfig::default())
        },
        ServerConfig::default(),
    );
    let mut joins = Vec::new();
    for i in 0..4 {
        let h = srv.handle();
        joins.push(std::thread::spawn(move || {
            let t = Topology::new(if i % 2 == 0 { 64 } else { 32 }, 768, 8, 64);
            let inputs = MhaInputs::generate(&t);
            h.call_blocking(Request::new(i, t, inputs)).unwrap()
        }));
    }
    for j in joins {
        let resp = j.join().unwrap();
        assert!(!resp.output.is_empty());
        assert!(resp.fabric_ms > 0.0);
    }
    let stats = srv.shutdown();
    assert_eq!(stats.served, 4);
}

#[test]
fn scheduler_distinct_topology_lower_bound_holds_e2e() {
    let mut s = Scheduler::new(SchedulerConfig {
        max_batch: 100,
        policy: BatchPolicy::GroupByTopology,
        fairness_window: 100,
    });
    let t1 = Topology::new(64, 768, 8, 64);
    let t2 = Topology::new(32, 768, 8, 64);
    for i in 0..10 {
        let t = if i % 2 == 0 { t1.clone() } else { t2.clone() };
        s.push(Request::new(i, t.clone(), MhaInputs::generate(&t)));
    }
    assert_eq!(s.distinct_topologies(), 2);
    let mut batches = 0;
    while s.next_batch().is_some() {
        batches += 1;
    }
    assert_eq!(batches, 2);
}

#[test]
fn corrupt_artifact_fails_loudly() {
    let Some(dir) = artifacts_dir() else { return };
    // Copy the manifest into a temp dir with a broken HLO file.
    let tmp = std::env::temp_dir().join(format!("famous_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest = std::fs::read_to_string(format!("{dir}/manifest.json")).unwrap();
    std::fs::write(tmp.join("manifest.json"), &manifest).unwrap();
    // All HLO files exist but contain garbage.
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "txt").unwrap_or(false) {
            std::fs::write(tmp.join(p.file_name().unwrap()), "HloModule garbage !!!").unwrap();
        }
    }
    let mut rt = Runtime::load(tmp.to_str().unwrap()).unwrap();
    let topo = Topology::new(16, 768, 8, 64);
    let err = rt.run_mha(&topo, &MhaInputs::generate(&topo));
    assert!(err.is_err(), "corrupt HLO must not silently succeed");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn missing_topology_artifact_is_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let topo = Topology::new(8, 128, 4, 32); // not in the registry
    let err = rt.run_mha(&topo, &MhaInputs::generate(&topo)).unwrap_err();
    assert!(err.to_string().contains("no artifact"), "{err}");
}
