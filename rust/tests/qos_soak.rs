//! Deterministic cluster soak suite (ISSUE 4).
//!
//! A seeded bursty arrival process drives a 4-device fleet through the
//! full QoS path — EDF batching, slack routing, shedding — on the
//! serving layer's *virtual clock*, so every deadline verdict is a
//! modeled quantity and the whole soak is exactly reproducible:
//!
//! * run-to-run determinism: deadline-miss counts, shed counts and even
//!   the per-class sojourn sums and output bits are identical across
//!   runs of the same seed;
//! * QoS value: at equal offered load, `SlackEdf` routing + EDF batching
//!   yields strictly fewer SLO violations (misses + sheds) than the
//!   PR-1 FIFO/affinity policy, which melts the hot devices;
//! * fault tolerance: a `DeviceHealth::Failed` crash mid-soak reroutes
//!   without dropping a single accepted request;
//! * functional ground truth: every accepted output is bit-identical to
//!   a serial single-accelerator run of the same request.

use famous::accel::FamousAccelerator;
use famous::cluster::{
    Cluster, ClusterConfig, DeviceSpec, FleetStats, LoadGen, LoadGenConfig, QosOutcome, QosPolicy,
    WorkloadProfile,
};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Priority, SchedulerConfig};
use famous::sim::SimConfig;

const SOAK_SEED: u64 = 0x5eed_f0cc;

/// Small shapes keep the int8 datapath cheap in debug CI runs; shares
/// are deliberately skewed so affinity routing concentrates load.
fn soak_mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(16, 256, 4, 64), 4.0),
        (Topology::new(32, 256, 4, 64), 2.0),
        (Topology::new(16, 512, 8, 64), 1.0),
    ]
}

/// Everything a soak run can be compared on, bit-exact.
#[derive(Debug, PartialEq, Eq)]
struct SoakSummary {
    offered: usize,
    served: u64,
    met: [u64; 3],
    missed: [u64; 3],
    shed: [u64; 3],
    /// Per-class sojourn sums, compared as raw f64 bits.
    sojourn_sum_bits: [u64; 3],
    /// FNV over every served output's f32 bits, in completion order.
    output_hash: u64,
}

struct SoakRun {
    summary: SoakSummary,
    /// (topology, output) per served request, completion order.
    outputs: Vec<(Topology, Vec<f32>)>,
    fleet: FleetStats,
}

fn run_soak(
    seed: u64,
    policy: QosPolicy,
    n: usize,
    rho: f64,
    fail_at: Option<usize>,
) -> SoakRun {
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    run_soak_with(devices, soak_mix(), seed, policy, n, rho, fail_at)
}

fn run_soak_with(
    devices: Vec<DeviceSpec>,
    mix: Vec<(Topology, f64)>,
    seed: u64,
    policy: QosPolicy,
    n: usize,
    rho: f64,
    fail_at: Option<usize>,
) -> SoakRun {
    // The shared bursty preset: MMPP averaging `rho` of fleet capacity,
    // High/Normal/Low on 4x/8x/12x mean-service deadline budgets.
    let arrivals =
        LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix.clone(), rho, seed)).generate_n(n);

    let scheduler = SchedulerConfig {
        max_batch: 8,
        policy: match policy {
            QosPolicy::SlackEdf => BatchPolicy::EdfWithinWindow,
            QosPolicy::Affinity => BatchPolicy::GroupByTopology,
        },
        fairness_window: 16,
    };
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let config = ClusterConfig { scheduler, qos: policy, ..ClusterConfig::default() };
    let mut cluster = Cluster::start(devices, &workload, config).unwrap();
    let h = cluster.handle();

    let mut outputs = Vec::new();
    let mut output_hash = 0xcbf2_9ce4_8422_2325u64;
    for (i, a) in arrivals.iter().enumerate() {
        if fail_at == Some(i) {
            assert!(cluster.fail_device(0), "device 0 must be live to fail");
        }
        match h.call_qos(a.materialize(i as u64)).expect("accepted request must be served") {
            QosOutcome::Served(resp) => {
                for v in &resp.output {
                    output_hash =
                        (output_hash ^ v.to_bits() as u64).wrapping_mul(0x1_0000_0000_01b3);
                }
                outputs.push((resp.topology.clone(), resp.output));
            }
            QosOutcome::Shed(notice) => {
                assert_eq!(notice.priority, Priority::Low, "only Low may be shed");
            }
            QosOutcome::Saturated(_) => {
                unreachable!("Block saturation policy never returns Saturated")
            }
        }
    }
    let fleet = cluster.shutdown();
    let slo = &fleet.totals.slo;
    let summary = SoakSummary {
        offered: n,
        served: fleet.totals.completed,
        met: slo.met,
        missed: slo.missed,
        shed: slo.shed,
        sojourn_sum_bits: [
            slo.sojourn[0].sum().to_bits(),
            slo.sojourn[1].sum().to_bits(),
            slo.sojourn[2].sum().to_bits(),
        ],
        output_hash,
    };
    SoakRun { summary, outputs, fleet }
}

/// Every served output must equal a serial single-accelerator run of
/// the same request (operands are deterministic per topology, so one
/// reference run per distinct topology covers the whole soak).
fn assert_outputs_bit_identical(outputs: &[(Topology, Vec<f32>)]) {
    let mut accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let mut references: Vec<(Topology, Vec<u32>)> = Vec::new();
    for (topo, out) in outputs {
        if !references.iter().any(|(t, _)| t == topo) {
            let inputs = famous::testdata::MhaInputs::generate(topo);
            let want = accel.run(topo, &inputs).unwrap().output;
            references.push((topo.clone(), want.iter().map(|v| v.to_bits()).collect()));
        }
        let want = &references.iter().find(|(t, _)| t == topo).unwrap().1;
        let got: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
        assert_eq!(&got, want, "cluster output diverged from serial run for {topo}");
    }
}

#[test]
fn soak_is_exactly_reproducible() {
    let n = 200;
    let a = run_soak(SOAK_SEED, QosPolicy::SlackEdf, n, 0.9, None);
    let b = run_soak(SOAK_SEED, QosPolicy::SlackEdf, n, 0.9, None);
    assert_eq!(a.summary, b.summary, "soak must be bit-reproducible across runs");
    // Conservation: every offered request is served or explicitly shed.
    let shed: u64 = a.summary.shed.iter().sum();
    assert_eq!(a.summary.served + shed, n as u64);
    assert_eq!(a.outputs.len() as u64, a.summary.served);
    // The report carries the QoS block.
    assert!(a.fleet.render().contains("QoS"), "{}", a.fleet.render());
    // A different seed produces a different trace (sanity against a
    // generator that ignores its seed).
    let c = run_soak(SOAK_SEED + 1, QosPolicy::SlackEdf, n, 0.9, None);
    assert_ne!(a.summary.sojourn_sum_bits, c.summary.sojourn_sum_bits);
}

#[test]
fn edf_slack_strictly_beats_fifo_affinity_at_equal_load() {
    // Same seed → identical arrival trace → equal offered load.  The
    // affinity policy pins each topology to its hot device, driving the
    // heavy-share devices supercritical while the rest idle; slack
    // routing spreads infeasible load across the fleet and sheds only
    // provably-late Low requests.
    let n = 240;
    let rho = 0.9;
    let edf = run_soak(SOAK_SEED, QosPolicy::SlackEdf, n, rho, None);
    let fifo = run_soak(SOAK_SEED, QosPolicy::Affinity, n, rho, None);

    let violations = |s: &SoakSummary| -> u64 {
        s.missed.iter().sum::<u64>() + s.shed.iter().sum::<u64>()
    };
    assert!(
        violations(&edf.summary) < violations(&fifo.summary),
        "EDF+slack violations {} !< FIFO/affinity violations {} (offered {})",
        violations(&edf.summary),
        violations(&fifo.summary),
        n
    );
    // Per-class: the latency-critical class must not be worse off.
    let hi = Priority::High.index();
    assert!(
        edf.summary.missed[hi] <= fifo.summary.missed[hi],
        "EDF high-priority misses {} > FIFO {}",
        edf.summary.missed[hi],
        fifo.summary.missed[hi]
    );
    // Affinity never sheds; EDF sheds only Low.
    assert_eq!(fifo.summary.shed, [0, 0, 0]);
    assert_eq!(edf.summary.shed[Priority::High.index()], 0);
    assert_eq!(edf.summary.shed[Priority::Normal.index()], 0);
    // Acceptance: accepted outputs remain bit-identical to serial
    // execution under the QoS policy.
    assert_outputs_bit_identical(&edf.outputs);
}

/// Long-sequence mix served by the fused-streaming build (ISSUE 5):
/// every shape is at or past `FUSED_SL_THRESHOLD`, so the whole soak
/// runs on the fused tile-streaming path.  Small d_model keeps the
/// int8 projections cheap in debug CI runs.
fn long_mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(512, 128, 2, 64), 3.0),
        (Topology::new(256, 128, 2, 64), 1.0),
    ]
}

/// Every served long-SL output must sit within the documented
/// fused-vs-reference tolerance (DESIGN.md §12) of a serial
/// reference-path run of the same request.
fn assert_outputs_within_fused_tolerance(outputs: &[(Topology, Vec<f32>)]) {
    use famous::sim::{fused, PreparedWeights, SoftmaxKind};
    let cfg = SimConfig::u55c_long();
    let mut references: Vec<(Topology, Vec<f32>)> = Vec::new();
    for (topo, out) in outputs {
        if !references.iter().any(|(t, _)| t == topo) {
            let inputs = famous::testdata::MhaInputs::generate(topo);
            let prepared = PreparedWeights::prepare(&cfg, topo, &inputs);
            let x = prepared.quantize_input(&inputs.x);
            references.push((topo.clone(), prepared.execute(&x))); // reference oracle
        }
        let (_, want) = references.iter().find(|(t, _)| t == topo).unwrap();
        fused::assert_within_tolerance(
            SoftmaxKind::Exact,
            topo.seq_len,
            want,
            out,
            &format!("cluster fused output for {topo}"),
        );
    }
}

#[test]
fn long_sl_soak_runs_fused_path_reproducibly_within_tolerance() {
    // SL=512-class serving end to end through the cluster: the auto
    // policy must dispatch every request on the fused path, miss/shed
    // counts and output hashes must be bit-reproducible run-to-run, and
    // served outputs must match the reference path within the
    // documented tolerance.
    let n = if cfg!(debug_assertions) { 8 } else { 32 };
    let devices = || (0..4).map(DeviceSpec::u55c_long).collect::<Vec<_>>();
    let a = run_soak_with(devices(), long_mix(), SOAK_SEED, QosPolicy::SlackEdf, n, 0.8, None);
    let b = run_soak_with(devices(), long_mix(), SOAK_SEED, QosPolicy::SlackEdf, n, 0.8, None);
    assert_eq!(a.summary, b.summary, "long-SL soak must be bit-reproducible");
    let shed: u64 = a.summary.shed.iter().sum();
    assert_eq!(a.summary.served + shed, n as u64);
    // Dispatch attribution: everything ran fused, nothing fell back.
    let fused: u64 = a.fleet.devices.iter().map(|d| d.stats.fused_dispatches).sum();
    let reference: u64 = a.fleet.devices.iter().map(|d| d.stats.reference_dispatches).sum();
    assert_eq!(fused, a.summary.served, "every long-SL request must run the fused path");
    assert_eq!(reference, 0, "no long-SL request may fall back to the SL×SL path");
    assert_outputs_within_fused_tolerance(&a.outputs);
}

#[test]
fn failed_device_mid_soak_reroutes_without_dropping() {
    let n = 120;
    let run = run_soak(SOAK_SEED, QosPolicy::SlackEdf, n, 0.5, Some(n / 3));
    // Conservation holds across the crash: every accepted request was
    // served (the dead ingress bounces, the router fails over).
    let shed: u64 = run.summary.shed.iter().sum();
    assert_eq!(run.summary.served + shed, n as u64, "requests dropped across the crash");
    assert_eq!(run.fleet.failed_devices(), 1);
    assert!(run.fleet.render().contains("FAILED"));
    // Outputs stay bit-identical even for rerouted requests.
    assert_outputs_bit_identical(&run.outputs);
}
