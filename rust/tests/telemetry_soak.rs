//! Tier-2 soak: the streaming telemetry pipeline and the threshold
//! control plane, end to end (DESIGN.md §13).
//!
//! Two scenarios, both on the seeded virtual clock and therefore exactly
//! reproducible:
//!
//! * **Conservation + reproducibility** — a healthy fleet under the
//!   shared bursty preset, with a telemetry ring small enough to force
//!   eviction.  Eviction must not lose counts (`sealed == Σ ring +
//!   evicted`), the sealed totals must agree with the independently
//!   maintained `FleetStats` roll-up, and the JSONL frame export must be
//!   byte-identical across two runs of the same seed.
//!
//! * **Silent-degradation drain** — one device's fabric clock is derated
//!   8× *without* touching its advertised latency model, so the router
//!   keeps believing it and its completions run hot.  A per-device
//!   p99-sojourn rule must notice the breach within a few windows, fire
//!   exactly once, drain exactly that device, and lose zero accepted
//!   requests — with the frame ring and the action log bit-reproducible
//!   across runs.

use famous::cluster::loadgen::mean_service_ms;
use famous::cluster::{
    ActionRecord, Cluster, ClusterConfig, ControlAction, ControlRule, DeviceHealth, DeviceSpec,
    FleetStats, LoadGen, LoadGenConfig, QosOutcome, RuleScope, RuleSignal, TelemetryConfig,
    TelemetrySnapshot, WorkloadProfile,
};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Priority, SchedulerConfig};

const SOAK_SEED: u64 = 0x7e1e_5c09;

/// Small shapes so hundreds of requests stay fast in debug builds
/// (same mix as the QoS soak suite).
fn soak_mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(16, 256, 4, 64), 4.0),
        (Topology::new(32, 256, 4, 64), 2.0),
        (Topology::new(16, 512, 8, 64), 1.0),
    ]
}

struct SoakRun {
    fleet: FleetStats,
    snap: TelemetrySnapshot,
    frames_jsonl: String,
    actions_jsonl: String,
    actions: Vec<ActionRecord>,
    served: u64,
    shed: u64,
}

/// Replay `n` bursty arrivals through a fleet with telemetry + rules
/// installed, pumping the control plane after every call (the cadence an
/// operator loop would run at).  Returns everything the assertions need.
fn run_soak(
    devices: Vec<DeviceSpec>,
    mix: Vec<(Topology, f64)>,
    rho: f64,
    n: usize,
    telemetry: TelemetryConfig,
    rules: Vec<ControlRule>,
) -> SoakRun {
    let arrivals =
        LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix.clone(), rho, SOAK_SEED))
            .generate_n(n);
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let scheduler = SchedulerConfig {
        max_batch: 8,
        policy: BatchPolicy::EdfWithinWindow,
        fairness_window: 16,
    };
    let mut cluster = Cluster::start(
        devices,
        &workload,
        ClusterConfig { scheduler, telemetry, ..ClusterConfig::qos() },
    )
    .expect("cluster boot");
    for rule in rules {
        cluster.add_control_rule(rule);
    }
    let h = cluster.handle();
    let (mut served, mut shed) = (0u64, 0u64);
    let mut actions = Vec::new();
    for (i, a) in arrivals.iter().enumerate() {
        match h.call_qos(a.materialize(i as u64)).expect("call_qos") {
            QosOutcome::Served(_) => served += 1,
            QosOutcome::Shed(notice) => {
                assert_eq!(notice.priority, Priority::Low, "router may shed only Low");
                shed += 1;
            }
            QosOutcome::Saturated(_) => {
                unreachable!("Block saturation policy never returns Saturated")
            }
        }
        actions.extend(cluster.pump_control());
    }
    // End of trace: flush the open partials and evaluate the last frames.
    cluster.seal_telemetry();
    actions.extend(cluster.pump_control());
    let snap = cluster.telemetry();
    let frames_jsonl = snap.to_jsonl();
    let actions_jsonl = cluster.control_log_jsonl();
    SoakRun {
        fleet: cluster.shutdown(),
        snap,
        frames_jsonl,
        actions_jsonl,
        actions,
        served,
        shed,
    }
}

#[test]
fn sealed_frames_conserve_and_reproduce() {
    let mk = || {
        let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        let base = mean_service_ms(&devices, &soak_mix());
        run_soak(
            devices,
            soak_mix(),
            0.7,
            300,
            // Ring far smaller than the frame count: eviction must fold,
            // never drop.
            TelemetryConfig { window_ms: 6.0 * base, grace_windows: 1, ring_capacity: 8 },
            Vec::new(),
        )
    };
    let run = mk();

    // The ring is bounded and eviction actually happened.
    assert_eq!(run.snap.frames.len(), 8, "ring holds exactly its capacity");
    assert!(
        run.snap.sealed.frames > 8,
        "trace too short to exercise eviction: {} frames sealed",
        run.snap.sealed.frames
    );
    assert!(run.snap.evicted.frames > 0);

    // Conservation: everything sealed is still accounted for, either in
    // the ring or in the eviction fold.
    let mut refold = run.snap.evicted.clone();
    for f in &run.snap.frames {
        refold.fold(f);
    }
    assert_eq!(refold, run.snap.sealed, "sealed != Σ ring + evicted");

    // The frame ledger agrees with the router/fleet roll-up that was
    // maintained independently of the telemetry path.
    let sealed = &run.snap.sealed;
    let totals = &run.fleet.totals;
    assert_eq!(sealed.arrivals_total(), 300, "every arrival has an ingress event");
    assert_eq!(run.served + run.shed, 300, "no request silently dropped");
    assert_eq!(sealed.completed, run.served);
    assert_eq!(sealed.completed, totals.completed);
    assert_eq!(sealed.met, totals.slo.met);
    assert_eq!(sealed.missed, totals.slo.missed);
    assert_eq!(sealed.shed, totals.slo.shed);
    assert_eq!(sealed.shed_total(), run.shed);
    assert_eq!(sealed.retries, totals.retries);
    assert_eq!(sealed.sharded, totals.sharded);
    assert_eq!(sealed.warm, totals.warm_hits);
    assert_eq!(sealed.dispatches(), run.fleet.served(), "hot+warm+cold == device invocations");
    assert_eq!(sealed.device_served.iter().sum::<u64>(), run.fleet.served());
    assert_eq!(run.snap.late_events, 0, "sequential dispatch never produces stragglers");

    // Byte-for-byte reproducibility of the export (the criterion the
    // JSONL artifact is defined by).
    let again = mk();
    assert_eq!(run.frames_jsonl, again.frames_jsonl, "frame export not reproducible");
    assert!(run.actions_jsonl.is_empty(), "no rules installed, no actions");
    assert!(again.actions_jsonl.is_empty());
}

#[test]
fn control_plane_drains_silently_degraded_device() {
    let mix = vec![(Topology::new(16, 256, 4, 64), 1.0)];
    let mk = || {
        // Device 0 runs at 1/8 of its advertised clock — the advertised
        // model (and hence routing estimates and admission) is untouched,
        // so only completion telemetry can reveal the problem.  Device 0
        // is also the placement primary for the single topology, which
        // keeps believed-feasible traffic flowing to it: every serve
        // completes at >= 8x the modeled service time, a sustained
        // per-window p99 breach.
        let mut devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        devices[0] = DeviceSpec::u55c(0).with_silent_derate(0.125);
        let base = mean_service_ms(&devices, &mix);
        let rule = ControlRule {
            name: "p99-sojourn-drain".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::SojournP99Ms,
            // Between the healthy fleet's worst bursty sojourns and the
            // degraded device's 8x-service floor.
            threshold: 7.0 * base,
            for_windows: 3,
            action: ControlAction::DrainDevice,
        };
        run_soak(
            devices,
            mix.clone(),
            0.45,
            400,
            TelemetryConfig { window_ms: 12.0 * base, grace_windows: 1, ring_capacity: 256 },
            vec![rule],
        )
    };
    let run = mk();

    // Exactly one action: the degraded device drained, nobody else.
    assert_eq!(run.actions.len(), 1, "expected one drain, got {:?}", run.actions);
    let act = &run.actions[0];
    assert_eq!(act.rule, "p99-sojourn-drain");
    assert_eq!(act.device, Some(0), "rule must target the degraded device");
    assert!(matches!(act.action, ControlAction::DrainDevice));
    assert_eq!(act.outcome, "drained device 0");
    // Fires within a handful of windows of the breach onset, not at the
    // end of the trace.
    assert!(act.frame <= 10, "drain fired late, at frame {}", act.frame);

    // The drain went through the cluster hook: device 0 reports Stopped
    // with its pre-drain stats; the rest of the fleet served on.
    assert_eq!(run.fleet.devices[0].health, DeviceHealth::Stopped);
    assert!(run.fleet.devices[0].stats.served > 0, "device 0 served before the drain");
    for d in &run.fleet.devices[1..] {
        assert_eq!(d.health, DeviceHealth::Live);
        assert!(d.stats.served > 0);
    }

    // Zero accepted requests dropped across the drain: every arrival is
    // either served or explicitly shed.
    assert_eq!(run.served + run.shed, 400);
    assert_eq!(run.snap.sealed.arrivals_total(), 400);
    assert_eq!(run.snap.sealed.completed, run.served);
    // The degradation was visible as deadline misses before the drain.
    assert!(run.snap.sealed.missed_total() > 0, "derated completions must miss deadlines");
    assert_eq!(run.snap.late_events, 0);

    // Frame ring and action log are bit-reproducible across runs.
    let again = mk();
    assert_eq!(run.frames_jsonl, again.frames_jsonl, "frame export not reproducible");
    assert_eq!(run.actions_jsonl, again.actions_jsonl, "action log not reproducible");
    assert!(!run.actions_jsonl.is_empty());
    assert_eq!(run.actions_jsonl.lines().count(), 1);
}
