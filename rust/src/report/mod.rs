//! Table rendering for the benchmark harness.
//!
//! Each paper table is regenerated as an aligned text table with the
//! paper's published value, our measured/modeled value, and the ratio —
//! the "shape" evidence EXPERIMENTS.md records.

use std::fmt::Write as _;

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let _ = write!(out, "{:<w$}  ", cells[i], w = widths[i]);
            }
            out.pop();
            out.pop();
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

/// Format a float with sensible precision for latency/GOPS cells.
pub fn fmt_f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

/// Format a ratio as "1.23x".
pub fn fmt_ratio(ours: f64, paper: f64) -> String {
    if paper == 0.0 {
        "-".into()
    } else {
        format!("{:.2}x", ours / paper)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yy".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // Columns align: "long_header" column starts at same offset.
        let h = lines[1];
        let r = lines[3];
        assert_eq!(h.find("long_header").unwrap(), 5);
        assert_eq!(r.find('2').unwrap(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new("T", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn float_formats() {
        assert_eq!(fmt_f(328.4), "328");
        assert_eq!(fmt_f(2.281), "2.28");
        assert_eq!(fmt_f(0.94), "0.940");
        assert_eq!(fmt_ratio(2.0, 1.0), "2.00x");
        assert_eq!(fmt_ratio(1.0, 0.0), "-");
    }
}
