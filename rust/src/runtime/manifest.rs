//! artifacts/manifest.json schema (written by python/compile/aot.py).

use crate::config::Topology;
use crate::jsonlite::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One lowered topology.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub topology: Topology,
    /// Deployment HLO (XLA-fused path), relative to the artifact dir.
    pub hlo: String,
    /// Kernel-structure HLO (Pallas interpret path), if shipped.
    pub hlo_pallas: Option<String>,
    /// Golden output file (f32 LE), if shipped.
    pub golden: Option<String>,
    pub golden_shape: Option<Vec<usize>>,
    /// sha256 of the oracle's input stream (regenerable via testdata).
    pub inputs_sha256: Option<String>,
    /// Argument name → dims, in row-major element order.
    pub args: BTreeMap<String, Vec<usize>>,
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format: String,
    pub arg_order: Vec<String>,
    pub grid_scale: f64,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse_str(&text)
    }

    pub fn parse_str(text: &str) -> Result<Self> {
        let j = parse(text).map_err(|e| anyhow!("{e}"))?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing 'format'"))?
            .to_string();
        if format != "hlo-text-v1" {
            bail!("unsupported manifest format '{format}'");
        }
        let arg_order = j
            .get("arg_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'arg_order'"))?
            .iter()
            .map(|v| v.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad arg name")))
            .collect::<Result<Vec<_>>>()?;
        let grid_scale = j
            .get("grid_scale")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest missing 'grid_scale'"))?;
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'entries'"))?
            .iter()
            .map(parse_entry)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { format, arg_order, grid_scale, entries })
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All topologies with artifacts, for discovery/listing.
    pub fn topologies(&self) -> Vec<Topology> {
        self.entries.iter().map(|e| e.topology.clone()).collect()
    }
}

fn parse_entry(j: &Json) -> Result<ArtifactEntry> {
    let get_str = |k: &str| {
        j.get(k).and_then(Json::as_str).map(str::to_string).ok_or_else(|| anyhow!("entry missing '{k}'"))
    };
    let get_usize = |k: &str| {
        j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("entry missing '{k}'"))
    };
    let name = get_str("name")?;
    let topology = Topology::new(
        get_usize("seq_len")?,
        get_usize("d_model")?,
        get_usize("heads")?,
        get_usize("tile_size")?,
    );
    topology.validate().map_err(|e| anyhow!("entry {name}: {e}"))?;
    let args = j
        .get("args")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("entry {name} missing args"))?
        .iter()
        .map(|(k, v)| {
            let dims = v
                .as_arr()
                .ok_or_else(|| anyhow!("arg {k}: not an array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("arg {k}: bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok((k.clone(), dims))
        })
        .collect::<Result<BTreeMap<_, _>>>()?;
    let golden_shape = j.get("golden_shape").and_then(Json::as_arr).map(|a| {
        a.iter().filter_map(Json::as_usize).collect::<Vec<_>>()
    });
    Ok(ArtifactEntry {
        hlo: get_str("hlo")?,
        hlo_pallas: j.get("hlo_pallas").and_then(Json::as_str).map(str::to_string),
        golden: j.get("golden").and_then(Json::as_str).map(str::to_string),
        golden_shape,
        inputs_sha256: j.get("inputs_sha256").and_then(Json::as_str).map(str::to_string),
        name,
        topology,
        args,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "arg_order": ["x", "wq"],
      "grid_scale": 0.015625,
      "entries": [
        {"name": "mha_sl8_d128_h4_ts32", "seq_len": 8, "d_model": 128,
         "heads": 4, "tile_size": 32, "d_k": 32, "n_tiles": 4,
         "hlo": "mha_sl8_d128_h4_ts32.hlo.txt",
         "golden": "g.bin", "golden_shape": [8, 128],
         "inputs_sha256": "ab",
         "args": {"x": [8, 128], "wq": [4, 32, 128]}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.arg_order, vec!["x", "wq"]);
        assert_eq!(m.grid_scale, 0.015625);
        let e = m.entry("mha_sl8_d128_h4_ts32").unwrap();
        assert_eq!(e.topology, Topology::new(8, 128, 4, 32));
        assert_eq!(e.args["wq"], vec![4, 32, 128]);
        assert_eq!(e.golden.as_deref(), Some("g.bin"));
        assert_eq!(e.golden_shape, Some(vec![8, 128]));
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text-v1", "hlo-text-v9");
        assert!(Manifest::parse_str(&bad).is_err());
    }

    #[test]
    fn rejects_invalid_topology() {
        let bad = SAMPLE.replace("\"heads\": 4", "\"heads\": 3"); // 128 % 3 != 0
        assert!(Manifest::parse_str(&bad).is_err());
    }

    #[test]
    fn entry_lookup_missing() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert!(m.entry("nope").is_none());
        assert_eq!(m.topologies().len(), 1);
    }
}
