//! PJRT runtime: loads the jax/Pallas-AOT'd HLO-text artifacts and runs
//! them on the request path.  Python never runs here — `make artifacts`
//! is the only python step, and the rust binary is self-contained after.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not the
//! serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md and aot.py).
//!
//! [`Runtime`] keeps one compiled executable per topology (compile-once,
//! execute-many — the FPGA analogue: one bitstream per build, one
//! register image per topology).  [`Backend`] abstracts the functional
//! engine so the coordinator can also run against the pure-rust simulator
//! datapath ([`SimBackend`]) when artifacts are unavailable.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use crate::config::Topology;
use crate::exec::{PoolHandle, ThreadPool};
use crate::sim::{ExecPath, KernelTier, PreparedWeights, Workspace};
use crate::testdata::MhaInputs;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Dispatch attribution per attention datapath (DESIGN.md §12) and per
/// kernel tier (DESIGN.md §14): how many requests a backend executed on
/// the fused tile-streaming path vs the materializing reference path,
/// and which kernel tier (scalar oracle, AVX2, AVX2+int8) ran them.
/// Mirrored into the accelerator and `CoordinatorStats` so fleet
/// observers can see which datapath and kernels served their traffic.
/// Every request increments exactly one path counter and exactly one
/// tier counter, so `total() == tier_total()` always.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PathCounters {
    pub fused: u64,
    pub reference: u64,
    pub scalar: u64,
    pub simd: u64,
    pub simd_int8: u64,
    /// Requests served by the end-to-end int8 attention tier
    /// (`KernelTier::SimdInt8Attn`, DESIGN.md §17).
    pub simd_int8_attn: u64,
    /// Requests whose every projection passed the ABFT checksum verify
    /// (DESIGN.md §15).  `integrity_pass + integrity_fail == total()`
    /// whenever integrity checks are on.
    pub integrity_pass: u64,
    /// Requests with at least one failed ABFT row checksum — corrupted
    /// staged operands or an accumulator upset.
    pub integrity_fail: u64,
}

impl PathCounters {
    pub fn total(&self) -> u64 {
        self.fused + self.reference
    }

    /// Requests attributed across kernel tiers (equals [`Self::total`]).
    pub fn tier_total(&self) -> u64 {
        self.scalar + self.simd + self.simd_int8 + self.simd_int8_attn
    }
}

/// A functional MHA engine: topology + operands → (SL × d_model) output.
pub trait Backend {
    fn run_mha(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<Vec<f32>>;

    /// Batched entry point: one programmed topology, many requests.
    /// Outputs are returned in request order and must be bit-identical to
    /// running each request through [`Backend::run_mha`] — the default
    /// implementation simply does that.  Engines with per-topology state
    /// (weight staging, compiled executables) override this to pay the
    /// programming cost once per batch.
    fn run_mha_batch(&mut self, topo: &Topology, inputs: &[&MhaInputs]) -> Result<Vec<Vec<f32>>> {
        inputs.iter().map(|&inp| self.run_mha(topo, inp)).collect()
    }

    /// Fused-vs-reference dispatch attribution.  Engines with a single
    /// datapath report the default (all zeros).
    fn path_counters(&self) -> PathCounters {
        PathCounters::default()
    }

    /// Per-request ABFT verdicts of the most recent
    /// [`Backend::run_mha`]/[`Backend::run_mha_batch`] call, in request
    /// order: `true` = at least one failed row checksum (corrupt).
    /// Engines without an integrity layer report empty (= all clean).
    fn last_integrity(&self) -> Vec<bool> {
        Vec::new()
    }

    fn name(&self) -> &'static str;
}

/// The PJRT-backed engine.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executables compiled since construction (telemetry for tests/bench).
    pub compilations: u64,
}

impl Runtime {
    /// Load the artifact directory produced by `make artifacts`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new(), compilations: 0 })
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Self> {
        Self::load("artifacts")
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&mut self, name: &str, variant: Variant) -> Result<&xla::PjRtLoadedExecutable> {
        let key = format!("{name}:{variant:?}");
        if !self.cache.contains_key(&key) {
            let entry = self
                .manifest
                .entry(name)
                .ok_or_else(|| anyhow!("topology '{name}' not in manifest"))?;
            let file = match variant {
                Variant::Deploy => entry.hlo.clone(),
                Variant::Pallas => entry
                    .hlo_pallas
                    .clone()
                    .ok_or_else(|| anyhow!("'{name}' ships no pallas variant"))?,
            };
            let path = self.dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(key.clone(), exe);
            self.compilations += 1;
        }
        Ok(&self.cache[&key])
    }

    /// Run a specific artifact variant (the deployment path is the
    /// default in [`Backend::run_mha`]; `Variant::Pallas` executes the
    /// kernel-structure HLO for cross-validation).
    pub fn run_mha_variant(
        &mut self,
        topo: &Topology,
        inputs: &MhaInputs,
        variant: Variant,
    ) -> Result<Vec<f32>> {
        self.run_inner(topo, inputs, variant)
    }

    /// Pre-compile every manifest entry (warm start for serving).
    pub fn warm_all(&mut self) -> Result<usize> {
        let names: Vec<String> = self.manifest.entries.iter().map(|e| e.name.clone()).collect();
        for n in &names {
            self.executable(n, Variant::Deploy)?;
        }
        Ok(names.len())
    }

    /// Load the golden output vector for `name`, if the manifest ships one.
    pub fn golden(&self, name: &str) -> Result<Option<Vec<f32>>> {
        let entry =
            self.manifest.entry(name).ok_or_else(|| anyhow!("'{name}' not in manifest"))?;
        let Some(golden) = &entry.golden else { return Ok(None) };
        let bytes = std::fs::read(self.dir.join(golden))
            .with_context(|| format!("reading golden for {name}"))?;
        if bytes.len() % 4 != 0 {
            bail!("golden file for {name} has odd length {}", bytes.len());
        }
        Ok(Some(
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
        ))
    }
}

impl Runtime {
    fn run_inner(
        &mut self,
        topo: &Topology,
        inputs: &MhaInputs,
        variant: Variant,
    ) -> Result<Vec<f32>> {
        let mut outs = self.run_many_inner(topo, &[inputs], variant)?;
        Ok(outs.pop().expect("one request in, one output out"))
    }

    /// One compiled executable, N executions: the manifest lookup and
    /// the compile/cache fetch are paid once per batch, then each
    /// request stages its literals and executes against the shared
    /// executable — the PJRT mirror of the sim backend's prepare-once
    /// batch path (ROADMAP PR-2 follow-up).  Outputs are bit-identical
    /// to serial [`Backend::run_mha`] calls: the same executable runs
    /// the same per-request literals in request order.
    fn run_many_inner(
        &mut self,
        topo: &Topology,
        inputs: &[&MhaInputs],
        variant: Variant,
    ) -> Result<Vec<Vec<f32>>> {
        let name = topo.name();
        let entry = self
            .manifest
            .entry(&name)
            .ok_or_else(|| anyhow!("topology '{name}' has no artifact"))?
            .clone();
        let arg_order = self.manifest.arg_order.clone();
        let exe = self.executable(&name, variant)?;

        let mut outputs = Vec::with_capacity(inputs.len());
        for &inp in inputs {
            let operands = inp.in_order();
            let mut literals = Vec::with_capacity(arg_order.len());
            for (arg_name, data) in arg_order.iter().zip(operands.iter()) {
                let dims = entry
                    .args
                    .get(arg_name)
                    .ok_or_else(|| anyhow!("arg '{arg_name}' missing from manifest entry"))?;
                let want: usize = dims.iter().product();
                if want != data.len() {
                    bail!("arg '{arg_name}': manifest says {want} elems, got {}", data.len());
                }
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())
                    .map_err(|e| anyhow!("reshape {arg_name}: {e:?}"))?;
                literals.push(lit);
            }

            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
            outputs.push(out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(outputs)
    }
}

/// Which lowering of a topology to execute (see aot.py's two variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// XLA-fused deployment path (default; fast on CPU PJRT).
    Deploy,
    /// Pallas interpret path (kernel structure; cross-validation).
    Pallas,
}

impl Backend for Runtime {
    /// Execute the deployment artifact for `topo` on `inputs`.
    fn run_mha(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<Vec<f32>> {
        self.run_inner(topo, inputs, Variant::Deploy)
    }

    /// Batched serving against one compiled executable: no more
    /// falling back to the default single-shot loop's repeated manifest
    /// lookups (the executable cache made those warm, but every request
    /// still re-cloned the manifest entry and arg order).
    fn run_mha_batch(&mut self, topo: &Topology, inputs: &[&MhaInputs]) -> Result<Vec<Vec<f32>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        self.run_many_inner(topo, inputs, Variant::Deploy)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Functional backend running the simulator's int8 datapath — used when
/// artifacts are unavailable and as an independent cross-check of the
/// PJRT path.
///
/// Purely functional: timing lives in [`crate::accel::ProgramImage`]
/// (program phase), so executing a request here runs no cycle-level
/// simulation.  Requests execute through resident [`Workspace`]s (one
/// owned by the backend for the single-shot path, one thread-local per
/// pool worker for the batch path), so warm requests allocate nothing on
/// the execute path.
///
/// Parallelism is two-level over one shared pool sized to
/// `min(batch × heads, cores)`: the batch fans out across workers, and
/// whatever headroom the batch leaves becomes head lanes *inside* each
/// request ([`PreparedWeights::execute_parallel`]).  A single request
/// therefore also runs head-parallel — the software mirror of the
/// fabric's `h` concurrent head pipelines.  Outputs are bit-identical to
/// the sequential path (exact integer GEMM, per-head f32 op order
/// untouched, disjoint output stripes).
///
/// The attention stage dispatches per [`ExecPolicy`] (DESIGN.md §12):
/// short sequences run the reference SL×SL path (the bit-identity
/// oracle), long sequences (SL ≥ [`FUSED_SL_THRESHOLD`], or worst-case
/// score scratch past [`SCORE_BYTES_BUDGET`]) run the fused
/// tile-streaming path, whose O(SL×TS) score footprint is what makes
/// them servable.  The path is a pure function of (policy, topology),
/// so batched and sequential serving of the same request always pick
/// the same datapath and stay bit-identical to each other on any host.
pub struct SimBackend {
    pub config: crate::sim::SimConfig,
    /// Attention datapath selection (DESIGN.md §12): `Auto` picks the
    /// fused tile-streaming path for long sequences / score-memory
    /// pressure, `Force` pins one path (tests, oracles).
    pub exec_policy: ExecPolicy,
    /// Kernel-tier selection (DESIGN.md §14): `Auto` runs the
    /// process-wide effective tier (env override, else best the host
    /// supports), `Force` pins one (clamped to host support at prepare
    /// time — `path_counters` reports what actually ran).
    pub tier_policy: TierPolicy,
    /// Shared workers for batch fan-out and head lanes; created on first
    /// use, re-created larger when a batch wants more concurrency.
    pool: Option<ThreadPool>,
    /// Consecutive pool sizings wanting at most half the current
    /// workers; drives the pool's high-water-mark decay (the pool
    /// analogue of `sim::Workspace`'s shrink policy).
    pool_lean_streak: u32,
    /// Resident scratch for the single-request path.
    workspace: Workspace,
    /// Fused/reference dispatch attribution.
    counters: PathCounters,
    /// Per-request ABFT verdicts of the most recent call (`true` =
    /// corrupt), request order.
    last_faulty: Vec<bool>,
    /// Prepare generation for transient fault plans: each preparation
    /// re-draws its faults (the scrub analogue — re-staging from the
    /// pristine host copy clears a transient upset).  Persistent plans
    /// ignore it.
    fault_epoch: u64,
}

/// How `SimBackend` picks the kernel tier for weight preparation
/// (DESIGN.md §14).  Like [`ExecPolicy`], the decision is a pure
/// function of the policy (plus one-time host detection) — never of the
/// request — so batched and sequential serving always run the same
/// kernels and stay bit-identical to each other.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TierPolicy {
    /// [`KernelTier::effective`]: the `FAMOUS_KERNEL_TIER` override when
    /// set, else the best tier the host supports.
    #[default]
    Auto,
    /// Pin a tier (tests, oracles, A/B benches).  Clamped to host
    /// support at prepare time, like every tier request.
    Force(KernelTier),
}

/// How `SimBackend` picks the attention datapath per request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecPolicy {
    /// `FusedTiled` when `seq_len ≥` [`FUSED_SL_THRESHOLD`] or when the
    /// reference path's worst-case score scratch (`heads × SL² × 4`
    /// bytes — one SL×SL buffer per head lane) would exceed
    /// [`SCORE_BYTES_BUDGET`]; `Reference` otherwise.  The decision is
    /// a pure function of the topology, never of host parallelism.
    #[default]
    Auto,
    Force(ExecPath),
}

/// Sequence length at which `ExecPolicy::Auto` switches to the fused
/// tile-streaming path: by SL=256 the SL×SL score walk is both the
/// memory and the wall-time loser (benches/exec.rs asserts the fused
/// win from here up).
pub const FUSED_SL_THRESHOLD: usize = 256;

/// Reference-path score-scratch budget for `ExecPolicy::Auto`'s
/// memory-pressure arm: wide-head topologies near the SL threshold
/// (e.g. 8 heads at SL ≥ 182 on the long build — the full-width shapes
/// the sharded cluster path would otherwise split) tip to the fused
/// path before the SL threshold alone would.
pub const SCORE_BYTES_BUDGET: usize = 1 << 20;

/// Pool sizings below half capacity before the worker pool shrinks to
/// the demanded size.
pub const POOL_SHRINK_WINDOW: u32 = 32;

thread_local! {
    /// Per-pool-worker scratch, resident across requests and batches —
    /// the host-side version of keeping buffers staged between requests
    /// (Peng et al., PAPERS.md).
    static WORKER_WS: std::cell::RefCell<Workspace> = std::cell::RefCell::new(Workspace::new());
}

impl SimBackend {
    pub fn new(config: crate::sim::SimConfig) -> Self {
        SimBackend {
            config,
            exec_policy: ExecPolicy::Auto,
            tier_policy: TierPolicy::default(),
            pool: None,
            pool_lean_streak: 0,
            workspace: Workspace::new(),
            counters: PathCounters::default(),
            last_faulty: Vec::new(),
            fault_epoch: 0,
        }
    }

    /// The config this preparation runs under: a transient fault plan
    /// advances to a fresh epoch (new seeded draws — the scrub), a
    /// persistent plan stays stuck at epoch 0.
    fn prepare_config(&mut self) -> crate::sim::SimConfig {
        let mut config = self.config.clone();
        if let Some(plan) = config.fault_plan.as_mut() {
            if !plan.persistent {
                *plan = plan.at_epoch(self.fault_epoch);
                self.fault_epoch += 1;
            }
        }
        config
    }

    /// Record per-request verdicts into the counters and the
    /// `last_integrity` snapshot.
    fn count_integrity(&mut self, faulty: Vec<bool>) {
        for &f in &faulty {
            if f {
                self.counters.integrity_fail += 1;
            } else {
                self.counters.integrity_pass += 1;
            }
        }
        self.last_faulty = faulty;
    }

    fn admit(&self, topo: &Topology) -> Result<()> {
        self.config.build.admits(topo).map_err(|e| anyhow!("sim: rejected: {e}"))
    }

    fn cores() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// The attention datapath for one request under the configured
    /// policy.  A pure function of (policy, topology) — deliberately
    /// independent of lane/core counts, so batched and single-shot
    /// serving of the same request always pick the same path on any
    /// host.
    pub fn choose_path(&self, topo: &Topology) -> ExecPath {
        match self.exec_policy {
            ExecPolicy::Force(path) => path,
            ExecPolicy::Auto => {
                // Worst-case reference score scratch for this request:
                // head lanes never exceed `heads`, and each holds SL²
                // floats on the reference path.
                let score_bytes = topo.heads * topo.seq_len * topo.seq_len * 4;
                if topo.seq_len >= FUSED_SL_THRESHOLD || score_bytes > SCORE_BYTES_BUDGET {
                    ExecPath::FusedTiled
                } else {
                    ExecPath::Reference
                }
            }
        }
    }

    /// [`Self::choose_path`] with request slack (carried-over ROADMAP
    /// item; DESIGN.md §12): `Force` still pins, `Auto` delegates to
    /// [`choose_path_deadline`] so a tight-deadline small-SL request can
    /// take the fused path when its modeled trace is cheaper.  Callers
    /// feed the two modeled latencies from
    /// [`crate::accel::FamousAccelerator::trace_summary`] (memoized per
    /// topology, so consulting them is allocation-free when warm).
    pub fn choose_path_with_slack(
        &self,
        topo: &Topology,
        slack_ms: f64,
        reference_ms: f64,
        fused_ms: f64,
    ) -> ExecPath {
        match self.exec_policy {
            ExecPolicy::Force(path) => path,
            ExecPolicy::Auto => choose_path_deadline(topo, slack_ms, reference_ms, fused_ms),
        }
    }

    /// The kernel tier requests prepare with under the configured
    /// policy (before the availability clamp — counting uses the
    /// clamped tier the prepared weights report).
    pub fn choose_tier(&self) -> KernelTier {
        match self.tier_policy {
            TierPolicy::Force(tier) => tier.clamp_available(),
            TierPolicy::Auto => KernelTier::effective(),
        }
    }

    fn count(&mut self, path: ExecPath, tier: KernelTier, requests: u64) {
        match path {
            ExecPath::FusedTiled => self.counters.fused += requests,
            ExecPath::Reference => self.counters.reference += requests,
        }
        match tier {
            KernelTier::Scalar => self.counters.scalar += requests,
            KernelTier::Simd => self.counters.simd += requests,
            KernelTier::SimdInt8 => self.counters.simd_int8 += requests,
            KernelTier::SimdInt8Attn => self.counters.simd_int8_attn += requests,
        }
    }

    /// The shared pool, grown to at least `want` workers (capped at the
    /// machine) — closes the ROADMAP "size the pool to the batch" item.
    /// Sizing decays like the workspaces do: [`POOL_SHRINK_WINDOW`]
    /// consecutive sizings wanting at most half the workers rebuild the
    /// pool at the demanded size, so a burst of wide batches does not
    /// pin idle threads forever.
    fn pool_for(&mut self, want: usize) -> &ThreadPool {
        let want = want.clamp(1, Self::cores());
        match self.pool.as_ref().map(ThreadPool::threads) {
            None => {
                self.pool = Some(ThreadPool::new(want));
                self.pool_lean_streak = 0;
            }
            Some(threads) if threads < want => {
                self.pool = Some(ThreadPool::new(want));
                self.pool_lean_streak = 0;
            }
            Some(threads) if want * 2 <= threads => {
                self.pool_lean_streak += 1;
                if self.pool_lean_streak >= POOL_SHRINK_WINDOW {
                    self.pool = Some(ThreadPool::new(want));
                    self.pool_lean_streak = 0;
                }
            }
            Some(_) => self.pool_lean_streak = 0,
        }
        self.pool.as_ref().expect("pool just ensured")
    }
}

/// Deadline-aware attention-path selection (DESIGN.md §12): the
/// `ExecPolicy::Auto` decision extended with the request's deadline
/// slack and the two modeled trace latencies for its topology.  A pure
/// function of its arguments — no host state, no randomness — so every
/// serving flavor that feeds it the same (topology, slack, model) picks
/// the same path and the bit-identity contract is untouched.
///
/// The hard arms of the base policy stay hard: score-scratch memory
/// pressure and the SL threshold always take the fused path (slack
/// cannot buy back an SL×SL buffer the workspace must not size).  Below
/// both arms — where the reference oracle is the default — a slack
/// tighter than the modeled reference latency switches to the fused
/// path *iff* its modeled trace is cheaper; when the fused trace is not
/// cheaper the switch would only add tolerance-level noise without
/// helping the deadline, so the oracle keeps the request.
pub fn choose_path_deadline(
    topo: &Topology,
    slack_ms: f64,
    reference_ms: f64,
    fused_ms: f64,
) -> ExecPath {
    let score_bytes = topo.heads * topo.seq_len * topo.seq_len * 4;
    if topo.seq_len >= FUSED_SL_THRESHOLD || score_bytes > SCORE_BYTES_BUDGET {
        return ExecPath::FusedTiled;
    }
    if slack_ms < reference_ms && fused_ms < reference_ms {
        ExecPath::FusedTiled
    } else {
        ExecPath::Reference
    }
}

/// Execute one request into a worker's resident workspace, head-parallel
/// when `lanes > 1`.  Falls back to a fresh workspace when the
/// thread-local one is already borrowed — a worker waiting on its head
/// lanes may help-execute *another* batch job (see
/// [`crate::exec::PoolHandle::scoped_mut`]), re-entering this function on
/// the same thread.
fn execute_on_worker(
    prepared: &PreparedWeights,
    x: &[f32],
    pool: &PoolHandle,
    lanes: usize,
    path: ExecPath,
) -> (Vec<f32>, u64) {
    let xq = prepared.quantize_input(x);
    WORKER_WS.with(|cell| match cell.try_borrow_mut() {
        Ok(mut ws) => {
            if lanes > 1 {
                prepared.execute_parallel_path(&xq, &mut ws, pool, lanes, path);
            } else {
                prepared.execute_into_path(&xq, &mut ws, path);
            }
            (ws.output().to_vec(), ws.integrity_faults())
        }
        Err(_) => {
            let mut ws = Workspace::new();
            prepared.execute_into_path(&xq, &mut ws, path);
            let faults = ws.integrity_faults();
            (ws.take_output(), faults)
        }
    })
}

impl Backend for SimBackend {
    fn run_mha(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<Vec<f32>> {
        self.admit(topo)?;
        let config = self.prepare_config();
        let prepared =
            PreparedWeights::prepare_with_tier(&config, topo, inputs, self.choose_tier());
        let x = prepared.quantize_input(&inputs.x);
        let lanes = topo.heads.min(Self::cores());
        let path = self.choose_path(topo);
        self.count(path, prepared.tier(), 1);
        if lanes > 1 {
            let handle = self.pool_for(lanes).handle();
            prepared.execute_parallel_path(&x, &mut self.workspace, &handle, lanes, path);
        } else {
            prepared.execute_into_path(&x, &mut self.workspace, path);
        }
        let faulty = self.workspace.integrity_faults() > 0;
        self.count_integrity(vec![faulty]);
        Ok(self.workspace.output().to_vec())
    }

    /// One weight preparation, N executions under the two-level split.
    /// Requests whose weight operands differ from the batch head's fall
    /// back to their own preparation (still inside the parallel map),
    /// preserving bit-identity with the sequential path unconditionally
    /// (the path is chosen once per batch from the topology alone, so
    /// batched and sequential serving run the same datapath).
    fn run_mha_batch(&mut self, topo: &Topology, inputs: &[&MhaInputs]) -> Result<Vec<Vec<f32>>> {
        let Some(first) = inputs.first().copied() else { return Ok(Vec::new()) };
        if inputs.len() == 1 {
            return Ok(vec![self.run_mha(topo, first)?]);
        }
        self.admit(topo)?;
        let batch = inputs.len();
        let tier = self.choose_tier();
        let config = self.prepare_config();
        let shared = Arc::new(PreparedWeights::prepare_with_tier(&config, topo, first, tier));
        let tier = shared.tier();
        let items: Vec<BatchItem> = inputs
            .iter()
            .map(|&inp| {
                if PreparedWeights::same_weights(first, inp) {
                    BatchItem::Shared { x: inp.x.clone() }
                } else {
                    BatchItem::Own { inputs: inp.clone() }
                }
            })
            .collect();
        let pool = self.pool_for(batch * topo.heads.max(1));
        // Headroom the batch leaves on the pool becomes head lanes inside
        // each request (the caller's helping share counts as one worker).
        let lanes = (pool.threads() / batch).clamp(1, topo.heads.max(1));
        let handle = pool.handle();
        let path = self.choose_path(topo);
        self.count(path, tier, batch as u64);
        let pool = self.pool.as_ref().expect("pool just ensured");
        let topo = topo.clone();
        let results = pool.parallel_map(items, move |item| match item {
            BatchItem::Shared { x } => execute_on_worker(&shared, &x, &handle, lanes, path),
            BatchItem::Own { inputs } => {
                // The batch's clamped tier, so weight-divergent requests
                // run the same kernels as their batchmates.
                let own = PreparedWeights::prepare_with_tier(&config, &topo, &inputs, tier);
                execute_on_worker(&own, &inputs.x, &handle, lanes, path)
            }
        });
        let (outputs, faults): (Vec<Vec<f32>>, Vec<u64>) = results.into_iter().unzip();
        self.count_integrity(faults.iter().map(|&f| f > 0).collect());
        Ok(outputs)
    }

    fn path_counters(&self) -> PathCounters {
        self.counters
    }

    fn last_integrity(&self) -> Vec<bool> {
        self.last_faulty.clone()
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

/// One request's share of a batch: its input plus either the batch-shared
/// prepared weights or (weight-divergent requests) its own operands.
enum BatchItem {
    Shared { x: Vec<f32> },
    Own { inputs: MhaInputs },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    // PJRT-dependent paths are exercised in rust/tests/ (they need the
    // artifacts directory); unit tests here cover the backend plumbing.

    #[test]
    fn sim_backend_runs() {
        let mut b = SimBackend::new(SimConfig::u55c());
        let topo = Topology::new(64, 768, 8, 64);
        let out = b.run_mha(&topo, &MhaInputs::generate(&topo)).unwrap();
        assert_eq!(out.len(), 64 * 768);
        assert_eq!(b.name(), "sim");
    }

    #[test]
    fn sim_backend_rejects_bad_topology() {
        let mut b = SimBackend::new(SimConfig::u55c());
        let topo = Topology::new(64, 1024, 8, 64); // exceeds synth max
        assert!(b.run_mha(&topo, &MhaInputs::generate(&topo)).is_err());
    }

    #[test]
    fn runtime_load_missing_dir_errors() {
        assert!(Runtime::load("/nonexistent/path").is_err());
    }

    #[test]
    fn sim_backend_batch_bit_identical_to_sequential() {
        let topo = Topology::new(8, 256, 4, 64);
        let mut requests = Vec::new();
        for i in 0..5u64 {
            let mut inp = MhaInputs::generate(&topo);
            inp.x = crate::testdata::gen_matrix(100 + i, topo.seq_len, topo.d_model);
            requests.push(inp);
        }
        // One weight-divergent request exercises the own-preparation path.
        requests[3].wq[7] = -requests[3].wq[7] + 0.25;

        let mut seq = SimBackend::new(SimConfig::u55c());
        let want: Vec<Vec<f32>> =
            requests.iter().map(|inp| seq.run_mha(&topo, inp).unwrap()).collect();

        let mut batched = SimBackend::new(SimConfig::u55c());
        let refs: Vec<&MhaInputs> = requests.iter().collect();
        let got = batched.run_mha_batch(&topo, &refs).unwrap();

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            let gb: Vec<u32> = g.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "batched output diverged from sequential");
        }
    }

    #[test]
    fn sim_backend_repeat_requests_identical_and_pool_grows_only() {
        let mut b = SimBackend::new(SimConfig::u55c());
        let topo = Topology::new(16, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        // Head-parallel single-shot path: repeat requests bit-identical.
        let o1 = b.run_mha(&topo, &inputs).unwrap();
        let o2 = b.run_mha(&topo, &inputs).unwrap();
        assert_eq!(o1, o2);
        let after_single = b.pool.as_ref().map(|p| p.threads());
        // A batch sizes the pool to min(batch × heads, cores) — never
        // smaller than what the single-shot path already built.
        let refs: Vec<&MhaInputs> = vec![&inputs; 4];
        let outs = b.run_mha_batch(&topo, &refs).unwrap();
        for o in &outs {
            assert_eq!(o, &o1);
        }
        let after_batch = b.pool.as_ref().map(|p| p.threads()).unwrap();
        if let Some(n) = after_single {
            assert!(after_batch >= n, "pool shrank: {after_batch} < {n}");
        }
        assert!(after_batch <= SimBackend::cores());
    }

    #[test]
    fn sim_backend_batch_empty_and_rejection() {
        let mut b = SimBackend::new(SimConfig::u55c());
        let topo = Topology::new(8, 256, 4, 64);
        assert!(b.run_mha_batch(&topo, &[]).unwrap().is_empty());
        let bad = Topology::new(64, 1024, 8, 64);
        let inp = MhaInputs::generate(&bad);
        assert!(b.run_mha_batch(&bad, &[&inp]).is_err());
    }

    #[test]
    fn auto_policy_picks_fused_above_threshold_and_counts() {
        let mut b = SimBackend::new(SimConfig::u55c_long());
        let short = Topology::new(64, 256, 4, 64);
        let long = Topology::new(256, 128, 2, 64);
        assert_eq!(b.choose_path(&short), ExecPath::Reference);
        assert_eq!(b.choose_path(&long), ExecPath::FusedTiled);
        // Memory pressure below the SL threshold: a wide-head shape
        // whose per-request score scratch (heads × SL² × 4 B) exceeds
        // the budget flips to fused; the same SL with few heads stays
        // on the reference path.
        assert_eq!(b.choose_path(&Topology::new(192, 768, 8, 64)), ExecPath::FusedTiled);
        assert_eq!(b.choose_path(&Topology::new(192, 768, 2, 64)), ExecPath::Reference);
        // Dispatch attribution.
        b.run_mha(&short, &MhaInputs::generate(&short)).unwrap();
        assert_eq!((b.path_counters().fused, b.path_counters().reference), (0, 1));
        b.run_mha(&long, &MhaInputs::generate(&long)).unwrap();
        assert_eq!((b.path_counters().fused, b.path_counters().reference), (1, 1));
        let inp = MhaInputs::generate(&long);
        let refs: Vec<&MhaInputs> = vec![&inp; 3];
        b.run_mha_batch(&long, &refs).unwrap();
        assert_eq!(b.path_counters().fused, 4);
        assert_eq!(b.path_counters().total(), 5);
        // Every request is attributed to exactly one tier too.
        assert_eq!(b.path_counters().tier_total(), 5);
    }

    #[test]
    fn tier_policy_attributes_and_forced_scalar_matches_oracle() {
        // Forcing the scalar tier pins the oracle kernels; the counters
        // attribute every request to the tier that actually ran, and
        // tier attribution is conserved against path attribution.
        let topo = Topology::new(16, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let mut forced = SimBackend::new(SimConfig::u55c());
        forced.tier_policy = TierPolicy::Force(KernelTier::Scalar);
        assert_eq!(forced.choose_tier(), KernelTier::Scalar);
        let out = forced.run_mha(&topo, &inputs).unwrap();
        assert_eq!(forced.path_counters().scalar, 1);
        assert_eq!(forced.path_counters().tier_total(), forced.path_counters().total());
        // The scalar-forced backend reproduces the prepare-level oracle
        // bit-for-bit (head-parallel execution does not reorder: the
        // flavor contract).
        let oracle = PreparedWeights::prepare(&forced.config, &topo, &inputs);
        let want = oracle.execute(&oracle.quantize_input(&inputs.x));
        assert_eq!(
            out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
        // Auto runs the process-wide effective tier and attributes it.
        let mut auto = SimBackend::new(SimConfig::u55c());
        assert_eq!(auto.choose_tier(), KernelTier::effective());
        auto.run_mha(&topo, &inputs).unwrap();
        let c = auto.path_counters();
        let effective_count = match KernelTier::effective() {
            KernelTier::Scalar => c.scalar,
            KernelTier::Simd => c.simd,
            KernelTier::SimdInt8 => c.simd_int8,
            KernelTier::SimdInt8Attn => c.simd_int8_attn,
        };
        assert_eq!(effective_count, 1);
        // An unavailable forced tier clamps (and counts) honestly.
        let mut clamped = SimBackend::new(SimConfig::u55c());
        clamped.tier_policy = TierPolicy::Force(KernelTier::SimdInt8);
        clamped.run_mha(&topo, &inputs).unwrap();
        let c = clamped.path_counters();
        if KernelTier::SimdInt8.is_available() {
            assert_eq!((c.simd_int8, c.scalar), (1, 0));
        } else {
            assert_eq!((c.simd_int8, c.scalar), (0, 1));
        }
    }

    #[test]
    fn int8_attn_tier_attributed_and_conserved() {
        // The end-to-end int8 attention tier flows through the same
        // attribution plumbing: forcing it counts simd_int8_attn (or
        // scalar after the non-AVX2 clamp), and tier conservation
        // (`total() == tier_total()`) holds across mixed-tier traffic.
        let topo = Topology::new(16, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let mut b = SimBackend::new(SimConfig::u55c());
        b.tier_policy = TierPolicy::Force(KernelTier::SimdInt8Attn);
        b.run_mha(&topo, &inputs).unwrap();
        let c = b.path_counters();
        if KernelTier::SimdInt8Attn.is_available() {
            assert_eq!((c.simd_int8_attn, c.scalar), (1, 0));
        } else {
            assert_eq!((c.simd_int8_attn, c.scalar), (0, 1));
        }
        assert_eq!(c.total(), c.tier_total());
        // Mix in another tier: both are attributed, conservation holds.
        b.tier_policy = TierPolicy::Force(KernelTier::Scalar);
        let refs: Vec<&MhaInputs> = vec![&inputs; 2];
        b.run_mha_batch(&topo, &refs).unwrap();
        let c = b.path_counters();
        assert_eq!(c.scalar, if KernelTier::SimdInt8Attn.is_available() { 2 } else { 3 });
        assert_eq!(c.total(), 3);
        assert_eq!(c.tier_total(), 3);
    }

    #[test]
    fn deadline_aware_path_selection_consults_modeled_traces() {
        // Satellite contract: choose_path_deadline is a pure function
        // tested against the accelerator's memoized trace model.  The
        // small-SL default is the reference oracle; slack tighter than
        // the modeled reference latency flips to fused exactly when the
        // fused trace is modeled cheaper; the SL and memory-pressure
        // arms stay hard regardless of slack.
        use crate::accel::FamousAccelerator;
        let cfg = SimConfig::u55c_long();
        let mut acc = FamousAccelerator::new(cfg.clone(), Box::new(SimBackend::new(cfg)));
        let small = Topology::new(64, 768, 2, 64);
        let reference_ms =
            acc.trace_summary(&small, ExecPath::Reference).unwrap().latency_ms;
        let fused_ms = acc.trace_summary(&small, ExecPath::FusedTiled).unwrap().latency_ms;
        // Generous slack: the oracle keeps the request.
        assert_eq!(
            choose_path_deadline(&small, reference_ms * 2.0, reference_ms, fused_ms),
            ExecPath::Reference
        );
        // Tight slack: switch iff the fused trace is cheaper.
        let want =
            if fused_ms < reference_ms { ExecPath::FusedTiled } else { ExecPath::Reference };
        assert_eq!(
            choose_path_deadline(&small, reference_ms * 0.5, reference_ms, fused_ms),
            want
        );
        // A modeled-cheaper fused trace under a blown deadline switches.
        assert_eq!(
            choose_path_deadline(&small, 0.0, 1.0, 0.5),
            ExecPath::FusedTiled
        );
        // ...but a modeled-dearer one cannot help the deadline: stay.
        assert_eq!(choose_path_deadline(&small, 0.0, 1.0, 2.0), ExecPath::Reference);
        // Hard arms ignore slack entirely.
        let long = Topology::new(512, 768, 8, 64);
        assert_eq!(choose_path_deadline(&long, f64::MAX, 1.0, 2.0), ExecPath::FusedTiled);
        let wide = Topology::new(192, 768, 8, 64); // memory-pressure arm
        assert_eq!(choose_path_deadline(&wide, f64::MAX, 1.0, 2.0), ExecPath::FusedTiled);
        // The policy-level hook: Force pins, Auto delegates.
        let mut b = SimBackend::new(SimConfig::u55c_long());
        assert_eq!(
            b.choose_path_with_slack(&small, 0.0, 1.0, 0.5),
            ExecPath::FusedTiled
        );
        b.exec_policy = ExecPolicy::Force(ExecPath::Reference);
        assert_eq!(
            b.choose_path_with_slack(&small, 0.0, 1.0, 0.5),
            ExecPath::Reference
        );
        // Consistency with the slack-free policy: with no deadline
        // pressure the two decisions agree on every small shape.
        let b = SimBackend::new(SimConfig::u55c_long());
        for topo in [small, Topology::new(128, 256, 2, 64), wide, long] {
            assert_eq!(
                b.choose_path_with_slack(&topo, f64::MAX, reference_ms, fused_ms),
                b.choose_path(&topo),
                "{topo}"
            );
        }
    }

    #[test]
    fn tier_batch_bit_identical_to_sequential() {
        // The batch path runs the same tier as sequential serving (the
        // tier is chosen once per batch from the policy alone), so the
        // existing bit-identity contract holds on every tier.
        let topo = Topology::new(8, 256, 4, 64);
        let inputs = MhaInputs::generate(&topo);
        for tier in KernelTier::ALL {
            let mut seq = SimBackend::new(SimConfig::u55c());
            seq.tier_policy = TierPolicy::Force(tier);
            let want = seq.run_mha(&topo, &inputs).unwrap();
            let mut batched = SimBackend::new(SimConfig::u55c());
            batched.tier_policy = TierPolicy::Force(tier);
            let refs: Vec<&MhaInputs> = vec![&inputs; 3];
            let got = batched.run_mha_batch(&topo, &refs).unwrap();
            for out in &got {
                assert_eq!(
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "tier {tier}: batch diverged from sequential"
                );
            }
            assert_eq!(batched.path_counters().tier_total(), 3);
        }
    }

    #[test]
    fn fused_requests_serve_and_match_reference_within_tolerance() {
        // A long-SL request through the auto policy must agree with the
        // forced reference path within the documented bound, and batch
        // serving must be bit-identical to single-shot fused serving.
        use crate::sim::fused::assert_within_tolerance;
        let topo = Topology::new(256, 128, 2, 64);
        let inputs = MhaInputs::generate(&topo);
        let mut auto = SimBackend::new(SimConfig::u55c_long());
        let fused_out = auto.run_mha(&topo, &inputs).unwrap();
        assert_eq!(auto.path_counters().fused, 1);
        let mut oracle = SimBackend::new(SimConfig::u55c_long());
        oracle.exec_policy = ExecPolicy::Force(ExecPath::Reference);
        let ref_out = oracle.run_mha(&topo, &inputs).unwrap();
        assert_eq!(oracle.path_counters().reference, 1);
        assert_within_tolerance(
            crate::sim::SoftmaxKind::Exact,
            topo.seq_len,
            &ref_out,
            &fused_out,
            "auto-policy fused serving",
        );
        let refs: Vec<&MhaInputs> = vec![&inputs; 2];
        let batched = auto.run_mha_batch(&topo, &refs).unwrap();
        for out in &batched {
            let ob: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
            let fb: Vec<u32> = fused_out.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ob, fb, "batched fused serving diverged from single-shot");
        }
    }

    #[test]
    fn pool_decays_after_sustained_low_demand() {
        let mut b = SimBackend::new(SimConfig::u55c());
        if SimBackend::cores() < 2 {
            return; // nothing to shrink on a single-core host
        }
        b.pool_for(SimBackend::cores());
        let peak = b.pool.as_ref().unwrap().threads();
        assert!(peak >= 2);
        // A blip of low demand keeps the pool (warm contract)...
        b.pool_for(1);
        assert_eq!(b.pool.as_ref().unwrap().threads(), peak);
        // ...a demand spike resets the streak...
        b.pool_for(peak);
        assert_eq!(b.pool_lean_streak, 0);
        // ...and a sustained window shrinks to the demanded size.
        for _ in 0..POOL_SHRINK_WINDOW {
            b.pool_for(1);
        }
        assert_eq!(b.pool.as_ref().unwrap().threads(), 1, "pool must decay to demand");
        // Growth after decay still works.
        b.pool_for(peak);
        assert_eq!(b.pool.as_ref().unwrap().threads(), peak);
    }

    #[test]
    fn default_batch_impl_loops_single_shot() {
        // A Backend without an override serves batches via run_mha.
        struct Counting(u64);
        impl Backend for Counting {
            fn run_mha(&mut self, topo: &Topology, _i: &MhaInputs) -> Result<Vec<f32>> {
                self.0 += 1;
                Ok(vec![0.0; topo.output_elems()])
            }
            fn name(&self) -> &'static str {
                "counting"
            }
        }
        let topo = Topology::new(4, 32, 2, 16);
        let inp = MhaInputs::generate(&topo);
        let mut c = Counting(0);
        let out = c.run_mha_batch(&topo, &[&inp, &inp, &inp]).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(c.0, 3);
    }
}
