//! PJRT runtime: loads the jax/Pallas-AOT'd HLO-text artifacts and runs
//! them on the request path.  Python never runs here — `make artifacts`
//! is the only python step, and the rust binary is self-contained after.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not the
//! serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md and aot.py).
//!
//! [`Runtime`] keeps one compiled executable per topology (compile-once,
//! execute-many — the FPGA analogue: one bitstream per build, one
//! register image per topology).  [`Backend`] abstracts the functional
//! engine so the coordinator can also run against the pure-rust simulator
//! datapath ([`SimBackend`]) when artifacts are unavailable.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use crate::config::Topology;
use crate::testdata::MhaInputs;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A functional MHA engine: topology + operands → (SL × d_model) output.
pub trait Backend {
    fn run_mha(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<Vec<f32>>;
    fn name(&self) -> &'static str;
}

/// The PJRT-backed engine.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executables compiled since construction (telemetry for tests/bench).
    pub compilations: u64,
}

impl Runtime {
    /// Load the artifact directory produced by `make artifacts`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new(), compilations: 0 })
    }

    /// Default artifact location relative to the repo root.
    pub fn load_default() -> Result<Self> {
        Self::load("artifacts")
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&mut self, name: &str, variant: Variant) -> Result<&xla::PjRtLoadedExecutable> {
        let key = format!("{name}:{variant:?}");
        if !self.cache.contains_key(&key) {
            let entry = self
                .manifest
                .entry(name)
                .ok_or_else(|| anyhow!("topology '{name}' not in manifest"))?;
            let file = match variant {
                Variant::Deploy => entry.hlo.clone(),
                Variant::Pallas => entry
                    .hlo_pallas
                    .clone()
                    .ok_or_else(|| anyhow!("'{name}' ships no pallas variant"))?,
            };
            let path = self.dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(key.clone(), exe);
            self.compilations += 1;
        }
        Ok(&self.cache[&key])
    }

    /// Run a specific artifact variant (the deployment path is the
    /// default in [`Backend::run_mha`]; `Variant::Pallas` executes the
    /// kernel-structure HLO for cross-validation).
    pub fn run_mha_variant(
        &mut self,
        topo: &Topology,
        inputs: &MhaInputs,
        variant: Variant,
    ) -> Result<Vec<f32>> {
        self.run_inner(topo, inputs, variant)
    }

    /// Pre-compile every manifest entry (warm start for serving).
    pub fn warm_all(&mut self) -> Result<usize> {
        let names: Vec<String> = self.manifest.entries.iter().map(|e| e.name.clone()).collect();
        for n in &names {
            self.executable(n, Variant::Deploy)?;
        }
        Ok(names.len())
    }

    /// Load the golden output vector for `name`, if the manifest ships one.
    pub fn golden(&self, name: &str) -> Result<Option<Vec<f32>>> {
        let entry =
            self.manifest.entry(name).ok_or_else(|| anyhow!("'{name}' not in manifest"))?;
        let Some(golden) = &entry.golden else { return Ok(None) };
        let bytes = std::fs::read(self.dir.join(golden))
            .with_context(|| format!("reading golden for {name}"))?;
        if bytes.len() % 4 != 0 {
            bail!("golden file for {name} has odd length {}", bytes.len());
        }
        Ok(Some(
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
        ))
    }
}

impl Runtime {
    fn run_inner(
        &mut self,
        topo: &Topology,
        inputs: &MhaInputs,
        variant: Variant,
    ) -> Result<Vec<f32>> {
        let name = topo.name();
        let entry = self
            .manifest
            .entry(&name)
            .ok_or_else(|| anyhow!("topology '{name}' has no artifact"))?
            .clone();
        let arg_order = self.manifest.arg_order.clone();
        let exe = self.executable(&name, variant)?;

        let operands = inputs.in_order();
        let mut literals = Vec::with_capacity(arg_order.len());
        for (arg_name, data) in arg_order.iter().zip(operands.iter()) {
            let dims = entry
                .args
                .get(arg_name)
                .ok_or_else(|| anyhow!("arg '{arg_name}' missing from manifest entry"))?;
            let want: usize = dims.iter().product();
            if want != data.len() {
                bail!("arg '{arg_name}': manifest says {want} elems, got {}", data.len());
            }
            let lit = xla::Literal::vec1(data)
                .reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())
                .map_err(|e| anyhow!("reshape {arg_name}: {e:?}"))?;
            literals.push(lit);
        }

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

/// Which lowering of a topology to execute (see aot.py's two variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// XLA-fused deployment path (default; fast on CPU PJRT).
    Deploy,
    /// Pallas interpret path (kernel structure; cross-validation).
    Pallas,
}

impl Backend for Runtime {
    /// Execute the deployment artifact for `topo` on `inputs`.
    fn run_mha(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<Vec<f32>> {
        self.run_inner(topo, inputs, Variant::Deploy)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Functional backend running the simulator's int8 datapath — used when
/// artifacts are unavailable and as an independent cross-check of the
/// PJRT path.
pub struct SimBackend {
    pub config: crate::sim::SimConfig,
}

impl SimBackend {
    pub fn new(config: crate::sim::SimConfig) -> Self {
        SimBackend { config }
    }
}

impl Backend for SimBackend {
    fn run_mha(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<Vec<f32>> {
        let mut sim = crate::sim::Simulator::new(self.config.clone());
        let r = sim.run(topo, inputs).map_err(|e| anyhow!("sim: {e}"))?;
        r.output.ok_or_else(|| anyhow!("simulator produced no functional output"))
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    // PJRT-dependent paths are exercised in rust/tests/ (they need the
    // artifacts directory); unit tests here cover the backend plumbing.

    #[test]
    fn sim_backend_runs() {
        let mut b = SimBackend::new(SimConfig::u55c());
        let topo = Topology::new(64, 768, 8, 64);
        let out = b.run_mha(&topo, &MhaInputs::generate(&topo)).unwrap();
        assert_eq!(out.len(), 64 * 768);
        assert_eq!(b.name(), "sim");
    }

    #[test]
    fn sim_backend_rejects_bad_topology() {
        let mut b = SimBackend::new(SimConfig::u55c());
        let topo = Topology::new(64, 1024, 8, 64); // exceeds synth max
        assert!(b.run_mha(&topo, &MhaInputs::generate(&topo)).is_err());
    }

    #[test]
    fn runtime_load_missing_dir_errors() {
        assert!(Runtime::load("/nonexistent/path").is_err());
    }
}
