//! FAMOUS — full-system reproduction of *“FAMOUS: Flexible Accelerator for
//! the Attention Mechanism of Transformer on UltraScale+ FPGAs”* (FPT 2024).
//!
//! The crate models the paper's accelerator end to end:
//!
//! * [`fpga`] — UltraScale+ device inventories, BRAM banking, HLS
//!   pipelined-loop latency algebra, and a structural resource estimator.
//! * [`sim`] — a cycle-approximate simulator of the three processing
//!   modules (`QKV_PM`, `QK_PM`, `SV_PM`), the AXI/HBM load path, and the
//!   MicroBlaze-style control plane, with a functional int8 datapath.
//! * [`analytical`] — the paper's Section VII latency model (eqs. 3–14).
//! * [`runtime`] — PJRT loader/executor for the jax/Pallas-AOT'd HLO
//!   artifacts (the functional oracle on the request path).
//! * [`accel`] — `FamousAccelerator`: functional output + latency report +
//!   resource feasibility for one request.
//! * [`coordinator`] — the host/MicroBlaze control flow as a request
//!   router/batcher with runtime (h, d_model, SL) reprogramming.
//! * [`cluster`] — scale-out: a fleet of heterogeneous simulated devices
//!   behind one ingress, with placement planning, topology-affinity
//!   routing, head-sharding of oversized requests, and fleet metrics.
//! * [`baselines`] — measured CPU attention plus calibrated models of the
//!   platforms the paper compares against (Tables II–IV).
//!
//! Substrates built from scratch (offline image; see DESIGN.md §2):
//! [`jsonlite`], [`fixed`], [`rng`], [`proptest_lite`], [`exec`],
//! [`cli`], [`error`] (plus the vendored `anyhow`/`xla` shims under
//! `rust/vendor/`).

pub mod analytical;
pub mod benchlib;
pub mod cli;
pub mod config;
pub mod error;
pub mod exec;
pub mod fixed;
pub mod fpga;
pub mod jsonlite;
pub mod metrics;
pub mod proptest_lite;
pub mod rng;
pub mod sim;
pub mod testdata;
// Layered on top (written after the substrates):
pub mod accel;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod report;
pub mod runtime;
