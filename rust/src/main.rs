//! `famous` — leader binary: run the accelerator, serve requests,
//! regenerate the paper's tables, inspect builds.

use famous::accel::FamousAccelerator;
use famous::analytical::{LatencyModel, TABLE1};
use famous::cli::Parser;
use famous::cluster::loadgen::{mean_service_ms, rate_for_utilization};
use famous::cluster::telemetry::render_top;
use famous::cluster::{
    parse_fleet, ArrivalProcess, Cluster, ClusterConfig, ControlAction, ControlRule, DesConfig,
    DeviceSpec, FleetSim, LoadGen, LoadGenConfig, QosOutcome, QosPolicy, RuleScope, RuleSignal,
    TelemetryConfig, WorkloadProfile,
};
use famous::config::Topology;
use famous::coordinator::{
    BatchPolicy, Coordinator, ModelDescriptor, Request, SchedulerConfig, Server, ServerConfig,
};
use famous::fpga::{Device, ResourceModel};
use famous::report::{fmt_f, Table};
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;

fn parser() -> Parser {
    Parser::new("famous", "FAMOUS attention accelerator (FPT'24) — full-system reproduction")
        .subcommand("run", "run one MHA invocation and print the report")
        .subcommand("serve", "serve a synthetic request stream through the coordinator")
        .subcommand("cluster", "serve a mixed workload across a simulated FPGA fleet")
        .subcommand("top", "live fleet telemetry dashboard under a seeded QoS load")
        .subcommand("table1", "reproduce Table I (all 12 tests)")
        .subcommand("resources", "print resource estimates / max-heads per device")
        .subcommand("trace", "dump the per-phase cycle trace as JSON")
        .subcommand("info", "list available artifacts")
        .opt_default("topology", "64,768,8", "SL,d_model,heads")
        .opt_default("tile-size", "64", "synthesis tile size TS")
        .opt_default("device", "u55c", "u55c | u200")
        .opt_default("artifacts", "artifacts", "artifact directory")
        .opt_default("requests", "32", "serve/cluster: number of synthetic requests")
        .opt_default("fleet", "u55c:2,u200:2", "cluster: device fleet, e.g. u55c:4")
        .opt_default("model", "", "serve: model descriptor JSON path")
        .opt_default("arrivals", "bursty", "cluster --qos: arrival process (poisson | bursty)")
        .opt_default("load", "0.9", "cluster --qos: offered load as a fraction of fleet capacity")
        .opt_default("seed", "7", "cluster --qos: load generator seed")
        .opt_default("window-ms", "0", "top: telemetry window (0 = 12x mean service time)")
        .opt_default("derate", "1.0", "top: silent clock derate on the last device (1.0 = healthy)")
        .opt_default("seu", "", "cluster/top: SEU fault plan 'seed:rate' on the last device")
        .opt_default("export", "", "top: write the sealed frame ring as JSONL to this path")
        .flag("plain", "top: append dashboard repaints instead of clearing the screen")
        .flag("qos", "cluster: QoS serving (loadgen arrivals, EDF+slack routing, SLO report)")
        .flag("des", "cluster: virtual-time discrete-event QoS simulation (no threads)")
        .flag("fused-service", "cluster --des: bill auto-fused shapes the per-tile trace")
        .flag("sim-datapath", "use the rust int8 datapath instead of PJRT")
        .flag("double-buffer", "enable load/compute overlap in the tile loop")
}

/// Parse `--seu seed:rate` (e.g. `0xBAD5EED:0.01` or `7:0.02`) into a
/// persistent stuck-at fault plan for the last fleet device.
fn parse_seu(s: &str) -> Result<famous::sim::FaultPlan, String> {
    let (seed, rate) = s.split_once(':').ok_or_else(|| format!("--seu '{s}' must be seed:rate"))?;
    let seed = seed.trim();
    let seed: u64 = if let Some(hex) = seed.strip_prefix("0x").or_else(|| seed.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).map_err(|_| format!("bad seu seed '{seed}'"))?
    } else {
        seed.parse().map_err(|_| format!("bad seu seed '{seed}'"))?
    };
    let rate: f64 = rate.trim().parse().map_err(|_| format!("bad seu rate '{rate}'"))?;
    if !(0.0..=1.0).contains(&rate) {
        return Err(format!("seu rate {rate} must be in [0, 1]"));
    }
    Ok(famous::sim::FaultPlan::seu(seed, rate))
}

/// Apply `--seu` to the last fleet device, if the flag is set.
fn apply_seu(args: &famous::cli::Args, devices: &mut [DeviceSpec]) -> anyhow::Result<bool> {
    let spec = args.get_or("seu", "");
    if spec.is_empty() {
        return Ok(false);
    }
    let plan = parse_seu(spec).map_err(anyhow::Error::msg)?;
    let last = devices.len() - 1;
    devices[last] = devices[last].clone().with_fault_plan(plan);
    Ok(true)
}

fn parse_topology(s: &str, ts: usize) -> Result<Topology, String> {
    let parts: Vec<&str> = s.split(',').map(str::trim).collect();
    if parts.len() != 3 {
        return Err(format!("topology '{s}' must be SL,d_model,heads"));
    }
    let nums: Vec<usize> = parts
        .iter()
        .map(|p| p.parse().map_err(|_| format!("bad number '{p}' in topology")))
        .collect::<Result<_, _>>()?;
    let t = Topology::new(nums[0], nums[1], nums[2], ts);
    t.validate().map_err(|e| e.to_string())?;
    Ok(t)
}

fn sim_config(args: &famous::cli::Args) -> Result<SimConfig, String> {
    let mut cfg = match args.get_or("device", "u55c") {
        "u55c" => SimConfig::u55c(),
        "u200" => SimConfig::u200(),
        other => return Err(format!("unknown device '{other}'")),
    };
    let ts = args.get_usize("tile-size")?.unwrap_or(64);
    if ts != cfg.build.tile_size {
        cfg.build.tile_size = ts;
        cfg.build.max_topology.tile_size = ts;
    }
    cfg.double_buffer = args.flag("double-buffer");
    Ok(cfg)
}

fn make_accel(args: &famous::cli::Args, cfg: SimConfig) -> anyhow::Result<FamousAccelerator> {
    if args.flag("sim-datapath") {
        Ok(FamousAccelerator::with_sim_datapath(cfg))
    } else {
        FamousAccelerator::with_pjrt(cfg, args.get_or("artifacts", "artifacts"))
    }
}

fn cmd_run(args: &famous::cli::Args) -> anyhow::Result<()> {
    let cfg = sim_config(args).map_err(anyhow::Error::msg)?;
    let ts = cfg.build.tile_size;
    let topo = parse_topology(args.get_or("topology", "64,768,8"), ts)
        .map_err(anyhow::Error::msg)?;
    let mut accel = make_accel(args, cfg)?;
    let inputs = MhaInputs::generate(&topo);
    let report = accel.run(&topo, &inputs)?;
    println!("topology      : {topo}");
    println!("backend       : {}", accel.backend_name());
    println!("latency       : {:.3} ms ({} cycles)", report.latency_ms, report.cycles);
    println!("compute-only  : {:.3} ms", report.compute_only_ms(accel.config.build.clock_hz));
    println!("GOPS (paper)  : {:.0}", report.gops);
    println!("GOPS (attn)   : {:.0}", report.gops_attention_only);
    let res = accel.resources();
    let u = accel.utilization();
    println!(
        "build         : DSP {} ({:.0}%)  BRAM18k {} ({:.0}%)  LUT {} ({:.0}%)  FF {} ({:.0}%)",
        res.dsp, u.dsp_pct, res.bram18k, u.bram_pct, res.lut, u.lut_pct, res.ff, u.ff_pct
    );
    println!("output[0..4]  : {:?}", &report.output[..4.min(report.output.len())]);
    Ok(())
}

fn cmd_serve(args: &famous::cli::Args) -> anyhow::Result<()> {
    let cfg = sim_config(args).map_err(anyhow::Error::msg)?;
    let n: usize = args.get_usize("requests").map_err(anyhow::Error::msg)?.unwrap_or(32);
    let ts = cfg.build.tile_size;
    // Workload: topologies from a model descriptor, or the paper's mix.
    let topos: Vec<Topology> = match args.get("model") {
        Some(path) if !path.is_empty() => {
            let desc = ModelDescriptor::from_file(path)?;
            vec![desc.topology(ts)?]
        }
        _ => vec![
            Topology::new(64, 768, 8, ts),
            Topology::new(32, 768, 8, ts),
            Topology::new(64, 512, 8, ts),
        ],
    };
    let use_sim = args.flag("sim-datapath");
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let cfg2 = cfg.clone();
    let srv = Server::start(
        move || {
            let accel = if use_sim {
                FamousAccelerator::with_sim_datapath(cfg2)
            } else {
                FamousAccelerator::with_pjrt(cfg2, &artifacts).expect("load artifacts")
            };
            Coordinator::new(
                accel,
                SchedulerConfig {
                    max_batch: 16,
                    policy: BatchPolicy::GroupByTopology,
                    fairness_window: 64,
                },
            )
        },
        ServerConfig::default(),
    );
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for i in 0..n {
        let h = srv.handle();
        let topo = topos[i % topos.len()].clone();
        joins.push(std::thread::spawn(move || {
            let inputs = MhaInputs::generate(&topo);
            h.call_blocking(Request::new(i as u64, topo, inputs))
        }));
    }
    let mut ok = 0;
    for j in joins {
        if j.join().unwrap().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = srv.shutdown();
    println!("served {ok}/{n} requests in {wall:.2}s wall ({:.1} req/s)", ok as f64 / wall);
    println!(
        "batches {}  reconfigurations {}  fabric p50 {:.3} ms  p99 {:.3} ms",
        stats.batches,
        stats.reconfigurations,
        stats.fabric_latency.percentile(50.0),
        stats.fabric_latency.percentile(99.0)
    );
    println!(
        "program cache: {} hits / {} timing sims ({:.0}% hit); modeled batch makespan {:.2} ms",
        stats.program_cache_hits,
        stats.timing_sims,
        stats.program_cache_hit_rate() * 100.0,
        stats.batch_makespan_ms
    );
    Ok(())
}

fn cmd_cluster(args: &famous::cli::Args) -> anyhow::Result<()> {
    let mut devices = parse_fleet(args.get_or("fleet", "u55c:2,u200:2"))?;
    let n: usize = args.get_usize("requests").map_err(anyhow::Error::msg)?.unwrap_or(32);
    if apply_seu(args, &mut devices)? {
        let name = &devices.last().unwrap().name;
        println!("SEU plan active on {name} (ABFT detection + reroute engaged)");
    }
    if args.flag("des") {
        return cmd_cluster_des(args, devices, n);
    }
    if args.flag("qos") {
        return cmd_cluster_qos(args, devices, n);
    }
    // The paper's flexibility mix, fleet-scale: BERT-base shapes at two
    // sequence lengths, a U200-friendly h=6 shape, and BERT-large —
    // whose d_model 1024 no single build admits, so it head-shards.
    let workload = vec![
        Topology::new(64, 768, 8, 64),
        Topology::new(32, 768, 8, 64),
        Topology::new(64, 768, 6, 64),
        Topology::new(64, 1024, 16, 64),
    ];
    let cluster = Cluster::start(
        devices,
        &WorkloadProfile::uniform(&workload),
        ClusterConfig::default(),
    )?;
    println!("fleet of {} devices; {} requests over {} topologies", cluster.device_count(), n, workload.len());
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for i in 0..n {
        let h = cluster.handle();
        let topo = workload[i % workload.len()].clone();
        joins.push(std::thread::spawn(move || {
            let inputs = MhaInputs::generate(&topo);
            h.call(Request::new(i as u64, topo, inputs))
        }));
    }
    let mut ok = 0;
    for j in joins {
        if j.join().unwrap().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let fleet = cluster.shutdown();
    print!("{}", fleet.render());
    println!("served {ok}/{n} in {wall:.2}s wall ({:.1} req/s)", ok as f64 / wall);
    Ok(())
}

/// `cluster --qos`: open-loop seeded arrivals with priority classes and
/// deadlines, EDF+slack serving, SLO-annotated fleet report.
fn cmd_cluster_qos(
    args: &famous::cli::Args,
    devices: Vec<DeviceSpec>,
    n: usize,
) -> anyhow::Result<()> {
    let rho = args.get_f64("load").map_err(anyhow::Error::msg)?.unwrap_or(0.9);
    let seed = args.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(7) as u64;
    // Single-device-servable shapes only: the QoS backlog model tracks
    // whole-device completions (sharded halves route per half).
    let mix: Vec<(Topology, f64)> = vec![
        (Topology::new(64, 768, 8, 64), 3.0),
        (Topology::new(32, 768, 8, 64), 2.0),
        (Topology::new(64, 512, 8, 64), 1.0),
    ];
    let rate_hz = rate_for_utilization(&devices, &mix, rho);
    // The shared bursty preset (MMPP at rho, 4x/8x/12x deadline
    // budgets); --arrivals poisson swaps in a flat process at the same
    // offered rate.
    let mut lg_config = LoadGenConfig::bursty_preset(&devices, mix.clone(), rho, seed);
    match args.get_or("arrivals", "bursty") {
        "bursty" => {}
        "poisson" => lg_config.process = ArrivalProcess::Poisson { rate_hz },
        other => anyhow::bail!("unknown arrival process '{other}' (poisson | bursty)"),
    }
    let arrivals = LoadGen::new(lg_config).generate_n(n);
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let cluster = Cluster::start(
        devices,
        &workload,
        ClusterConfig {
            scheduler: SchedulerConfig {
                policy: BatchPolicy::EdfWithinWindow,
                ..SchedulerConfig::default()
            },
            qos: QosPolicy::SlackEdf,
            ..ClusterConfig::default()
        },
    )?;
    println!(
        "QoS fleet of {} devices; {} {} arrivals at {:.0} req/s (rho {:.2}, seed {seed})",
        cluster.device_count(),
        n,
        args.get_or("arrivals", "bursty"),
        rate_hz,
        rho
    );
    let h = cluster.handle();
    let t0 = std::time::Instant::now();
    let (mut served, mut shed, mut saturated) = (0usize, 0usize, 0usize);
    for (i, a) in arrivals.iter().enumerate() {
        match h.call_qos(a.materialize(i as u64))? {
            QosOutcome::Served(_) => served += 1,
            QosOutcome::Shed(_) => shed += 1,
            QosOutcome::Saturated(_) => saturated += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let fleet = cluster.shutdown();
    print!("{}", fleet.render());
    println!("served {served}, shed {shed}, saturated {saturated} of {n} in {wall:.2}s wall");
    Ok(())
}

/// `cluster --des`: the same QoS fleet and seeded arrival stream as
/// `--qos`, but simulated in virtual time on the discrete-event mirror
/// (DESIGN.md §16) — no device threads, hour-scale traces in wall-clock
/// seconds, bit-reproducible under a fixed seed.
fn cmd_cluster_des(
    args: &famous::cli::Args,
    devices: Vec<DeviceSpec>,
    n: usize,
) -> anyhow::Result<()> {
    let rho = args.get_f64("load").map_err(anyhow::Error::msg)?.unwrap_or(0.9);
    let seed = args.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(7) as u64;
    // The same single-device-servable mix as `--qos`, so reports are
    // directly comparable between the threaded fleet and the simulator.
    let mix: Vec<(Topology, f64)> = vec![
        (Topology::new(64, 768, 8, 64), 3.0),
        (Topology::new(32, 768, 8, 64), 2.0),
        (Topology::new(64, 512, 8, 64), 1.0),
    ];
    let rate_hz = rate_for_utilization(&devices, &mix, rho);
    let mut lg_config = LoadGenConfig::bursty_preset(&devices, mix.clone(), rho, seed);
    match args.get_or("arrivals", "bursty") {
        "bursty" => {}
        "poisson" => lg_config.process = ArrivalProcess::Poisson { rate_hz },
        other => anyhow::bail!("unknown arrival process '{other}' (poisson | bursty)"),
    }
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let config = DesConfig {
        cluster: ClusterConfig {
            scheduler: SchedulerConfig {
                policy: BatchPolicy::EdfWithinWindow,
                ..SchedulerConfig::default()
            },
            qos: QosPolicy::SlackEdf,
            ..ClusterConfig::default()
        },
        fused_service: args.flag("fused-service"),
    };
    let mut sim = FleetSim::new(devices, &workload, config)?;
    println!(
        "DES fleet of {} devices; {} {} arrivals at {:.0} req/s (rho {:.2}, seed {seed}{})",
        sim.device_count(),
        n,
        args.get_or("arrivals", "bursty"),
        rate_hz,
        rho,
        if args.flag("fused-service") { ", fused service model" } else { "" },
    );
    let mut gen = LoadGen::new(lg_config);
    let report = sim.run(&mut gen, n);
    sim.seal_telemetry();
    print!("{}", report.render());
    Ok(())
}

/// `famous top`: drive a seeded QoS load through the fleet and render
/// the telemetry ring as a refreshing operator dashboard (DESIGN.md
/// §13).  `--derate` silently throttles the last device's fabric clock
/// so the default drain rule has something to catch; `--export` dumps
/// the sealed frame ring as JSONL for offline analysis.
fn cmd_top(args: &famous::cli::Args) -> anyhow::Result<()> {
    let mut devices = parse_fleet(args.get_or("fleet", "u55c:2,u200:2"))?;
    let n: usize = args.get_usize("requests").map_err(anyhow::Error::msg)?.unwrap_or(400);
    let rho = args.get_f64("load").map_err(anyhow::Error::msg)?.unwrap_or(0.9);
    let seed = args.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(7) as u64;
    let derate = args.get_f64("derate").map_err(anyhow::Error::msg)?.unwrap_or(1.0);
    if derate < 1.0 {
        let last = devices.len() - 1;
        devices[last] = devices[last].clone().with_silent_derate(derate);
    }
    let seu = apply_seu(args, &mut devices)?;
    let mix: Vec<(Topology, f64)> = vec![
        (Topology::new(64, 768, 8, 64), 3.0),
        (Topology::new(32, 768, 8, 64), 2.0),
        (Topology::new(64, 512, 8, 64), 1.0),
    ];
    let base = mean_service_ms(&devices, &mix);
    let mut window_ms = args.get_f64("window-ms").map_err(anyhow::Error::msg)?.unwrap_or(0.0);
    if window_ms <= 0.0 {
        window_ms = 12.0 * base;
    }
    let arrivals = LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix.clone(), rho, seed))
        .generate_n(n);
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let mut cluster = Cluster::start(
        devices,
        &workload,
        ClusterConfig {
            scheduler: SchedulerConfig {
                policy: BatchPolicy::EdfWithinWindow,
                ..SchedulerConfig::default()
            },
            qos: QosPolicy::SlackEdf,
            telemetry: TelemetryConfig {
                window_ms,
                grace_windows: 1,
                ring_capacity: 240,
            },
            ..ClusterConfig::default()
        },
    )?;
    // Default operator policy: drain a device whose windowed p99 sojourn
    // stays pathological, and tighten Normal admission once the fleet
    // starts shedding (sheds mean Low is already drowning).
    cluster.add_control_rule(ControlRule {
        name: "p99-sojourn-drain".to_string(),
        scope: RuleScope::PerDevice,
        signal: RuleSignal::SojournP99Ms,
        threshold: 6.0 * base,
        for_windows: 3,
        action: ControlAction::DrainDevice,
    });
    cluster.add_control_rule(ControlRule {
        name: "shed-tightens-normal".to_string(),
        scope: RuleScope::Fleet,
        signal: RuleSignal::ShedCount,
        threshold: 0.0,
        for_windows: 2,
        action: ControlAction::SetAdmissionMargin {
            priority: famous::coordinator::Priority::Normal,
            margin_ms: 0.0,
        },
    });
    if seu {
        // SEU policy pair (DESIGN.md §15): quarantine a device whose
        // windowed ABFT detection rate stays nonzero, then restore it
        // after it has sat drained through clean windows.
        cluster.add_control_rule(ControlRule {
            name: "integrity-quarantine".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::IntegrityErrorRate,
            threshold: 0.0,
            for_windows: 2,
            action: ControlAction::DrainDevice,
        });
        cluster.add_control_rule(ControlRule {
            name: "integrity-undrain".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::IntegrityErrorRate,
            threshold: 0.0,
            for_windows: 4,
            action: ControlAction::UndrainDevice,
        });
    }
    let names = cluster.device_names();
    let plain = args.flag("plain");
    println!(
        "famous top — {} devices, {} arrivals (rho {rho:.2}, seed {seed}), window {:.2} ms{}",
        names.len(),
        n,
        window_ms,
        if derate < 1.0 { format!(", last device derated to {derate:.2}x") } else { String::new() }
    );
    if seu {
        println!("SEU plan active on {} (quarantine + undrain rules armed)", names.last().unwrap());
    }
    let h = cluster.handle();
    let (mut served, mut shed, mut saturated) = (0usize, 0usize, 0usize);
    let mut painted = 0u64;
    for (i, a) in arrivals.iter().enumerate() {
        match h.call_qos(a.materialize(i as u64))? {
            QosOutcome::Served(_) => served += 1,
            QosOutcome::Shed(_) => shed += 1,
            QosOutcome::Saturated(_) => saturated += 1,
        }
        cluster.pump_control();
        let snap = cluster.telemetry();
        if snap.sealed.frames > painted {
            painted = snap.sealed.frames;
            if !plain {
                print!("\x1b[2J\x1b[H");
            }
            print!("{}", render_top(&snap.frames, &names, cluster.control_log()));
        }
    }
    cluster.seal_telemetry();
    cluster.pump_control();
    let snap = cluster.telemetry();
    if !plain {
        print!("\x1b[2J\x1b[H");
    }
    print!("{}", render_top(&snap.frames, &names, cluster.control_log()));
    let export = args.get_or("export", "");
    if !export.is_empty() {
        std::fs::write(export, snap.to_jsonl())?;
        println!("exported {} sealed frames to {export}", snap.frames.len());
    }
    let actions = cluster.control_log().len();
    let fleet = cluster.shutdown();
    print!("{}", fleet.render());
    println!(
        "served {served}, shed {shed}, saturated {saturated} of {n}; {actions} control action(s)"
    );
    Ok(())
}

fn cmd_table1(args: &famous::cli::Args) -> anyhow::Result<()> {
    let model = LatencyModel::default();
    let rm = ResourceModel::default();
    let mut t = Table::new(
        "Table I — runtime programmability (paper vs model)",
        &[
            "test", "SL", "d_model", "h", "TS", "dev", "paper ms", "ours ms", "resid",
            "paper GOPS", "ours GOPS",
        ],
    );
    for row in TABLE1 {
        if row.d_model % row.heads != 0 {
            t.row(vec![
                row.test.to_string(),
                row.seq_len.to_string(),
                row.d_model.to_string(),
                row.heads.to_string(),
                row.tile_size.to_string(),
                row.device.into(),
                fmt_f(row.latency_ms),
                "-".into(),
                "d%h != 0".into(),
                fmt_f(row.gops),
                "-".into(),
            ]);
            continue;
        }
        let topo = row.topology();
        let ours = model.predict(&topo).total_ms();
        let gops = famous::metrics::OpCount::paper_convention(&topo) / (ours * 1e-3);
        t.row(vec![
            row.test.to_string(),
            row.seq_len.to_string(),
            row.d_model.to_string(),
            row.heads.to_string(),
            row.tile_size.to_string(),
            row.device.into(),
            fmt_f(row.latency_ms),
            fmt_f(ours),
            format!("{:+.1}%", (ours - row.latency_ms) / row.latency_ms * 100.0),
            fmt_f(row.gops),
            fmt_f(gops),
        ]);
    }
    print!("{}", t.render());
    let _ = args;
    // Resource rows for the synthesized builds.
    let mut r = Table::new(
        "Table I resources (paper vs structural estimate)",
        &["build", "DSP paper", "DSP ours", "BRAM paper", "BRAM ours", "LUT paper", "LUT ours"],
    );
    for (label, topo, dsp, bram, lut) in [
        ("U55C TS=64", Topology::new(64, 768, 8, 64), 4157u64, 3148u64, 1_284_782u64),
        ("U55C TS=32", Topology::new(64, 768, 8, 32), 3636, 2636, 746_769),
        ("U55C TS=16", Topology::new(64, 768, 8, 16), 2996, 2380, 607_554),
        ("U200 TS=64", Topology::new(64, 768, 6, 64), 3306, 2740, 1_048_022),
    ] {
        let e = rm.estimate(&topo);
        r.row(vec![
            label.into(),
            dsp.to_string(),
            e.dsp.to_string(),
            bram.to_string(),
            e.bram18k.to_string(),
            lut.to_string(),
            e.lut.to_string(),
        ]);
    }
    print!("{}", r.render());
    Ok(())
}

fn cmd_resources(_args: &famous::cli::Args) -> anyhow::Result<()> {
    let rm = ResourceModel::default();
    let mut t = Table::new(
        "Max parallel heads per device (TS=64, d_model=768, SL=64)",
        &["device", "DSP", "BRAM18k", "LUT", "max heads"],
    );
    for dev in [
        Device::alveo_u55c(),
        Device::alveo_u200(),
        Device::vu9p(),
        Device::vu13p(),
        Device::alveo_u250(),
        Device::vu37p(),
    ] {
        let mh = rm.max_heads(&dev, 768, 64, 64);
        t.row(vec![
            dev.name.clone(),
            dev.dsp.to_string(),
            dev.bram18k.to_string(),
            dev.lut.to_string(),
            mh.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_info(args: &famous::cli::Args) -> anyhow::Result<()> {
    let rt = famous::runtime::Runtime::load(args.get_or("artifacts", "artifacts"))?;
    println!(
        "artifacts: {} entries (grid scale {})",
        rt.manifest.entries.len(),
        rt.manifest.grid_scale
    );
    for e in &rt.manifest.entries {
        println!(
            "  {:32} hlo={:36} golden={}",
            e.name,
            e.hlo,
            e.golden.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

fn cmd_trace(args: &famous::cli::Args) -> anyhow::Result<()> {
    let cfg = sim_config(args).map_err(anyhow::Error::msg)?;
    let ts = cfg.build.tile_size;
    let topo = parse_topology(args.get_or("topology", "64,768,8"), ts)
        .map_err(anyhow::Error::msg)?;
    let mut sim = famous::sim::Simulator::new(cfg);
    let r = sim.run_timing(&topo).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{}", r.trace.to_json().to_string());
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = parser();
    let args = match p.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("top") => cmd_top(&args),
        Some("table1") => cmd_table1(&args),
        Some("resources") => cmd_resources(&args),
        Some("info") => cmd_info(&args),
        Some("trace") => cmd_trace(&args),
        _ => {
            eprintln!("{}", p.usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
