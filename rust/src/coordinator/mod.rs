//! Layer-3 coordinator: the host/MicroBlaze control flow as a service.
//!
//! The paper's programmability story (Fig. 6): extract the topology from a
//! trained model, generate control words, program the accelerator, run —
//! no re-synthesis between applications.  The coordinator makes that an
//! operational serving loop:
//!
//! * [`model_desc`] — model descriptor → [`crate::config::Topology`] +
//!   control words (the `.pth`-interpreter step, sans PyTorch).
//! * [`scheduler`] — request queue + topology-grouping batcher: the
//!   accelerator pays one reprogramming per topology *switch*, so the
//!   scheduler greedily groups same-topology requests (bounded by a
//!   fairness window) to minimize switches.
//! * [`server`] — a threaded front-end: bounded ingress channel
//!   (backpressure), worker thread owning the accelerator, per-request
//!   response channels, and live stats snapshots for fleet observers.
//!
//! [`Coordinator`] is the synchronous core — directly testable, and what
//! the server thread drives.  Serving follows the accelerator's
//! program/execute split (DESIGN.md §9): each batch is programmed once
//! (topology-keyed cache, so repeat topologies run zero timing sims) and
//! executed whole through [`FamousAccelerator::run_batch`] — on the sim
//! datapath that fans requests out over a worker pool with one shared
//! set of prepared weight buffers.  A batch occupies the modeled fabric
//! for its *makespan* (max over the batch, all same-topology requests
//! being identical in timing), not the sum of its per-request latencies.

pub mod model_desc;
pub mod scheduler;
pub mod server;

pub use model_desc::ModelDescriptor;
pub use scheduler::{BatchPolicy, Priority, Request, Scheduler, SchedulerConfig};
pub use server::{Server, ServerConfig, ServerHandle, SubmitError};

use crate::accel::FamousAccelerator;
use crate::config::Topology;
use crate::metrics::LatencyStats;
use anyhow::Result;

/// ABFT integrity outcome of one served request (DESIGN.md §15).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IntegrityVerdict {
    /// Every projection of every head passed the checksum verify first
    /// try (also the verdict when integrity checks are off).
    #[default]
    Clean,
    /// The first execution failed the verify; the local scrub-retry
    /// (re-prepare from the pristine host copy) re-served it clean.
    Recovered,
    /// Still failing after the scrub-retry — the output must NOT be
    /// served; the router re-executes cross-device from
    /// [`Response::returned_inputs`].
    Corrupt,
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub topology: Topology,
    /// QoS class the request carried (echoed for per-class accounting).
    pub priority: Priority,
    pub output: Vec<f32>,
    /// Modeled fabric latency of the invocation that served this request.
    pub fabric_ms: f64,
    pub gops: f64,
    /// Whether serving this request required reprogramming the registers.
    pub reprogrammed: bool,
    /// ABFT integrity outcome; `Corrupt` means `output` is untrusted.
    pub verdict: IntegrityVerdict,
    /// The request operands, handed back when the verdict is `Corrupt`
    /// so the router can rebuild the request and re-execute it on
    /// another device (the `SubmitError::Busy` hand-back idiom).
    pub returned_inputs: Option<Box<crate::testdata::MhaInputs>>,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub served: u64,
    pub batches: u64,
    pub reconfigurations: u64,
    pub rejected: u64,
    pub fabric_latency: LatencyStats,
    /// Timing simulations actually run (program-cache misses).
    pub timing_sims: u64,
    /// Program requests served from the topology-keyed cache.
    pub program_cache_hits: u64,
    /// Modeled fabric occupancy: Σ per-batch makespan, where a batch's
    /// makespan is the max over its requests (a programmed same-topology
    /// batch streams through the fabric as one pipeline), not the sum.
    pub batch_makespan_ms: f64,
    /// Requests executed on the fused tile-streaming attention path
    /// (DESIGN.md §12) vs the materializing reference path.  Mirrored
    /// from the backend's dispatch attribution; zero for single-datapath
    /// engines (PJRT).
    pub fused_dispatches: u64,
    pub reference_dispatches: u64,
    /// Requests attributed per kernel tier (DESIGN.md §14, §17): the
    /// scalar oracle kernels, the AVX2 tier, the AVX2+int8-GEMM tier,
    /// and the end-to-end int8 attention tier.  Mirrored from the same
    /// backend counters; conserved against the path split
    /// (`scalar + simd + simd_int8 + simd_int8_attn == fused +
    /// reference`).
    pub scalar_tier_dispatches: u64,
    pub simd_tier_dispatches: u64,
    pub simd_int8_tier_dispatches: u64,
    pub simd_int8_attn_tier_dispatches: u64,
    /// The accelerator's ProgramCache contents at the last stats mirror,
    /// LRU-first (see [`crate::accel::ProgramCache::topologies`]).  Lets
    /// fleet observers — and the router's warm-set mirror tests — see
    /// exactly which topologies a device could replay without a timing
    /// sim.
    pub cached_topologies: Vec<Topology>,
    /// Requests whose first execution failed the ABFT checksum verify
    /// (detected corruptions, DESIGN.md §15).
    pub integrity_detected: u64,
    /// Detected requests the local scrub-retry re-served clean.
    pub integrity_recovered: u64,
    /// Detected requests still failing after the scrub-retry, escalated
    /// to the router as `Corrupt` (their outputs are never served).
    pub integrity_corrupt: u64,
}

impl CoordinatorStats {
    /// Fraction of program requests served without a timing sim.
    pub fn program_cache_hit_rate(&self) -> f64 {
        let total = self.program_cache_hits + self.timing_sims;
        if total == 0 {
            return 0.0;
        }
        self.program_cache_hits as f64 / total as f64
    }
}

/// The synchronous serving core: scheduler + accelerator.
pub struct Coordinator {
    pub accel: FamousAccelerator,
    pub scheduler: Scheduler,
    pub stats: CoordinatorStats,
    last_topology: Option<Topology>,
}

impl Coordinator {
    pub fn new(accel: FamousAccelerator, sched_config: SchedulerConfig) -> Self {
        Coordinator {
            accel,
            scheduler: Scheduler::new(sched_config),
            stats: CoordinatorStats::default(),
            last_topology: None,
        }
    }

    /// Enqueue a request (admission-checked against the synthesized
    /// build).  Rejected requests are counted and returned as Err.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if let Err(e) = self.accel.config.build.admits(&req.topology) {
            self.stats.rejected += 1;
            anyhow::bail!("rejected request {}: {e}", req.id);
        }
        self.scheduler.push(req);
        Ok(())
    }

    /// Serve the next batch (all same topology): program once, execute
    /// the whole batch through the accelerator's batched entry point.
    /// Returns the responses, or None if the queue is empty.
    pub fn serve_next_batch(&mut self) -> Result<Option<Vec<Response>>> {
        let Some(batch) = self.scheduler.next_batch() else { return Ok(None) };
        let topo = batch[0].topology.clone();
        let reprogrammed = self.last_topology.as_ref() != Some(&topo);
        if reprogrammed {
            self.stats.reconfigurations += 1;
            self.last_topology = Some(topo.clone());
        }
        let input_refs: Vec<&crate::testdata::MhaInputs> =
            batch.iter().map(|r| &r.inputs).collect();
        let reports = self.accel.run_batch(&topo, &input_refs);
        drop(input_refs);
        // Mirror the accelerator's program-phase counters before the
        // error check: a timing sim that ran ahead of a backend failure
        // must still be counted (the accel is owned exclusively by this
        // coordinator, so absolute copies are exact).
        self.mirror_accel_counters();
        let reports = reports?;
        // Per-request ABFT verdicts of the batch just executed, request
        // order (empty = no integrity layer = all clean).
        let verdicts = self.accel.last_integrity();
        let mut batch_makespan = 0.0f64;
        let mut responses = Vec::with_capacity(batch.len());
        for (idx, (req, mut report)) in batch.into_iter().zip(reports).enumerate() {
            let mut verdict = IntegrityVerdict::Clean;
            let mut returned_inputs = None;
            if verdicts.get(idx).copied().unwrap_or(false) {
                self.stats.integrity_detected += 1;
                // Local scrub: re-prepare the weights from the pristine
                // host copy and re-execute once.  A transient upset
                // re-draws at a fresh epoch and clears; a persistent
                // (stuck-at) fault survives and escalates.
                match self.accel.run(&req.topology, &req.inputs) {
                    Ok(clean)
                        if !self.accel.last_integrity().first().copied().unwrap_or(false) =>
                    {
                        report = clean;
                        verdict = IntegrityVerdict::Recovered;
                        self.stats.integrity_recovered += 1;
                    }
                    _ => {
                        verdict = IntegrityVerdict::Corrupt;
                        self.stats.integrity_corrupt += 1;
                        returned_inputs = Some(Box::new(req.inputs.clone()));
                    }
                }
            }
            self.stats.served += 1;
            self.stats.fabric_latency.record(report.latency_ms);
            batch_makespan = batch_makespan.max(report.latency_ms);
            responses.push(Response {
                id: req.id,
                topology: req.topology,
                priority: req.priority,
                output: report.output,
                fabric_ms: report.latency_ms,
                gops: report.gops,
                reprogrammed,
                verdict,
                returned_inputs,
            });
        }
        // Scrub-retries above ran through the accelerator again: refresh
        // the mirrored counters so they stay absolute.
        self.mirror_accel_counters();
        self.stats.batches += 1;
        self.stats.batch_makespan_ms += batch_makespan;
        Ok(Some(responses))
    }

    /// Mirror the accelerator's absolute counters into the stats (the
    /// accel is owned exclusively by this coordinator, so copies are
    /// exact).
    fn mirror_accel_counters(&mut self) {
        self.stats.timing_sims = self.accel.timing_sims_run;
        self.stats.program_cache_hits = self.accel.program_cache_hits;
        let paths = self.accel.path_counters();
        self.stats.fused_dispatches = paths.fused;
        self.stats.reference_dispatches = paths.reference;
        self.stats.scalar_tier_dispatches = paths.scalar;
        self.stats.simd_tier_dispatches = paths.simd;
        self.stats.simd_int8_tier_dispatches = paths.simd_int8;
        self.stats.simd_int8_attn_tier_dispatches = paths.simd_int8_attn;
        self.stats.cached_topologies = self.accel.programs.topologies();
    }

    /// Drain the whole queue, returning responses in completion order.
    pub fn serve_all(&mut self) -> Result<Vec<Response>> {
        let mut all = Vec::new();
        while let Some(mut batch) = self.serve_next_batch()? {
            all.append(&mut batch);
        }
        Ok(all)
    }

    pub fn queue_len(&self) -> usize {
        self.scheduler.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;
    use crate::testdata::MhaInputs;

    fn coordinator(policy: BatchPolicy) -> Coordinator {
        let accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
        Coordinator::new(
            accel,
            SchedulerConfig { max_batch: 8, policy, fairness_window: 64 },
        )
    }

    fn req(id: u64, topo: Topology) -> Request {
        let inputs = MhaInputs::generate(&topo);
        Request::new(id, topo, inputs)
    }

    #[test]
    fn serves_all_no_loss_no_dup() {
        let mut c = coordinator(BatchPolicy::GroupByTopology);
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        for i in 0..10 {
            let t = if i % 2 == 0 { t1.clone() } else { t2.clone() };
            c.submit(req(i, t)).unwrap();
        }
        let resp = c.serve_all().unwrap();
        assert_eq!(resp.len(), 10);
        let mut ids: Vec<u64> = resp.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(c.stats.served, 10);
    }

    #[test]
    fn grouping_minimizes_reconfigurations() {
        let mut grouped = coordinator(BatchPolicy::GroupByTopology);
        let mut fifo = coordinator(BatchPolicy::Fifo);
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        for i in 0..8 {
            let t = if i % 2 == 0 { t1.clone() } else { t2.clone() };
            grouped.submit(req(i, t.clone())).unwrap();
            fifo.submit(req(i, t)).unwrap();
        }
        grouped.serve_all().unwrap();
        fifo.serve_all().unwrap();
        // Interleaved stream: FIFO reprograms every batch, grouping twice.
        assert_eq!(grouped.stats.reconfigurations, 2);
        assert!(fifo.stats.reconfigurations > 2);
    }

    #[test]
    fn rejects_oversynthesized_requests() {
        let mut c = coordinator(BatchPolicy::GroupByTopology);
        let too_big = Topology::new(256, 768, 8, 64);
        assert!(c.submit(req(0, too_big)).is_err());
        assert_eq!(c.stats.rejected, 1);
        assert_eq!(c.queue_len(), 0);
    }

    #[test]
    fn stats_track_latency() {
        let mut c = coordinator(BatchPolicy::GroupByTopology);
        let t = Topology::new(64, 768, 8, 64);
        c.submit(req(1, t)).unwrap();
        c.serve_all().unwrap();
        assert_eq!(c.stats.fabric_latency.count(), 1);
        assert!((c.stats.fabric_latency.mean() - 0.94).abs() < 0.01);
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut c = coordinator(BatchPolicy::Fifo);
        assert!(c.serve_next_batch().unwrap().is_none());
    }

    #[test]
    fn batch_serving_programs_once_per_topology() {
        let mut c = coordinator(BatchPolicy::GroupByTopology);
        let t = Topology::new(32, 768, 8, 64);
        for i in 0..6 {
            c.submit(req(i, t.clone())).unwrap();
        }
        c.serve_all().unwrap();
        assert_eq!(c.stats.timing_sims, 1, "one program for the whole batch");
        assert_eq!(c.stats.batches, 1);
        // Batch occupies the fabric for its makespan (one invocation of a
        // same-topology batch), not the sum of per-request latencies.
        assert!((c.stats.batch_makespan_ms - c.stats.fabric_latency.mean()).abs() < 1e-12);
        assert!(c.stats.batch_makespan_ms < c.stats.fabric_latency.sum());
        // A second same-topology wave runs zero new timing sims.
        for i in 6..10 {
            c.submit(req(i, t.clone())).unwrap();
        }
        c.serve_all().unwrap();
        assert_eq!(c.stats.timing_sims, 1);
        assert!(c.stats.program_cache_hits >= 1);
        assert!(c.stats.program_cache_hit_rate() > 0.0);
    }
}
