//! Threaded serving front-end.
//!
//! One worker thread owns the [`Coordinator`] (and through it the PJRT
//! executables / simulator); clients submit through a bounded channel —
//! full queue = backpressure at the ingress, mirroring the paper's
//! host-side flow control — and receive their response over a dedicated
//! oneshot-style channel.

use super::{Coordinator, Response};
use crate::coordinator::scheduler::Request;
use crate::exec::{bounded, BoundedSender};
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Server tuning.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Ingress queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Collect up to this many pending submissions before serving a round.
    pub ingest_burst: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_capacity: 256, ingest_burst: 32 }
    }
}

enum Msg {
    Job(Request, mpsc::Sender<Result<Response, String>>),
    /// Live stats snapshot (answered after the current serving round, so
    /// the caller observes every job submitted before it).
    Stats(mpsc::Sender<super::CoordinatorStats>),
    Shutdown,
    /// Crash simulation ([`Server::kill`]): exit immediately, dropping
    /// queued work without a reply — as a dying process would.
    Die,
}

/// Why a non-blocking submission did not produce a response.
///
/// The cluster router needs to distinguish "this device is busy, try
/// another" (the request comes back untouched for re-dispatch) from
/// "this device processed and failed the request" (admission or engine
/// error — retrying elsewhere may still make sense, but the request is
/// gone).
#[derive(Debug)]
pub enum SubmitError {
    /// Ingress queue full or server gone: the request is handed back so
    /// the caller can re-route it without cloning the operands.
    Busy(Request),
    /// The server accepted the message but serving failed.
    Failed(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy(r) => write!(f, "device busy (backpressure) for request {}", r.id),
            SubmitError::Failed(e) => write!(f, "{e}"),
        }
    }
}

/// Client-side handle: submit requests, await responses.
pub struct ServerHandle {
    tx: BoundedSender<Msg>,
}

impl Clone for ServerHandle {
    fn clone(&self) -> Self {
        ServerHandle { tx: self.tx.clone() }
    }
}

impl ServerHandle {
    /// Submit and block until served.  Errors if the queue is full
    /// (backpressure surfaced to the caller) or the server is down.
    pub fn call(&self, req: Request) -> Result<Response> {
        self.try_call(req).map_err(|e| match e {
            SubmitError::Busy(_) => anyhow!("server queue full or shut down (backpressure)"),
            SubmitError::Failed(msg) => anyhow!(msg),
        })
    }

    /// Non-blocking submit that returns the request on backpressure so a
    /// router can re-dispatch it to another device (the cluster layer's
    /// failover path).  Blocks only while the request is being served.
    pub fn try_call(&self, req: Request) -> Result<Response, SubmitError> {
        let (rtx, rrx) = mpsc::channel();
        if let Err(msg) = self.tx.try_send(Msg::Job(req, rtx)) {
            let Msg::Job(req, _) = msg else { unreachable!("sent a Job") };
            return Err(SubmitError::Busy(req));
        }
        match rrx.recv() {
            Err(_) => Err(SubmitError::Failed("server dropped request".into())),
            Ok(Err(e)) => Err(SubmitError::Failed(e)),
            Ok(Ok(resp)) => Ok(resp),
        }
    }

    /// Requests currently waiting in the ingress queue (load signal for
    /// least-loaded routing).
    pub fn pending(&self) -> usize {
        self.tx.len()
    }

    /// Is the worker still serving?  False once it exited — whether by a
    /// clean shutdown or an engine failure (the worker owns the ingress
    /// receiver, so its exit closes the channel).  The cluster layer uses
    /// this to tell a crashed device from a live one at shutdown time.
    pub fn is_alive(&self) -> bool {
        !self.tx.is_closed()
    }

    /// Enqueue a stats-snapshot request without waiting for the reply.
    /// Lets a fleet observer fan the request out to every device first
    /// and then collect, so total latency is the slowest device's round
    /// rather than the sum — assuming ingress queues have space: the
    /// request shares the bounded job channel, so a saturated device
    /// blocks the send until a slot frees.
    pub fn request_stats(&self) -> Result<mpsc::Receiver<super::CoordinatorStats>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Stats(rtx)).map_err(|_| anyhow!("server shut down"))?;
        Ok(rrx)
    }

    /// Live (pre-shutdown) snapshot of the coordinator's serving stats.
    /// Blocks until the worker finishes its current round.
    pub fn stats(&self) -> Result<super::CoordinatorStats> {
        self.request_stats()?.recv().map_err(|_| anyhow!("server dropped stats request"))
    }

    /// Blocking submit (waits for queue space instead of failing).
    pub fn call_blocking(&self, req: Request) -> Result<Response> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Job(req, rtx))
            .map_err(|_| anyhow!("server shut down"))?;
        rrx.recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }
}

/// The running server.
pub struct Server {
    handle: ServerHandle,
    worker: Option<JoinHandle<super::CoordinatorStats>>,
}

impl Server {
    /// Start the worker thread.  The coordinator (whose PJRT client is not
    /// `Send`) is constructed *on* the worker thread via `factory`; final
    /// stats come back from `shutdown()`.
    pub fn start(
        factory: impl FnOnce() -> Coordinator + Send + 'static,
        config: ServerConfig,
    ) -> Self {
        let (tx, rx) = bounded::<Msg>(config.queue_capacity);
        let worker = std::thread::Builder::new()
            .name("famous-coordinator".into())
            .spawn(move || {
                let mut coordinator = factory();
                let mut replies: Vec<(u64, mpsc::Sender<Result<Response, String>>)> = Vec::new();
                'outer: loop {
                    // Block for one message, then opportunistically drain a
                    // burst so the scheduler sees a window to batch over.
                    let first = match rx.recv() {
                        Some(m) => m,
                        None => break,
                    };
                    let mut msgs = vec![first];
                    msgs.extend(rx.drain_up_to(config.ingest_burst));
                    let mut shutdown = false;
                    let mut stats_waiters: Vec<mpsc::Sender<super::CoordinatorStats>> = Vec::new();
                    for m in msgs {
                        match m {
                            Msg::Shutdown => shutdown = true,
                            Msg::Die => {
                                // Abandon queued work and pending replies:
                                // clients observe a dropped channel, the
                                // router a closed ingress (it fails over).
                                return coordinator.stats.clone();
                            }
                            Msg::Stats(reply) => stats_waiters.push(reply),
                            Msg::Job(req, reply) => {
                                let id = req.id;
                                match coordinator.submit(req) {
                                    Ok(()) => replies.push((id, reply)),
                                    Err(e) => {
                                        let _ = reply.send(Err(e.to_string()));
                                    }
                                }
                            }
                        }
                    }
                    // Serve everything queued.
                    loop {
                        match coordinator.serve_next_batch() {
                            Ok(Some(responses)) => {
                                for resp in responses {
                                    if let Some(pos) =
                                        replies.iter().position(|(id, _)| *id == resp.id)
                                    {
                                        let (_, reply) = replies.swap_remove(pos);
                                        let _ = reply.send(Ok(resp));
                                    }
                                }
                            }
                            Ok(None) => break,
                            Err(e) => {
                                // Engine failure: fail all waiters, stop.
                                for (_, reply) in replies.drain(..) {
                                    let _ = reply.send(Err(format!("engine: {e}")));
                                }
                                break 'outer;
                            }
                        }
                    }
                    // Stats snapshots reflect the round just served.
                    for reply in stats_waiters {
                        let _ = reply.send(coordinator.stats.clone());
                    }
                    if shutdown {
                        break;
                    }
                }
                coordinator.stats.clone()
            })
            .expect("spawn coordinator worker");
        Server { handle: ServerHandle { tx }, worker: Some(worker) }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Stop the worker and collect the final serving statistics.
    pub fn shutdown(mut self) -> super::CoordinatorStats {
        let _ = self.handle.tx.send(Msg::Shutdown);
        self.worker.take().expect("not yet shut down").join().expect("worker panicked")
    }

    /// Kill the worker as a crash would: no drain, no final stats — any
    /// queued request is dropped without a reply.  Chaos hook for the
    /// cluster soak suite (`Cluster::fail_device`).
    pub fn kill(mut self) {
        let _ = self.handle.tx.send(Msg::Die);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::FamousAccelerator;
    use crate::config::Topology;
    use crate::coordinator::{BatchPolicy, SchedulerConfig};
    use crate::sim::SimConfig;
    use crate::testdata::MhaInputs;

    fn server() -> Server {
        Server::start(
            || {
                let accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
                Coordinator::new(
                    accel,
                    SchedulerConfig {
                        max_batch: 8,
                        policy: BatchPolicy::GroupByTopology,
                        fairness_window: 64,
                    },
                )
            },
            ServerConfig::default(),
        )
    }

    fn req(id: u64, sl: usize) -> Request {
        let topo = Topology::new(sl, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        Request::new(id, topo, inputs)
    }

    #[test]
    fn serves_single_request() {
        let srv = server();
        let resp = srv.handle().call(req(1, 64)).unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.output.len(), 64 * 768);
        assert!((resp.fabric_ms - 0.94).abs() < 0.01);
        let stats = srv.shutdown();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn serves_concurrent_clients() {
        let srv = server();
        let mut joins = Vec::new();
        for i in 0..6 {
            let h = srv.handle();
            joins.push(std::thread::spawn(move || {
                let sl = if i % 2 == 0 { 64 } else { 32 };
                h.call_blocking(req(i, sl)).unwrap()
            }));
        }
        let responses: Vec<Response> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(responses.len(), 6);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        let stats = srv.shutdown();
        assert_eq!(stats.served, 6);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn rejects_inadmissible_request() {
        let srv = server();
        let err = srv.handle().call(req(9, 512)).unwrap_err(); // SL 512 > max 128
        assert!(err.to_string().contains("rejected"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn try_call_serves_and_reports_failures() {
        let srv = server();
        assert_eq!(srv.handle().pending(), 0);
        let resp = srv.handle().try_call(req(1, 64)).unwrap();
        assert_eq!(resp.id, 1);
        // Inadmissible topology: the request is consumed, not bounced.
        match srv.handle().try_call(req(2, 512)) {
            Err(SubmitError::Failed(e)) => assert!(e.contains("rejected"), "{e}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        srv.shutdown();
    }

    #[test]
    fn live_stats_snapshot_mid_run() {
        let srv = server();
        srv.handle().call(req(1, 64)).unwrap();
        let snap = srv.handle().stats().unwrap();
        assert_eq!(snap.served, 1);
        assert_eq!(snap.timing_sims, 1);
        srv.handle().call(req(2, 64)).unwrap();
        let snap2 = srv.handle().stats().unwrap();
        assert_eq!(snap2.served, 2);
        assert_eq!(snap2.timing_sims, 1, "repeat topology hits the program cache");
        assert!(snap2.program_cache_hits >= 1);
        let final_stats = srv.shutdown();
        assert_eq!(final_stats.served, 2);
    }

    #[test]
    fn handle_reports_liveness() {
        let srv = server();
        let h = srv.handle();
        assert!(h.is_alive());
        srv.handle().call(req(1, 64)).unwrap();
        assert!(h.is_alive(), "serving does not close the ingress");
        srv.shutdown();
        assert!(!h.is_alive(), "worker exit closes the ingress");
    }

    #[test]
    fn kill_closes_ingress_without_stats() {
        let srv = server();
        srv.handle().call(req(1, 64)).unwrap();
        let h = srv.handle();
        srv.kill();
        assert!(!h.is_alive(), "killed worker must close the ingress");
        // Subsequent submissions bounce (the router's failover signal).
        match h.try_call(req(2, 64)) {
            Err(SubmitError::Busy(r)) => assert_eq!(r.id, 2),
            other => panic!("expected Busy bounce off a dead ingress, got {other:?}"),
        }
    }

    #[test]
    fn shutdown_returns_stats() {
        let srv = server();
        srv.handle().call(req(1, 64)).unwrap();
        srv.handle().call(req(2, 64)).unwrap();
        let stats = srv.shutdown();
        assert_eq!(stats.served, 2);
        assert!(stats.fabric_latency.mean() > 0.0);
    }
}
