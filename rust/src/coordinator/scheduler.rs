//! Request queue + batching policy.
//!
//! Reprogramming the accelerator's registers between topologies is cheap
//! but not free (one µB control sequence ≈ the analytical model's C0),
//! and more importantly each *switch* flushes the weight tiles staged in
//! BRAM.  The scheduler therefore groups same-topology requests into
//! batches, bounded by `max_batch` and by a fairness window so a steady
//! stream of one topology cannot starve others indefinitely.
//!
//! With QoS serving (DESIGN.md §11) requests additionally carry a
//! [`Priority`] class and an optional deadline on the serving layer's
//! *virtual clock* (modeled milliseconds, like every latency in this
//! repository).  [`BatchPolicy::EdfWithinWindow`] anchors each batch on
//! the most urgent request inside the fairness window — priority class
//! first, earliest deadline within a class — while keeping both the
//! topology-grouping and the bounded-reordering guarantees: nothing
//! beyond the window ever jumps the line, and an aging counter forces
//! the queue head to anchor a batch after at most `fairness_window`
//! consecutive pass-overs, so sustained urgent load degrades to FIFO
//! instead of starving best-effort traffic.

use crate::config::Topology;
use crate::testdata::MhaInputs;
use std::collections::VecDeque;

/// Request QoS class.  Declaration order is scheduling order (`High`
/// ranks before `Normal` before `Low` under the derived `Ord`);
/// [`Priority::index`] is the per-class slot in the fleet's SLO arrays.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-critical traffic; never shed.
    High,
    /// The default class for callers that do not speak QoS.
    #[default]
    Normal,
    /// Background traffic; may be shed when provably late.
    Low,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    /// Dense index for per-class metric arrays.
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub topology: Topology,
    pub inputs: MhaInputs,
    /// QoS class: scheduling weight; `Low` may be shed when provably
    /// late (cluster router, DESIGN.md §11).
    pub priority: Priority,
    /// Arrival time on the serving layer's virtual clock, in modeled
    /// ms (0 for closed-loop callers that do not track arrivals).
    pub arrival_ms: f64,
    /// Absolute deadline on the same clock; `None` = best effort.
    pub deadline_ms: Option<f64>,
}

impl Request {
    /// A best-effort request: `Normal` priority, no deadline, virtual
    /// arrival at t = 0.
    pub fn new(id: u64, topology: Topology, inputs: MhaInputs) -> Self {
        Request {
            id,
            topology,
            inputs,
            priority: Priority::Normal,
            arrival_ms: 0.0,
            deadline_ms: None,
        }
    }

    /// Attach QoS metadata (builder style).
    pub fn with_qos(
        mut self,
        priority: Priority,
        arrival_ms: f64,
        deadline_ms: Option<f64>,
    ) -> Self {
        self.priority = priority;
        self.arrival_ms = arrival_ms;
        self.deadline_ms = deadline_ms;
        self
    }

    /// Urgency ordering used by [`BatchPolicy::EdfWithinWindow`]:
    /// priority class first, then earliest deadline within a class (no
    /// deadline sorts last); queue position breaks remaining ties.
    pub fn edf_before(&self, other: &Request) -> bool {
        if self.priority != other.priority {
            return self.priority < other.priority;
        }
        self.deadline_ms.unwrap_or(f64::INFINITY) < other.deadline_ms.unwrap_or(f64::INFINITY)
    }
}

/// Batch formation policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Strict arrival order; a batch ends when the topology changes.
    Fifo,
    /// Pull all queued requests matching the head's topology (up to
    /// max_batch), skipping over others — minimizes reconfigurations.
    GroupByTopology,
    /// Earliest-deadline-first within the fairness window: each batch
    /// anchors on the most urgent request among the first
    /// `fairness_window` queue positions (priority class, then
    /// deadline), then groups same-topology requests exactly like
    /// `GroupByTopology`.  The queue head is force-anchored after
    /// `fairness_window` consecutive pass-overs (no starvation).
    EdfWithinWindow,
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub max_batch: usize,
    pub policy: BatchPolicy,
    /// GroupByTopology looks at most this far past the head for matches
    /// (fairness: bounded reordering).
    pub fairness_window: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 16, policy: BatchPolicy::GroupByTopology, fairness_window: 128 }
    }
}

/// The queue.
pub struct Scheduler {
    pub config: SchedulerConfig,
    queue: VecDeque<Request>,
    /// EDF aging: the head id when the last batch formed, and how many
    /// consecutive batches it has been passed over as anchor.
    last_head: Option<u64>,
    head_skips: usize,
}

impl Scheduler {
    pub fn new(config: SchedulerConfig) -> Self {
        assert!(config.max_batch > 0);
        // A zero window would skip even the queue head: GroupByTopology
        // could then return an empty batch and serving would never
        // progress.  Window ≥ 1 guarantees the head is always served.
        assert!(config.fairness_window > 0, "fairness_window must be ≥ 1");
        Scheduler { config, queue: VecDeque::new(), last_head: None, head_skips: 0 }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The request currently at the queue head (next to age out).
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Form the next batch (non-empty, all same topology), or None.
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        let head = self.queue.front()?.topology.clone();
        let batch = match self.config.policy {
            BatchPolicy::Fifo => {
                let mut batch = Vec::new();
                while batch.len() < self.config.max_batch {
                    match self.queue.front() {
                        Some(r) if r.topology == head => {
                            batch.push(self.queue.pop_front().unwrap())
                        }
                        _ => break,
                    }
                }
                batch
            }
            BatchPolicy::GroupByTopology => self.pull_group(&head, None),
            BatchPolicy::EdfWithinWindow => {
                let anchor = self.edf_anchor();
                let topo = self.queue[anchor].topology.clone();
                self.pull_group(&topo, Some(anchor))
            }
        };
        debug_assert!(!batch.is_empty());
        Some(batch)
    }

    /// Pick the EDF anchor position within the fairness window, with
    /// aging: once the same head request has been passed over
    /// `fairness_window` consecutive times it anchors the next batch
    /// unconditionally, so bounded reordering degrades to FIFO under
    /// sustained urgent load instead of starving the head.
    fn edf_anchor(&mut self) -> usize {
        let head_id = self.queue.front().map(|r| r.id);
        if self.last_head != head_id {
            self.last_head = head_id;
            self.head_skips = 0;
        }
        let window = self.config.fairness_window.min(self.queue.len());
        let mut anchor = 0;
        if self.head_skips < self.config.fairness_window {
            for i in 1..window {
                if self.queue[i].edf_before(&self.queue[anchor]) {
                    anchor = i;
                }
            }
        }
        if anchor == 0 {
            self.head_skips = 0;
        } else {
            self.head_skips += 1;
        }
        anchor
    }

    /// Pull up to `max_batch` requests matching `topo` from the first
    /// `fairness_window` queue positions, preserving queue order.
    /// `must_take` (a queue index whose topology is `topo`) is always
    /// included: when the position-ordered matches would fill the batch
    /// before reaching it, it takes the final slot.
    fn pull_group(&mut self, topo: &Topology, must_take: Option<usize>) -> Vec<Request> {
        let window = self.config.fairness_window.min(self.queue.len());
        let mut take: Vec<usize> = (0..window)
            .filter(|&i| self.queue[i].topology == *topo)
            .take(self.config.max_batch)
            .collect();
        if let Some(m) = must_take {
            if !take.contains(&m) {
                take.pop();
                take.push(m);
            }
        }
        let mut batch = Vec::with_capacity(take.len());
        let old = std::mem::take(&mut self.queue);
        for (i, r) in old.into_iter().enumerate() {
            if take.contains(&i) {
                batch.push(r);
            } else {
                self.queue.push_back(r);
            }
        }
        batch
    }

    /// Number of topology switches an oracle batcher would need for the
    /// current queue contents (lower bound = distinct topologies).
    pub fn distinct_topologies(&self) -> usize {
        let mut seen: Vec<&Topology> = Vec::new();
        for r in &self.queue {
            if !seen.contains(&&r.topology) {
                seen.push(&r.topology);
            }
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::{run, Gen};

    fn req(id: u64, sl: usize) -> Request {
        let topo = Topology::new(sl, 768, 8, 64);
        // Tiny placeholder operands: scheduler tests don't execute them.
        Request::new(
            id,
            topo,
            MhaInputs {
                x: vec![],
                wq: vec![],
                wk: vec![],
                wv: vec![],
                bq: vec![],
                bk: vec![],
                bv: vec![],
            },
        )
    }

    fn qreq(id: u64, sl: usize, priority: Priority, deadline_ms: Option<f64>) -> Request {
        req(id, sl).with_qos(priority, 0.0, deadline_ms)
    }

    #[test]
    fn fifo_batches_stop_at_topology_change() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 10,
            policy: BatchPolicy::Fifo,
            fairness_window: 100,
        });
        for (i, sl) in [64, 64, 32, 64].iter().enumerate() {
            s.push(req(i as u64, *sl));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = s.next_batch().unwrap();
        assert_eq!(b2[0].id, 2);
    }

    #[test]
    fn grouping_pulls_matching_from_window() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        for (i, sl) in [64, 32, 64, 32, 64].iter().enumerate() {
            s.push(req(i as u64, *sl));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        let b2 = s.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert!(s.is_empty());
    }

    #[test]
    fn max_batch_respected() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            ..SchedulerConfig::default()
        });
        for i in 0..5 {
            s.push(req(i, 64));
        }
        assert_eq!(s.next_batch().unwrap().len(), 2);
        assert_eq!(s.next_batch().unwrap().len(), 2);
        assert_eq!(s.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn fairness_window_bounds_reordering() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 100,
            policy: BatchPolicy::GroupByTopology,
            fairness_window: 2,
        });
        // Head topology 64; matching request at position 3 is outside the
        // window and must NOT be pulled forward.
        for (i, sl) in [64, 32, 32, 64].iter().enumerate() {
            s.push(req(i as u64, *sl));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn edf_anchors_most_urgent_within_window() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 8,
            policy: BatchPolicy::EdfWithinWindow,
            fairness_window: 8,
        });
        // Normal best-effort head, a Low with a deadline, then a High
        // with a later deadline: priority class dominates, so the High
        // anchors the first batch despite its looser deadline.
        s.push(qreq(0, 64, Priority::Normal, None));
        s.push(qreq(1, 32, Priority::Low, Some(50.0)));
        s.push(qreq(2, 16, Priority::High, Some(200.0)));
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        // Within a class, the earlier deadline wins.
        let mut s2 = Scheduler::new(SchedulerConfig {
            max_batch: 8,
            policy: BatchPolicy::EdfWithinWindow,
            fairness_window: 8,
        });
        s2.push(qreq(0, 64, Priority::Low, Some(100.0)));
        s2.push(qreq(1, 32, Priority::Low, Some(10.0)));
        let b2 = s2.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn edf_groups_anchor_topology_in_queue_order() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 8,
            policy: BatchPolicy::EdfWithinWindow,
            fairness_window: 8,
        });
        s.push(qreq(0, 64, Priority::Normal, None));
        s.push(qreq(1, 32, Priority::Normal, Some(500.0)));
        s.push(qreq(2, 32, Priority::High, Some(40.0)));
        s.push(qreq(3, 64, Priority::Normal, None));
        // Anchor is id 2 (High); the batch is every SL=32 request in the
        // window, in queue order.
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        let b2 = s.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
    }

    #[test]
    fn edf_urgent_beyond_window_cannot_jump() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 8,
            policy: BatchPolicy::EdfWithinWindow,
            fairness_window: 2,
        });
        s.push(qreq(0, 64, Priority::Normal, None));
        s.push(qreq(1, 64, Priority::Normal, None));
        s.push(qreq(2, 32, Priority::High, Some(1.0))); // outside window
        let b1 = s.next_batch().unwrap();
        assert!(b1.iter().all(|r| r.id < 2), "{:?}", b1.iter().map(|r| r.id).collect::<Vec<_>>());
    }

    #[test]
    fn edf_aging_forces_head_after_window_skips() {
        let window = 3usize;
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 8,
            policy: BatchPolicy::EdfWithinWindow,
            fairness_window: window,
        });
        // A Low head under a sustained stream of urgent High requests
        // (two fresh ones after every batch, keeping the window full of
        // higher-urgency work): served within fairness_window+1 batches.
        s.push(qreq(0, 64, Priority::Low, None));
        let mut next_id = 1u64;
        for _ in 0..2 {
            s.push(qreq(next_id, 32, Priority::High, Some(next_id as f64)));
            next_id += 1;
        }
        let mut batches_until_head = 0;
        loop {
            let batch = s.next_batch().unwrap();
            batches_until_head += 1;
            if batch.iter().any(|r| r.id == 0) {
                break;
            }
            for _ in 0..2 {
                s.push(qreq(next_id, 32, Priority::High, Some(next_id as f64)));
                next_id += 1;
            }
            assert!(batches_until_head < 20, "head starved");
        }
        assert!(
            batches_until_head <= window + 1,
            "head served after {batches_until_head} batches (window {window})"
        );
    }

    #[test]
    fn edf_anchor_beyond_max_batch_matches_still_served() {
        // Four SL=32 requests ahead of the urgent one, max_batch 2: the
        // urgent anchor must claim the final slot rather than drop out.
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            policy: BatchPolicy::EdfWithinWindow,
            fairness_window: 8,
        });
        for i in 0..4 {
            s.push(qreq(i, 32, Priority::Normal, None));
        }
        s.push(qreq(4, 32, Priority::High, Some(5.0)));
        let b1 = s.next_batch().unwrap();
        assert!(b1.iter().any(|r| r.id == 4), "{:?}", b1.iter().map(|r| r.id).collect::<Vec<_>>());
        assert_eq!(b1.len(), 2);
    }

    // ---- property tests (proptest_lite) ---------------------------------

    fn any_policy(g: &mut Gen) -> BatchPolicy {
        *g.pick(&[BatchPolicy::Fifo, BatchPolicy::GroupByTopology, BatchPolicy::EdfWithinWindow])
    }

    fn any_qos(g: &mut Gen, req: Request) -> Request {
        let priority = *g.pick(&Priority::ALL);
        let deadline = if g.bool() { Some(g.f64_in(0.0, 100.0)) } else { None };
        req.with_qos(priority, 0.0, deadline)
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        run("scheduler conservation", 200, |g: &mut Gen| {
            let n = g.usize_in(0, 40);
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 8),
                policy: any_policy(g),
                fairness_window: g.usize_in(1, 16),
            });
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                let r = req(i as u64, *g.pick(&sls));
                s.push(any_qos(g, r));
            }
            let mut seen = Vec::new();
            while let Some(batch) = s.next_batch() {
                assert!(batch.len() <= s.config.max_batch);
                // homogeneity
                assert!(batch.iter().all(|r| r.topology == batch[0].topology));
                seen.extend(batch.iter().map(|r| r.id));
            }
            seen.sort();
            assert_eq!(seen, (0..n as u64).collect::<Vec<_>>());
        });
    }

    #[test]
    fn prop_edf_reorders_only_within_fairness_window() {
        // Bounded reordering holds for EDF exactly as for grouping: a
        // batch may only contain ids from the first `window` positions.
        run("edf bounded reordering", 300, |g: &mut Gen| {
            let window = g.usize_in(1, 12);
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 10),
                policy: BatchPolicy::EdfWithinWindow,
                fairness_window: window,
            });
            let n = g.usize_in(1, 40);
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                let r = req(i as u64, *g.pick(&sls));
                s.push(any_qos(g, r));
            }
            let mut front: Vec<u64> = (0..n as u64).collect();
            while let Some(batch) = s.next_batch() {
                let eligible = &front[..window.min(front.len())];
                for r in &batch {
                    assert!(
                        eligible.contains(&r.id),
                        "id {} pulled from beyond window {window}: {eligible:?}",
                        r.id
                    );
                }
                front.retain(|id| !batch.iter().any(|r| r.id == *id));
            }
            assert!(front.is_empty());
        });
    }

    #[test]
    fn prop_edf_head_wait_bounded_under_sustained_urgent_load() {
        // Starvation-freedom for EDF (DESIGN.md §11): however urgent the
        // traffic arriving behind it, the queue head is passed over at
        // most `fairness_window` consecutive batches before the aging
        // counter forces it to anchor.
        run("edf head wait bound", 150, |g: &mut Gen| {
            let window = g.usize_in(1, 8);
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 6),
                policy: BatchPolicy::EdfWithinWindow,
                fairness_window: window,
            });
            let sls = [16usize, 32, 64];
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 10) {
                let r = req(next_id, *g.pick(&sls));
                s.push(any_qos(g, r));
                next_id += 1;
            }
            let mut head = s.peek().map(|r| r.id);
            let mut wait = 0usize;
            let mut rounds = 0;
            while let Some(batch) = s.next_batch() {
                if batch.iter().any(|r| Some(r.id) == head) {
                    wait = 0;
                } else {
                    wait += 1;
                }
                assert!(wait <= window, "head {head:?} waited {wait} > window {window}");
                // Sustained load: urgent arrivals keep landing while the
                // backlog drains (stop feeding after 30 rounds so the
                // case terminates).
                rounds += 1;
                if rounds < 30 {
                    for _ in 0..g.usize_in(0, 2) {
                        s.push(
                            req(next_id, *g.pick(&sls))
                                .with_qos(Priority::High, 0.0, Some(g.f64_in(0.0, 5.0))),
                        );
                        next_id += 1;
                    }
                }
                let new_head = s.peek().map(|r| r.id);
                if new_head != head {
                    head = new_head;
                    wait = 0;
                }
            }
        });
    }

    #[test]
    fn prop_grouping_reorders_only_within_fairness_window() {
        // Bounded reordering (DESIGN.md §7): GroupByTopology may pull a
        // request forward only from the first `fairness_window` queue
        // positions — nothing beyond the window ever jumps the line.
        run("bounded reordering", 300, |g: &mut Gen| {
            let window = g.usize_in(1, 12);
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 10),
                policy: BatchPolicy::GroupByTopology,
                fairness_window: window,
            });
            let n = g.usize_in(1, 40);
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                s.push(req(i as u64, *g.pick(&sls)));
            }
            // Queue ids are 0..n in order; a batch may only contain ids
            // from the first min(window, len) positions.
            let mut front: Vec<u64> = (0..n as u64).collect();
            while let Some(batch) = s.next_batch() {
                let eligible = &front[..window.min(front.len())];
                for r in &batch {
                    assert!(
                        eligible.contains(&r.id),
                        "id {} pulled from beyond window {window}: {eligible:?}",
                        r.id
                    );
                }
                front.retain(|id| !batch.iter().any(|r| r.id == *id));
            }
            assert!(front.is_empty());
        });
    }

    #[test]
    fn prop_head_always_served_no_starvation() {
        // Starvation-freedom (DESIGN.md §7): the queue head is in every
        // batch, so every request is served within (queue position)
        // batches of reaching the front, whatever topology mix follows.
        run("head always served", 300, |g: &mut Gen| {
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 8),
                policy: if g.bool() { BatchPolicy::Fifo } else { BatchPolicy::GroupByTopology },
                fairness_window: g.usize_in(1, 16),
            });
            let n = g.usize_in(1, 40);
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                s.push(req(i as u64, *g.pick(&sls)));
            }
            let mut expected_head: Vec<u64> = (0..n as u64).collect();
            let mut batches = 0;
            while let Some(batch) = s.next_batch() {
                batches += 1;
                assert!(
                    batch.iter().any(|r| r.id == expected_head[0]),
                    "head {} skipped by batch {:?}",
                    expected_head[0],
                    batch.iter().map(|r| r.id).collect::<Vec<_>>()
                );
                expected_head.retain(|id| !batch.iter().any(|r| r.id == *id));
            }
            assert!(expected_head.is_empty(), "requests starved: {expected_head:?}");
            assert!(batches <= n, "more batches than requests");
        });
    }

    #[test]
    fn prop_grouping_never_worse_than_fifo() {
        run("grouping switch count", 100, |g: &mut Gen| {
            let n = g.usize_in(1, 30);
            let sls = [32usize, 64];
            let stream: Vec<usize> = (0..n).map(|_| *g.pick(&sls)).collect();
            let count_switches = |policy: BatchPolicy| {
                let mut s = Scheduler::new(SchedulerConfig {
                    max_batch: 1000,
                    policy,
                    fairness_window: 1000,
                });
                for (i, sl) in stream.iter().enumerate() {
                    s.push(req(i as u64, *sl));
                }
                let mut switches = 0;
                let mut last: Option<Topology> = None;
                while let Some(b) = s.next_batch() {
                    if last.as_ref() != Some(&b[0].topology) {
                        switches += 1;
                        last = Some(b[0].topology.clone());
                    }
                }
                switches
            };
            assert!(
                count_switches(BatchPolicy::GroupByTopology) <= count_switches(BatchPolicy::Fifo)
            );
        });
    }
}
