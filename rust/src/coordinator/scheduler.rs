//! Request queue + batching policy.
//!
//! Reprogramming the accelerator's registers between topologies is cheap
//! but not free (one µB control sequence ≈ the analytical model's C0),
//! and more importantly each *switch* flushes the weight tiles staged in
//! BRAM.  The scheduler therefore groups same-topology requests into
//! batches, bounded by `max_batch` and by a fairness window so a steady
//! stream of one topology cannot starve others indefinitely.

use crate::config::Topology;
use crate::testdata::MhaInputs;
use std::collections::VecDeque;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub topology: Topology,
    pub inputs: MhaInputs,
}

/// Batch formation policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Strict arrival order; a batch ends when the topology changes.
    Fifo,
    /// Pull all queued requests matching the head's topology (up to
    /// max_batch), skipping over others — minimizes reconfigurations.
    GroupByTopology,
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub max_batch: usize,
    pub policy: BatchPolicy,
    /// GroupByTopology looks at most this far past the head for matches
    /// (fairness: bounded reordering).
    pub fairness_window: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 16, policy: BatchPolicy::GroupByTopology, fairness_window: 128 }
    }
}

/// The queue.
pub struct Scheduler {
    pub config: SchedulerConfig,
    queue: VecDeque<Request>,
}

impl Scheduler {
    pub fn new(config: SchedulerConfig) -> Self {
        assert!(config.max_batch > 0);
        // A zero window would skip even the queue head: GroupByTopology
        // could then return an empty batch and serving would never
        // progress.  Window ≥ 1 guarantees the head is always served.
        assert!(config.fairness_window > 0, "fairness_window must be ≥ 1");
        Scheduler { config, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Form the next batch (non-empty, all same topology), or None.
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        let head = self.queue.front()?.topology.clone();
        let mut batch = Vec::new();
        match self.config.policy {
            BatchPolicy::Fifo => {
                while batch.len() < self.config.max_batch {
                    match self.queue.front() {
                        Some(r) if r.topology == head => {
                            batch.push(self.queue.pop_front().unwrap())
                        }
                        _ => break,
                    }
                }
            }
            BatchPolicy::GroupByTopology => {
                let window = self.config.fairness_window.min(self.queue.len());
                let mut kept = VecDeque::with_capacity(self.queue.len());
                let mut scanned = 0;
                while let Some(r) = self.queue.pop_front() {
                    if batch.len() < self.config.max_batch
                        && scanned < window
                        && r.topology == head
                    {
                        batch.push(r);
                    } else {
                        kept.push_back(r);
                    }
                    scanned += 1;
                }
                self.queue = kept;
            }
        }
        debug_assert!(!batch.is_empty());
        Some(batch)
    }

    /// Number of topology switches an oracle batcher would need for the
    /// current queue contents (lower bound = distinct topologies).
    pub fn distinct_topologies(&self) -> usize {
        let mut seen: Vec<&Topology> = Vec::new();
        for r in &self.queue {
            if !seen.contains(&&r.topology) {
                seen.push(&r.topology);
            }
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::{run, Gen};

    fn req(id: u64, sl: usize) -> Request {
        let topo = Topology::new(sl, 768, 8, 64);
        // Tiny placeholder operands: scheduler tests don't execute them.
        Request {
            id,
            topology: topo,
            inputs: MhaInputs {
                x: vec![],
                wq: vec![],
                wk: vec![],
                wv: vec![],
                bq: vec![],
                bk: vec![],
                bv: vec![],
            },
        }
    }

    #[test]
    fn fifo_batches_stop_at_topology_change() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 10,
            policy: BatchPolicy::Fifo,
            fairness_window: 100,
        });
        for (i, sl) in [64, 64, 32, 64].iter().enumerate() {
            s.push(req(i as u64, *sl));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = s.next_batch().unwrap();
        assert_eq!(b2[0].id, 2);
    }

    #[test]
    fn grouping_pulls_matching_from_window() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        for (i, sl) in [64, 32, 64, 32, 64].iter().enumerate() {
            s.push(req(i as u64, *sl));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        let b2 = s.next_batch().unwrap();
        assert_eq!(b2.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert!(s.is_empty());
    }

    #[test]
    fn max_batch_respected() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 2,
            ..SchedulerConfig::default()
        });
        for i in 0..5 {
            s.push(req(i, 64));
        }
        assert_eq!(s.next_batch().unwrap().len(), 2);
        assert_eq!(s.next_batch().unwrap().len(), 2);
        assert_eq!(s.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn fairness_window_bounds_reordering() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 100,
            policy: BatchPolicy::GroupByTopology,
            fairness_window: 2,
        });
        // Head topology 64; matching request at position 3 is outside the
        // window and must NOT be pulled forward.
        for (i, sl) in [64, 32, 32, 64].iter().enumerate() {
            s.push(req(i as u64, *sl));
        }
        let b1 = s.next_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
    }

    // ---- property tests (proptest_lite) ---------------------------------

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        run("scheduler conservation", 200, |g: &mut Gen| {
            let n = g.usize_in(0, 40);
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 8),
                policy: if g.bool() { BatchPolicy::Fifo } else { BatchPolicy::GroupByTopology },
                fairness_window: g.usize_in(1, 16),
            });
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                s.push(req(i as u64, *g.pick(&sls)));
            }
            let mut seen = Vec::new();
            while let Some(batch) = s.next_batch() {
                assert!(batch.len() <= s.config.max_batch);
                // homogeneity
                assert!(batch.iter().all(|r| r.topology == batch[0].topology));
                seen.extend(batch.iter().map(|r| r.id));
            }
            seen.sort();
            assert_eq!(seen, (0..n as u64).collect::<Vec<_>>());
        });
    }

    #[test]
    fn prop_grouping_reorders_only_within_fairness_window() {
        // Bounded reordering (DESIGN.md §7): GroupByTopology may pull a
        // request forward only from the first `fairness_window` queue
        // positions — nothing beyond the window ever jumps the line.
        run("bounded reordering", 300, |g: &mut Gen| {
            let window = g.usize_in(1, 12);
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 10),
                policy: BatchPolicy::GroupByTopology,
                fairness_window: window,
            });
            let n = g.usize_in(1, 40);
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                s.push(req(i as u64, *g.pick(&sls)));
            }
            // Queue ids are 0..n in order; a batch may only contain ids
            // from the first min(window, len) positions.
            let mut front: Vec<u64> = (0..n as u64).collect();
            while let Some(batch) = s.next_batch() {
                let eligible = &front[..window.min(front.len())];
                for r in &batch {
                    assert!(
                        eligible.contains(&r.id),
                        "id {} pulled from beyond window {window}: {eligible:?}",
                        r.id
                    );
                }
                front.retain(|id| !batch.iter().any(|r| r.id == *id));
            }
            assert!(front.is_empty());
        });
    }

    #[test]
    fn prop_head_always_served_no_starvation() {
        // Starvation-freedom (DESIGN.md §7): the queue head is in every
        // batch, so every request is served within (queue position)
        // batches of reaching the front, whatever topology mix follows.
        run("head always served", 300, |g: &mut Gen| {
            let mut s = Scheduler::new(SchedulerConfig {
                max_batch: g.usize_in(1, 8),
                policy: if g.bool() { BatchPolicy::Fifo } else { BatchPolicy::GroupByTopology },
                fairness_window: g.usize_in(1, 16),
            });
            let n = g.usize_in(1, 40);
            let sls = [16usize, 32, 64, 128];
            for i in 0..n {
                s.push(req(i as u64, *g.pick(&sls)));
            }
            let mut expected_head: Vec<u64> = (0..n as u64).collect();
            let mut batches = 0;
            while let Some(batch) = s.next_batch() {
                batches += 1;
                assert!(
                    batch.iter().any(|r| r.id == expected_head[0]),
                    "head {} skipped by batch {:?}",
                    expected_head[0],
                    batch.iter().map(|r| r.id).collect::<Vec<_>>()
                );
                expected_head.retain(|id| !batch.iter().any(|r| r.id == *id));
            }
            assert!(expected_head.is_empty(), "requests starved: {expected_head:?}");
            assert!(batches <= n, "more batches than requests");
        });
    }

    #[test]
    fn prop_grouping_never_worse_than_fifo() {
        run("grouping switch count", 100, |g: &mut Gen| {
            let n = g.usize_in(1, 30);
            let sls = [32usize, 64];
            let stream: Vec<usize> = (0..n).map(|_| *g.pick(&sls)).collect();
            let count_switches = |policy: BatchPolicy| {
                let mut s = Scheduler::new(SchedulerConfig {
                    max_batch: 1000,
                    policy,
                    fairness_window: 1000,
                });
                for (i, sl) in stream.iter().enumerate() {
                    s.push(req(i as u64, *sl));
                }
                let mut switches = 0;
                let mut last: Option<Topology> = None;
                while let Some(b) = s.next_batch() {
                    if last.as_ref() != Some(&b[0].topology) {
                        switches += 1;
                        last = Some(b[0].topology.clone());
                    }
                }
                switches
            };
            assert!(
                count_switches(BatchPolicy::GroupByTopology) <= count_switches(BatchPolicy::Fifo)
            );
        });
    }
}
