//! Model descriptor extraction — the Fig. 6 flow without PyTorch.
//!
//! The paper saves trained models as `.pth`, runs a python interpreter to
//! extract (heads, embedding dim, sequence length), and feeds those to the
//! host software which generates control words.  Our equivalent carries
//! the extracted topology as a small JSON descriptor (what that
//! interpreter would emit), so the rust host performs the same
//! descriptor → control-words step with no python on the request path.

use crate::config::Topology;
use crate::jsonlite::{parse, Json};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Extracted model metadata (the output of the paper's interpreter step).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDescriptor {
    pub name: String,
    /// Source framework tag (informational; e.g. "pytorch").
    pub framework: String,
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    /// Encoder layer count (used by the encoder-extension example).
    pub layers: usize,
}

impl ModelDescriptor {
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = parse(text).map_err(|e| anyhow!("{e}"))?;
        let get = |k: &str| {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("descriptor missing '{k}'"))
        };
        Ok(ModelDescriptor {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            framework: j
                .get("framework")
                .and_then(Json::as_str)
                .unwrap_or("pytorch")
                .to_string(),
            seq_len: get("seq_len")?,
            d_model: get("d_model")?,
            heads: get("heads")?,
            layers: j.get("layers").and_then(Json::as_usize).unwrap_or(1),
        })
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow!("reading {}: {e}", path.as_ref().display()))?;
        Self::from_json_str(&text)
    }

    /// The topology this model needs on a build with tile size `ts`.
    pub fn topology(&self, ts: usize) -> Result<Topology> {
        let t = Topology::new(self.seq_len, self.d_model, self.heads, ts);
        t.validate().map_err(|e| anyhow!("{e}"))?;
        Ok(t)
    }

    /// Well-known descriptors matching the paper's evaluation workloads.
    pub fn bert_variant() -> Self {
        // "a variant of BERT": d_model 768, 8 heads, SL 64 (Section VI).
        ModelDescriptor {
            name: "bert-variant".into(),
            framework: "pytorch".into(),
            seq_len: 64,
            d_model: 768,
            heads: 8,
            layers: 12,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::from(self.name.as_str())),
            ("framework", Json::from(self.framework.as_str())),
            ("seq_len", Json::from(self.seq_len as f64)),
            ("d_model", Json::from(self.d_model as f64)),
            ("heads", Json::from(self.heads as f64)),
            ("layers", Json::from(self.layers as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_descriptor() {
        let d = ModelDescriptor::from_json_str(
            r#"{"name": "tiny", "seq_len": 32, "d_model": 256, "heads": 4, "layers": 2}"#,
        )
        .unwrap();
        assert_eq!(d.heads, 4);
        assert_eq!(d.layers, 2);
        assert_eq!(d.topology(64).unwrap(), Topology::new(32, 256, 4, 64));
    }

    #[test]
    fn missing_field_errors() {
        assert!(ModelDescriptor::from_json_str(r#"{"seq_len": 32}"#).is_err());
    }

    #[test]
    fn invalid_topology_errors() {
        let d = ModelDescriptor::from_json_str(
            r#"{"seq_len": 32, "d_model": 250, "heads": 4}"#,
        )
        .unwrap();
        assert!(d.topology(64).is_err()); // 250 % 4 != 0
    }

    #[test]
    fn roundtrip() {
        let d = ModelDescriptor::bert_variant();
        let d2 = ModelDescriptor::from_json_str(&d.to_json().to_string()).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn bert_variant_fits_u55c_build() {
        let d = ModelDescriptor::bert_variant();
        let t = d.topology(64).unwrap();
        assert!(crate::config::AcceleratorConfig::u55c_ts64().admits(&t).is_ok());
    }
}
