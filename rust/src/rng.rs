//! Deterministic PRNG substrate (no `rand` crate in the offline image).
//!
//! `XorShift64` drives the property-test harness and workload generators;
//! `Lcg32` is the *cross-language* generator shared with
//! `python/compile/testdata.py` (see [`crate::testdata`]).

/// xorshift64* — fast, well-distributed, deterministic.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self { state: seed ^ 0x9E37_79B9_7F4A_7C15 | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`; `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Rejection sampling to kill modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform float in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// The 32-bit LCG shared with the python testdata generator
/// (`state = 1664525*state + 1013904223 mod 2^32`).
#[derive(Clone, Debug)]
pub struct Lcg32 {
    state: u64,
}

impl Lcg32 {
    /// Matches `testdata._lcg_vals`: seed is scrambled by the Knuth
    /// multiplier mod 2^32 (0 maps to 1).
    pub fn from_test_seed(seed: u64) -> Self {
        let s = seed.wrapping_mul(2_654_435_761) % (1 << 32);
        Self { state: if s == 0 { 1 } else { s } }
    }

    pub fn next_state(&mut self) -> u64 {
        self.state = (1_664_525u64.wrapping_mul(self.state) + 1_013_904_223) % (1 << 32);
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xorshift_seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift64::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = XorShift64::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..500 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = XorShift64::new(3);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        let mean = acc / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn lcg_matches_python_pin() {
        // Mirrors python/tests/test_aot.py::test_testdata_lcg_is_stable.
        let mut lcg = Lcg32::from_test_seed(1);
        let vals: Vec<i64> = (0..8)
            .map(|_| ((lcg.next_state() >> 16) % 33) as i64 - 16)
            .collect();
        assert_eq!(vals, vec![-11, 4, 6, 11, -9, -10, 14, 15]);
    }
}
