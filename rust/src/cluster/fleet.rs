//! Fleet metrics: per-device serving stats rolled up to cluster level.
//!
//! Each device's [`crate::coordinator::CoordinatorStats`] is the ground
//! truth for what its coordinator did (served, batches,
//! reconfigurations, fabric latency samples).  The router contributes
//! what only it can see: completed client requests (a sharded request is
//! one client request but two device invocations), failover retries,
//! affinity hit rates, and the modeled GOP of all work dispatched.
//!
//! Throughput is *modeled*, like every latency in this repository: the
//! cluster's makespan is the busiest device's total fabric occupancy,
//! where a same-topology batch occupies its device for the batch's
//! makespan (max over the batch — one programmed pipeline), not the sum
//! of its per-request latencies.  `cluster_gops = Σ GOP / max_d Σ
//! batch_makespan(d)` — the steady-state rate an operator would see if
//! the fabric were the bottleneck.  Wall-clock rates (host threading,
//! channel overhead) are reported separately by the example/bench
//! harnesses.

use super::DeviceSpec;
use crate::coordinator::{CoordinatorStats, Priority};
use crate::fpga::resources::{ResourceModel, Utilization};
use crate::metrics::LatencyStats;
use crate::report::{fmt_f, Table};

/// Router-side counters (everything per-device stats cannot know).
#[derive(Clone, Debug, Default)]
pub struct RouterTotals {
    /// Client-visible requests completed (sharded counts once).
    pub completed: u64,
    /// Requests served via the two-device shard path.
    pub sharded: u64,
    /// Backpressure bounces to another device.
    pub retries: u64,
    /// Requests landing on their programmed/pinned device.
    pub affinity_hits: u64,
    pub affinity_misses: u64,
    /// Requests landing on a device that was *warm* for the topology
    /// (present in its ProgramCache) without being *hot* (currently
    /// programmed) — the routing delta contributed by the warm-set
    /// signal beyond plain hot affinity.
    pub warm_hits: u64,
    /// Requests no device (even sharded) could admit.
    pub rejected: u64,
    /// Requests bounced out under [`super::router::SaturationPolicy::Typed`]
    /// after exhausting the bounded-backoff retry budget (DESIGN.md §15).
    pub saturated: u64,
    /// ABFT checksum mismatches detected fleet-wide (each is one
    /// corrupted device invocation that was *not* served silently).
    pub integrity_detected: u64,
    /// Detections healed by a local scrub-retry on the same device
    /// (transient fault; re-prepare restored clean weights).
    pub integrity_recovered: u64,
    /// Corrupt responses healed by re-executing the request on a
    /// different device (persistent fault on the original).
    pub integrity_rerouted: u64,
    /// Corrupt responses the router could not heal (no spare device /
    /// retry budget exhausted) — surfaced to the caller flagged, never
    /// silently.
    pub integrity_failed: u64,
    /// Modeled GOP dispatched (paper op-counting convention, per
    /// sub-request — DESIGN.md §5).
    pub total_gop: f64,
    /// Per-priority SLO counters (QoS serving, DESIGN.md §11).
    pub slo: SloStats,
}

/// Per-priority SLO roll-up.  Latencies are modeled *sojourn* times on
/// the router's virtual clock — queue wait under the backlog model plus
/// modeled fabric service — and deadline verdicts compare that
/// completion estimate against the request's absolute deadline.  Like
/// every latency in this repository, these are modeled quantities:
/// deterministic for a fixed request trace, which is what lets the soak
/// suite assert exact reproducibility.
#[derive(Clone, Debug, Default)]
pub struct SloStats {
    /// Modeled sojourn (completion − arrival) per priority class,
    /// indexed by [`Priority::index`].
    pub sojourn: [LatencyStats; 3],
    /// Completed with the deadline met / missed, per class.
    pub met: [u64; 3],
    pub missed: [u64; 3],
    /// Completed requests that carried no deadline, per class.
    pub best_effort: [u64; 3],
    /// Shed at ingress (provably late under the backlog model; the
    /// router sheds only `Low`), per class.
    pub shed: [u64; 3],
}

impl SloStats {
    /// Record a completed request.  `missed` is `None` for best-effort
    /// traffic (no deadline), otherwise whether the deadline was missed.
    pub fn record_completion(&mut self, p: Priority, sojourn_ms: f64, missed: Option<bool>) {
        let i = p.index();
        self.sojourn[i].record(sojourn_ms);
        match missed {
            None => self.best_effort[i] += 1,
            Some(false) => self.met[i] += 1,
            Some(true) => self.missed[i] += 1,
        }
    }

    pub fn record_shed(&mut self, p: Priority) {
        self.shed[p.index()] += 1;
    }

    /// Requests of this class that carried a deadline (completed or
    /// shed).
    pub fn deadline_demand(&self, p: Priority) -> u64 {
        let i = p.index();
        self.met[i] + self.missed[i] + self.shed[i]
    }

    /// SLO violations for this class: completed late, or shed.
    pub fn violations(&self, p: Priority) -> u64 {
        let i = p.index();
        self.missed[i] + self.shed[i]
    }

    /// Deadline-miss rate for one class (violations / deadline demand).
    pub fn miss_rate(&self, p: Priority) -> f64 {
        let demand = self.deadline_demand(p);
        if demand == 0 {
            return 0.0;
        }
        self.violations(p) as f64 / demand as f64
    }

    /// Fleet-wide miss rate over every deadline-bearing request.
    pub fn overall_miss_rate(&self) -> f64 {
        let demand: u64 = Priority::ALL.iter().map(|&p| self.deadline_demand(p)).sum();
        if demand == 0 {
            return 0.0;
        }
        let violations: u64 = Priority::ALL.iter().map(|&p| self.violations(p)).sum();
        violations as f64 / demand as f64
    }

    pub fn total_shed(&self) -> u64 {
        self.shed.iter().sum()
    }

    pub fn total_missed(&self) -> u64 {
        self.missed.iter().sum()
    }

    /// Completed requests of this class (any deadline state).
    pub fn served(&self, p: Priority) -> u64 {
        self.sojourn[p.index()].count() as u64
    }

    /// Has any QoS-*signalled* traffic been recorded — a deadline, a
    /// shed, or a non-default priority class?  Gates the QoS block of
    /// the fleet report: plain best-effort `Normal` traffic (every
    /// pre-QoS caller) keeps the old report output, even though its
    /// sojourns are still collected.
    pub fn any(&self) -> bool {
        Priority::ALL.iter().any(|&p| self.deadline_demand(p) > 0)
            || self.served(Priority::High) > 0
            || self.served(Priority::Low) > 0
    }
}

/// Liveness of one device at report time.  Distinguishes "zeroed stats
/// because the device sat idle" from "zeroed stats because its worker is
/// gone" — the two rendered identically before the health flag existed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeviceHealth {
    /// Server running and answering stats requests.
    #[default]
    Live,
    /// Drained deliberately (maintenance / elasticity); its stats are the
    /// final pre-drain roll-up.
    Stopped,
    /// Worker died or stopped answering: zeroed stats mean *unknown*,
    /// not idle.
    Failed,
}

impl DeviceHealth {
    pub fn label(self) -> &'static str {
        match self {
            DeviceHealth::Live => "live",
            DeviceHealth::Stopped => "stopped",
            DeviceHealth::Failed => "FAILED",
        }
    }
}

/// One device's roll-up.
#[derive(Clone, Debug)]
pub struct DeviceReport {
    pub id: usize,
    pub name: String,
    /// FPGA part, e.g. `XCU55C-FSVH2892-2L-E`.
    pub part: String,
    pub stats: CoordinatorStats,
    /// Static post-synthesis resource utilization of the build.
    pub utilization: Utilization,
    /// Liveness at report time (see [`DeviceHealth`]).
    pub health: DeviceHealth,
}

impl DeviceReport {
    /// Total modeled fabric occupancy of this device: Σ per-batch
    /// makespan.  A programmed same-topology batch streams through the
    /// fabric as one pipeline, so it occupies the device for the max of
    /// its per-request latencies (all identical at one topology), not
    /// their sum — see DESIGN.md §9.
    pub fn busy_ms(&self) -> f64 {
        self.stats.batch_makespan_ms
    }

    /// Fraction of program phases this device served from its
    /// topology-keyed cache (no timing sim).
    pub fn program_cache_hit_rate(&self) -> f64 {
        self.stats.program_cache_hit_rate()
    }
}

/// The cluster-level report.
#[derive(Clone, Debug, Default)]
pub struct FleetStats {
    pub devices: Vec<DeviceReport>,
    /// All devices' fabric latency samples merged (cluster percentiles).
    pub fabric_latency: LatencyStats,
    pub totals: RouterTotals,
}

impl FleetStats {
    /// Build the report from per-device stats + router counters, every
    /// device presumed live (the pre-health-flag behavior).
    pub fn assemble(
        specs: &[DeviceSpec],
        coord: Vec<CoordinatorStats>,
        totals: RouterTotals,
    ) -> FleetStats {
        let health = vec![DeviceHealth::Live; specs.len()];
        Self::assemble_with_health(specs, coord, health, totals)
    }

    /// Build the report with explicit per-device health (what
    /// `Cluster::fleet_snapshot` observed when collecting the stats).
    pub fn assemble_with_health(
        specs: &[DeviceSpec],
        coord: Vec<CoordinatorStats>,
        health: Vec<DeviceHealth>,
        totals: RouterTotals,
    ) -> FleetStats {
        assert_eq!(specs.len(), coord.len());
        assert_eq!(specs.len(), health.len());
        let rm = ResourceModel::default();
        let mut fabric = LatencyStats::default();
        let devices = specs
            .iter()
            .zip(coord)
            .zip(health)
            .map(|((spec, stats), health)| {
                fabric.merge(&stats.fabric_latency);
                // Same synthesis-point convention as accel::resources():
                // resources are set by the synthesized maxima at SL=64.
                let mut synth = spec.sim.build.max_topology.clone();
                synth.seq_len = synth.seq_len.min(64);
                let utilization = rm.estimate(&synth).utilization(&spec.sim.build.device);
                DeviceReport {
                    id: spec.id,
                    name: spec.name.clone(),
                    part: spec.sim.build.device.part.clone(),
                    stats,
                    utilization,
                    health,
                }
            })
            .collect();
        FleetStats { devices, fabric_latency: fabric, totals }
    }

    /// Devices currently able to serve.
    pub fn live_devices(&self) -> usize {
        self.devices.iter().filter(|d| d.health == DeviceHealth::Live).count()
    }

    /// Devices whose stats cannot be trusted (worker crashed mid-run).
    pub fn failed_devices(&self) -> usize {
        self.devices.iter().filter(|d| d.health == DeviceHealth::Failed).count()
    }

    /// Device invocations served (≥ completed when requests shard).
    pub fn served(&self) -> u64 {
        self.devices.iter().map(|d| d.stats.served).sum()
    }

    pub fn reconfigurations(&self) -> u64 {
        self.devices.iter().map(|d| d.stats.reconfigurations).sum()
    }

    pub fn batches(&self) -> u64 {
        self.devices.iter().map(|d| d.stats.batches).sum()
    }

    /// Timing simulations run fleet-wide (program-cache misses).
    pub fn timing_sims(&self) -> u64 {
        self.devices.iter().map(|d| d.stats.timing_sims).sum()
    }

    /// Program phases served from a cache fleet-wide.
    pub fn program_cache_hits(&self) -> u64 {
        self.devices.iter().map(|d| d.stats.program_cache_hits).sum()
    }

    /// Fleet-wide program-cache hit rate.
    pub fn program_cache_hit_rate(&self) -> f64 {
        let total = self.program_cache_hits() + self.timing_sims();
        if total == 0 {
            return 0.0;
        }
        self.program_cache_hits() as f64 / total as f64
    }

    /// Reconfigurations per client-visible request.
    pub fn reconfigs_per_request(&self) -> f64 {
        self.reconfigurations() as f64 / (self.totals.completed.max(1)) as f64
    }

    /// Modeled cluster makespan: the busiest device's fabric occupancy.
    pub fn makespan_ms(&self) -> f64 {
        self.devices.iter().map(DeviceReport::busy_ms).fold(0.0, f64::max)
    }

    /// Modeled aggregate throughput at the fabric bottleneck.
    pub fn cluster_gops(&self) -> f64 {
        let ms = self.makespan_ms();
        if ms <= 0.0 {
            return 0.0;
        }
        self.totals.total_gop / (ms * 1e-3)
    }

    pub fn affinity_hit_rate(&self) -> f64 {
        let total = self.totals.affinity_hits + self.totals.affinity_misses;
        if total == 0 {
            return 0.0;
        }
        self.totals.affinity_hits as f64 / total as f64
    }

    /// Per-device share of the makespan (1.0 = the critical device).
    pub fn occupancy(&self, device: usize) -> f64 {
        let ms = self.makespan_ms();
        if ms <= 0.0 {
            return 0.0;
        }
        self.devices[device].busy_ms() / ms
    }

    /// Render the fleet report (the `cluster` subcommand / example
    /// output).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Fleet report — per device",
            &[
                "device", "part", "health", "served", "batches", "reconf", "sims", "cache %",
                "progs", "busy ms", "occ %", "LUT %", "BRAM %",
            ],
        );
        for d in &self.devices {
            t.row(vec![
                d.name.clone(),
                d.part.clone(),
                d.health.label().to_string(),
                d.stats.served.to_string(),
                d.stats.batches.to_string(),
                d.stats.reconfigurations.to_string(),
                d.stats.timing_sims.to_string(),
                format!("{:.0}", d.program_cache_hit_rate() * 100.0),
                d.stats.cached_topologies.len().to_string(),
                fmt_f(d.busy_ms()),
                format!("{:.0}", self.occupancy(d.id) * 100.0),
                format!("{:.0}", d.utilization.lut_pct),
                format!("{:.0}", d.utilization.bram_pct),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "cluster: {} requests ({} sharded, {} rejected), {} device invocations\n",
            self.totals.completed,
            self.totals.sharded,
            self.totals.rejected,
            self.served()
        ));
        out.push_str(&format!(
            "modeled GOPS {:.0} over makespan {:.2} ms (batch makespan = max-of-batch); \
             fabric p50 {:.3} ms p99 {:.3} ms\n",
            self.cluster_gops(),
            self.makespan_ms(),
            self.fabric_latency.percentile(50.0),
            self.fabric_latency.percentile(99.0)
        ));
        out.push_str(&format!(
            "program cache: {} hits / {} timing sims ({:.0}% hit rate)\n",
            self.program_cache_hits(),
            self.timing_sims(),
            self.program_cache_hit_rate() * 100.0
        ));
        if self.failed_devices() > 0 {
            out.push_str(&format!(
                "WARNING: {} device(s) FAILED — their zeroed stats are unknowns, not idleness\n",
                self.failed_devices()
            ));
        }
        out.push_str(&format!(
            "reconfigurations: {} total, {:.2} per request; affinity {:.0}% ({} hits / {} misses, \
             {} warm); {} retries\n",
            self.reconfigurations(),
            self.reconfigs_per_request(),
            self.affinity_hit_rate() * 100.0,
            self.totals.affinity_hits,
            self.totals.affinity_misses,
            self.totals.warm_hits,
            self.totals.retries
        ));
        if self.totals.integrity_detected > 0 || self.totals.saturated > 0 {
            out.push_str(&format!(
                "integrity: {} detected ({} scrubbed locally, {} rerouted, {} unhealed); \
                 {} saturated\n",
                self.totals.integrity_detected,
                self.totals.integrity_recovered,
                self.totals.integrity_rerouted,
                self.totals.integrity_failed,
                self.totals.saturated
            ));
            if self.totals.integrity_failed > 0 {
                out.push_str(&format!(
                    "WARNING: {} corrupt response(s) served flagged — no spare device could \
                     re-execute them\n",
                    self.totals.integrity_failed
                ));
            }
        }
        let slo = &self.totals.slo;
        if slo.any() {
            let mut q = Table::new(
                "QoS — per priority class (virtual-clock sojourn)",
                &["class", "served", "p50 ms", "p99 ms", "met", "missed", "shed", "miss %"],
            );
            for p in Priority::ALL {
                let i = p.index();
                q.row(vec![
                    p.label().to_string(),
                    slo.served(p).to_string(),
                    fmt_f(slo.sojourn[i].percentile(50.0)),
                    fmt_f(slo.sojourn[i].percentile(99.0)),
                    slo.met[i].to_string(),
                    slo.missed[i].to_string(),
                    slo.shed[i].to_string(),
                    format!("{:.1}", slo.miss_rate(p) * 100.0),
                ]);
            }
            out.push_str(&q.render());
            out.push_str(&format!(
                "deadline miss rate {:.1}% overall ({} missed + {} shed of {} with deadlines)\n",
                slo.overall_miss_rate() * 100.0,
                slo.total_missed(),
                slo.total_shed(),
                Priority::ALL.iter().map(|&p| slo.deadline_demand(p)).sum::<u64>()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(served: u64, reconf: u64, lat: &[f64]) -> CoordinatorStats {
        let mut s = CoordinatorStats {
            served,
            batches: served,
            reconfigurations: reconf,
            rejected: 0,
            fabric_latency: LatencyStats::default(),
            // One-request batches: each batch's makespan is its latency.
            timing_sims: reconf,
            program_cache_hits: served.saturating_sub(reconf),
            batch_makespan_ms: lat.iter().sum(),
            ..CoordinatorStats::default()
        };
        for &v in lat {
            s.fabric_latency.record(v);
        }
        s
    }

    fn two_device_fleet() -> FleetStats {
        let specs = vec![DeviceSpec::u55c(0), DeviceSpec::u200(1)];
        let coord = vec![stats(3, 1, &[1.0, 1.0, 2.0]), stats(2, 2, &[3.0, 0.5])];
        let totals = RouterTotals {
            completed: 5,
            sharded: 0,
            retries: 1,
            affinity_hits: 4,
            affinity_misses: 1,
            warm_hits: 1,
            rejected: 0,
            total_gop: 2.0,
            ..RouterTotals::default()
        };
        FleetStats::assemble(&specs, coord, totals)
    }

    #[test]
    fn aggregates_across_devices() {
        let f = two_device_fleet();
        assert_eq!(f.served(), 5);
        assert_eq!(f.reconfigurations(), 3);
        assert_eq!(f.fabric_latency.count(), 5);
        // Makespan = busiest device: device 0 is 4.0 ms, device 1 is 3.5.
        assert!((f.makespan_ms() - 4.0).abs() < 1e-12);
        // 2 GOP over 4 ms = 500 GOPS.
        assert!((f.cluster_gops() - 500.0).abs() < 1e-9);
        assert!((f.affinity_hit_rate() - 0.8).abs() < 1e-12);
        assert!((f.reconfigs_per_request() - 0.6).abs() < 1e-12);
        assert!((f.occupancy(0) - 1.0).abs() < 1e-12);
        assert!((f.occupancy(1) - 0.875).abs() < 1e-12);
    }

    #[test]
    fn static_utilization_matches_paper_builds() {
        let f = two_device_fleet();
        // U55C TS=64 build: ~98% LUT (Table I).
        assert!((f.devices[0].utilization.lut_pct - 98.0).abs() < 2.5);
        // U200 h=6 build: ~89% LUT.
        assert!(f.devices[1].utilization.lut_pct > 80.0);
    }

    #[test]
    fn render_mentions_key_lines() {
        let s = two_device_fleet().render();
        assert!(s.contains("Fleet report"));
        assert!(s.contains("u55c-0"));
        assert!(s.contains("modeled GOPS"));
        assert!(s.contains("affinity 80%"));
        assert!(s.contains("program cache"));
    }

    #[test]
    fn program_cache_rollup() {
        let f = two_device_fleet();
        assert_eq!(f.timing_sims(), 3);
        assert_eq!(f.program_cache_hits(), 2);
        assert!((f.program_cache_hit_rate() - 0.4).abs() < 1e-12);
        assert!((f.devices[0].program_cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(f.devices[1].program_cache_hit_rate(), 0.0);
    }

    #[test]
    fn health_flag_distinguishes_failed_from_idle() {
        let specs = vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1), DeviceSpec::u200(2)];
        // Device 1 is idle (zero stats, live); device 2 crashed (zero
        // stats, failed) — same numbers, different meaning.
        let coord = vec![
            stats(3, 1, &[1.0, 1.0, 2.0]),
            CoordinatorStats::default(),
            CoordinatorStats::default(),
        ];
        let health = vec![DeviceHealth::Live, DeviceHealth::Live, DeviceHealth::Failed];
        let f = FleetStats::assemble_with_health(&specs, coord, health, RouterTotals::default());
        assert_eq!(f.live_devices(), 2);
        assert_eq!(f.failed_devices(), 1);
        assert_eq!(f.devices[1].health, DeviceHealth::Live);
        assert_eq!(f.devices[2].health, DeviceHealth::Failed);
        let s = f.render();
        assert!(s.contains("health"), "{s}");
        assert!(s.contains("FAILED"), "{s}");
        assert!(s.contains("WARNING: 1 device(s) FAILED"), "{s}");
    }

    #[test]
    fn assemble_defaults_to_live() {
        let f = two_device_fleet();
        assert_eq!(f.live_devices(), 2);
        assert_eq!(f.failed_devices(), 0);
        assert!(f.devices.iter().all(|d| d.health == DeviceHealth::Live));
        assert!(!f.render().contains("WARNING"));
    }

    #[test]
    fn slo_stats_rates_and_demand() {
        let mut slo = SloStats::default();
        slo.record_completion(Priority::High, 1.0, Some(false));
        slo.record_completion(Priority::High, 3.0, Some(true));
        slo.record_completion(Priority::Normal, 2.0, None);
        slo.record_completion(Priority::Low, 9.0, Some(true));
        slo.record_shed(Priority::Low);
        assert_eq!(slo.deadline_demand(Priority::High), 2);
        assert_eq!(slo.violations(Priority::High), 1);
        assert!((slo.miss_rate(Priority::High) - 0.5).abs() < 1e-12);
        // Best-effort traffic counts toward served, not deadline demand.
        assert_eq!(slo.deadline_demand(Priority::Normal), 0);
        assert_eq!(slo.miss_rate(Priority::Normal), 0.0);
        assert_eq!(slo.served(Priority::Normal), 1);
        // Shed counts as demand and as a violation.
        assert_eq!(slo.deadline_demand(Priority::Low), 2);
        assert_eq!(slo.violations(Priority::Low), 2);
        assert_eq!(slo.total_shed(), 1);
        assert_eq!(slo.total_missed(), 2);
        // Overall: 3 violations over 4 deadline-bearing requests.
        assert!((slo.overall_miss_rate() - 0.75).abs() < 1e-12);
        assert!(slo.any());
        assert!(!SloStats::default().any());
    }

    #[test]
    fn render_includes_qos_block_only_with_traffic() {
        let mut f = two_device_fleet();
        assert!(!f.render().contains("QoS"), "no QoS traffic, no QoS block");
        f.totals.slo.record_completion(Priority::High, 1.5, Some(false));
        f.totals.slo.record_shed(Priority::Low);
        let r = f.render();
        assert!(r.contains("QoS"), "{r}");
        assert!(r.contains("high"), "{r}");
        assert!(r.contains("deadline miss rate"), "{r}");
    }

    #[test]
    fn render_integrity_line_only_when_detected() {
        let mut f = two_device_fleet();
        assert!(!f.render().contains("integrity"), "clean fleet hides the integrity line");
        f.totals.integrity_detected = 3;
        f.totals.integrity_recovered = 2;
        f.totals.integrity_rerouted = 1;
        let r = f.render();
        assert!(
            r.contains("integrity: 3 detected (2 scrubbed locally, 1 rerouted, 0 unhealed)"),
            "{r}"
        );
        assert!(!r.contains("WARNING"), "healed corruption is not a warning");
        f.totals.integrity_failed = 1;
        assert!(f.render().contains("WARNING: 1 corrupt response(s)"));
    }

    #[test]
    fn empty_fleet_is_safe() {
        let f = FleetStats::default();
        assert_eq!(f.cluster_gops(), 0.0);
        assert_eq!(f.makespan_ms(), 0.0);
        assert_eq!(f.affinity_hit_rate(), 0.0);
    }
}
