//! Head-group sharding of one MHA request across two devices.
//!
//! MHA heads are mutually independent after the QKV projections, so a
//! request can be split into two head groups, each served as a smaller
//! self-contained topology, with a host-side column concat at the end —
//! the classic tensor-parallel attention split, restricted to the shapes
//! the accelerator's `(SL, d_model, h)` register interface can express.
//!
//! Shapes: the full request `(SL, d, h)` becomes two half-requests
//! `(SL, d/2, h/2)` with the per-head width `d_k = d/h` preserved.  Head
//! group A owns embedding columns `[0, d/2)` and heads `[0, h/2)`; group
//! B owns the rest.  Each group's projections contract over its own
//! embedding slice (block-diagonal weight partitioning) — the partition
//! the paper's per-head datapath makes natural, since a single card
//! cannot hold the full-width weight tiles of an oversized `d_model` in
//! the first place.  The single-device reference for a sharded request is
//! therefore *the same two half-topology runs* executed back to back on
//! one card; the cluster runs them on two cards concurrently and
//! reassembles bit-identically (DESIGN.md §7, `rust/tests/cluster.rs`).

use crate::config::Topology;
use crate::testdata::MhaInputs;
use anyhow::{bail, Result};

/// How to split one oversized topology across two devices.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// The topology as the client requested it.
    pub full: Topology,
    /// The per-device half topology (both halves are identical shapes).
    pub half: Topology,
}

impl ShardPlan {
    /// Plan a two-way head split of `full`, if its shape allows one:
    /// even heads, even `d_model`, and a half that is still a valid
    /// topology on the same tile size (which preserves `d_k` exactly).
    pub fn plan(full: &Topology) -> Option<ShardPlan> {
        if full.validate().is_err() || full.heads % 2 != 0 || full.d_model % 2 != 0 {
            return None;
        }
        let half =
            Topology::new(full.seq_len, full.d_model / 2, full.heads / 2, full.tile_size);
        half.validate().ok()?;
        debug_assert_eq!(half.d_k(), full.d_k());
        Some(ShardPlan { full: full.clone(), half })
    }

    /// Slice the full request's operands into the two head groups'
    /// operands (group A = low columns/heads, group B = high).
    pub fn split_inputs(&self, inputs: &MhaInputs) -> Result<(MhaInputs, MhaInputs)> {
        let (sl, dm, h) = (self.full.seq_len, self.full.d_model, self.full.heads);
        let dk = self.full.d_k();
        if inputs.x.len() != sl * dm || inputs.wq.len() != h * dk * dm {
            bail!(
                "operand shapes do not match topology {}: x has {} elems, wq {}",
                self.full,
                inputs.x.len(),
                inputs.wq.len()
            );
        }
        let (hd, cd) = (h / 2 * dk, dm / 2);
        let side = |lo: bool| MhaInputs {
            x: slice_block(&inputs.x, dm, 0, sl, col0(lo, cd), cd),
            wq: slice_block(&inputs.wq, dm, col0(lo, hd), hd, col0(lo, cd), cd),
            wk: slice_block(&inputs.wk, dm, col0(lo, hd), hd, col0(lo, cd), cd),
            wv: slice_block(&inputs.wv, dm, col0(lo, hd), hd, col0(lo, cd), cd),
            bq: slice_block(&inputs.bq, dk, col0(lo, h / 2), h / 2, 0, dk),
            bk: slice_block(&inputs.bk, dk, col0(lo, h / 2), h / 2, 0, dk),
            bv: slice_block(&inputs.bv, dk, col0(lo, h / 2), h / 2, 0, dk),
        };
        Ok((side(true), side(false)))
    }

    /// Reassemble the full `(SL, d_model)` output from the two halves'
    /// `(SL, d_model/2)` outputs by column concatenation.
    pub fn concat_outputs(&self, lo: &[f32], hi: &[f32]) -> Result<Vec<f32>> {
        let (sl, half_w) = (self.full.seq_len, self.full.d_model / 2);
        if lo.len() != sl * half_w || hi.len() != sl * half_w {
            bail!(
                "half outputs have {} / {} elems, expected {} each",
                lo.len(),
                hi.len(),
                sl * half_w
            );
        }
        let mut out = Vec::with_capacity(sl * self.full.d_model);
        for r in 0..sl {
            out.extend_from_slice(&lo[r * half_w..(r + 1) * half_w]);
            out.extend_from_slice(&hi[r * half_w..(r + 1) * half_w]);
        }
        Ok(out)
    }
}

/// Start column/row of a side: group A starts at 0, group B at `width`.
fn col0(lo: bool, width: usize) -> usize {
    if lo {
        0
    } else {
        width
    }
}

/// Copy the `[r0, r0+nrows) × [c0, c0+ncols)` block of a row-major
/// matrix with `stride` columns.
fn slice_block(m: &[f32], stride: usize, r0: usize, nrows: usize, c0: usize, ncols: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(nrows * ncols);
    for r in r0..r0 + nrows {
        out.extend_from_slice(&m[r * stride + c0..r * stride + c0 + ncols]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::{run, Gen};

    #[test]
    fn plans_bert_large_split() {
        // BERT-large: d_model 1024, 16 heads, d_k 64.  Neither paper
        // build admits d_model 1024; the halves (512, 8) fit everywhere.
        let full = Topology::new(64, 1024, 16, 64);
        let plan = ShardPlan::plan(&full).unwrap();
        assert_eq!(plan.half, Topology::new(64, 512, 8, 64));
        assert_eq!(plan.half.d_k(), full.d_k());
    }

    #[test]
    fn rejects_unsplittable_shapes() {
        // Odd heads.
        assert!(ShardPlan::plan(&Topology::new(64, 768, 3, 64)).is_none());
        // Half d_model not divisible by the tile size (704/2 = 352).
        assert!(ShardPlan::plan(&Topology::new(64, 704, 22, 64)).is_none());
        // Invalid full topology.
        assert!(ShardPlan::plan(&Topology::new(0, 768, 8, 64)).is_none());
    }

    #[test]
    fn split_shapes_match_half_topology() {
        let full = Topology::new(16, 1024, 16, 64);
        let plan = ShardPlan::plan(&full).unwrap();
        let inputs = MhaInputs::generate(&full);
        let (a, b) = plan.split_inputs(&inputs).unwrap();
        let want = MhaInputs::generate(&plan.half);
        for (got, reference) in [(&a, &want), (&b, &want)] {
            assert_eq!(got.x.len(), reference.x.len());
            assert_eq!(got.wq.len(), reference.wq.len());
            assert_eq!(got.bq.len(), reference.bq.len());
        }
    }

    #[test]
    fn split_slices_correct_blocks() {
        let full = Topology::new(4, 8, 2, 4);
        let plan = ShardPlan::plan(&full).unwrap();
        let inputs = MhaInputs::generate(&full);
        let (a, b) = plan.split_inputs(&inputs).unwrap();
        // x row 0, group A = cols 0..4, group B = cols 4..8.
        assert_eq!(a.x[..4], inputs.x[..4]);
        assert_eq!(b.x[..4], inputs.x[4..8]);
        // wq: full is [2*4 rows, 8 cols]; group B owns rows 4.., cols 4...
        assert_eq!(b.wq[0], inputs.wq[4 * 8 + 4]);
        // biases: group B owns head row 1.
        assert_eq!(b.bq[..4], inputs.bq[4..8]);
    }

    #[test]
    fn concat_inverts_column_split() {
        let full = Topology::new(4, 8, 2, 4);
        let plan = ShardPlan::plan(&full).unwrap();
        // Treat x itself as an "output" matrix: split its columns, then
        // concat must reproduce it exactly.
        let m = MhaInputs::generate(&full).x;
        let lo = slice_block(&m, 8, 0, 4, 0, 4);
        let hi = slice_block(&m, 8, 0, 4, 4, 4);
        assert_eq!(plan.concat_outputs(&lo, &hi).unwrap(), m);
    }

    #[test]
    fn shape_mismatches_error() {
        let plan = ShardPlan::plan(&Topology::new(4, 8, 2, 4)).unwrap();
        let wrong = MhaInputs::generate(&Topology::new(8, 8, 2, 4));
        assert!(plan.split_inputs(&wrong).is_err());
        assert!(plan.concat_outputs(&[0.0; 3], &[0.0; 16]).is_err());
    }

    #[test]
    fn prop_split_concat_roundtrip_on_outputs() {
        run("shard split/concat roundtrip", 50, |g: &mut Gen| {
            let sl = *g.pick(&[2usize, 4, 8]);
            let plan = ShardPlan::plan(&Topology::new(sl, 8, 2, 4)).unwrap();
            let n = sl * 8;
            let m: Vec<f32> = (0..n).map(|i| (g.i64_in(-100, 100) + i as i64) as f32).collect();
            let lo = slice_block(&m, 8, 0, sl, 0, 4);
            let hi = slice_block(&m, 8, 0, sl, 4, 4);
            assert_eq!(plan.concat_outputs(&lo, &hi).unwrap(), m);
        });
    }
}
