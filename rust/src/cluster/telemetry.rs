//! Streaming fleet telemetry: windowed partial-frame aggregation plus a
//! threshold-driven control plane (DESIGN.md §13).
//!
//! The one-shot snapshot strings of `fleet.rs` answer "what happened
//! over the whole run"; serving needs "what happened in the *last
//! window*, and is a device drifting".  This module provides that layer
//! in the style of a DAQ event aggregator:
//!
//! * The router emits [`TelemetryEvent`]s (ingress, completion, shed,
//!   reject) stamped with the **virtual** `arrival_ms` clock.  Events
//!   land in per-window *partial frames* keyed by
//!   `floor(t_ms / window_ms)`.
//! * A watermark (the latest ingress time seen) drives sealing: window
//!   `k` seals once the watermark passes the end of window
//!   `k + grace_windows`, at which point the partial becomes an
//!   immutable [`TelemetryFrame`] in a bounded ring.  Frames are
//!   **contiguous** — empty windows seal as zero frames — so frame
//!   index `k` always covers `[k·w, (k+1)·w)`.
//! * Events older than the seal watermark (late stragglers) are never
//!   silently dropped: they are counted and reported on the next sealed
//!   frame's `late_events`.
//! * Ring eviction folds the evicted frame into a running
//!   [`FrameTotals`], so `sealed == Σ ring + evicted` holds forever
//!   (conservation; asserted by `tests/telemetry_soak.rs`).
//!
//! Everything is a pure function of the seeded virtual clock — two runs
//! of the same soak produce byte-identical JSONL frame exports.
//!
//! The [`ControlPlane`] closes the loop: declarative [`ControlRule`]s
//! (signal, threshold, K consecutive windows, action) are evaluated per
//! sealed frame; firings execute through `Cluster` hooks (drain device,
//! tighten admission margins) and every action is recorded as an
//! auditable [`ActionRecord`].

use crate::config::Topology;
use crate::coordinator::Priority;
use crate::jsonlite::Json;
use crate::metrics::LatencyStats;
use crate::runtime::{FUSED_SL_THRESHOLD, SCORE_BYTES_BUDGET};
use crate::sim::KernelTier;
use std::collections::{BTreeMap, VecDeque};

/// Aggregation tuning (part of `ClusterConfig`; `Copy` so the cluster
/// config stays `Copy`).
#[derive(Clone, Copy, Debug)]
pub struct TelemetryConfig {
    /// Window length in virtual milliseconds.  The default is one
    /// second of virtual `arrival_ms` clock; soaks use much smaller
    /// windows scaled to the mean service time.
    pub window_ms: f64,
    /// How many windows past `k` the watermark must reach before `k`
    /// seals.  Grace absorbs completions recorded shortly after the
    /// ingress that advanced the watermark.
    pub grace_windows: u32,
    /// Bounded ring capacity; evicted frames fold into running totals.
    pub ring_capacity: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { window_ms: 1000.0, grace_windows: 1, ring_capacity: 120 }
    }
}

/// Program-cache heat of one dispatch, as classified by the router's
/// warm-set mirror at dispatch time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Heat {
    /// Device was last programmed with exactly this topology.
    Hot,
    /// Topology resident in the device's program cache but not current:
    /// reprogramming replays cached registers instead of re-deriving.
    Warm,
    /// Full program derivation (or first contact).
    Cold,
}

/// One device invocation attributed to a completion (two for a sharded
/// request).
#[derive(Clone, Copy, Debug)]
pub struct DeviceTouch {
    pub device: usize,
    pub heat: Heat,
    /// Whether the auto exec policy picks the fused tile-streaming path
    /// for this shape (mirror of `SimBackend::choose_path`).
    pub fused: bool,
    /// Kernel tier the dispatch executed with (DESIGN.md §14/§17).
    /// Attributed per touch rather than per frame so fleets mixing
    /// tiers across devices (or flipping tiers mid-run) stay exact.
    pub tier: KernelTier,
}

/// Mirror of the runtime's `ExecPolicy::Auto` path choice, usable
/// router-side without a backend round trip: fused tile-streaming when
/// the sequence is long or the score matrix would blow the budget.
pub fn auto_fused_path(topo: &Topology) -> bool {
    let score_bytes = topo.heads * topo.seq_len * topo.seq_len * 4;
    topo.seq_len >= FUSED_SL_THRESHOLD || score_bytes > SCORE_BYTES_BUDGET
}

/// A raw telemetry event, stamped with the virtual clock.
#[derive(Clone, Debug)]
pub enum TelemetryEvent {
    /// A request entered the router (watermark driver).
    Ingress { t_ms: f64, priority: Priority },
    /// A request finished; `missed` is `None` for best-effort requests.
    Completion {
        t_ms: f64,
        priority: Priority,
        sojourn_ms: f64,
        missed: Option<bool>,
        sharded: bool,
        bounces: u64,
        touches: Vec<DeviceTouch>,
    },
    /// Admission control shed the request at ingress.
    Shed { t_ms: f64, priority: Priority },
    /// No placement admits the topology (and sharding cannot split it).
    Reject { t_ms: f64 },
    /// The ABFT layer flagged a checksum breach on `device`
    /// (DESIGN.md §15).  `contained` means a scrub-retry or cross-device
    /// re-execution produced a verified-clean result before the response
    /// left the router; `false` means a corrupt output was surfaced.
    Integrity { t_ms: f64, device: usize, contained: bool },
}

impl TelemetryEvent {
    fn t_ms(&self) -> f64 {
        match self {
            TelemetryEvent::Ingress { t_ms, .. }
            | TelemetryEvent::Completion { t_ms, .. }
            | TelemetryEvent::Shed { t_ms, .. }
            | TelemetryEvent::Reject { t_ms }
            | TelemetryEvent::Integrity { t_ms, .. } => *t_ms,
        }
    }
}

/// Sealed sojourn statistics for one window (nearest-rank percentiles
/// over the window's completions).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowStat {
    pub count: u64,
    pub sum_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl WindowStat {
    fn seal(s: &LatencyStats) -> WindowStat {
        WindowStat {
            count: s.count() as u64,
            sum_ms: s.sum(),
            p50_ms: s.percentile(50.0),
            p99_ms: s.percentile(99.0),
            max_ms: s.max(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }

    fn to_json(self) -> Json {
        Json::obj([
            ("count", Json::Num(self.count as f64)),
            ("sum_ms", Json::Num(self.sum_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("max_ms", Json::Num(self.max_ms)),
        ])
    }
}

/// Per-device slice of a sealed frame.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceWindow {
    /// Invocations completed on this device in the window (a sharded
    /// request counts once per touched device).
    pub served: u64,
    pub met: u64,
    pub missed: u64,
    pub sojourn: WindowStat,
    pub hot: u64,
    pub warm: u64,
    pub cold: u64,
    pub fused: u64,
    pub reference: u64,
    /// ABFT checksum breaches attributed to this device in the window.
    pub integrity_detected: u64,
    /// Breaches on this device that still escaped as corrupt outputs.
    pub integrity_corrupt: u64,
    /// Router backlog-model lead over the window end at seal time:
    /// `max(0, backlog_ms − window_end)` — how far ahead of real time
    /// the device's queue horizon sits.
    pub backlog_lead_ms: f64,
    /// Device was stopped/failed at seal time.
    pub down: bool,
}

impl DeviceWindow {
    fn to_json(&self) -> Json {
        Json::obj([
            ("served", Json::Num(self.served as f64)),
            ("met", Json::Num(self.met as f64)),
            ("missed", Json::Num(self.missed as f64)),
            ("sojourn", self.sojourn.to_json()),
            ("hot", Json::Num(self.hot as f64)),
            ("warm", Json::Num(self.warm as f64)),
            ("cold", Json::Num(self.cold as f64)),
            ("fused", Json::Num(self.fused as f64)),
            ("reference", Json::Num(self.reference as f64)),
            ("integrity_detected", Json::Num(self.integrity_detected as f64)),
            ("integrity_corrupt", Json::Num(self.integrity_corrupt as f64)),
            ("backlog_lead_ms", Json::Num(self.backlog_lead_ms)),
            ("down", Json::Bool(self.down)),
        ])
    }
}

/// One sealed, immutable telemetry window.  Per-priority arrays are
/// indexed by `Priority::index()` (High, Normal, Low).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryFrame {
    pub index: u64,
    pub start_ms: f64,
    pub end_ms: f64,
    pub arrivals: [u64; 3],
    pub completed: u64,
    pub met: [u64; 3],
    pub missed: [u64; 3],
    pub best_effort: [u64; 3],
    pub shed: [u64; 3],
    pub rejected: u64,
    /// Backpressure bounces attributed to this window's completions.
    pub retries: u64,
    pub sharded: u64,
    pub sojourn: WindowStat,
    pub hot: u64,
    pub warm: u64,
    pub cold: u64,
    pub fused: u64,
    pub reference: u64,
    /// Device invocations in the window by kernel tier, indexed by
    /// [`KernelTier::index`] (DESIGN.md §14/§17).  Replaces the old
    /// single `kernel_tier` label, which silently mislabeled fleets
    /// mixing tiers across devices; per-touch counts make
    /// `Σ tier_dispatches == dispatches()` a checkable conservation law.
    pub tier_dispatches: [u64; KernelTier::COUNT],
    /// Straggler events that arrived after their window sealed; counted
    /// here (the first frame sealed after the straggler), never silent.
    pub late_events: u64,
    /// ABFT checksum breaches detected in the window (DESIGN.md §15).
    pub integrity_detected: u64,
    /// Breaches contained before the response left the router
    /// (scrub-retry or cross-device re-execution verified clean).
    pub integrity_recovered: u64,
    /// Breaches that escaped as corrupt outputs (must stay zero while
    /// recovery works).
    pub integrity_corrupt: u64,
    pub devices: Vec<DeviceWindow>,
}

impl TelemetryFrame {
    pub fn arrivals_total(&self) -> u64 {
        self.arrivals.iter().sum()
    }

    pub fn met_total(&self) -> u64 {
        self.met.iter().sum()
    }

    pub fn missed_total(&self) -> u64 {
        self.missed.iter().sum()
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    /// Device invocations in the window (hot + warm + cold).
    pub fn dispatches(&self) -> u64 {
        self.hot + self.warm + self.cold
    }

    /// Device invocations summed over kernel tiers; conserved against
    /// [`TelemetryFrame::dispatches`] (every touch carries exactly one
    /// heat and one tier).
    pub fn tier_dispatches_total(&self) -> u64 {
        self.tier_dispatches.iter().sum()
    }

    /// Program-cache hit rate of the window's dispatches (hot or warm).
    pub fn warmth_rate(&self) -> f64 {
        let d = self.dispatches();
        if d == 0 {
            0.0
        } else {
            (self.hot + self.warm) as f64 / d as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let per_prio = |v: &[u64; 3]| Json::Arr(v.iter().map(|&c| Json::Num(c as f64)).collect());
        Json::obj([
            ("index", Json::Num(self.index as f64)),
            ("start_ms", Json::Num(self.start_ms)),
            ("end_ms", Json::Num(self.end_ms)),
            ("arrivals", per_prio(&self.arrivals)),
            ("completed", Json::Num(self.completed as f64)),
            ("met", per_prio(&self.met)),
            ("missed", per_prio(&self.missed)),
            ("best_effort", per_prio(&self.best_effort)),
            ("shed", per_prio(&self.shed)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("sharded", Json::Num(self.sharded as f64)),
            ("sojourn", self.sojourn.to_json()),
            ("hot", Json::Num(self.hot as f64)),
            ("warm", Json::Num(self.warm as f64)),
            ("cold", Json::Num(self.cold as f64)),
            ("fused", Json::Num(self.fused as f64)),
            ("reference", Json::Num(self.reference as f64)),
            (
                "tier_dispatches",
                Json::obj(
                    KernelTier::ALL
                        .iter()
                        .map(|t| (t.name(), Json::Num(self.tier_dispatches[t.index()] as f64))),
                ),
            ),
            ("late_events", Json::Num(self.late_events as f64)),
            ("integrity_detected", Json::Num(self.integrity_detected as f64)),
            ("integrity_recovered", Json::Num(self.integrity_recovered as f64)),
            ("integrity_corrupt", Json::Num(self.integrity_corrupt as f64)),
            ("devices", Json::Arr(self.devices.iter().map(|d| d.to_json()).collect())),
        ])
    }
}

/// Running fold of sealed frames (conservation ledger).  Maintained
/// twice by the aggregator — once over everything sealed, once over
/// evictions — so `sealed == Σ ring + evicted` is checkable.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FrameTotals {
    pub frames: u64,
    pub arrivals: [u64; 3],
    pub completed: u64,
    pub met: [u64; 3],
    pub missed: [u64; 3],
    pub best_effort: [u64; 3],
    pub shed: [u64; 3],
    pub rejected: u64,
    pub retries: u64,
    pub sharded: u64,
    pub hot: u64,
    pub warm: u64,
    pub cold: u64,
    pub fused: u64,
    pub reference: u64,
    /// Dispatches by kernel tier, indexed by [`KernelTier::index`].
    pub tier_dispatches: [u64; KernelTier::COUNT],
    pub late_events: u64,
    pub integrity_detected: u64,
    pub integrity_recovered: u64,
    pub integrity_corrupt: u64,
    pub sojourn_count: u64,
    pub sojourn_sum_ms: f64,
    /// Per-device completed invocation counts.
    pub device_served: Vec<u64>,
}

impl FrameTotals {
    pub fn fold(&mut self, f: &TelemetryFrame) {
        self.frames += 1;
        for i in 0..3 {
            self.arrivals[i] += f.arrivals[i];
            self.met[i] += f.met[i];
            self.missed[i] += f.missed[i];
            self.best_effort[i] += f.best_effort[i];
            self.shed[i] += f.shed[i];
        }
        self.completed += f.completed;
        self.rejected += f.rejected;
        self.retries += f.retries;
        self.sharded += f.sharded;
        self.hot += f.hot;
        self.warm += f.warm;
        self.cold += f.cold;
        self.fused += f.fused;
        self.reference += f.reference;
        for i in 0..KernelTier::COUNT {
            self.tier_dispatches[i] += f.tier_dispatches[i];
        }
        self.late_events += f.late_events;
        self.integrity_detected += f.integrity_detected;
        self.integrity_recovered += f.integrity_recovered;
        self.integrity_corrupt += f.integrity_corrupt;
        self.sojourn_count += f.sojourn.count;
        self.sojourn_sum_ms += f.sojourn.sum_ms;
        if self.device_served.len() < f.devices.len() {
            self.device_served.resize(f.devices.len(), 0);
        }
        for (i, d) in f.devices.iter().enumerate() {
            self.device_served[i] += d.served;
        }
    }

    pub fn arrivals_total(&self) -> u64 {
        self.arrivals.iter().sum()
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    pub fn missed_total(&self) -> u64 {
        self.missed.iter().sum()
    }

    pub fn met_total(&self) -> u64 {
        self.met.iter().sum()
    }

    /// Device invocations (hot + warm + cold == Σ device_served).
    pub fn dispatches(&self) -> u64 {
        self.hot + self.warm + self.cold
    }
}

/// Mutable accumulator for one not-yet-sealed window.
#[derive(Clone, Debug)]
struct Partial {
    arrivals: [u64; 3],
    completed: u64,
    met: [u64; 3],
    missed: [u64; 3],
    best_effort: [u64; 3],
    shed: [u64; 3],
    rejected: u64,
    retries: u64,
    sharded: u64,
    sojourn: LatencyStats,
    hot: u64,
    warm: u64,
    cold: u64,
    fused: u64,
    reference: u64,
    tier_dispatches: [u64; KernelTier::COUNT],
    integrity_detected: u64,
    integrity_recovered: u64,
    integrity_corrupt: u64,
    devices: Vec<DevPartial>,
}

#[derive(Clone, Debug, Default)]
struct DevPartial {
    served: u64,
    met: u64,
    missed: u64,
    sojourn: LatencyStats,
    hot: u64,
    warm: u64,
    cold: u64,
    fused: u64,
    reference: u64,
    integrity_detected: u64,
    integrity_corrupt: u64,
}

impl Partial {
    fn new(n_devices: usize) -> Partial {
        Partial {
            arrivals: [0; 3],
            completed: 0,
            met: [0; 3],
            missed: [0; 3],
            best_effort: [0; 3],
            shed: [0; 3],
            rejected: 0,
            retries: 0,
            sharded: 0,
            sojourn: LatencyStats::default(),
            hot: 0,
            warm: 0,
            cold: 0,
            fused: 0,
            reference: 0,
            tier_dispatches: [0; KernelTier::COUNT],
            integrity_detected: 0,
            integrity_recovered: 0,
            integrity_corrupt: 0,
            devices: vec![DevPartial::default(); n_devices],
        }
    }

    fn absorb(&mut self, ev: &TelemetryEvent) {
        match ev {
            TelemetryEvent::Ingress { priority, .. } => {
                self.arrivals[priority.index()] += 1;
            }
            TelemetryEvent::Completion {
                priority, sojourn_ms, missed, sharded, bounces, touches, ..
            } => {
                self.completed += 1;
                self.retries += *bounces;
                if *sharded {
                    self.sharded += 1;
                }
                let p = priority.index();
                match missed {
                    Some(false) => self.met[p] += 1,
                    Some(true) => self.missed[p] += 1,
                    None => self.best_effort[p] += 1,
                }
                self.sojourn.record(*sojourn_ms);
                for t in touches {
                    match t.heat {
                        Heat::Hot => self.hot += 1,
                        Heat::Warm => self.warm += 1,
                        Heat::Cold => self.cold += 1,
                    }
                    if t.fused {
                        self.fused += 1;
                    } else {
                        self.reference += 1;
                    }
                    self.tier_dispatches[t.tier.index()] += 1;
                    if let Some(d) = self.devices.get_mut(t.device) {
                        d.served += 1;
                        match missed {
                            Some(false) => d.met += 1,
                            Some(true) => d.missed += 1,
                            None => {}
                        }
                        d.sojourn.record(*sojourn_ms);
                        match t.heat {
                            Heat::Hot => d.hot += 1,
                            Heat::Warm => d.warm += 1,
                            Heat::Cold => d.cold += 1,
                        }
                        if t.fused {
                            d.fused += 1;
                        } else {
                            d.reference += 1;
                        }
                    }
                }
            }
            TelemetryEvent::Shed { priority, .. } => {
                self.shed[priority.index()] += 1;
            }
            TelemetryEvent::Reject { .. } => {
                self.rejected += 1;
            }
            TelemetryEvent::Integrity { device, contained, .. } => {
                self.integrity_detected += 1;
                if *contained {
                    self.integrity_recovered += 1;
                } else {
                    self.integrity_corrupt += 1;
                }
                if let Some(d) = self.devices.get_mut(*device) {
                    d.integrity_detected += 1;
                    if !contained {
                        d.integrity_corrupt += 1;
                    }
                }
            }
        }
    }

    fn seal(
        self,
        index: u64,
        window_ms: f64,
        backlog_ms: &[f64],
        down: &[bool],
        late_events: u64,
    ) -> TelemetryFrame {
        let end_ms = (index + 1) as f64 * window_ms;
        let devices = self
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| DeviceWindow {
                served: d.served,
                met: d.met,
                missed: d.missed,
                sojourn: WindowStat::seal(&d.sojourn),
                hot: d.hot,
                warm: d.warm,
                cold: d.cold,
                fused: d.fused,
                reference: d.reference,
                integrity_detected: d.integrity_detected,
                integrity_corrupt: d.integrity_corrupt,
                backlog_lead_ms: (backlog_ms.get(i).copied().unwrap_or(0.0) - end_ms).max(0.0),
                down: down.get(i).copied().unwrap_or(false),
            })
            .collect();
        TelemetryFrame {
            index,
            start_ms: index as f64 * window_ms,
            end_ms,
            arrivals: self.arrivals,
            completed: self.completed,
            met: self.met,
            missed: self.missed,
            best_effort: self.best_effort,
            shed: self.shed,
            rejected: self.rejected,
            retries: self.retries,
            sharded: self.sharded,
            sojourn: WindowStat::seal(&self.sojourn),
            hot: self.hot,
            warm: self.warm,
            cold: self.cold,
            fused: self.fused,
            reference: self.reference,
            tier_dispatches: self.tier_dispatches,
            late_events,
            integrity_detected: self.integrity_detected,
            integrity_recovered: self.integrity_recovered,
            integrity_corrupt: self.integrity_corrupt,
            devices,
        }
    }
}

/// Cloneable snapshot of the aggregator's state (ring + totals), the
/// unit of JSONL export and cross-run reproducibility checks.
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    pub frames: Vec<TelemetryFrame>,
    pub sealed: FrameTotals,
    pub evicted: FrameTotals,
    pub late_events: u64,
    pub window_ms: f64,
}

impl TelemetrySnapshot {
    /// One JSON object per sealed frame, newline-terminated.  Byte
    /// equality of two exports is the reproducibility criterion.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for f in &self.frames {
            out.push_str(&f.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

/// DAQ-style windowed aggregator: events → partial frames → sealed ring.
#[derive(Debug)]
pub struct FrameAggregator {
    cfg: TelemetryConfig,
    n_devices: usize,
    /// Next window index to seal; windows `< next_seal` are immutable.
    next_seal: u64,
    partials: BTreeMap<u64, Partial>,
    ring: VecDeque<TelemetryFrame>,
    sealed: FrameTotals,
    evicted: FrameTotals,
    /// Late stragglers not yet attributed to a sealed frame.
    late_pending: u64,
    late_total: u64,
    backlog_gauge: Vec<f64>,
    down_gauge: Vec<bool>,
}

impl FrameAggregator {
    pub fn new(cfg: TelemetryConfig, n_devices: usize) -> FrameAggregator {
        assert!(cfg.window_ms > 0.0, "telemetry window must be positive");
        assert!(cfg.ring_capacity > 0, "telemetry ring must hold at least one frame");
        FrameAggregator {
            cfg,
            n_devices,
            next_seal: 0,
            partials: BTreeMap::new(),
            ring: VecDeque::new(),
            sealed: FrameTotals::default(),
            evicted: FrameTotals::default(),
            late_pending: 0,
            late_total: 0,
            backlog_gauge: vec![0.0; n_devices],
            down_gauge: vec![false; n_devices],
        }
    }

    fn window_of(&self, t_ms: f64) -> u64 {
        if t_ms <= 0.0 {
            0
        } else {
            (t_ms / self.cfg.window_ms) as u64
        }
    }

    /// Record one event into its window's partial.  Events for already
    /// sealed windows are counted as late stragglers and surface on the
    /// next sealed frame — never silently dropped.
    pub fn record(&mut self, ev: TelemetryEvent) {
        let k = self.window_of(ev.t_ms());
        if k < self.next_seal {
            self.late_pending += 1;
            self.late_total += 1;
            return;
        }
        let n = self.n_devices;
        self.partials.entry(k).or_insert_with(|| Partial::new(n)).absorb(&ev);
    }

    /// Refresh the gauge values (router backlog model, device health)
    /// sampled into frames at seal time.
    pub fn observe_gauges(&mut self, backlog_ms: &[f64], down: &[bool]) {
        self.backlog_gauge.clear();
        self.backlog_gauge.extend_from_slice(backlog_ms);
        self.down_gauge.clear();
        self.down_gauge.extend_from_slice(down);
    }

    /// Advance the watermark to virtual time `t_ms`, sealing every
    /// window whose grace period it has passed (including empty ones —
    /// frames stay contiguous).
    pub fn advance(&mut self, t_ms: f64) {
        let grace = self.cfg.grace_windows as u64;
        while (self.next_seal + 1 + grace) as f64 * self.cfg.window_ms <= t_ms {
            self.seal_next();
        }
    }

    /// Flush: seal everything outstanding (end of run).
    pub fn seal_all(&mut self) {
        while !self.partials.is_empty() {
            self.seal_next();
        }
    }

    fn seal_next(&mut self) {
        let k = self.next_seal;
        self.next_seal += 1;
        let partial = self.partials.remove(&k).unwrap_or_else(|| Partial::new(self.n_devices));
        let late = std::mem::take(&mut self.late_pending);
        let frame =
            partial.seal(k, self.cfg.window_ms, &self.backlog_gauge, &self.down_gauge, late);
        self.sealed.fold(&frame);
        self.ring.push_back(frame);
        while self.ring.len() > self.cfg.ring_capacity {
            let old = self.ring.pop_front().expect("ring non-empty");
            self.evicted.fold(&old);
        }
    }

    pub fn frames(&self) -> impl Iterator<Item = &TelemetryFrame> {
        self.ring.iter()
    }

    /// Clone the frames with `index >= since` still in the ring (the
    /// control plane's incremental read).
    pub fn frames_since(&self, since: u64) -> Vec<TelemetryFrame> {
        self.ring.iter().filter(|f| f.index >= since).cloned().collect()
    }

    pub fn sealed_totals(&self) -> &FrameTotals {
        &self.sealed
    }

    pub fn evicted_totals(&self) -> &FrameTotals {
        &self.evicted
    }

    /// Total late stragglers observed (attributed or still pending).
    pub fn late_events_total(&self) -> u64 {
        self.late_total
    }

    pub fn window_ms(&self) -> f64 {
        self.cfg.window_ms
    }

    pub fn snapshot(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            frames: self.ring.iter().cloned().collect(),
            sealed: self.sealed.clone(),
            evicted: self.evicted.clone(),
            late_events: self.late_total,
            window_ms: self.cfg.window_ms,
        }
    }
}

// ---------------------------------------------------------------------------
// Control plane
// ---------------------------------------------------------------------------

/// What a rule applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleScope {
    /// Evaluate fleet-wide frame counters.
    Fleet,
    /// Evaluate each device's window slice independently (down devices
    /// are skipped).
    PerDevice,
}

/// The frame quantity a rule thresholds on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleSignal {
    /// p99 sojourn of the window's completions, ms.  Windows with no
    /// completions carry no evidence and reset the breach streak.
    SojournP99Ms,
    /// Deadline misses in the window (count).
    MissCount,
    /// Sheds in the window (count; fleet scope only — sheds are not
    /// attributed to a device).
    ShedCount,
    /// Router backlog-model lead over the window end, ms.
    BacklogLeadMs,
    /// ABFT checksum breaches per device invocation in the window
    /// (detected / dispatches; per-device: detected / served) —
    /// DESIGN.md §15.  Windows with no dispatches read 0.
    IntegrityErrorRate,
}

/// What to do when a rule fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ControlAction {
    /// Stop dispatching to the breaching device and drain its queue
    /// (`Cluster::stop_device`).  Requires `RuleScope::PerDevice`.
    DrainDevice,
    /// Tighten (or install) the admission margin for a priority class:
    /// a request is shed unless some device can finish `margin_ms`
    /// before its deadline.
    SetAdmissionMargin { priority: Priority, margin_ms: f64 },
    /// Record only — an auditable note in the action log.
    Alert,
    /// Restore a previously drained device (`Cluster::restart_device`)
    /// after `for_windows` consecutive *clean* windows — the inverse of
    /// [`ControlAction::DrainDevice`] and the release half of the
    /// quarantine loop (DESIGN.md §15).  Requires `RuleScope::PerDevice`;
    /// unlike every other action its streak counts windows where the
    /// signal stays *at or under* the threshold while the device is
    /// down, and firing re-arms (it may fire once per drain cycle).
    UndrainDevice,
}

impl ControlAction {
    fn label(&self) -> String {
        match self {
            ControlAction::DrainDevice => "drain_device".to_string(),
            ControlAction::SetAdmissionMargin { priority, margin_ms } => {
                format!("set_admission_margin[{}]={margin_ms}ms", priority.label())
            }
            ControlAction::Alert => "alert".to_string(),
            ControlAction::UndrainDevice => "undrain_device".to_string(),
        }
    }
}

/// A declarative threshold rule: fire `action` after `for_windows`
/// *consecutive* frames where `signal > threshold`.  One-shot per
/// target: once fired for a device (or the fleet), it stays fired.
#[derive(Clone, Debug)]
pub struct ControlRule {
    pub name: String,
    pub scope: RuleScope,
    pub signal: RuleSignal,
    pub threshold: f64,
    pub for_windows: u32,
    pub action: ControlAction,
}

/// A rule crossing its streak threshold on one sealed frame; the
/// cluster executes it and records the outcome as an [`ActionRecord`].
#[derive(Clone, Debug)]
pub struct Firing {
    pub rule: String,
    pub frame: u64,
    pub at_ms: f64,
    pub device: Option<usize>,
    pub observed: f64,
    pub action: ControlAction,
}

/// Audit-log entry: what fired, on what evidence, and what the
/// execution hook reported back.
#[derive(Clone, Debug)]
pub struct ActionRecord {
    pub frame: u64,
    pub at_ms: f64,
    pub rule: String,
    pub device: Option<usize>,
    pub observed: f64,
    pub action: ControlAction,
    pub outcome: String,
}

impl ActionRecord {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("frame", Json::Num(self.frame as f64)),
            ("at_ms", Json::Num(self.at_ms)),
            ("rule", Json::Str(self.rule.clone())),
            (
                "device",
                match self.device {
                    Some(d) => Json::Num(d as f64),
                    None => Json::Null,
                },
            ),
            ("observed", Json::Num(self.observed)),
            ("action", Json::Str(self.action.label())),
            ("outcome", Json::Str(self.outcome.clone())),
        ])
    }
}

/// Evaluates [`ControlRule`]s over sealed frames and keeps the audit
/// log.  Pure state machine: given the same frame sequence it produces
/// the same firings, so control actions inherit the soak's determinism.
#[derive(Debug, Default)]
pub struct ControlPlane {
    rules: Vec<ControlRule>,
    /// Per rule, per target (one slot for Fleet scope) breach streaks.
    streaks: Vec<Vec<u32>>,
    fired: Vec<Vec<bool>>,
    log: Vec<ActionRecord>,
    /// Next frame index to evaluate (frames below this are done).
    cursor: u64,
}

impl ControlPlane {
    pub fn new(rules: Vec<ControlRule>) -> ControlPlane {
        let mut cp = ControlPlane::default();
        for r in rules {
            cp.add_rule(r);
        }
        cp
    }

    pub fn add_rule(&mut self, rule: ControlRule) {
        self.rules.push(rule);
        self.streaks.push(Vec::new());
        self.fired.push(Vec::new());
    }

    pub fn rules(&self) -> &[ControlRule] {
        &self.rules
    }

    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    pub fn log(&self) -> &[ActionRecord] {
        &self.log
    }

    /// One JSON object per action record, newline-terminated.
    pub fn log_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.log {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Append an executed firing (with its outcome) to the audit log.
    pub fn record(&mut self, firing: &Firing, outcome: String) -> ActionRecord {
        let rec = ActionRecord {
            frame: firing.frame,
            at_ms: firing.at_ms,
            rule: firing.rule.clone(),
            device: firing.device,
            observed: firing.observed,
            action: firing.action,
            outcome,
        };
        self.log.push(rec.clone());
        rec
    }

    /// Evaluate every rule against one sealed frame, updating streaks;
    /// returns the firings that crossed their `for_windows` threshold.
    pub fn evaluate(&mut self, frame: &TelemetryFrame) -> Vec<Firing> {
        let mut firings = Vec::new();
        for (ri, rule) in self.rules.iter().enumerate() {
            let n_targets = match rule.scope {
                RuleScope::Fleet => 1,
                RuleScope::PerDevice => frame.devices.len(),
            };
            if self.streaks[ri].len() < n_targets {
                self.streaks[ri].resize(n_targets, 0);
                self.fired[ri].resize(n_targets, false);
            }
            for target in 0..n_targets {
                let device = match rule.scope {
                    RuleScope::Fleet => None,
                    RuleScope::PerDevice => Some(target),
                };
                if matches!(rule.action, ControlAction::UndrainDevice) {
                    // Inverted rule: count clean windows while the target
                    // is down; a live target re-arms the one-shot latch
                    // so the rule can fire again after the next drain.
                    let down = device
                        .and_then(|i| frame.devices.get(i))
                        .is_some_and(|d| d.down);
                    if !down {
                        self.streaks[ri][target] = 0;
                        self.fired[ri][target] = false;
                        continue;
                    }
                    // The drained device produces no evidence of its
                    // own; judge the fleet-level signal (no news — no
                    // breaches anywhere — is good news here).
                    let value = signal_value(rule, frame, None);
                    match value {
                        Some(v) if v > rule.threshold => self.streaks[ri][target] = 0,
                        _ => self.streaks[ri][target] += 1,
                    }
                    if self.streaks[ri][target] >= rule.for_windows && !self.fired[ri][target] {
                        self.fired[ri][target] = true;
                        firings.push(Firing {
                            rule: rule.name.clone(),
                            frame: frame.index,
                            at_ms: frame.end_ms,
                            device,
                            observed: value.unwrap_or(0.0),
                            action: rule.action,
                        });
                    }
                    continue;
                }
                let value = signal_value(rule, frame, device);
                match value {
                    Some(v) if v > rule.threshold => self.streaks[ri][target] += 1,
                    _ => self.streaks[ri][target] = 0,
                }
                if self.streaks[ri][target] >= rule.for_windows && !self.fired[ri][target] {
                    self.fired[ri][target] = true;
                    firings.push(Firing {
                        rule: rule.name.clone(),
                        frame: frame.index,
                        at_ms: frame.end_ms,
                        device,
                        observed: value.unwrap_or(0.0),
                        action: rule.action,
                    });
                }
            }
        }
        self.cursor = self.cursor.max(frame.index + 1);
        firings
    }

    /// Clear every per-device streak and one-shot latch for `target` —
    /// called when a device is restored (undrained) so drain rules get a
    /// fresh observation window instead of re-firing on stale state.
    pub fn reset_device(&mut self, target: usize) {
        for (ri, rule) in self.rules.iter().enumerate() {
            if rule.scope == RuleScope::PerDevice && target < self.streaks[ri].len() {
                self.streaks[ri][target] = 0;
                self.fired[ri][target] = false;
            }
        }
    }
}

/// The signal value for one rule target, or `None` when the frame
/// carries no evidence (no completions for sojourn signals, device
/// down, or a per-device scope on a fleet-only signal).  `None` resets
/// the streak.
fn signal_value(rule: &ControlRule, frame: &TelemetryFrame, device: Option<usize>) -> Option<f64> {
    match device {
        None => match rule.signal {
            RuleSignal::SojournP99Ms => {
                (frame.sojourn.count > 0).then_some(frame.sojourn.p99_ms)
            }
            RuleSignal::MissCount => Some(frame.missed_total() as f64),
            RuleSignal::ShedCount => Some(frame.shed_total() as f64),
            RuleSignal::BacklogLeadMs => frame
                .devices
                .iter()
                .filter(|d| !d.down)
                .map(|d| d.backlog_lead_ms)
                .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v)))),
            RuleSignal::IntegrityErrorRate => Some(
                frame.integrity_detected as f64 / frame.dispatches().max(1) as f64,
            ),
        },
        Some(i) => {
            let d = frame.devices.get(i)?;
            if d.down {
                return None;
            }
            match rule.signal {
                RuleSignal::SojournP99Ms => (d.sojourn.count > 0).then_some(d.sojourn.p99_ms),
                RuleSignal::MissCount => Some(d.missed as f64),
                RuleSignal::ShedCount => None,
                RuleSignal::BacklogLeadMs => Some(d.backlog_lead_ms),
                RuleSignal::IntegrityErrorRate => {
                    Some(d.integrity_detected as f64 / d.served.max(1) as f64)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Operator view
// ---------------------------------------------------------------------------

/// Unicode sparkline of a series, scaled to its own max.
pub fn sparkline(values: &[f64]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().copied().fold(0.0_f64, f64::max);
    values
        .iter()
        .map(|&v| {
            if max <= 0.0 || v <= 0.0 {
                ' '
            } else {
                let idx = (v / max * 8.0).ceil() as usize;
                GLYPHS[idx.clamp(1, 8) - 1]
            }
        })
        .collect()
}

/// Render the `famous top` operator dashboard from the frame ring: a
/// fleet summary over the visible span, a per-device table for the last
/// frame, a completions-per-window sparkline, and the tail of the
/// control-plane action log.  Pure string in, string out (unit-tested;
/// the CLI adds the ANSI clear).
pub fn render_top(frames: &[TelemetryFrame], names: &[String], log: &[ActionRecord]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let Some(last) = frames.last() else {
        return "telemetry: no sealed frames yet\n".to_string();
    };
    let mut span = FrameTotals::default();
    for f in frames {
        span.fold(f);
    }
    let _ = writeln!(
        out,
        "frames {}..{}  window {:.3} ms  span {:.1} ms",
        frames[0].index,
        last.index,
        last.end_ms - last.start_ms,
        last.end_ms - frames[0].start_ms,
    );
    let _ = writeln!(
        out,
        "fleet: {} arrivals  {} done  {} met  {} missed  {} shed  {} rejected  \
         warmth {:.0}%  late {}",
        span.arrivals_total(),
        span.completed,
        span.met_total(),
        span.missed_total(),
        span.shed_total(),
        span.rejected,
        if span.dispatches() == 0 {
            0.0
        } else {
            (span.hot + span.warm) as f64 / span.dispatches() as f64 * 100.0
        },
        span.late_events,
    );
    let mut tier_mix = String::new();
    for t in KernelTier::ALL {
        let n = span.tier_dispatches[t.index()];
        if n > 0 {
            let _ = write!(tier_mix, "  {} {n}", t.name());
        }
    }
    if !tier_mix.is_empty() {
        let _ = writeln!(out, "tiers:{tier_mix}");
    }
    if span.integrity_detected > 0 {
        let _ = writeln!(
            out,
            "integrity: {} detected  {} recovered  {} corrupt",
            span.integrity_detected, span.integrity_recovered, span.integrity_corrupt,
        );
    }
    // Quarantine ledger: devices drained by the control plane (and not
    // since restored) are "quar", not failed hardware.
    let mut quarantined = vec![false; last.devices.len()];
    for r in log {
        if let Some(d) = r.device {
            if d < quarantined.len() {
                match r.action {
                    ControlAction::DrainDevice => quarantined[d] = true,
                    ControlAction::UndrainDevice => quarantined[d] = false,
                    _ => {}
                }
            }
        }
    }
    let served: Vec<f64> = frames.iter().map(|f| f.completed as f64).collect();
    let tail = served.len().saturating_sub(60);
    let _ = writeln!(out, "done/window |{}|", sparkline(&served[tail..]));
    let _ = writeln!(
        out,
        "{:<14} {:>6} {:>5} {:>5} {:>9} {:>11} {:>6} {:>6} {:>9} {:>6}",
        "device (last)", "served", "met", "miss", "p99 ms", "hot/warm/cold", "fused%", "integ",
        "lead ms", "health",
    );
    for (i, d) in last.devices.iter().enumerate() {
        let name = names.get(i).map(String::as_str).unwrap_or("?");
        let fused_pct = if d.served == 0 {
            0.0
        } else {
            d.fused as f64 / (d.fused + d.reference) as f64 * 100.0
        };
        let health = if d.down {
            if quarantined.get(i).copied().unwrap_or(false) {
                "quar"
            } else {
                "down"
            }
        } else {
            "live"
        };
        let _ = writeln!(
            out,
            "{:<14} {:>6} {:>5} {:>5} {:>9.3} {:>11} {:>6.0} {:>6} {:>9.2} {:>6}",
            format!("{i}:{name}"),
            d.served,
            d.met,
            d.missed,
            d.sojourn.p99_ms,
            format!("{}/{}/{}", d.hot, d.warm, d.cold),
            fused_pct,
            d.integrity_detected,
            d.backlog_lead_ms,
            health,
        );
    }
    let quar_count = last
        .devices
        .iter()
        .enumerate()
        .filter(|(i, d)| d.down && quarantined.get(*i).copied().unwrap_or(false))
        .count();
    if quar_count > 0 {
        let _ = writeln!(
            out,
            "WARNING: {quar_count} device(s) quarantined by the control plane — \
             drained pending clean windows, not failed hardware",
        );
    }
    if !log.is_empty() {
        let _ = writeln!(out, "control actions (last {}):", log.len().min(5));
        for r in log.iter().rev().take(5).rev() {
            let dev = r.device.map(|d| format!(" device {d}")).unwrap_or_default();
            let _ = writeln!(
                out,
                "  frame {} @ {:.1} ms  rule '{}'{}  observed {:.3}  -> {}",
                r.frame, r.at_ms, r.rule, dev, r.observed, r.outcome,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExecPath, SimBackend};

    fn cfg(window_ms: f64, grace: u32, ring: usize) -> TelemetryConfig {
        TelemetryConfig { window_ms, grace_windows: grace, ring_capacity: ring }
    }

    fn touch(device: usize, heat: Heat) -> DeviceTouch {
        DeviceTouch { device, heat, fused: false, tier: KernelTier::Scalar }
    }

    fn completion(t_ms: f64, sojourn_ms: f64, device: usize, heat: Heat) -> TelemetryEvent {
        TelemetryEvent::Completion {
            t_ms,
            priority: Priority::Normal,
            sojourn_ms,
            missed: Some(false),
            sharded: false,
            bounces: 0,
            touches: vec![touch(device, heat)],
        }
    }

    fn ingress(t_ms: f64) -> TelemetryEvent {
        TelemetryEvent::Ingress { t_ms, priority: Priority::Normal }
    }

    #[test]
    fn windows_seal_contiguously_with_grace() {
        let mut agg = FrameAggregator::new(cfg(10.0, 1, 16), 2);
        agg.record(ingress(1.0));
        agg.record(completion(2.0, 1.5, 0, Heat::Cold));
        agg.record(ingress(12.0));
        // Window 3 is populated; window 2 stays empty.
        agg.record(ingress(35.0));
        agg.advance(35.0);
        // Watermark 35: window 0 sealed (needs t >= 20), window 1 (t >= 30)
        // sealed, window 2 (t >= 40) not yet.
        let frames: Vec<_> = agg.frames().cloned().collect();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].index, 0);
        assert_eq!(frames[0].arrivals_total(), 1);
        assert_eq!(frames[0].completed, 1);
        assert_eq!(frames[0].devices[0].served, 1);
        assert_eq!(frames[1].index, 1);
        assert_eq!(frames[1].arrivals_total(), 1);
        agg.seal_all();
        let frames: Vec<_> = agg.frames().cloned().collect();
        // Contiguous through window 3: the empty window 2 sealed too.
        assert_eq!(frames.len(), 4);
        assert_eq!(frames[2].arrivals_total(), 0);
        assert_eq!(frames[3].arrivals_total(), 1);
    }

    #[test]
    fn late_stragglers_are_counted_never_silent() {
        let mut agg = FrameAggregator::new(cfg(10.0, 0, 16), 1);
        agg.record(ingress(5.0));
        agg.advance(25.0); // seals windows 0 and 1
        assert_eq!(agg.frames().count(), 2);
        // A completion stamped inside the already sealed window 0.
        agg.record(completion(8.0, 3.0, 0, Heat::Cold));
        assert_eq!(agg.late_events_total(), 1);
        agg.record(ingress(31.0));
        agg.advance(31.0); // hmm: grace 0 seals window 2 at t >= 30
        let frames: Vec<_> = agg.frames().cloned().collect();
        assert_eq!(frames.len(), 3);
        // The straggler is attributed to the next sealed frame's
        // late_events and nowhere else.
        assert_eq!(frames[2].late_events, 1);
        assert_eq!(frames[2].completed, 0);
        let total: u64 = frames.iter().map(|f| f.late_events).sum();
        assert_eq!(total, agg.late_events_total());
    }

    #[test]
    fn ring_eviction_preserves_conservation() {
        let mut agg = FrameAggregator::new(cfg(10.0, 0, 2), 1);
        for k in 0..5u64 {
            let t = k as f64 * 10.0 + 1.0;
            agg.record(ingress(t));
            agg.record(completion(t + 1.0, 0.5 + k as f64, 0, Heat::Hot));
        }
        agg.seal_all();
        assert_eq!(agg.frames().count(), 2); // ring capacity
        assert_eq!(agg.sealed_totals().frames, 5);
        assert_eq!(agg.evicted_totals().frames, 3);
        let mut refold = agg.evicted_totals().clone();
        for f in agg.frames() {
            refold.fold(f);
        }
        assert_eq!(&refold, agg.sealed_totals());
        assert_eq!(refold.completed, 5);
        assert_eq!(refold.arrivals_total(), 5);
        assert_eq!(refold.device_served, vec![5]);
    }

    #[test]
    fn snapshot_jsonl_is_deterministic() {
        let build = |soj: f64| {
            let mut agg = FrameAggregator::new(cfg(5.0, 1, 8), 2);
            agg.record(ingress(0.5));
            agg.record(completion(1.0, soj, 1, Heat::Warm));
            agg.observe_gauges(&[0.0, 7.5], &[false, false]);
            agg.seal_all();
            agg.snapshot().to_jsonl()
        };
        let a = build(1.25);
        assert_eq!(a, build(1.25));
        assert_ne!(a, build(1.5));
        assert!(a.contains("\"warm\":1"), "{a}");
        assert!(a.contains("backlog_lead_ms"), "{a}");
        // Per-tier dispatch counts (Json::Obj sorts keys; tier names
        // happen to sort in `KernelTier::ALL` order).
        assert!(
            a.contains(
                "\"tier_dispatches\":{\"scalar\":1,\"simd\":0,\"simd-int8\":0,\
                 \"simd-int8-attn\":0}"
            ),
            "{a}"
        );
        assert!(!a.contains("kernel_tier"), "single-label field must be gone: {a}");
        assert_eq!(a.lines().count(), 1);
    }

    #[test]
    fn mixed_tier_touches_attributed_per_dispatch() {
        let mut agg = FrameAggregator::new(cfg(10.0, 0, 8), 3);
        agg.record(TelemetryEvent::Completion {
            t_ms: 1.0,
            priority: Priority::Normal,
            sojourn_ms: 1.0,
            missed: Some(false),
            sharded: true,
            bounces: 0,
            touches: vec![
                DeviceTouch { device: 0, heat: Heat::Hot, fused: true, tier: KernelTier::Simd },
                DeviceTouch {
                    device: 1,
                    heat: Heat::Cold,
                    fused: true,
                    tier: KernelTier::SimdInt8Attn,
                },
            ],
        });
        agg.record(TelemetryEvent::Completion {
            t_ms: 2.0,
            priority: Priority::Normal,
            sojourn_ms: 1.0,
            missed: Some(false),
            sharded: false,
            bounces: 0,
            touches: vec![DeviceTouch {
                device: 2,
                heat: Heat::Warm,
                fused: false,
                tier: KernelTier::SimdInt8,
            }],
        });
        agg.seal_all();
        let f = agg.frames().last().unwrap().clone();
        assert_eq!(f.tier_dispatches[KernelTier::Scalar.index()], 0);
        assert_eq!(f.tier_dispatches[KernelTier::Simd.index()], 1);
        assert_eq!(f.tier_dispatches[KernelTier::SimdInt8.index()], 1);
        assert_eq!(f.tier_dispatches[KernelTier::SimdInt8Attn.index()], 1);
        // Conservation: every touch carries exactly one tier and one heat.
        assert_eq!(f.tier_dispatches_total(), f.dispatches());
        let t = agg.sealed_totals();
        assert_eq!(t.tier_dispatches.iter().sum::<u64>(), t.dispatches());
        // The operator view surfaces the mix (and only nonzero tiers).
        let names = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let view = render_top(&[f], &names, &[]);
        assert!(view.contains("tiers:  simd 1  simd-int8 1  simd-int8-attn 1"), "{view}");
        assert!(!view.contains("scalar"), "{view}");
    }

    #[test]
    fn auto_fused_matches_backend_policy() {
        let backend = SimBackend::new(crate::sim::SimConfig::u55c());
        for topo in [
            Topology::new(16, 256, 4, 64),
            Topology::new(64, 768, 8, 64),
            Topology::new(256, 512, 8, 64),
            Topology::new(1024, 768, 8, 64),
            // 16·128²·4 bytes == the budget exactly: stays on reference.
            Topology::new(128, 1024, 16, 64),
        ] {
            let fused = backend.choose_path(&topo) == ExecPath::FusedTiled;
            assert_eq!(auto_fused_path(&topo), fused, "{topo:?}");
        }
    }

    fn frame_with_p99(index: u64, dev_p99: &[f64]) -> TelemetryFrame {
        let mut agg = FrameAggregator::new(cfg(10.0, 0, 64), dev_p99.len());
        for (i, &p) in dev_p99.iter().enumerate() {
            if p > 0.0 {
                agg.record(completion(index as f64 * 10.0 + 1.0, p, i, Heat::Cold));
            }
        }
        agg.record(ingress(index as f64 * 10.0 + 1.0));
        agg.seal_all();
        let mut f = agg.frames().last().unwrap().clone();
        f.index = index;
        f
    }

    #[test]
    fn control_rule_fires_after_k_consecutive_breaches_once() {
        let mut cp = ControlPlane::new(vec![ControlRule {
            name: "p99-drain".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::SojournP99Ms,
            threshold: 5.0,
            for_windows: 3,
            action: ControlAction::DrainDevice,
        }]);
        // Device 1 breaches; device 0 stays healthy.  A no-evidence
        // window (no completions) resets the streak.
        assert!(cp.evaluate(&frame_with_p99(0, &[1.0, 9.0])).is_empty());
        assert!(cp.evaluate(&frame_with_p99(1, &[1.0, 9.0])).is_empty());
        assert!(cp.evaluate(&frame_with_p99(2, &[1.0, 0.0])).is_empty()); // reset
        assert!(cp.evaluate(&frame_with_p99(3, &[1.0, 9.0])).is_empty());
        assert!(cp.evaluate(&frame_with_p99(4, &[1.0, 9.0])).is_empty());
        let firings = cp.evaluate(&frame_with_p99(5, &[1.0, 9.0]));
        assert_eq!(firings.len(), 1);
        assert_eq!(firings[0].device, Some(1));
        assert_eq!(firings[0].action, ControlAction::DrainDevice);
        assert!((firings[0].observed - 9.0).abs() < 1e-12);
        // One-shot: further breaches do not re-fire.
        assert!(cp.evaluate(&frame_with_p99(6, &[1.0, 9.0])).is_empty());
        assert_eq!(cp.cursor(), 7);
        let rec = cp.record(&firings[0], "drained device 1".to_string());
        assert_eq!(rec.frame, 5);
        let jsonl = cp.log_jsonl();
        assert!(jsonl.contains("p99-drain"), "{jsonl}");
        assert!(jsonl.contains("drain_device"), "{jsonl}");
        assert_eq!(jsonl, cp.log_jsonl());
    }

    #[test]
    fn fleet_scope_rules_and_down_devices() {
        let mut cp = ControlPlane::new(vec![ControlRule {
            name: "miss-alert".to_string(),
            scope: RuleScope::Fleet,
            signal: RuleSignal::MissCount,
            threshold: 0.0,
            for_windows: 1,
            action: ControlAction::Alert,
        }]);
        let mut f = frame_with_p99(0, &[1.0]);
        assert!(cp.evaluate(&f).is_empty()); // met, not missed
        f.index = 1;
        f.missed[Priority::Normal.index()] = 2;
        let firings = cp.evaluate(&f);
        assert_eq!(firings.len(), 1);
        assert_eq!(firings[0].device, None);
        assert!((firings[0].observed - 2.0).abs() < 1e-12);

        // A down device yields no evidence for per-device signals.
        let rule = ControlRule {
            name: "x".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::BacklogLeadMs,
            threshold: 0.0,
            for_windows: 1,
            action: ControlAction::Alert,
        };
        let mut g = frame_with_p99(0, &[1.0]);
        g.devices[0].backlog_lead_ms = 42.0;
        g.devices[0].down = true;
        assert_eq!(signal_value(&rule, &g, Some(0)), None);
        g.devices[0].down = false;
        assert_eq!(signal_value(&rule, &g, Some(0)), Some(42.0));
    }

    #[test]
    fn sparkline_and_render_top() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[0.0, 0.0]), "  ");
        let s = sparkline(&[1.0, 4.0, 8.0]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.ends_with('█'), "{s}");

        let frames = vec![frame_with_p99(0, &[1.0, 2.0])];
        let names = vec!["u55c".to_string(), "u200".to_string()];
        let log = vec![ActionRecord {
            frame: 0,
            at_ms: 10.0,
            rule: "p99-drain".to_string(),
            device: Some(1),
            observed: 9.0,
            action: ControlAction::DrainDevice,
            outcome: "drained device 1".to_string(),
        }];
        let view = render_top(&frames, &names, &log);
        assert!(view.contains("0:u55c"), "{view}");
        assert!(view.contains("1:u200"), "{view}");
        assert!(view.contains("p99-drain"), "{view}");
        assert!(view.contains("drained device 1"), "{view}");
        assert!(render_top(&[], &names, &log).contains("no sealed frames"));
    }

    #[test]
    fn integrity_events_fold_and_drive_signals() {
        let mut agg = FrameAggregator::new(cfg(10.0, 0, 8), 2);
        agg.record(ingress(1.0));
        agg.record(completion(2.0, 1.0, 1, Heat::Hot));
        agg.record(TelemetryEvent::Integrity { t_ms: 2.0, device: 1, contained: true });
        agg.record(TelemetryEvent::Integrity { t_ms: 3.0, device: 1, contained: false });
        agg.seal_all();
        let f = agg.frames().last().unwrap().clone();
        assert_eq!(f.integrity_detected, 2);
        assert_eq!(f.integrity_recovered, 1);
        assert_eq!(f.integrity_corrupt, 1);
        assert_eq!(f.devices[1].integrity_detected, 2);
        assert_eq!(f.devices[1].integrity_corrupt, 1);
        assert_eq!(f.devices[0].integrity_detected, 0);
        let t = agg.sealed_totals();
        assert_eq!((t.integrity_detected, t.integrity_recovered, t.integrity_corrupt), (2, 1, 1));
        let jsonl = agg.snapshot().to_jsonl();
        assert!(jsonl.contains("\"integrity_detected\":2"), "{jsonl}");
        assert!(jsonl.contains("\"integrity_corrupt\":1"), "{jsonl}");

        let rule = ControlRule {
            name: "q".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::IntegrityErrorRate,
            threshold: 0.0,
            for_windows: 1,
            action: ControlAction::DrainDevice,
        };
        // Device 1: 2 breaches over 1 served invocation; device 0 clean;
        // fleet: 2 breaches over 1 dispatch.
        assert_eq!(signal_value(&rule, &f, Some(1)), Some(2.0));
        assert_eq!(signal_value(&rule, &f, Some(0)), Some(0.0));
        assert_eq!(signal_value(&rule, &f, None), Some(2.0));
    }

    #[test]
    fn undrain_rule_counts_clean_windows_and_rearms() {
        let mut cp = ControlPlane::new(vec![ControlRule {
            name: "undrain".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::IntegrityErrorRate,
            threshold: 0.0,
            for_windows: 2,
            action: ControlAction::UndrainDevice,
        }]);
        // One frame: device 0 serves; device 1 is `down` (or not); an
        // optional fleet-visible breach keeps the window dirty.
        let mk = |index: u64, down: bool, breach: bool| {
            let mut agg = FrameAggregator::new(cfg(10.0, 0, 8), 2);
            agg.record(completion(1.0, 1.0, 0, Heat::Hot));
            if breach {
                agg.record(TelemetryEvent::Integrity { t_ms: 1.5, device: 0, contained: true });
            }
            agg.observe_gauges(&[0.0, 0.0], &[false, down]);
            agg.seal_all();
            let mut f = agg.frames().last().unwrap().clone();
            f.index = index;
            f
        };
        // Live device: rule idles (and keeps the latch armed).
        assert!(cp.evaluate(&mk(0, false, false)).is_empty());
        // Drained, but the fleet still sees breaches: streak resets.
        assert!(cp.evaluate(&mk(1, true, true)).is_empty());
        assert!(cp.evaluate(&mk(2, true, false)).is_empty()); // clean 1/2
        let firings = cp.evaluate(&mk(3, true, false)); // clean 2/2
        assert_eq!(firings.len(), 1);
        assert_eq!(firings[0].device, Some(1));
        assert_eq!(firings[0].action, ControlAction::UndrainDevice);
        // Back live: the latch re-arms, so a later drain cycle can fire
        // the undrain again — unlike every one-shot rule.
        assert!(cp.evaluate(&mk(4, false, false)).is_empty());
        assert!(cp.evaluate(&mk(5, true, false)).is_empty());
        assert_eq!(cp.evaluate(&mk(6, true, false)).len(), 1, "must re-fire after re-drain");
    }

    #[test]
    fn reset_device_clears_streaks_and_latch() {
        let mut cp = ControlPlane::new(vec![ControlRule {
            name: "drain".to_string(),
            scope: RuleScope::PerDevice,
            signal: RuleSignal::SojournP99Ms,
            threshold: 5.0,
            for_windows: 3,
            action: ControlAction::DrainDevice,
        }]);
        for i in 0..3 {
            let n = cp.evaluate(&frame_with_p99(i, &[9.0])).len();
            assert_eq!(n, usize::from(i == 2));
        }
        // Latched: more breaches stay silent until the device is reset.
        assert!(cp.evaluate(&frame_with_p99(3, &[9.0])).is_empty());
        cp.reset_device(0);
        assert!(cp.evaluate(&frame_with_p99(4, &[9.0])).is_empty());
        assert!(cp.evaluate(&frame_with_p99(5, &[9.0])).is_empty());
        assert_eq!(cp.evaluate(&frame_with_p99(6, &[9.0])).len(), 1, "fresh 3-window streak");
    }

    #[test]
    fn render_top_marks_quarantined_devices() {
        let mut f = frame_with_p99(0, &[1.0, 0.0]);
        f.devices[1].down = true;
        let names = vec!["a".to_string(), "b".to_string()];
        let log = vec![ActionRecord {
            frame: 0,
            at_ms: 10.0,
            rule: "integrity-drain".to_string(),
            device: Some(1),
            observed: 1.0,
            action: ControlAction::DrainDevice,
            outcome: "drained device 1".to_string(),
        }];
        let view = render_top(&[f.clone()], &names, &log);
        assert!(view.contains("quar"), "{view}");
        assert!(view.contains("WARNING: 1 device(s) quarantined"), "{view}");
        // An undrain record (and the device back up) clears the flag.
        let mut log2 = log.clone();
        log2.push(ActionRecord {
            frame: 3,
            at_ms: 40.0,
            rule: "undrain".to_string(),
            device: Some(1),
            observed: 0.0,
            action: ControlAction::UndrainDevice,
            outcome: "restored device 1".to_string(),
        });
        f.devices[1].down = false;
        let view2 = render_top(&[f], &names, &log2);
        assert!(!view2.contains("WARNING"), "{view2}");
        assert!(view2.contains("live"), "{view2}");
    }

    #[test]
    fn frame_totals_fold_tracks_priorities() {
        let mut agg = FrameAggregator::new(cfg(10.0, 0, 8), 1);
        agg.record(TelemetryEvent::Ingress { t_ms: 1.0, priority: Priority::High });
        agg.record(TelemetryEvent::Shed { t_ms: 1.5, priority: Priority::Low });
        agg.record(TelemetryEvent::Reject { t_ms: 2.0 });
        agg.record(TelemetryEvent::Completion {
            t_ms: 3.0,
            priority: Priority::High,
            sojourn_ms: 2.0,
            missed: Some(true),
            sharded: true,
            bounces: 2,
            touches: vec![touch(0, Heat::Hot), touch(0, Heat::Cold)],
        });
        agg.seal_all();
        let t = agg.sealed_totals();
        assert_eq!(t.arrivals[Priority::High.index()], 1);
        assert_eq!(t.shed[Priority::Low.index()], 1);
        assert_eq!(t.rejected, 1);
        assert_eq!(t.missed[Priority::High.index()], 1);
        assert_eq!(t.sharded, 1);
        assert_eq!(t.retries, 2);
        assert_eq!(t.dispatches(), 2);
        assert_eq!(t.device_served, vec![2]);
        assert_eq!(t.sojourn_count, 1);
    }
}
