//! Virtual-time discrete-event fleet simulator (DESIGN.md §16).
//!
//! The threaded soak executes every request on real threads, so
//! "millions of users" capacity studies top out at what the host can
//! physically run.  This module replays the same routing pipeline in
//! *virtual time*: a min-heap of timestamped component wake-ups — the
//! load source emitting its next arrival, device service completions —
//! advances a global virtual clock, and per-request service times come
//! from the devices' cached [`crate::accel::ProgramImage`] phase traces
//! instead of wall-clock thread execution.  An hour-long million-request
//! trace simulates in wall-clock seconds and is bit-reproducible under a
//! fixed seed.
//!
//! **Fidelity contract** (asserted by `rust/tests/des_soak.rs`): driven
//! by the same seeded arrival stream and `ClusterConfig`, the simulator
//! produces *exactly* the counters and telemetry of a threaded
//! [`super::Cluster`] whose client submits sequentially — identical
//! conservation totals (offered = served + shed + rejected) and
//! byte-identical telemetry frame ledgers.  That works because every
//! latency in this repository is already modeled on the virtual request
//! clock; the threads only ever carried the *functional* datapath, which
//! the DES does not re-execute.  The mirror is exact on three grounds:
//!
//! * **Service times.**  Each simulated device owns a
//!   [`FamousAccelerator`] booted from the spec's *derated* build —
//!   exactly what `Cluster::start` boots — so `fabric_ms` equals the
//!   `ProgramImage::latency_ms` the threaded device would bill, while
//!   routing keeps planning with the advertised
//!   [`DeviceSpec::predicted_ms`] model (silent-derate drift included).
//! * **Event order.**  A sequential client fully processes arrival *i*
//!   (ingress → admission → dispatch bookkeeping → completion record)
//!   before arrival *i+1* touches the router, so the DES records
//!   completion telemetry *eagerly* at arrival-processing time (stamped
//!   with its future `done_ms`, exactly like the threaded router) and
//!   uses heap completion wake-ups only for auxiliary occupancy stats.
//! * **Queue depths.**  Sequential driving means every ingress queue is
//!   empty at ranking time, so the `Affinity` arm's `pending` signal is
//!   identically 0 — bounces never happen and dispatch always lands on
//!   the top-ranked candidate.
//!
//! With [`DesConfig::fused_service`] the simulator leaves mirror mode
//! and bills shapes the auto exec policy runs fused with the corrected
//! per-tile `FusedTiled` trace ([`FamousAccelerator::trace_summary`])
//! instead of the reference `SL×SL` phases — the what-if lever the
//! capacity study sweeps (`examples/capacity_study.rs`).

use super::fleet::RouterTotals;
use super::placement::{PlacementPlan, PlacementPlanner, WorkloadProfile};
use super::router::{
    order_candidates, order_candidates_by_slack, preferred_devices, CandidateView, ClusterConfig,
    QosPolicy, SlackView, WarmSet, DEFAULT_ADMISSION_MARGIN_MS,
};
use super::shard::ShardPlan;
use super::telemetry::{
    self, ActionRecord, ControlAction, ControlPlane, ControlRule, DeviceTouch, FrameAggregator,
    Heat, TelemetryEvent, TelemetrySnapshot,
};
use super::{Arrival, DeviceSpec, LoadGen};
use crate::accel::FamousAccelerator;
use crate::config::Topology;
use crate::metrics::OpCount;
use crate::sim::ExecPath;
use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One scheduled wake-up: ordering key is `(time-bits, sequence)`.
/// Payloads never participate in the ordering, so the queue is generic
/// without an `Ord` bound on `T`.
struct Entry<T> {
    /// `f64::to_bits` of the timestamp — monotone in the value for the
    /// non-negative finite floats [`EventQueue::push`] admits.
    key: u64,
    /// Push sequence number: FIFO among equal timestamps, and a total
    /// order overall (determinism does not hinge on heap internals).
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.key, self.seq).cmp(&(other.key, other.seq))
    }
}

/// Deterministic timestamp-ordered event queue: the DES core.
///
/// A thin discipline over `BinaryHeap`: timestamps must be finite and
/// non-negative (so their bit patterns order like the values), ties pop
/// in push order, and [`EventQueue::pop`] *asserts* the dispatch
/// sequence never goes backwards in time — the invariant the property
/// suite fuzzes (`rust/tests/properties.rs`).
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<Entry<T>>>,
    next_seq: u64,
    /// Bits of the most recently popped timestamp (monotonicity check).
    popped_key: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, popped_key: 0 }
    }

    /// Schedule `payload` at virtual time `t_ms` (finite, `>= 0`).
    pub fn push(&mut self, t_ms: f64, payload: T) {
        assert!(
            t_ms.is_finite() && t_ms >= 0.0,
            "event timestamp must be finite and non-negative, got {t_ms}"
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Entry { key: t_ms.to_bits(), seq, payload }));
    }

    /// Pop the earliest event.  Panics if the heap would hand events
    /// out of timestamp order — that would silently corrupt every
    /// statistic built on the virtual clock, so it is a hard invariant,
    /// not a debug check.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let Reverse(e) = self.heap.pop()?;
        assert!(
            e.key >= self.popped_key,
            "event heap dispatched out of timestamp order: {} after {}",
            f64::from_bits(e.key),
            f64::from_bits(self.popped_key),
        );
        self.popped_key = e.key;
        Some((f64::from_bits(e.key), e.payload))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// DES tuning: the threaded cluster's config plus the service-model
/// lever.
#[derive(Clone, Copy, Debug, Default)]
pub struct DesConfig {
    /// Routing/QoS/telemetry configuration, interpreted exactly as the
    /// threaded [`super::Cluster`] does.  `scheduler`, `server`,
    /// `max_retries`, `saturation` and `clock` are carried for parity
    /// but have no observable effect under sequential-equivalent
    /// simulation (queues never fill, so nothing bounces or blocks).
    pub cluster: ClusterConfig,
    /// Bill shapes the auto exec policy runs fused with the corrected
    /// per-tile `FusedTiled` trace instead of the reference `SL×SL`
    /// phases.  Off by default: the threaded fleet's devices still bill
    /// reference timing, and mirror mode must match them byte-for-byte.
    pub fused_service: bool,
}

/// One simulated fleet member: advertised spec + the derated "booted"
/// accelerator whose program cache supplies service times.
struct DeviceModel {
    spec: DeviceSpec,
    accel: FamousAccelerator,
}

/// A scheduled component wake-up.
enum Event {
    /// The load source emits an arrival (and re-arms for the next one).
    Arrival(Arrival),
    /// A device finishes one dispatched (sub-)request.
    Completion { device: usize, fabric_ms: f64 },
}

/// Final report of one simulated trace.
#[derive(Clone, Debug)]
pub struct DesReport {
    /// Arrivals offered to the router mirror.
    pub offered: u64,
    /// Client-visible requests completed (sharded counts once).
    pub served: u64,
    pub shed: u64,
    pub rejected: u64,
    /// Virtual span of the trace: the last event's timestamp, ms.
    pub virtual_ms: f64,
    /// Host wall time the simulation took, ms.
    pub wall_ms: f64,
    /// Heap events dispatched (arrivals + completions).
    pub events: u64,
    /// Peak concurrent device invocations in flight.
    pub peak_in_flight: u64,
    /// Modeled fabric occupancy per device, ms.
    pub device_busy_ms: Vec<f64>,
    /// Full router-mirror counters (SLO stats included).
    pub totals: RouterTotals,
}

impl DesReport {
    /// offered = served + shed + rejected — the conservation invariant
    /// shared with the threaded soak.
    pub fn conserved(&self) -> bool {
        self.offered == self.served + self.shed + self.rejected
    }

    /// Virtual-over-wall speedup (how much faster than real time the
    /// trace simulated).
    pub fn speedup(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.virtual_ms / self.wall_ms
        } else {
            f64::INFINITY
        }
    }

    /// Modeled utilization of one device over the virtual span.
    pub fn utilization(&self, device: usize) -> f64 {
        if self.virtual_ms > 0.0 {
            self.device_busy_ms.get(device).copied().unwrap_or(0.0) / self.virtual_ms
        } else {
            0.0
        }
    }

    /// SLO violation rate over deadline-bearing traffic (misses + sheds
    /// over demand) — the capacity study's knee signal.
    pub fn violation_rate(&self) -> f64 {
        self.totals.slo.overall_miss_rate()
    }

    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "des: {} offered = {} served + {} shed + {} rejected  (conserved: {})",
            self.offered,
            self.served,
            self.shed,
            self.rejected,
            self.conserved(),
        );
        let _ = writeln!(
            out,
            "     virtual {:.1} ms in {:.1} ms wall ({:.0}x real time), {} events, peak {} in flight",
            self.virtual_ms,
            self.wall_ms,
            self.speedup(),
            self.events,
            self.peak_in_flight,
        );
        let _ = writeln!(
            out,
            "     violation rate {:.4}  miss {}  utilization {}",
            self.violation_rate(),
            self.totals.slo.total_missed(),
            (0..self.device_busy_ms.len())
                .map(|i| format!("{:.0}%", self.utilization(i) * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
        );
        out
    }
}

/// The virtual-time fleet: a deterministic mirror of
/// [`super::Cluster`]'s routing state machine, driven by an
/// [`EventQueue`] instead of client threads.
pub struct FleetSim {
    devices: Vec<DeviceModel>,
    plan: PlacementPlan,
    qos: QosPolicy,
    fused_service: bool,
    queue: EventQueue<Event>,

    // --- router-state mirror (field-for-field with `RouterState`) ---
    last_topology: Vec<Option<Topology>>,
    backlog_ms: Vec<f64>,
    down: Vec<bool>,
    warm: Vec<WarmSet>,
    admission_margin_ms: [Option<f64>; 3],
    totals: RouterTotals,

    telemetry: FrameAggregator,
    control: ControlPlane,

    // --- auxiliary occupancy stats (heap-driven; never fed back into
    // the router mirror, so they cannot perturb the byte-identity) ---
    clock_ms: f64,
    offered: u64,
    events: u64,
    in_flight: u64,
    peak_in_flight: u64,
    busy_ms: Vec<f64>,
}

impl FleetSim {
    /// Mirror of `Cluster::start`: renumber devices, plan placement,
    /// and boot each device's accelerator at its *real* (possibly
    /// silently derated) clock while routing keeps the advertised model.
    pub fn new(
        devices: Vec<DeviceSpec>,
        workload: &WorkloadProfile,
        config: DesConfig,
    ) -> Result<FleetSim> {
        if devices.is_empty() {
            bail!("fleet simulator needs at least one device");
        }
        let mut devices = devices;
        for (i, d) in devices.iter_mut().enumerate() {
            d.id = i;
        }
        let plan = PlacementPlanner::default().plan(&devices, workload);
        let models: Vec<DeviceModel> = devices
            .into_iter()
            .map(|spec| {
                let mut sim = spec.sim.clone();
                sim.build.clock_hz *= spec.silent_derate;
                DeviceModel { spec, accel: FamousAccelerator::with_sim_datapath(sim) }
            })
            .collect();
        let n = models.len();
        Ok(FleetSim {
            devices: models,
            plan,
            qos: config.cluster.qos,
            fused_service: config.fused_service,
            queue: EventQueue::new(),
            last_topology: vec![None; n],
            backlog_ms: vec![0.0; n],
            down: vec![false; n],
            warm: vec![WarmSet::default(); n],
            admission_margin_ms: DEFAULT_ADMISSION_MARGIN_MS,
            totals: RouterTotals::default(),
            telemetry: FrameAggregator::new(config.cluster.telemetry, n),
            control: ControlPlane::default(),
            clock_ms: 0.0,
            offered: 0,
            events: 0,
            in_flight: 0,
            peak_in_flight: 0,
            busy_ms: vec![0.0; n],
        })
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.plan
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    pub fn device_names(&self) -> Vec<String> {
        self.devices.iter().map(|d| d.spec.name.clone()).collect()
    }

    /// Install a control rule, evaluated per sealed telemetry frame
    /// after every processed arrival (the DES pumps its own control
    /// plane — there is no operator thread in virtual time).
    pub fn add_control_rule(&mut self, rule: ControlRule) {
        self.control.add_rule(rule);
    }

    pub fn control_log(&self) -> &[ActionRecord] {
        self.control.log()
    }

    pub fn control_log_jsonl(&self) -> String {
        self.control.log_jsonl()
    }

    /// Snapshot the telemetry ring + running totals (same unit of
    /// reproducibility as `Cluster::telemetry`).
    pub fn telemetry(&self) -> TelemetrySnapshot {
        self.telemetry.snapshot()
    }

    /// Seal every outstanding partial frame (end of run).
    pub fn seal_telemetry(&mut self) {
        self.telemetry.seal_all();
    }

    pub fn totals(&self) -> &RouterTotals {
        &self.totals
    }

    /// Simulate the next `n` arrivals drawn lazily from `gen` — the
    /// load source is a heap component that re-arms itself after each
    /// emission, so arbitrarily long traces never materialize an
    /// arrival vector.  Drawing one arrival at a time emits exactly the
    /// stream one `generate_n(n)` call would.
    pub fn run(&mut self, gen: &mut LoadGen, n: usize) -> DesReport {
        let mut remaining = n;
        self.run_source(move || {
            if remaining == 0 {
                return None;
            }
            remaining -= 1;
            gen.generate_n(1).pop()
        })
    }

    /// Simulate a pre-generated arrival trace (the cross-check path:
    /// the threaded soak replays the identical vector).
    pub fn run_trace(&mut self, arrivals: &[Arrival]) -> DesReport {
        let mut it = arrivals.iter().cloned();
        self.run_source(move || it.next())
    }

    /// The event loop: seed the load source, then drain the heap.  The
    /// popped timestamp *is* the global virtual clock — the monotone-pop
    /// assertion inside [`EventQueue`] guarantees it never runs
    /// backwards.
    fn run_source(&mut self, mut next: impl FnMut() -> Option<Arrival>) -> DesReport {
        let wall_start = std::time::Instant::now();
        let events_before = self.events;
        if let Some(a) = next() {
            self.queue.push(a.arrival_ms, Event::Arrival(a));
        }
        while let Some((t, ev)) = self.queue.pop() {
            self.clock_ms = t;
            self.events += 1;
            match ev {
                Event::Arrival(a) => {
                    self.process_arrival(&a);
                    if !self.control.rules().is_empty() {
                        self.pump_control();
                    }
                    if let Some(b) = next() {
                        self.queue.push(b.arrival_ms, Event::Arrival(b));
                    }
                }
                Event::Completion { device, fabric_ms } => {
                    self.in_flight -= 1;
                    self.busy_ms[device] += fabric_ms;
                }
            }
        }
        let report = DesReport {
            offered: self.offered,
            served: self.totals.completed,
            shed: self.totals.slo.total_shed(),
            rejected: self.totals.rejected,
            virtual_ms: self.clock_ms,
            wall_ms: wall_start.elapsed().as_secs_f64() * 1000.0,
            events: self.events - events_before,
            peak_in_flight: self.peak_in_flight,
            device_busy_ms: self.busy_ms.clone(),
            totals: self.totals.clone(),
        };
        assert!(
            report.conserved(),
            "conservation violated: {} offered != {} served + {} shed + {} rejected",
            report.offered,
            report.served,
            report.shed,
            report.rejected,
        );
        report
    }

    /// Mirror of `ClusterHandle::call_qos`, minus the functional
    /// datapath: ingress telemetry, admission control, dispatch
    /// bookkeeping and eager completion records in the threaded
    /// router's exact order.
    fn process_arrival(&mut self, a: &Arrival) {
        self.offered += 1;
        let topo = &a.topology;
        // telemetry_ingress: gauges, watermark, ingress record.
        self.telemetry.observe_gauges(&self.backlog_ms, &self.down);
        self.telemetry.advance(a.arrival_ms);
        self.telemetry.record(TelemetryEvent::Ingress { t_ms: a.arrival_ms, priority: a.priority });
        let single = self.devices.iter().any(|d| d.spec.admits(topo));
        let shard = if single {
            None
        } else {
            self.plan
                .placement(topo)
                .and_then(|p| p.shard.clone())
                .or_else(|| ShardPlan::plan(topo))
                .filter(|s| self.devices.iter().any(|d| d.spec.admits(&s.half)))
        };
        if !single && shard.is_none() {
            self.totals.rejected += 1;
            self.telemetry.record(TelemetryEvent::Reject { t_ms: a.arrival_ms });
            return;
        }
        // Admission control (SlackEdf only): shed a deadline request no
        // live admitting device can finish `margin` early.
        if self.qos == QosPolicy::SlackEdf {
            let margin = self.admission_margin_ms[a.priority.index()];
            if let (Some(margin), Some(deadline)) = (margin, a.deadline_ms) {
                let check = shard.as_ref().map(|s| &s.half).unwrap_or(topo);
                if let Some(best) = self.best_completion_ms(check, a.arrival_ms) {
                    if best > deadline - margin {
                        self.totals.slo.record_shed(a.priority);
                        self.telemetry.record(TelemetryEvent::Shed {
                            t_ms: a.arrival_ms,
                            priority: a.priority,
                        });
                        return;
                    }
                }
            }
        }
        match shard {
            None => {
                let (dev, done, heat) = self.dispatch(topo, a, None);
                let missed = a.deadline_ms.map(|dl| done > dl);
                self.totals.completed += 1;
                self.totals.slo.record_completion(a.priority, done - a.arrival_ms, missed);
                self.telemetry.record(TelemetryEvent::Completion {
                    t_ms: done,
                    priority: a.priority,
                    sojourn_ms: done - a.arrival_ms,
                    missed,
                    sharded: false,
                    bounces: 0,
                    touches: vec![DeviceTouch {
                        device: dev,
                        heat,
                        fused: telemetry::auto_fused_path(topo),
                        tier: crate::sim::KernelTier::effective(),
                    }],
                });
            }
            Some(s) => {
                // Mirror of `call_sharded`, serialized deterministically
                // lo-then-hi: the high half is steered off the low
                // half's primary device so the halves overlap when the
                // fleet allows (the backlog model makes the overlap
                // itself; order of bookkeeping is what threads leave
                // nondeterministic and the DES pins down).
                let lo_primary = self.rank(&s.half, None, a).first().copied();
                let (lo_dev, lo_done, lo_heat) = self.dispatch(&s.half, a, None);
                let (hi_dev, hi_done, hi_heat) = self.dispatch(&s.half, a, lo_primary);
                let done = lo_done.max(hi_done);
                let missed = a.deadline_ms.map(|dl| done > dl);
                self.totals.completed += 1;
                self.totals.sharded += 1;
                self.totals.slo.record_completion(a.priority, done - a.arrival_ms, missed);
                let fused = telemetry::auto_fused_path(&s.half);
                let tier = crate::sim::KernelTier::effective();
                self.telemetry.record(TelemetryEvent::Completion {
                    t_ms: done,
                    priority: a.priority,
                    sojourn_ms: done - a.arrival_ms,
                    missed,
                    sharded: true,
                    bounces: 0,
                    touches: vec![
                        DeviceTouch { device: lo_dev, heat: lo_heat, fused, tier },
                        DeviceTouch { device: hi_dev, heat: hi_heat, fused, tier },
                    ],
                });
            }
        }
    }

    /// Mirror of `call_single`'s success path plus `record`: rank, take
    /// the best candidate (sequential driving never bounces), bill the
    /// service model, and advance the backlog horizon.  Returns
    /// `(device, done_ms, heat)` and schedules the completion wake-up.
    fn dispatch(
        &mut self,
        topo: &Topology,
        a: &Arrival,
        exclude: Option<usize>,
    ) -> (usize, f64, Heat) {
        let mut candidates = self.rank(topo, exclude, a);
        if candidates.is_empty() {
            candidates = self.rank(topo, None, a);
        }
        let dev = candidates[0];
        let fabric_ms = self.service_ms(dev, topo);
        // `record()` bookkeeping, field for field.
        let preferred = preferred_devices(&self.plan, topo);
        let hot = self.last_topology[dev].as_ref() == Some(topo);
        let warm = !hot && self.warm[dev].contains(topo);
        let heat = match (hot, warm) {
            (true, _) => Heat::Hot,
            (false, true) => Heat::Warm,
            (false, false) => Heat::Cold,
        };
        if warm {
            self.totals.warm_hits += 1;
        }
        let planned = preferred.first() == Some(&dev) || self.plan.is_pinned(dev, topo);
        if hot || planned {
            self.totals.affinity_hits += 1;
        } else {
            self.totals.affinity_misses += 1;
        }
        self.last_topology[dev] = Some(topo.clone());
        self.warm[dev].touch(topo);
        self.totals.total_gop += OpCount::paper_convention(topo);
        let done = self.backlog_ms[dev].max(a.arrival_ms) + fabric_ms;
        self.backlog_ms[dev] = done;
        // Auxiliary occupancy tracking rides the heap.
        self.queue.push(done, Event::Completion { device: dev, fabric_ms });
        self.in_flight += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        (dev, done, heat)
    }

    /// Mirror of `rank`: slack-aware under `SlackEdf`, PR-1
    /// hot/planned/least-loaded under `Affinity` — with `pending` pinned
    /// to 0, the value a sequentially driven fleet always observes.
    fn rank(&self, topo: &Topology, exclude: Option<usize>, a: &Arrival) -> Vec<usize> {
        let preferred = preferred_devices(&self.plan, topo);
        let position = |id: usize| preferred.iter().position(|&p| p == id).unwrap_or(usize::MAX);
        if self.qos == QosPolicy::SlackEdf {
            let views: Vec<SlackView> = self
                .devices
                .iter()
                .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
                .map(|d| {
                    if self.down[d.spec.id] {
                        return SlackView {
                            id: d.spec.id,
                            hot: false,
                            warm: false,
                            preference: usize::MAX,
                            est_completion_ms: f64::INFINITY,
                            slack_ms: f64::NEG_INFINITY,
                        };
                    }
                    let est =
                        self.backlog_ms[d.spec.id].max(a.arrival_ms) + d.spec.predicted_ms(topo);
                    let hot = self.last_topology[d.spec.id].as_ref() == Some(topo);
                    SlackView {
                        id: d.spec.id,
                        hot,
                        warm: !hot && self.warm[d.spec.id].contains(topo),
                        preference: position(d.spec.id),
                        est_completion_ms: est,
                        slack_ms: a.deadline_ms.map_or(f64::INFINITY, |dl| dl - est),
                    }
                })
                .collect();
            return order_candidates_by_slack(views);
        }
        let views: Vec<CandidateView> = self
            .devices
            .iter()
            .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
            .map(|d| {
                if self.down[d.spec.id] {
                    return CandidateView {
                        id: d.spec.id,
                        hot: false,
                        warm: false,
                        preference: usize::MAX,
                        pending: usize::MAX,
                    };
                }
                let hot = self.last_topology[d.spec.id].as_ref() == Some(topo);
                CandidateView {
                    id: d.spec.id,
                    hot,
                    warm: !hot && self.warm[d.spec.id].contains(topo),
                    preference: position(d.spec.id),
                    pending: 0,
                }
            })
            .collect();
        order_candidates(views)
    }

    /// Mirror of `best_completion_ms`: best modeled completion over
    /// *live* admitting devices under the advertised model.
    fn best_completion_ms(&self, topo: &Topology, arrival_ms: f64) -> Option<f64> {
        self.devices
            .iter()
            .filter(|d| !self.down[d.spec.id] && d.spec.admits(topo))
            .map(|d| self.backlog_ms[d.spec.id].max(arrival_ms) + d.spec.predicted_ms(topo))
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// The service model: what the booted (derated) device bills for
    /// one invocation of `topo`.  Mirror mode replays the reference
    /// `ProgramImage` latency — exactly the threaded device's
    /// `fabric_ms`; with [`DesConfig::fused_service`] shapes the auto
    /// policy runs fused are billed the corrected per-tile trace.
    fn service_ms(&mut self, dev: usize, topo: &Topology) -> f64 {
        let d = &mut self.devices[dev];
        if self.fused_service && telemetry::auto_fused_path(topo) {
            d.accel
                .trace_summary(topo, ExecPath::FusedTiled)
                .expect("ranked device must admit the topology")
                .latency_ms
        } else {
            d.accel.program(topo).expect("ranked device must admit the topology").latency_ms()
        }
    }

    /// Mirror of `Cluster::pump_control` + `execute_control`: evaluate
    /// rules over newly sealed frames and apply the firings to the
    /// simulated fleet state.
    pub fn pump_control(&mut self) -> Vec<ActionRecord> {
        let frames = self.telemetry.frames_since(self.control.cursor());
        let mut out = Vec::new();
        for frame in &frames {
            let firings = self.control.evaluate(frame);
            for firing in firings {
                let outcome = self.execute_control(&firing);
                out.push(self.control.record(&firing, outcome));
            }
        }
        out
    }

    fn execute_control(&mut self, firing: &telemetry::Firing) -> String {
        match firing.action {
            ControlAction::DrainDevice => {
                let id = firing.device.expect("DrainDevice rules are per-device scoped");
                if self.down[id] {
                    format!("device {id} already stopped")
                } else {
                    // Mirror of `stop_device`'s router-visible effects;
                    // the frozen backlog horizon stays, exactly as the
                    // threaded drain leaves it.
                    self.down[id] = true;
                    self.last_topology[id] = None;
                    self.warm[id].clear();
                    format!("drained device {id}")
                }
            }
            ControlAction::SetAdmissionMargin { priority, margin_ms } => {
                self.admission_margin_ms[priority.index()] = Some(margin_ms);
                format!("admission margin for {} set to {margin_ms} ms", priority.label())
            }
            ControlAction::Alert => "alert".to_string(),
            ControlAction::UndrainDevice => {
                let id = firing.device.expect("UndrainDevice rules are per-device scoped");
                if self.down[id] {
                    // Mirror of `restart_device`: fresh horizon, cold
                    // affinity memory, re-armed drain rules.
                    self.down[id] = false;
                    self.last_topology[id] = None;
                    self.warm[id].clear();
                    self.backlog_ms[id] = 0.0;
                    self.control.reset_device(id);
                    format!("restored device {id}")
                } else {
                    format!("device {id} already live")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::LoadGenConfig;

    #[test]
    fn event_queue_orders_by_time_then_push_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a1");
        q.push(2.0, "b");
        q.push(1.0, "a2");
        q.push(0.0, "zero");
        let mut seen = Vec::new();
        while let Some((t, v)) = q.pop() {
            seen.push((t, v));
        }
        assert_eq!(
            seen,
            vec![(0.0, "zero"), (1.0, "a1"), (1.0, "a2"), (2.0, "b"), (3.0, "c")],
            "ties must pop in push order"
        );
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn event_queue_rejects_bad_timestamps() {
        EventQueue::new().push(f64::NAN, ());
    }

    fn mix() -> Vec<(Topology, f64)> {
        vec![
            (Topology::new(16, 256, 4, 64), 4.0),
            (Topology::new(32, 256, 4, 64), 2.0),
            (Topology::new(16, 512, 8, 64), 1.0),
        ]
    }

    fn sim(qos: QosPolicy, fused_service: bool) -> (FleetSim, LoadGen) {
        let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        let mut workload = WorkloadProfile::default();
        for (t, s) in &mix() {
            workload.push(t.clone(), *s);
        }
        let cluster = ClusterConfig { qos, ..ClusterConfig::default() };
        let fs = FleetSim::new(devices.clone(), &workload, DesConfig { cluster, fused_service })
            .unwrap();
        let gen = LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix(), 0.9, 0x5eed));
        (fs, gen)
    }

    #[test]
    fn des_conserves_and_reproduces_bit_exactly() {
        let run = || {
            let (mut fs, mut gen) = sim(QosPolicy::SlackEdf, false);
            let report = fs.run(&mut gen, 400);
            fs.seal_telemetry();
            (report, fs.telemetry().to_jsonl())
        };
        let (a, jsonl_a) = run();
        let (b, jsonl_b) = run();
        assert!(a.conserved());
        assert_eq!(a.offered, 400);
        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.totals.slo.met, b.totals.slo.met);
        assert_eq!(a.totals.slo.missed, b.totals.slo.missed);
        for i in 0..3 {
            assert_eq!(
                a.totals.slo.sojourn[i].sum().to_bits(),
                b.totals.slo.sojourn[i].sum().to_bits(),
                "class {i} sojourn sum must be bit-identical"
            );
        }
        assert_eq!(jsonl_a, jsonl_b, "telemetry ledgers must be byte-identical");
        assert!(a.virtual_ms > 0.0);
        assert_eq!(a.events, 400 + a.served + a.totals.sharded);
    }

    #[test]
    fn lazy_load_source_matches_pregenerated_trace() {
        let (mut lazy, mut gen) = sim(QosPolicy::SlackEdf, false);
        let a = lazy.run(&mut gen, 250);
        lazy.seal_telemetry();

        let (mut eager, mut gen2) = sim(QosPolicy::SlackEdf, false);
        let arrivals = gen2.generate_n(250);
        let b = eager.run_trace(&arrivals);
        eager.seal_telemetry();

        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(lazy.telemetry().to_jsonl(), eager.telemetry().to_jsonl());
    }

    #[test]
    fn fused_service_shortens_long_sl_virtual_time() {
        let mix = vec![(Topology::new(512, 128, 2, 64), 1.0)];
        let devices: Vec<DeviceSpec> = (0..2).map(DeviceSpec::u55c_long).collect();
        let mut workload = WorkloadProfile::default();
        workload.push(mix[0].0.clone(), 1.0);
        let run = |fused_service| {
            let cfg = DesConfig {
                cluster: ClusterConfig { qos: QosPolicy::SlackEdf, ..ClusterConfig::default() },
                fused_service,
            };
            let mut fs = FleetSim::new(devices.clone(), &workload, cfg).unwrap();
            let mut gen =
                LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix.clone(), 0.8, 7));
            fs.run(&mut gen, 40)
        };
        let reference = run(false);
        let fused = run(true);
        assert!(reference.conserved() && fused.conserved());
        // SL=512 is past FUSED_SL_THRESHOLD, so every request is billed
        // the corrected per-tile trace — strictly less fabric occupancy.
        let ref_busy: f64 = reference.device_busy_ms.iter().sum();
        let fused_busy: f64 = fused.device_busy_ms.iter().sum();
        assert!(
            fused_busy < ref_busy,
            "fused-billed occupancy {fused_busy} ms !< reference {ref_busy} ms"
        );
    }

    #[test]
    fn control_rules_drain_and_tighten_in_virtual_time() {
        use super::super::telemetry::{RuleScope, RuleSignal};
        use crate::coordinator::Priority;
        let (mut fs, mut gen) = sim(QosPolicy::SlackEdf, false);
        fs.add_control_rule(ControlRule {
            name: "tighten-low".to_string(),
            scope: RuleScope::Fleet,
            signal: RuleSignal::ShedCount,
            threshold: 0.0,
            for_windows: 1,
            action: ControlAction::SetAdmissionMargin {
                priority: Priority::Low,
                margin_ms: 5.0,
            },
        });
        let report = fs.run(&mut gen, 600);
        assert!(report.conserved());
        if report.shed > 0 {
            // The rule fired on the first shedding window and installed
            // the margin through the DES-local execution hook.
            assert!(
                !fs.control_log().is_empty(),
                "sheds occurred but the control rule never fired"
            );
            assert_eq!(fs.admission_margin_ms[Priority::Low.index()], Some(5.0));
            assert!(fs.control_log_jsonl().contains("tighten-low"));
        }
    }
}
