//! The cluster router/dispatcher: one ingress over N device servers.
//!
//! Routing policy, in priority order (see [`order_candidates`]):
//!
//! 1. **Hot affinity** — the device the router last sent this topology
//!    to needs no reprogramming; keeping a topology on its device is
//!    `BatchPolicy::GroupByTopology` lifted to the fleet.
//! 2. **Warm affinity** — a device holding the topology in its program
//!    cache replays cached registers instead of re-deriving the
//!    program; the router tracks each device's warm set with a
//!    [`WarmSet`] mirror of `ProgramCache` (DESIGN.md §13).
//! 3. **Placement affinity** — the planner's preferred device order
//!    (weight tiles pinned in BRAM).
//! 4. **Least-loaded** — fewest requests waiting in the device's
//!    ingress queue.
//!
//! Every request also streams telemetry events (ingress, completion,
//! shed, reject) into the windowed [`FrameAggregator`]; the
//! [`ControlPlane`] owned by [`Cluster`] evaluates threshold rules over
//! the sealed frames ([`Cluster::pump_control`]).
//!
//! Backpressure is failover, not failure: a full device queue bounces
//! the request (operands returned, not cloned) to the next candidate,
//! up to `max_retries` bounces, after which the router blocks on the
//! best candidate rather than spin.  A topology no single device admits
//! is head-sharded per the placement plan: two half-requests on two
//! devices, rejoined with a host-side column concat ([`super::shard`]).

use super::fleet::{DeviceHealth, FleetStats, RouterTotals};
use super::placement::{PlacementPlan, PlacementPlanner, WorkloadProfile};
use super::shard::ShardPlan;
use super::telemetry::{
    self, ActionRecord, ControlAction, ControlPlane, ControlRule, DeviceTouch, Firing,
    FrameAggregator, Heat, TelemetryConfig, TelemetryEvent, TelemetrySnapshot,
};
use super::DeviceSpec;
use crate::accel::{FamousAccelerator, DEFAULT_PROGRAM_CACHE};
use crate::config::Topology;
use crate::coordinator::{
    BatchPolicy, Coordinator, CoordinatorStats, IntegrityVerdict, Priority, Request, Response,
    SchedulerConfig, Server, ServerConfig, ServerHandle, SubmitError,
};
use crate::metrics::OpCount;
use crate::rng::XorShift64;
use anyhow::{anyhow, bail, Result};
use std::sync::{Arc, Mutex};

/// Fleet-level QoS routing policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QosPolicy {
    /// PR-1 routing: hot affinity, then placement preference, then
    /// least-loaded.  Deadlines are accounted but never acted on.
    #[default]
    Affinity,
    /// Slack-aware routing: candidates that can meet the deadline under
    /// the backlog model come first (hot/planned/earliest-completion
    /// among them), and a `Low` request no device can serve in time is
    /// shed with an explicit [`QosOutcome::Shed`] instead of queueing
    /// to die.  Pair with `BatchPolicy::EdfWithinWindow` per device
    /// ([`ClusterConfig::qos`]).
    SlackEdf,
}

/// What the router does when a request exhausts its bounce budget
/// (`max_retries` Busy hand-backs) with every candidate still full.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SaturationPolicy {
    /// Block for queue space on the best candidate — backpressure
    /// propagates to the client and no request is ever dropped.
    #[default]
    Block,
    /// Hand the request back as a typed [`QosOutcome::Saturated`]
    /// instead of blocking, so the caller decides (re-submit, downgrade,
    /// drop).  Pairs with the bounded-backoff bounce loop.
    Typed,
}

/// How the router's real-time waits (the Busy-bounce backoff) pass:
/// against the host's wall clock, or as bookkept advances of a virtual
/// clock that never stall the calling thread.  Everything *modeled*
/// (arrivals, backlog horizons, deadlines, telemetry windows) already
/// runs on the virtual request clock; this knob covers the one place
/// the router touches host time, so a virtual-time harness (DESIGN.md
/// §16) is never blocked by a wall-clock sleep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockMode {
    /// Backoff sleeps on `std::thread::sleep` (production serving).
    #[default]
    Wall,
    /// Backoff accrues on an atomic virtual counter and returns
    /// immediately ([`VirtualClock`]).
    Virtual,
}

/// The router's clock seam: every real-time wait goes through this
/// trait so virtual-time mode can advance a counter instead of
/// stalling an event loop.
pub trait Clock: Send + Sync {
    fn sleep(&self, d: std::time::Duration);
    /// Total virtual time accrued by `sleep` calls (0 for a wall
    /// clock, whose waits really elapsed).
    fn slept_micros(&self) -> u64 {
        0
    }
}

/// [`ClockMode::Wall`]: waits really block the calling thread.
#[derive(Debug, Default)]
pub struct WallClock;

impl Clock for WallClock {
    fn sleep(&self, d: std::time::Duration) {
        std::thread::sleep(d);
    }
}

/// [`ClockMode::Virtual`]: waits accrue on an atomic counter and return
/// immediately, so backoff advances virtual time instead of stalling
/// whoever drives the clock.
#[derive(Debug, Default)]
pub struct VirtualClock {
    micros: std::sync::atomic::AtomicU64,
}

impl Clock for VirtualClock {
    fn sleep(&self, d: std::time::Duration) {
        self.micros.fetch_add(d.as_micros() as u64, std::sync::atomic::Ordering::Relaxed);
    }

    fn slept_micros(&self) -> u64 {
        self.micros.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Cluster tuning.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Per-device scheduler (batching) configuration.
    pub scheduler: SchedulerConfig,
    /// Per-device server (ingress queue) configuration.
    pub server: ServerConfig,
    /// Backpressure bounces before blocking on the best candidate.
    pub max_retries: usize,
    /// Fleet-level routing policy (DESIGN.md §11).
    pub qos: QosPolicy,
    /// Telemetry windowing/ring tuning (DESIGN.md §13).
    pub telemetry: TelemetryConfig,
    /// Bounce-budget exhaustion behavior (DESIGN.md §15).
    pub saturation: SaturationPolicy,
    /// Wall vs virtual backoff time (DESIGN.md §16).
    pub clock: ClockMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            scheduler: SchedulerConfig::default(),
            server: ServerConfig::default(),
            max_retries: 3,
            qos: QosPolicy::Affinity,
            telemetry: TelemetryConfig::default(),
            saturation: SaturationPolicy::Block,
            clock: ClockMode::Wall,
        }
    }
}

impl ClusterConfig {
    /// QoS serving preset: slack-aware routing at the fleet level plus
    /// EDF-within-window batching on every device.
    pub fn qos() -> Self {
        ClusterConfig {
            scheduler: SchedulerConfig {
                policy: BatchPolicy::EdfWithinWindow,
                ..SchedulerConfig::default()
            },
            qos: QosPolicy::SlackEdf,
            ..ClusterConfig::default()
        }
    }
}

/// One completed cluster request.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    pub id: u64,
    /// The topology as the client requested it (the full shape for
    /// sharded requests).
    pub topology: Topology,
    /// Functional output, `SL × d_model` of the requested topology.
    pub output: Vec<f32>,
    /// Modeled fabric latency: the slower half for sharded requests
    /// (halves run concurrently).
    pub fabric_ms: f64,
    /// Modeled throughput for this request's work.
    pub gops: f64,
    /// Whether any serving device reprogrammed for this request's batch.
    pub reprogrammed: bool,
    /// Devices that served it (two when sharded).
    pub devices: Vec<usize>,
    pub sharded: bool,
    /// QoS class the request carried.
    pub priority: Priority,
    /// Absolute deadline on the virtual clock, if any.
    pub deadline_ms: Option<f64>,
    /// Modeled completion time on the virtual clock (arrival + queue
    /// wait under the backlog model + fabric service).
    pub completed_ms: f64,
    /// `completed_ms > deadline_ms` (always false for best-effort).
    pub deadline_missed: bool,
    /// ABFT integrity verdict for the served output (DESIGN.md §15):
    /// `Clean` (every checksum held), `Recovered` (a breach was detected
    /// and a scrub-retry or cross-device re-execution produced this
    /// verified-clean output), or `Corrupt` (containment failed — the
    /// output is flagged, never silently served).  Worst-of for sharded
    /// requests.
    pub verdict: IntegrityVerdict,
}

/// Outcome of a QoS-routed request: served, or explicitly shed at
/// ingress because no device could meet its deadline under the backlog
/// model.  With default admission margins only `Low` is ever shed; the
/// telemetry control plane can install margins for other classes
/// ([`ClusterHandle::set_admission_margin`], DESIGN.md §13).
#[derive(Clone, Debug)]
pub enum QosOutcome {
    Served(ClusterResponse),
    Shed(ShedNotice),
    /// The request exhausted its bounce budget with every candidate's
    /// ingress still full ([`SaturationPolicy::Typed`] only — under the
    /// default `Block` policy the router blocks instead).
    Saturated(SaturationNotice),
}

impl QosOutcome {
    pub fn served(self) -> Option<ClusterResponse> {
        match self {
            QosOutcome::Served(r) => Some(r),
            QosOutcome::Shed(_) | QosOutcome::Saturated(_) => None,
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, QosOutcome::Shed(_))
    }

    pub fn is_saturated(&self) -> bool {
        matches!(self, QosOutcome::Saturated(_))
    }
}

/// Why a request was shed (returned to the client, never silent).
#[derive(Clone, Debug)]
pub struct ShedNotice {
    pub id: u64,
    pub priority: Priority,
    pub deadline_ms: f64,
    /// Best completion any admitting device could offer under the
    /// backlog model — already past the deadline.
    pub predicted_completion_ms: f64,
}

/// Why a request was handed back at saturation (never silent).
#[derive(Clone, Debug)]
pub struct SaturationNotice {
    pub id: u64,
    pub priority: Priority,
    /// Busy hand-backs absorbed before giving up.
    pub bounces: u64,
}

struct DeviceEndpoint {
    spec: DeviceSpec,
    /// Behind a mutex so [`Cluster::restart_device`] can swap in a fresh
    /// server's handle (undrain) while client threads route.  Callers
    /// clone the handle out in a statement-scoped lock — never hold it
    /// across a blocking submit.
    handle: Mutex<ServerHandle>,
}

impl DeviceEndpoint {
    fn handle(&self) -> ServerHandle {
        self.handle.lock().unwrap().clone()
    }
}

/// Router-side mirror of one device's topology-keyed `ProgramCache`
/// (same LRU policy, same default capacity).  A device programs exactly
/// the topologies the router dispatches to it, so under the router's
/// one-at-a-time bookkeeping the mirror tracks the device's
/// `ProgramCache::topologies` without a worker round trip — giving
/// ranking a warm-set signal per dispatch.  `CoordinatorStats::
/// cached_topologies` lets tests cross-check mirror against device.
#[derive(Clone, Debug, Default)]
pub struct WarmSet {
    /// Least-recently-used first, like `ProgramCache::topologies`.
    lru: std::collections::VecDeque<Topology>,
}

impl WarmSet {
    pub(crate) fn contains(&self, topo: &Topology) -> bool {
        self.lru.contains(topo)
    }

    pub(crate) fn touch(&mut self, topo: &Topology) {
        if let Some(pos) = self.lru.iter().position(|t| t == topo) {
            self.lru.remove(pos);
        }
        self.lru.push_back(topo.clone());
        while self.lru.len() > DEFAULT_PROGRAM_CACHE {
            self.lru.pop_front();
        }
    }

    pub(crate) fn clear(&mut self) {
        self.lru.clear();
    }

    /// Cached topologies, LRU first (mirrors `ProgramCache::topologies`).
    pub fn topologies(&self) -> Vec<Topology> {
        self.lru.iter().cloned().collect()
    }
}

#[derive(Default)]
struct RouterState {
    /// Router's view of each device's currently-programmed topology.
    last_topology: Vec<Option<Topology>>,
    /// Modeled completion horizon per device, in absolute virtual-clock
    /// ms: the time the device would finish everything the router has
    /// dispatched to it, under the analytical service model (DESIGN.md
    /// §11).  Queue delay for a request arriving at `t` is
    /// `max(backlog, t) − t`.
    backlog_ms: Vec<f64>,
    /// Devices known dead to the router (`Cluster::fail_device` /
    /// `Cluster::stop_device`).  A dead device's frozen `backlog_ms`
    /// horizon would otherwise look ever more attractive as the live
    /// fleet's horizons advance; the backlog model observes health so
    /// `SlackEdf` ranks a dead horizon as infeasible instead of routing
    /// to it (ROADMAP PR-4 follow-up).
    down: Vec<bool>,
    /// Per-device program-cache mirror (warm-affinity routing signal).
    warm: Vec<WarmSet>,
    /// Admission margin per priority class (indexed by
    /// `Priority::index()`): `Some(m)` sheds a deadline request unless
    /// some device can finish `m` ms before the deadline; `None`
    /// disables shedding for the class.  Default: only `Low` sheds,
    /// with zero margin.  The control plane tightens these.
    admission_margin_ms: [Option<f64>; 3],
    totals: RouterTotals,
}

/// Default admission margins: `Low` sheds at zero margin, `High` and
/// `Normal` are never shed (they run late instead).  Shared with the
/// discrete-event mirror ([`super::des`]), which must admit identically.
pub(crate) const DEFAULT_ADMISSION_MARGIN_MS: [Option<f64>; 3] = [None, None, Some(0.0)];

struct Shared {
    devices: Vec<DeviceEndpoint>,
    plan: PlacementPlan,
    max_retries: usize,
    qos: QosPolicy,
    saturation: SaturationPolicy,
    /// Real-time wait seam (bounce backoff): wall or virtual.
    clock: Arc<dyn Clock>,
    state: Mutex<RouterState>,
    telemetry: Mutex<FrameAggregator>,
}

/// A running fleet: per-device servers plus the routing front-end.
pub struct Cluster {
    shared: Arc<Shared>,
    /// `None` once a device has been drained via [`Cluster::stop_device`].
    servers: Vec<Option<Server>>,
    early_stats: Vec<Option<CoordinatorStats>>,
    /// Devices killed via [`Cluster::fail_device`] (reported `Failed`,
    /// not `Stopped`).
    failed: Vec<bool>,
    /// Boot configuration, kept so [`Cluster::restart_device`] can
    /// rebuild a drained device's server exactly as `start` did.
    scheduler: SchedulerConfig,
    server_cfg: ServerConfig,
    /// Threshold rules + audit log, evaluated over sealed frames by
    /// [`Cluster::pump_control`].
    control: ControlPlane,
}

/// Cloneable client handle (safe to share across request threads).
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

impl Cluster {
    /// Start one coordinator server per device (sim-datapath backend —
    /// the PJRT path needs per-process artifacts and is stubbed offline)
    /// and plan placement for the expected workload.
    pub fn start(
        devices: Vec<DeviceSpec>,
        workload: &WorkloadProfile,
        config: ClusterConfig,
    ) -> Result<Cluster> {
        if devices.is_empty() {
            bail!("cluster needs at least one device");
        }
        // Routing indexes devices by id; renumber to be safe.
        let mut devices = devices;
        for (i, d) in devices.iter_mut().enumerate() {
            d.id = i;
        }
        let plan = PlacementPlanner::default().plan(&devices, workload);
        let mut endpoints = Vec::with_capacity(devices.len());
        let mut servers = Vec::with_capacity(devices.len());
        for spec in devices {
            // The booted device runs at its *real* (possibly silently
            // derated) clock; the router keeps planning with the
            // advertised `spec.sim` model (see `DeviceSpec::silent_derate`).
            let mut sim = spec.sim.clone();
            sim.build.clock_hz *= spec.silent_derate;
            let sched = config.scheduler;
            let server = Server::start(
                move || {
                    let accel = FamousAccelerator::with_sim_datapath(sim);
                    Coordinator::new(accel, sched)
                },
                config.server,
            );
            endpoints.push(DeviceEndpoint { spec, handle: Mutex::new(server.handle()) });
            servers.push(Some(server));
        }
        let n = endpoints.len();
        let clock: Arc<dyn Clock> = match config.clock {
            ClockMode::Wall => Arc::new(WallClock),
            ClockMode::Virtual => Arc::new(VirtualClock::default()),
        };
        let shared = Arc::new(Shared {
            devices: endpoints,
            plan,
            max_retries: config.max_retries,
            qos: config.qos,
            saturation: config.saturation,
            clock,
            state: Mutex::new(RouterState {
                last_topology: vec![None; n],
                backlog_ms: vec![0.0; n],
                down: vec![false; n],
                warm: vec![WarmSet::default(); n],
                admission_margin_ms: DEFAULT_ADMISSION_MARGIN_MS,
                totals: RouterTotals::default(),
            }),
            telemetry: Mutex::new(FrameAggregator::new(config.telemetry, n)),
        });
        Ok(Cluster {
            shared,
            servers,
            early_stats: vec![None; n],
            failed: vec![false; n],
            scheduler: config.scheduler,
            server_cfg: config.server,
            control: ControlPlane::default(),
        })
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: Arc::clone(&self.shared) }
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.shared.plan
    }

    pub fn device_count(&self) -> usize {
        self.shared.devices.len()
    }

    /// Drain one device (elasticity / maintenance): its server shuts
    /// down and subsequent routing fails over to the rest of the fleet.
    /// Returns its stats, or None if already stopped.
    pub fn stop_device(&mut self, id: usize) -> Option<CoordinatorStats> {
        let server = self.servers.get_mut(id)?.take()?;
        let stats = server.shutdown();
        self.early_stats[id] = Some(stats.clone());
        // Drop the router's affinity memory for the drained device so it
        // stops ranking as "hot" for the topology it last served, and
        // mark it down so the backlog model stops treating its frozen
        // horizon as feasible capacity.
        let mut st = self.shared.state.lock().unwrap();
        st.last_topology[id] = None;
        st.down[id] = true;
        st.warm[id].clear();
        drop(st);
        Some(stats)
    }

    /// Simulate a device crash (chaos hook for the soak suite): the
    /// worker is killed without a drain — queued work is dropped exactly
    /// as a process death would drop it — and fleet reports flag the
    /// device `Failed` rather than `Stopped`.  The router is told (both
    /// ranking arms demote the corpse to last resort, the backlog model
    /// marks its horizon infeasible), so accepted requests reroute
    /// without probing the dead ingress; the bounce path remains the
    /// backstop for deaths the router was never told about.
    pub fn fail_device(&mut self, id: usize) -> bool {
        let Some(server) = self.servers.get_mut(id).and_then(|s| s.take()) else {
            return false;
        };
        server.kill();
        self.failed[id] = true;
        let mut st = self.shared.state.lock().unwrap();
        st.last_topology[id] = None;
        st.down[id] = true;
        st.warm[id].clear();
        drop(st);
        true
    }

    /// Restore a drained (or failed) device: boot a fresh server from
    /// the device's original spec — same factory, scheduler, and queue
    /// config as [`Cluster::start`], including any silent derate or
    /// fault plan the spec carries — swap its handle into the routing
    /// table, and clear the down flag so ranking sees live capacity
    /// again.  The restarted worker begins with an empty queue, cold
    /// program cache, and a re-prepared (fresh-epoch) weight stage.
    /// Returns `false` if the device is already live.  This is the
    /// execution hook behind [`ControlAction::UndrainDevice`]
    /// (DESIGN.md §15).
    pub fn restart_device(&mut self, id: usize) -> bool {
        let Some(slot) = self.servers.get_mut(id) else {
            return false;
        };
        if slot.is_some() {
            return false;
        }
        let spec = self.shared.devices[id].spec.clone();
        let mut sim = spec.sim.clone();
        sim.build.clock_hz *= spec.silent_derate;
        let sched = self.scheduler;
        let server = Server::start(
            move || {
                let accel = FamousAccelerator::with_sim_datapath(sim);
                Coordinator::new(accel, sched)
            },
            self.server_cfg,
        );
        // Swap the routing handle in its own statement-scoped lock
        // (never nested with the state lock — rank() orders state →
        // handle).
        *self.shared.devices[id].handle.lock().unwrap() = server.handle();
        *slot = Some(server);
        self.failed[id] = false;
        let mut st = self.shared.state.lock().unwrap();
        st.down[id] = false;
        st.last_topology[id] = None;
        st.warm[id].clear();
        // Fresh worker, empty queue: its completion horizon restarts at
        // the clock epoch (queue delay is max(backlog, arrival) − arrival,
        // so a zero horizon just means "no queue").
        st.backlog_ms[id] = 0.0;
        drop(st);
        true
    }

    /// Device names in routing-id order (dashboard labels).
    pub fn device_names(&self) -> Vec<String> {
        self.shared.devices.iter().map(|d| d.spec.name.clone()).collect()
    }

    /// Install a control rule, evaluated per sealed telemetry frame by
    /// [`Cluster::pump_control`].
    pub fn add_control_rule(&mut self, rule: ControlRule) {
        self.control.add_rule(rule);
    }

    /// The control plane's audit log (every executed action).
    pub fn control_log(&self) -> &[ActionRecord] {
        self.control.log()
    }

    /// The audit log as JSONL (reproducibility artifact).
    pub fn control_log_jsonl(&self) -> String {
        self.control.log_jsonl()
    }

    /// Snapshot the telemetry ring + running totals.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        self.shared.telemetry.lock().unwrap().snapshot()
    }

    /// Virtual time accrued by the router's backoff waits, in µs —
    /// always 0 under [`ClockMode::Wall`], whose waits really elapsed.
    pub fn backoff_slept_micros(&self) -> u64 {
        self.shared.clock.slept_micros()
    }

    /// Seal every outstanding partial frame (end of run / final report).
    pub fn seal_telemetry(&self) {
        self.shared.telemetry.lock().unwrap().seal_all();
    }

    /// Evaluate control rules over every frame sealed since the last
    /// pump, execute the firings through cluster hooks (drain device,
    /// set admission margin), and return the audit records appended.
    /// Deterministic: frames are a pure function of the seeded virtual
    /// clock, and rule evaluation is a pure state machine over them.
    pub fn pump_control(&mut self) -> Vec<ActionRecord> {
        let frames = {
            let agg = self.shared.telemetry.lock().unwrap();
            agg.frames_since(self.control.cursor())
        };
        let mut out = Vec::new();
        for frame in &frames {
            let firings = self.control.evaluate(frame);
            for firing in firings {
                let outcome = self.execute_control(&firing);
                out.push(self.control.record(&firing, outcome));
            }
        }
        out
    }

    fn execute_control(&mut self, firing: &Firing) -> String {
        match firing.action {
            ControlAction::DrainDevice => {
                let id = firing.device.expect("DrainDevice rules are per-device scoped");
                if self.stop_device(id).is_some() {
                    format!("drained device {id}")
                } else {
                    format!("device {id} already stopped")
                }
            }
            ControlAction::SetAdmissionMargin { priority, margin_ms } => {
                let mut st = self.shared.state.lock().unwrap();
                st.admission_margin_ms[priority.index()] = Some(margin_ms);
                drop(st);
                format!("admission margin for {} set to {margin_ms} ms", priority.label())
            }
            ControlAction::Alert => "alert".to_string(),
            ControlAction::UndrainDevice => {
                let id = firing.device.expect("UndrainDevice rules are per-device scoped");
                if self.restart_device(id) {
                    // Give drain rules a fresh observation window on the
                    // restored device instead of a stale latched streak.
                    self.control.reset_device(id);
                    format!("restored device {id}")
                } else {
                    format!("device {id} already live")
                }
            }
        }
    }

    /// Live (pre-shutdown) fleet snapshot: per-device stats fetched from
    /// the running servers (each answers after its current serving
    /// round), merged with the router's current totals.  Lets operators
    /// observe cluster GOPS / reconfigurations / cache hit rates mid-run
    /// without draining anything.  Requests fan out to every device
    /// before any reply is awaited, so absent ingress backpressure the
    /// snapshot costs the slowest device's round, not the sum (a device
    /// with a full ingress queue still blocks its send — the request
    /// shares the bounded job channel).  Each device carries a
    /// [`DeviceHealth`] flag: a deliberately drained device reports
    /// `Stopped` with its final stats, while one whose worker died
    /// reports `Failed` with default (zero) stats — zeroed *unknowns*,
    /// no longer indistinguishable from an idle device.
    pub fn fleet_snapshot(&self) -> FleetStats {
        let mut health = Vec::with_capacity(self.servers.len());
        let pending: Vec<Option<std::sync::mpsc::Receiver<CoordinatorStats>>> = self
            .servers
            .iter()
            .enumerate()
            .map(|(i, server)| match server {
                None => {
                    health.push(if self.failed[i] {
                        DeviceHealth::Failed
                    } else {
                        DeviceHealth::Stopped
                    });
                    None
                }
                Some(s) => match s.handle().request_stats() {
                    Ok(rx) => {
                        health.push(DeviceHealth::Live);
                        Some(rx)
                    }
                    Err(_) => {
                        health.push(DeviceHealth::Failed);
                        None
                    }
                },
            })
            .collect();
        let coord: Vec<CoordinatorStats> = pending
            .into_iter()
            .enumerate()
            .map(|(i, rx)| match rx {
                Some(rx) => rx.recv().unwrap_or_else(|_| {
                    // Worker died between the request and the reply.
                    health[i] = DeviceHealth::Failed;
                    CoordinatorStats::default()
                }),
                None => self.early_stats[i].clone().unwrap_or_default(),
            })
            .collect();
        let specs: Vec<DeviceSpec> = self.shared.devices.iter().map(|d| d.spec.clone()).collect();
        let totals = self.shared.state.lock().unwrap().totals.clone();
        FleetStats::assemble_with_health(&specs, coord, health, totals)
    }

    /// Stop every device and assemble the fleet report.  Devices that
    /// served until this clean shutdown report `Live`; ones drained
    /// earlier via [`Self::stop_device`] report `Stopped`; ones whose
    /// worker had already died (engine failure) report `Failed` — their
    /// joined stats stop at the crash.
    pub fn shutdown(mut self) -> FleetStats {
        let mut coord = Vec::with_capacity(self.servers.len());
        let mut health = Vec::with_capacity(self.servers.len());
        for (i, server) in self.servers.into_iter().enumerate() {
            let stats = match server {
                Some(s) => {
                    // Probe before sending the shutdown message: a closed
                    // ingress here means the worker exited on its own.
                    health.push(if s.handle().is_alive() {
                        DeviceHealth::Live
                    } else {
                        DeviceHealth::Failed
                    });
                    s.shutdown()
                }
                None => {
                    health.push(if self.failed[i] {
                        DeviceHealth::Failed
                    } else {
                        DeviceHealth::Stopped
                    });
                    self.early_stats[i].take().unwrap_or_default()
                }
            };
            coord.push(stats);
        }
        let specs: Vec<DeviceSpec> =
            self.shared.devices.iter().map(|d| d.spec.clone()).collect();
        let totals = self.shared.state.lock().unwrap().totals.clone();
        FleetStats::assemble_with_health(&specs, coord, health, totals)
    }
}

/// Pure ranking input: one candidate device's routing signals.
#[derive(Clone, Debug)]
pub struct CandidateView {
    pub id: usize,
    /// Router last routed this topology here (no reprogramming needed).
    pub hot: bool,
    /// Topology resident in the device's program cache (register replay
    /// instead of full program derivation) per the router's [`WarmSet`]
    /// mirror.
    pub warm: bool,
    /// Position in the placement plan's preference list (usize::MAX if
    /// the plan does not mention this device for the topology).
    pub preference: usize,
    /// Requests waiting in the device's ingress queue.
    pub pending: usize,
}

/// Order candidates best-first: hot, then warm, then planner
/// preference, then least-loaded, then id (determinism).  Pure —
/// unit-tested directly.
pub fn order_candidates(mut views: Vec<CandidateView>) -> Vec<usize> {
    views.sort_by_key(|v| (!v.hot as u8, !v.warm as u8, v.preference, v.pending, v.id));
    views.into_iter().map(|v| v.id).collect()
}

/// One candidate's slack-routing signals ([`QosPolicy::SlackEdf`]).
#[derive(Clone, Debug)]
pub struct SlackView {
    pub id: usize,
    /// Router last routed this topology here (no reprogramming needed).
    pub hot: bool,
    /// Topology in the device's program cache ([`WarmSet`] mirror).
    pub warm: bool,
    /// Position in the placement plan's preference list.
    pub preference: usize,
    /// Modeled completion time if dispatched now (virtual-clock ms).
    pub est_completion_ms: f64,
    /// `deadline − est_completion` (+∞ when the request has no
    /// deadline).
    pub slack_ms: f64,
}

/// Order slack-aware candidates best-first: devices that meet the
/// deadline come first (hot, then warm, then planned, then earliest
/// completion among them — "prefer warm when slack permits"), then the
/// provably-late ones by least lateness; id breaks every tie
/// (determinism).  Pure — unit-tested directly.
pub fn order_candidates_by_slack(mut views: Vec<SlackView>) -> Vec<usize> {
    use std::cmp::Ordering;
    views.sort_by(|a, b| {
        let fa = a.slack_ms >= 0.0;
        let fb = b.slack_ms >= 0.0;
        let key = fb.cmp(&fa).then_with(|| {
            if fa && fb {
                (!a.hot)
                    .cmp(&!b.hot)
                    .then((!a.warm).cmp(&!b.warm))
                    .then(a.preference.cmp(&b.preference))
                    .then(
                        a.est_completion_ms
                            .partial_cmp(&b.est_completion_ms)
                            .unwrap_or(Ordering::Equal),
                    )
            } else {
                b.slack_ms.partial_cmp(&a.slack_ms).unwrap_or(Ordering::Equal)
            }
        });
        key.then(a.id.cmp(&b.id))
    });
    views.into_iter().map(|v| v.id).collect()
}

/// QoS metadata peeled off a request before it is moved into dispatch.
#[derive(Clone, Copy, Debug)]
struct QosMeta {
    priority: Priority,
    arrival_ms: f64,
    deadline_ms: Option<f64>,
}

impl QosMeta {
    fn of(req: &Request) -> Self {
        QosMeta {
            priority: req.priority,
            arrival_ms: req.arrival_ms,
            deadline_ms: req.deadline_ms,
        }
    }
}

impl ClusterHandle {
    /// Serve one request, blocking until the response: routes to a
    /// single device when possible, transparently head-shards otherwise.
    /// A shed request (QoS policies only) surfaces as an error here; use
    /// [`Self::call_qos`] to observe shedding as a typed outcome.
    pub fn call(&self, req: Request) -> Result<ClusterResponse> {
        match self.call_qos(req)? {
            QosOutcome::Served(resp) => Ok(resp),
            QosOutcome::Shed(s) => bail!(
                "request {} shed: deadline {:.3} ms unreachable (best completion {:.3} ms)",
                s.id,
                s.deadline_ms,
                s.predicted_completion_ms
            ),
            QosOutcome::Saturated(s) => bail!(
                "request {} saturated: every candidate ingress full after {} bounces",
                s.id,
                s.bounces
            ),
        }
    }

    /// Serve one request with an explicit QoS outcome: `Served` with
    /// the response, or `Shed` when the class's admission margin is set
    /// and no admitting device can meet the deadline that much early
    /// under the backlog model (`QosPolicy::SlackEdf` only — `Affinity`
    /// never sheds; default margins shed only `Low`).
    pub fn call_qos(&self, req: Request) -> Result<QosOutcome> {
        let topo = req.topology.clone();
        let meta = QosMeta::of(&req);
        self.telemetry_ingress(&meta);
        let single = self.shared.devices.iter().any(|d| d.spec.admits(&topo));
        let shard = if single {
            None
        } else {
            self.shared
                .plan
                .placement(&topo)
                .and_then(|p| p.shard.clone())
                .or_else(|| ShardPlan::plan(&topo))
                .filter(|s| self.shared.devices.iter().any(|d| d.spec.admits(&s.half)))
        };
        if !single && shard.is_none() {
            self.shared.state.lock().unwrap().totals.rejected += 1;
            self.telemetry_event(TelemetryEvent::Reject { t_ms: meta.arrival_ms });
            bail!("no device admits topology {topo} and no head-shard of it is servable");
        }
        // Admission control: a request whose deadline no admitting
        // device can meet `margin` early is shed explicitly instead of
        // queued to die.  Default margins shed only `Low` (at zero
        // margin); the control plane can install margins for the other
        // classes (DESIGN.md §13).
        if self.shared.qos == QosPolicy::SlackEdf {
            let margin =
                self.shared.state.lock().unwrap().admission_margin_ms[meta.priority.index()];
            if let (Some(margin), Some(deadline)) = (margin, meta.deadline_ms) {
                let check = shard.as_ref().map(|s| &s.half).unwrap_or(&topo);
                if let Some(best) = self.best_completion_ms(check, meta.arrival_ms) {
                    if best > deadline - margin {
                        let mut st = self.shared.state.lock().unwrap();
                        st.totals.slo.record_shed(meta.priority);
                        drop(st);
                        self.telemetry_event(TelemetryEvent::Shed {
                            t_ms: meta.arrival_ms,
                            priority: meta.priority,
                        });
                        return Ok(QosOutcome::Shed(ShedNotice {
                            id: req.id,
                            priority: meta.priority,
                            deadline_ms: deadline,
                            predicted_completion_ms: best,
                        }));
                    }
                }
            }
        }
        let resp = match shard {
            None => {
                let id = req.id;
                let d = match self.call_single_verified(req, None)? {
                    SingleOutcome::Done(d) => d,
                    SingleOutcome::Saturated { bounces } => {
                        return Ok(QosOutcome::Saturated(SaturationNotice {
                            id,
                            priority: meta.priority,
                            bounces,
                        }));
                    }
                };
                let missed = meta.deadline_ms.map(|dl| d.done_ms > dl);
                let mut st = self.shared.state.lock().unwrap();
                st.totals.completed += 1;
                st.totals.slo.record_completion(
                    meta.priority,
                    d.done_ms - meta.arrival_ms,
                    missed,
                );
                drop(st);
                self.telemetry_event(TelemetryEvent::Completion {
                    t_ms: d.done_ms,
                    priority: meta.priority,
                    sojourn_ms: d.done_ms - meta.arrival_ms,
                    missed,
                    sharded: false,
                    bounces: d.bounces,
                    touches: vec![DeviceTouch {
                        device: d.device,
                        heat: d.heat,
                        fused: telemetry::auto_fused_path(&topo),
                        tier: crate::sim::KernelTier::effective(),
                    }],
                });
                ClusterResponse {
                    id: d.resp.id,
                    topology: topo,
                    output: d.resp.output,
                    fabric_ms: d.resp.fabric_ms,
                    gops: d.resp.gops,
                    reprogrammed: d.resp.reprogrammed,
                    devices: vec![d.device],
                    sharded: false,
                    priority: meta.priority,
                    deadline_ms: meta.deadline_ms,
                    completed_ms: d.done_ms,
                    deadline_missed: missed.unwrap_or(false),
                    verdict: d.resp.verdict,
                }
            }
            Some(s) => match self.call_sharded(req, s, &meta)? {
                QosOutcome::Served(r) => r,
                other => return Ok(other),
            },
        };
        Ok(QosOutcome::Served(resp))
    }

    /// The router's warm-set mirror for one device: cached topologies,
    /// LRU first (matches `ProgramCache::topologies` on the device).
    pub fn warm_topologies(&self, device: usize) -> Vec<Topology> {
        let st = self.shared.state.lock().unwrap();
        st.warm.get(device).map(WarmSet::topologies).unwrap_or_default()
    }

    /// Set (or clear, with `None`) the admission margin for a priority
    /// class — the control-plane hook behind
    /// [`ControlAction::SetAdmissionMargin`].
    pub fn set_admission_margin(&self, priority: Priority, margin_ms: Option<f64>) {
        self.shared.state.lock().unwrap().admission_margin_ms[priority.index()] = margin_ms;
    }

    pub fn admission_margin(&self, priority: Priority) -> Option<f64> {
        self.shared.state.lock().unwrap().admission_margin_ms[priority.index()]
    }

    /// Snapshot the telemetry ring + running totals.
    pub fn telemetry(&self) -> TelemetrySnapshot {
        self.shared.telemetry.lock().unwrap().snapshot()
    }

    /// Ingress-side telemetry: refresh the gauges, advance the seal
    /// watermark to this arrival, and record the ingress event.  The
    /// watermark only ever moves on ingress, so completions (recorded
    /// at dispatch bookkeeping time, at or after their request's
    /// arrival) land in open windows — the grace period absorbs
    /// concurrent stragglers.
    fn telemetry_ingress(&self, meta: &QosMeta) {
        let (backlog, down) = {
            let st = self.shared.state.lock().unwrap();
            (st.backlog_ms.clone(), st.down.clone())
        };
        let mut agg = self.shared.telemetry.lock().unwrap();
        agg.observe_gauges(&backlog, &down);
        agg.advance(meta.arrival_ms);
        agg.record(TelemetryEvent::Ingress { t_ms: meta.arrival_ms, priority: meta.priority });
    }

    fn telemetry_event(&self, ev: TelemetryEvent) {
        self.shared.telemetry.lock().unwrap().record(ev);
    }

    /// Best modeled completion over *live* admitting devices for `topo`
    /// (None when nothing admits it): the shed test's "provably late"
    /// bound.  A dead device's frozen horizon is not capacity.
    fn best_completion_ms(&self, topo: &Topology, arrival_ms: f64) -> Option<f64> {
        let st = self.shared.state.lock().unwrap();
        self.shared
            .devices
            .iter()
            .filter(|d| !st.down[d.spec.id] && d.spec.admits(topo))
            .map(|d| st.backlog_ms[d.spec.id].max(arrival_ms) + d.spec.predicted_ms(topo))
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Rank admitting devices for `topo`, best first.  Under
    /// `SlackEdf` the ordering is slack-aware (deadline-feasible
    /// devices first, by modeled completion); under `Affinity` it is
    /// the PR-1 hot/planned/least-loaded order.
    fn rank(&self, topo: &Topology, exclude: Option<usize>, qos: Option<&QosMeta>) -> Vec<usize> {
        let preferred = preferred_devices(&self.shared.plan, topo);
        let st = self.shared.state.lock().unwrap();
        let position = |id: usize| preferred.iter().position(|&p| p == id).unwrap_or(usize::MAX);
        if let (QosPolicy::SlackEdf, Some(meta)) = (self.shared.qos, qos) {
            let views: Vec<SlackView> = self
                .shared
                .devices
                .iter()
                .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
                .map(|d| {
                    // A down device's horizon froze at its death: rank
                    // it infeasible (−∞ slack sorts after every live
                    // candidate, feasible or late) so SlackEdf never
                    // chases a frozen horizon; it stays a candidate of
                    // last resort only.
                    if st.down[d.spec.id] {
                        return SlackView {
                            id: d.spec.id,
                            hot: false,
                            warm: false,
                            preference: usize::MAX,
                            est_completion_ms: f64::INFINITY,
                            slack_ms: f64::NEG_INFINITY,
                        };
                    }
                    let est = st.backlog_ms[d.spec.id].max(meta.arrival_ms)
                        + d.spec.predicted_ms(topo);
                    let hot = st.last_topology[d.spec.id].as_ref() == Some(topo);
                    SlackView {
                        id: d.spec.id,
                        hot,
                        warm: !hot && st.warm[d.spec.id].contains(topo),
                        preference: position(d.spec.id),
                        est_completion_ms: est,
                        slack_ms: meta.deadline_ms.map_or(f64::INFINITY, |dl| dl - est),
                    }
                })
                .collect();
            drop(st);
            return order_candidates_by_slack(views);
        }
        let views: Vec<CandidateView> = self
            .shared
            .devices
            .iter()
            .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
            .map(|d| {
                // A known-down device's empty ingress would rank it
                // least-loaded first forever (one bounce per request);
                // demote it to a candidate of last resort here too.
                if st.down[d.spec.id] {
                    return CandidateView {
                        id: d.spec.id,
                        hot: false,
                        warm: false,
                        preference: usize::MAX,
                        pending: usize::MAX,
                    };
                }
                let hot = st.last_topology[d.spec.id].as_ref() == Some(topo);
                CandidateView {
                    id: d.spec.id,
                    hot,
                    warm: !hot && st.warm[d.spec.id].contains(topo),
                    preference: position(d.spec.id),
                    pending: d.handle().pending(),
                }
            })
            .collect();
        drop(st);
        order_candidates(views)
    }

    /// Route one single-device request with backpressure failover:
    /// Busy hand-backs walk the candidate ranking with bounded
    /// exponential backoff + seeded jitter between probes, up to
    /// `max_retries` bounces; exhaustion either blocks on the best
    /// candidate ([`SaturationPolicy::Block`]) or hands the request
    /// back typed ([`SaturationPolicy::Typed`]).
    fn call_single(&self, req: Request, exclude: Option<usize>) -> Result<SingleOutcome> {
        let topo = req.topology.clone();
        let meta = QosMeta::of(&req);
        let mut candidates = self.rank(&topo, exclude, Some(&meta));
        if candidates.is_empty() {
            // Exclusion left nothing; fall back to the full fleet.
            candidates = self.rank(&topo, None, Some(&meta));
        }
        if candidates.is_empty() {
            self.shared.state.lock().unwrap().totals.rejected += 1;
            self.telemetry_event(TelemetryEvent::Reject { t_ms: meta.arrival_ms });
            bail!("no device in the fleet admits topology {topo}");
        }
        let mut req = req;
        let mut bounces = 0u64;
        let mut idx = 0usize;
        let mut bounced: Vec<usize> = Vec::new();
        loop {
            if bounces >= self.shared.max_retries as u64 {
                if self.shared.saturation == SaturationPolicy::Typed {
                    self.shared.state.lock().unwrap().totals.saturated += 1;
                    return Ok(SingleOutcome::Saturated { bounces });
                }
                // Enough spinning: block for queue space on the best
                // candidate (backpressure propagates to the client).
                // Prefer one that did not just bounce us — a bounce can
                // mean the device is gone, not merely full, and blocking
                // on a dead channel fails a still-servable request.
                let dev = candidates
                    .iter()
                    .copied()
                    .find(|d| !bounced.contains(d))
                    .unwrap_or(candidates[0]);
                let resp = self.shared.devices[dev]
                    .handle()
                    .call_blocking(req)
                    .map_err(|e| anyhow!("device {dev}: {e}"))?;
                return Ok(SingleOutcome::Done(self.record(resp, dev, &topo, &meta, bounces)));
            }
            let dev = candidates[idx % candidates.len()];
            match self.shared.devices[dev].handle().try_call(req) {
                Ok(resp) => {
                    return Ok(SingleOutcome::Done(self.record(resp, dev, &topo, &meta, bounces)))
                }
                Err(SubmitError::Busy(returned)) => {
                    req = returned;
                    bounces += 1;
                    idx += 1;
                    if !bounced.contains(&dev) {
                        bounced.push(dev);
                    }
                    self.shared.state.lock().unwrap().totals.retries += 1;
                    // Real-time backoff before the next probe: the
                    // virtual-clock latency model is untouched, but the
                    // wall-clock spin on a saturated fleet is bounded
                    // and decorrelated across clients.  Routed through
                    // the clock seam so virtual-time mode advances a
                    // counter instead of stalling the event loop.
                    self.shared.clock.sleep(bounce_backoff(bounces, req.id));
                }
                Err(SubmitError::Failed(e)) => bail!("device {dev}: {e}"),
            }
        }
    }

    /// [`Self::call_single`] plus the cross-device half of the ABFT
    /// recovery ladder (DESIGN.md §15).  The coordinator already
    /// scrub-retried locally; a response still flagged `Corrupt` carries
    /// its operands back, so the router re-executes it on another device
    /// (bounded by `max_retries` hops).  A reroute that comes back clean
    /// is relabeled `Recovered`; if every hop fails, the corrupt output
    /// is surfaced with its `Corrupt` verdict — flagged, never silent.
    fn call_single_verified(&self, req: Request, exclude: Option<usize>) -> Result<SingleOutcome> {
        let topo = req.topology.clone();
        let meta = QosMeta::of(&req);
        let id = req.id;
        let mut cur = match self.call_single(req, exclude)? {
            SingleOutcome::Done(d) => d,
            sat => return Ok(sat),
        };
        let mut rerouted = false;
        let mut hops = 0usize;
        while cur.resp.verdict == IntegrityVerdict::Corrupt {
            let inputs = cur.resp.returned_inputs.take();
            let budget = hops < self.shared.max_retries.max(1);
            let (Some(inputs), true) = (inputs, budget) else {
                // Containment failed: count it, flag it, surface it.
                self.shared.state.lock().unwrap().totals.integrity_failed += 1;
                self.telemetry_event(TelemetryEvent::Integrity {
                    t_ms: cur.done_ms,
                    device: cur.device,
                    contained: false,
                });
                return Ok(SingleOutcome::Done(cur));
            };
            hops += 1;
            let bad = cur.device;
            let retry = Request::new(id, topo.clone(), *inputs).with_qos(
                meta.priority,
                meta.arrival_ms,
                meta.deadline_ms,
            );
            match self.call_single(retry, Some(bad)) {
                Ok(SingleOutcome::Done(next)) => {
                    // The breach on `bad` was contained by re-executing
                    // elsewhere (whether or not the new device is clean
                    // — its own verdict gets its own round).
                    self.shared.state.lock().unwrap().totals.integrity_rerouted += 1;
                    self.telemetry_event(TelemetryEvent::Integrity {
                        t_ms: next.done_ms,
                        device: bad,
                        contained: true,
                    });
                    rerouted = true;
                    cur = next;
                }
                Ok(SingleOutcome::Saturated { .. }) | Err(_) => {
                    // No capacity (or no device) to re-execute on: the
                    // original corrupt output is all we have.
                    self.shared.state.lock().unwrap().totals.integrity_failed += 1;
                    self.telemetry_event(TelemetryEvent::Integrity {
                        t_ms: cur.done_ms,
                        device: cur.device,
                        contained: false,
                    });
                    return Ok(SingleOutcome::Done(cur));
                }
            }
        }
        if rerouted {
            cur.resp.verdict = IntegrityVerdict::Recovered;
        }
        Ok(SingleOutcome::Done(cur))
    }

    /// Two half-requests on (preferably) two devices, concat on the
    /// host.  Either half saturating (typed policy only) saturates the
    /// whole request — the other half's work is done but its output is
    /// discarded, and the combined bounce count rides the notice.
    fn call_sharded(&self, req: Request, shard: ShardPlan, meta: &QosMeta) -> Result<QosOutcome> {
        let (lo, hi) = shard.split_inputs(&req.inputs)?;
        let req_lo = Request::new(req.id, shard.half.clone(), lo)
            .with_qos(req.priority, req.arrival_ms, req.deadline_ms);
        let req_hi = Request::new(req.id, shard.half.clone(), hi)
            .with_qos(req.priority, req.arrival_ms, req.deadline_ms);
        // Steer the high half away from the low half's likely device so
        // the halves actually run concurrently when the fleet allows.
        let low_primary = self.rank(&shard.half, None, Some(meta)).first().copied();
        let other = self.clone();
        let hi_worker =
            std::thread::spawn(move || other.call_single_verified(req_hi, low_primary));
        let lo_result = self.call_single_verified(req_lo, None);
        let hi_result =
            hi_worker.join().map_err(|_| anyhow!("shard worker thread panicked"))?;
        let (lo, hi) = match (lo_result?, hi_result?) {
            (SingleOutcome::Done(lo), SingleOutcome::Done(hi)) => (lo, hi),
            (lo, hi) => {
                let bounces = [&lo, &hi]
                    .iter()
                    .map(|o| match o {
                        SingleOutcome::Done(d) => d.bounces,
                        SingleOutcome::Saturated { bounces } => *bounces,
                    })
                    .sum::<u64>();
                return Ok(QosOutcome::Saturated(SaturationNotice {
                    id: req.id,
                    priority: meta.priority,
                    bounces,
                }));
            }
        };
        let output = shard.concat_outputs(&lo.resp.output, &hi.resp.output)?;
        let fabric_ms = lo.resp.fabric_ms.max(hi.resp.fabric_ms);
        let gop = 2.0 * OpCount::paper_convention(&shard.half);
        let done = lo.done_ms.max(hi.done_ms);
        let missed = meta.deadline_ms.map(|dl| done > dl);
        let mut st = self.shared.state.lock().unwrap();
        st.totals.completed += 1;
        st.totals.sharded += 1;
        st.totals.slo.record_completion(meta.priority, done - meta.arrival_ms, missed);
        drop(st);
        let fused = telemetry::auto_fused_path(&shard.half);
        let tier = crate::sim::KernelTier::effective();
        self.telemetry_event(TelemetryEvent::Completion {
            t_ms: done,
            priority: meta.priority,
            sojourn_ms: done - meta.arrival_ms,
            missed,
            sharded: true,
            bounces: lo.bounces + hi.bounces,
            touches: vec![
                DeviceTouch { device: lo.device, heat: lo.heat, fused, tier },
                DeviceTouch { device: hi.device, heat: hi.heat, fused, tier },
            ],
        });
        // Worst-of verdict: a corrupt half corrupts the concat.
        let verdict = match (lo.resp.verdict, hi.resp.verdict) {
            (IntegrityVerdict::Corrupt, _) | (_, IntegrityVerdict::Corrupt) => {
                IntegrityVerdict::Corrupt
            }
            (IntegrityVerdict::Recovered, _) | (_, IntegrityVerdict::Recovered) => {
                IntegrityVerdict::Recovered
            }
            _ => IntegrityVerdict::Clean,
        };
        Ok(QosOutcome::Served(ClusterResponse {
            id: req.id,
            topology: shard.full.clone(),
            output,
            fabric_ms,
            gops: gop / (fabric_ms * 1e-3),
            reprogrammed: lo.resp.reprogrammed || hi.resp.reprogrammed,
            devices: vec![lo.device, hi.device],
            sharded: true,
            priority: meta.priority,
            deadline_ms: meta.deadline_ms,
            completed_ms: done,
            deadline_missed: missed.unwrap_or(false),
            verdict,
        }))
    }

    /// Book-keeping after a device served a (sub-)request: affinity
    /// counters, the device's programmed-topology memory, the warm-set
    /// mirror, and the backlog-model advance that yields the modeled
    /// completion time.
    fn record(
        &self,
        resp: Response,
        dev: usize,
        topo: &Topology,
        meta: &QosMeta,
        bounces: u64,
    ) -> Dispatched {
        let preferred = preferred_devices(&self.shared.plan, topo);
        let mut st = self.shared.state.lock().unwrap();
        let hot = st.last_topology[dev].as_ref() == Some(topo);
        let warm = !hot && st.warm[dev].contains(topo);
        let heat = match (hot, warm) {
            (true, _) => Heat::Hot,
            (false, true) => Heat::Warm,
            (false, false) => Heat::Cold,
        };
        if warm {
            st.totals.warm_hits += 1;
        }
        let planned = preferred.first() == Some(&dev) || self.shared.plan.is_pinned(dev, topo);
        if hot || planned {
            st.totals.affinity_hits += 1;
        } else {
            st.totals.affinity_misses += 1;
        }
        st.last_topology[dev] = Some(topo.clone());
        st.warm[dev].touch(topo);
        st.totals.total_gop += OpCount::paper_convention(topo);
        let done = st.backlog_ms[dev].max(meta.arrival_ms) + resp.fabric_ms;
        st.backlog_ms[dev] = done;
        // ABFT verdict accounting (DESIGN.md §15).  A locally recovered
        // breach (coordinator scrub-retry) is fully resolved here; a
        // still-corrupt response is only *detected* here — containment
        // is decided by the reroute ladder in `call_single_verified`,
        // which emits the Integrity event once the outcome is known.
        match resp.verdict {
            IntegrityVerdict::Clean => {}
            IntegrityVerdict::Recovered => {
                st.totals.integrity_detected += 1;
                st.totals.integrity_recovered += 1;
            }
            IntegrityVerdict::Corrupt => {
                st.totals.integrity_detected += 1;
            }
        }
        let verdict = resp.verdict;
        drop(st);
        if verdict == IntegrityVerdict::Recovered {
            self.telemetry_event(TelemetryEvent::Integrity {
                t_ms: done,
                device: dev,
                contained: true,
            });
        }
        Dispatched { resp, device: dev, done_ms: done, heat, bounces }
    }
}

/// Outcome of one routed device invocation; the telemetry attribution
/// (heat, bounce count) rides along with the response.
struct Dispatched {
    resp: Response,
    device: usize,
    /// Modeled completion time on the virtual clock.
    done_ms: f64,
    heat: Heat,
    bounces: u64,
}

/// What a single-device dispatch produced: a served response, or a
/// typed saturation hand-back ([`SaturationPolicy::Typed`]).
enum SingleOutcome {
    Done(Dispatched),
    Saturated { bounces: u64 },
}

/// Bounded exponential backoff with seeded jitter for the Busy-bounce
/// loop: 50 µs doubling per attempt, capped at 2 ms, plus up to +50%
/// jitter drawn deterministically from the request id and attempt
/// number (so two runs of the same trace sleep identically, and two
/// colliding clients sleep differently).  Pure — unit-tested directly.
pub fn bounce_backoff(attempt: u64, request_id: u64) -> std::time::Duration {
    const BASE_US: u64 = 50;
    const CAP_US: u64 = 2_000;
    let exp = attempt.saturating_sub(1).min(16) as u32;
    let base = BASE_US.saturating_mul(1u64 << exp).min(CAP_US);
    let jitter = XorShift64::new(request_id ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .below(base / 2 + 1);
    std::time::Duration::from_micros(base + jitter)
}

/// The plan's device preference list for `topo` — including when `topo`
/// is the half shape of a sharded placement.  Shared with the
/// discrete-event mirror ([`super::des`]), which must rank identically.
pub(crate) fn preferred_devices<'a>(plan: &'a PlacementPlan, topo: &Topology) -> &'a [usize] {
    if let Some(p) = plan.placement(topo) {
        return &p.devices;
    }
    for p in &plan.placements {
        if let Some(s) = &p.shard {
            if &s.half == topo {
                return &p.devices;
            }
        }
    }
    &[]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testdata::MhaInputs;

    fn req(id: u64, topo: &Topology) -> Request {
        Request::new(id, topo.clone(), MhaInputs::generate(topo))
    }

    fn two_u55c(workload: &[Topology]) -> Cluster {
        Cluster::start(
            vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(workload),
            ClusterConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn order_prefers_hot_then_warm_then_plan_then_load() {
        let v = |id, hot, warm, preference, pending| CandidateView {
            id,
            hot,
            warm,
            preference,
            pending,
        };
        // Hot beats everything, even a deep queue.
        assert_eq!(
            order_candidates(vec![v(0, false, false, 0, 0), v(1, true, false, usize::MAX, 9)]),
            vec![1, 0]
        );
        // Warm beats plan preference and load (register replay is
        // cheaper than a full program derivation)...
        assert_eq!(
            order_candidates(vec![v(0, false, false, 0, 0), v(1, false, true, usize::MAX, 5)]),
            vec![1, 0]
        );
        // ...but never beats hot.
        assert_eq!(
            order_candidates(vec![v(0, false, true, 0, 0), v(1, true, false, usize::MAX, 9)]),
            vec![1, 0]
        );
        // Plan preference beats load...
        assert_eq!(
            order_candidates(vec![v(0, false, false, usize::MAX, 0), v(1, false, false, 0, 5)]),
            vec![1, 0]
        );
        // ...and load breaks preference ties, id breaks full ties.
        assert_eq!(
            order_candidates(vec![
                v(0, false, false, 1, 7),
                v(1, false, false, 1, 2),
                v(2, false, false, 1, 7),
            ]),
            vec![1, 0, 2]
        );
    }

    #[test]
    fn affinity_keeps_topologies_on_their_devices() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone()]);
        let h = cluster.handle();
        // Interleaved sequential stream: affinity must pin each topology
        // to one device, so per-device streams are homogeneous.
        let mut device_of = std::collections::HashMap::new();
        for i in 0..8u64 {
            let t = if i % 2 == 0 { &t1 } else { &t2 };
            let resp = h.call(req(i, t)).unwrap();
            assert_eq!(resp.devices.len(), 1);
            let prev = device_of.insert(t.clone(), resp.devices[0]);
            if let Some(p) = prev {
                assert_eq!(p, resp.devices[0], "topology moved devices");
            }
        }
        assert_ne!(device_of[&t1], device_of[&t2], "both topologies on one device");
        let fleet = cluster.shutdown();
        // One reprogram per device, ever — the whole point of affinity.
        assert_eq!(fleet.reconfigurations(), 2);
        assert_eq!(fleet.totals.completed, 8);
        assert_eq!(fleet.totals.affinity_hits, 8);
        assert_eq!(fleet.totals.affinity_misses, 0);
    }

    #[test]
    fn failover_when_device_unavailable() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        // Prime affinity onto the planner's primary.
        let first = h.call(req(0, &t)).unwrap();
        let primary = first.devices[0];
        // Drain that device: the router is told, so failover is a
        // ranking decision — the drained ingress is never even probed.
        cluster.stop_device(primary).unwrap();
        let resp = h.call(req(1, &t)).unwrap();
        assert_eq!(resp.devices.len(), 1);
        assert_ne!(resp.devices[0], primary, "must fail over to the live device");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a drained device");
        assert_eq!(fleet.totals.completed, 2);
    }

    #[test]
    fn sharded_request_served_and_reassembled() {
        let large = Topology::new(16, 1024, 16, 64);
        let cluster = two_u55c(std::slice::from_ref(&large));
        let h = cluster.handle();
        let inputs = MhaInputs::generate(&large);
        let resp = h
            .call(Request::new(7, large.clone(), inputs.clone()))
            .unwrap();
        assert!(resp.sharded);
        assert_eq!(resp.devices.len(), 2);
        assert_ne!(resp.devices[0], resp.devices[1], "halves should use both devices");
        assert_eq!(resp.output.len(), 16 * 1024);
        // Reference: the same two halves on one local accelerator.
        let plan = ShardPlan::plan(&large).unwrap();
        let (lo, hi) = plan.split_inputs(&inputs).unwrap();
        let mut accel = FamousAccelerator::with_sim_datapath(crate::sim::SimConfig::u55c());
        let lo_out = accel.run(&plan.half, &lo).unwrap().output;
        let hi_out = accel.run(&plan.half, &hi).unwrap().output;
        let want = plan.concat_outputs(&lo_out, &hi_out).unwrap();
        assert_eq!(resp.output, want, "sharded output must be bit-identical");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.sharded, 1);
        assert_eq!(fleet.served(), 2, "one request, two device invocations");
    }

    #[test]
    fn live_snapshot_observes_mid_run_state() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        h.call(req(0, &t)).unwrap();
        h.call(req(1, &t)).unwrap();
        let snap = cluster.fleet_snapshot();
        assert_eq!(snap.totals.completed, 2);
        assert_eq!(snap.served(), 2);
        assert!(snap.makespan_ms() > 0.0);
        assert!(snap.timing_sims() >= 1);
        assert_eq!(snap.live_devices(), 2, "both devices up -> both live");
        // Snapshots keep working after a device drains (early stats),
        // and the drained device is flagged, not shown as a zeroed peer.
        cluster.stop_device(0).unwrap();
        let snap2 = cluster.fleet_snapshot();
        assert_eq!(snap2.totals.completed, 2);
        assert_eq!(snap2.served(), 2);
        assert_eq!(snap2.devices[0].health, DeviceHealth::Stopped);
        assert_eq!(snap2.devices[1].health, DeviceHealth::Live);
        assert_eq!(snap2.live_devices(), 1);
        assert_eq!(snap2.failed_devices(), 0);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 2);
        assert_eq!(fleet.served(), snap.served());
        assert_eq!(fleet.devices[0].health, DeviceHealth::Stopped);
        assert_eq!(fleet.devices[1].health, DeviceHealth::Live);
    }

    #[test]
    fn unservable_topology_rejected() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        // SL 256 exceeds every synthesized max and head-sharding cannot
        // reduce SL.
        let err = h.call(req(0, &Topology::new(256, 768, 8, 64))).unwrap_err();
        assert!(err.to_string().contains("no device admits"), "{err}");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.rejected, 1);
        assert_eq!(fleet.totals.completed, 0);
    }

    #[test]
    fn slack_order_prefers_feasible_then_hot_then_warm_then_earliest() {
        let v = |id, hot, warm, preference, est, slack| SlackView {
            id,
            hot,
            warm,
            preference,
            est_completion_ms: est,
            slack_ms: slack,
        };
        // A feasible cold device beats an infeasible hot one.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, true, false, 0, 9.0, -1.0),
                v(1, false, false, usize::MAX, 3.0, 2.0),
            ]),
            vec![1, 0]
        );
        // Among feasible devices: hot first, then warm, then plan, then
        // earliest modeled completion.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, false, false, 0, 1.0, 5.0),
                v(1, true, false, usize::MAX, 4.0, 2.0),
                v(2, false, false, 0, 0.5, 5.5),
            ]),
            vec![1, 2, 0]
        );
        // Warm beats a colder device with plan preference and an
        // earlier estimate — as long as both are feasible ("prefer warm
        // when slack permits").
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, false, false, 0, 1.0, 5.0),
                v(1, false, true, usize::MAX, 4.0, 2.0),
            ]),
            vec![1, 0]
        );
        // ...but feasibility still dominates warmth.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, false, false, 0, 1.0, 5.0),
                v(1, false, true, usize::MAX, 9.0, -1.0),
            ]),
            vec![0, 1]
        );
        // All infeasible: least-late first.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, true, false, 0, 9.0, -5.0),
                v(1, false, false, 1, 7.0, -3.0),
            ]),
            vec![1, 0]
        );
        // A down device's view (−∞ slack, +∞ completion) ranks after
        // every live candidate — even a provably-late one.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, false, false, usize::MAX, f64::INFINITY, f64::NEG_INFINITY),
                v(1, false, false, 1, 50.0, -40.0),
                v(2, false, false, 0, 3.0, 2.0),
            ]),
            vec![2, 1, 0]
        );
    }

    #[test]
    fn slack_routing_never_probes_a_failed_horizon() {
        // A dead device's backlog horizon freezes and would otherwise
        // become the "best" completion estimate as the live fleet
        // backs up; the backlog model must observe health so SlackEdf
        // routes around the corpse without a single bounce.
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = qos_two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        // Build a backlog on whichever device serves first.
        let live = h.call(req(0, &t)).unwrap().devices[0];
        let dead = 1 - live;
        assert!(cluster.fail_device(dead));
        // Tight-deadline traffic: the live device is provably late, the
        // dead one's frozen (empty) horizon would look feasible.  The
        // router must still pick the live device, with zero retries —
        // it never even probes the dead ingress.
        for i in 1..4u64 {
            let r = h
                .call_qos(req(i, &t).with_qos(Priority::High, 0.0, Some(1.2 * ms)))
                .unwrap()
                .served()
                .expect("high priority is never shed");
            assert_eq!(r.devices, vec![live], "routed toward a frozen horizon");
        }
        // The shed bound likewise ignores the dead horizon: a Low
        // request sheds on the live device's real backlog, not the
        // corpse's optimistic one.
        let out = h
            .call_qos(req(9, &t).with_qos(Priority::Low, 0.0, Some(1.2 * ms)))
            .unwrap();
        assert!(out.is_shed(), "dead horizon must not count as shed-saving capacity");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a dead device");
        assert_eq!(fleet.totals.completed, 4);
        assert_eq!(fleet.devices[dead].health, DeviceHealth::Failed);
    }

    #[test]
    fn stopped_device_horizon_also_infeasible() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = qos_two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let live = h.call(req(0, &t)).unwrap().devices[0];
        let drained = 1 - live;
        cluster.stop_device(drained).unwrap();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        for i in 1..3u64 {
            let r = h
                .call_qos(req(i, &t).with_qos(Priority::High, 0.0, Some(1.2 * ms)))
                .unwrap()
                .served()
                .unwrap();
            assert_eq!(r.devices, vec![live]);
        }
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a drained device");
        assert_eq!(fleet.devices[drained].health, DeviceHealth::Stopped);
    }

    fn qos_two_u55c(workload: &[Topology]) -> Cluster {
        Cluster::start(
            vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(workload),
            ClusterConfig::qos(),
        )
        .unwrap()
    }

    #[test]
    fn qos_completions_track_backlog_and_deadlines() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = qos_two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        // Two same-arrival requests with a deadline only one device-slot
        // can meet: slack routing puts them on different devices, so
        // both meet it (affinity routing would stack them on one).
        let deadline = Some(1.5 * ms);
        let r1 = h
            .call_qos(req(1, &t).with_qos(Priority::High, 0.0, deadline))
            .unwrap()
            .served()
            .unwrap();
        let r2 = h
            .call_qos(req(2, &t).with_qos(Priority::High, 0.0, deadline))
            .unwrap()
            .served()
            .unwrap();
        assert!(!r1.deadline_missed && !r2.deadline_missed, "{r1:?} {r2:?}");
        assert_ne!(r1.devices, r2.devices, "slack routing must spread infeasible load");
        assert!((r1.completed_ms - ms).abs() < 1e-9);
        // A third request at t=0 now finds both devices backlogged: it
        // completes at 2·ms and misses the same deadline.
        let r3 = h
            .call_qos(req(3, &t).with_qos(Priority::High, 0.0, deadline))
            .unwrap()
            .served()
            .unwrap();
        assert!(r3.deadline_missed, "{r3:?}");
        assert!((r3.completed_ms - 2.0 * ms).abs() < 1e-9);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.slo.met[Priority::High.index()], 2);
        assert_eq!(fleet.totals.slo.missed[Priority::High.index()], 1);
        assert!(fleet.render().contains("QoS"));
    }

    #[test]
    fn provably_late_low_priority_is_shed_not_queued() {
        let t = Topology::new(64, 768, 8, 64);
        let one = |topos: &[Topology]| {
            Cluster::start(
                vec![DeviceSpec::u55c(0)],
                &WorkloadProfile::uniform(topos),
                ClusterConfig::qos(),
            )
            .unwrap()
        };
        let cluster = one(std::slice::from_ref(&t));
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        // Fill the lone device's modeled backlog past the deadline.
        for i in 0..4u64 {
            h.call(req(i, &t)).unwrap();
        }
        let out = h
            .call_qos(req(9, &t).with_qos(Priority::Low, 0.0, Some(1.5 * ms)))
            .unwrap();
        match out {
            QosOutcome::Shed(n) => {
                assert_eq!(n.id, 9);
                assert_eq!(n.priority, Priority::Low);
                assert!(n.predicted_completion_ms > n.deadline_ms);
            }
            QosOutcome::Served(r) => panic!("expected shed, served: {r:?}"),
            QosOutcome::Saturated(_) => panic!("Block policy never saturates"),
        }
        // High priority is never shed — it runs late instead.
        let r = h
            .call_qos(req(10, &t).with_qos(Priority::High, 0.0, Some(1.5 * ms)))
            .unwrap()
            .served()
            .expect("high priority must be served");
        assert!(r.deadline_missed);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.slo.shed[Priority::Low.index()], 1);
        assert_eq!(fleet.totals.completed, 5, "shed request never dispatched");
        // call() surfaces a shed as an error mentioning the deadline.
        let cluster2 = one(std::slice::from_ref(&t));
        let h2 = cluster2.handle();
        h2.call(req(0, &t)).unwrap();
        let err = h2.call(req(9, &t).with_qos(Priority::Low, 0.0, Some(0.1))).unwrap_err();
        assert!(err.to_string().contains("shed"), "{err}");
        cluster2.shutdown();
    }

    #[test]
    fn failed_device_flagged_and_rerouted() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let first = h.call(req(0, &t)).unwrap();
        let dead = first.devices[0];
        assert!(cluster.fail_device(dead));
        assert!(!cluster.fail_device(dead), "double-fail is a no-op");
        // Requests keep flowing: the router was told about the crash,
        // so it reroutes by ranking — no probe of the dead ingress.
        for i in 1..4u64 {
            let resp = h.call(req(i, &t)).unwrap();
            assert_ne!(resp.devices[0], dead, "routed to the dead device");
        }
        let snap = cluster.fleet_snapshot();
        assert_eq!(snap.devices[dead].health, DeviceHealth::Failed);
        assert_eq!(snap.failed_devices(), 1);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.devices[dead].health, DeviceHealth::Failed);
        assert_eq!(fleet.totals.completed, 4);
        assert_eq!(fleet.totals.retries, 0, "router probed a failed device");
        assert!(fleet.render().contains("FAILED"));
    }

    #[test]
    fn warm_routing_prefers_cached_device_when_slack_permits() {
        // None of these topologies appear in the workload profile, so
        // plan preference is MAX everywhere and ranking is decided by
        // hot/warm/est alone.
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let t3 = Topology::new(64, 512, 8, 64);
        let other = Topology::new(128, 768, 8, 64);
        let cluster = qos_two_u55c(std::slice::from_ref(&other));
        let h = cluster.handle();
        let pred2 = DeviceSpec::u55c(0).predicted_ms(&t2);
        // Build state: d0 serves t1; d1 serves t2, then t3 twice (t3 is
        // hot on d1, t2 only *warm* — in the cache, not programmed).
        let r0 = h.call(req(0, &t1)).unwrap();
        assert_eq!(r0.devices, vec![0], "empty fleet ties break by id");
        for (i, t) in [(1u64, &t2), (2, &t3), (3, &t3)] {
            let r = h.call(req(i, t)).unwrap();
            assert_eq!(r.devices, vec![1], "{t:?} must land on the lighter device");
        }
        assert_eq!(h.warm_topologies(1), vec![t2.clone(), t3.clone()], "LRU mirror");
        // Best-effort t2: d0 is *colder and earlier* (backlog m1 vs
        // m2+2·m3), d1 is warm.  Warmth must win while slack permits
        // (no deadline = infinite slack).
        let r4 = h.call(req(4, &t2)).unwrap();
        assert_eq!(r4.devices, vec![1], "warm device must win over an earlier cold one");
        // Tight-deadline t2: feasible on d0 only — feasibility beats
        // warmth, so the router abandons the warm device.
        let d0_est = r0.completed_ms + pred2;
        let d1_est = r4.completed_ms + pred2;
        assert!(d1_est > d0_est);
        let deadline = 0.5 * (d0_est + d1_est);
        let r5 = h
            .call_qos(req(5, &t2).with_qos(Priority::High, 0.0, Some(deadline)))
            .unwrap()
            .served()
            .unwrap();
        assert_eq!(r5.devices, vec![0], "slack must override warm affinity");
        assert!(!r5.deadline_missed);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.warm_hits, 1, "exactly r4 was a warm dispatch");
    }

    #[test]
    fn warm_mirror_matches_device_program_cache() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let t3 = Topology::new(64, 512, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone(), t3.clone()]);
        let h = cluster.handle();
        // Sequential stream (single-request batches): the device's
        // ProgramCache sees exactly the dispatch order the mirror sees.
        for (i, t) in [&t1, &t2, &t3, &t1, &t2, &t3, &t1].into_iter().enumerate() {
            h.call(req(i as u64, t)).unwrap();
        }
        let mirrors: Vec<Vec<Topology>> = (0..2).map(|d| h.warm_topologies(d)).collect();
        let fleet = cluster.shutdown();
        for (d, mirror) in mirrors.iter().enumerate() {
            assert_eq!(
                &fleet.devices[d].stats.cached_topologies, mirror,
                "device {d}: warm-set mirror diverged from the real ProgramCache"
            );
            assert!(!mirror.is_empty(), "device {d} never served");
        }
    }

    #[test]
    fn admission_margins_extend_shedding_beyond_low() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = Cluster::start(
            vec![DeviceSpec::u55c(0)],
            &WorkloadProfile::uniform(std::slice::from_ref(&t)),
            ClusterConfig::qos(),
        )
        .unwrap();
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        for i in 0..3u64 {
            h.call(req(i, &t)).unwrap();
        }
        // Default margins: Normal is never shed — it runs late.
        let r = h
            .call_qos(req(10, &t).with_qos(Priority::Normal, 0.0, Some(1.5 * ms)))
            .unwrap()
            .served()
            .expect("Normal not shed by default");
        assert!(r.deadline_missed);
        // The control-plane hook tightens Normal to a zero margin: the
        // same hopeless request is now shed at ingress.
        h.set_admission_margin(Priority::Normal, Some(0.0));
        assert_eq!(h.admission_margin(Priority::Normal), Some(0.0));
        let out = h
            .call_qos(req(11, &t).with_qos(Priority::Normal, 0.0, Some(1.5 * ms)))
            .unwrap();
        assert!(out.is_shed(), "tightened Normal must shed");
        // High still has no margin — served late, never shed.
        let r_high = h
            .call_qos(req(12, &t).with_qos(Priority::High, 0.0, Some(1.5 * ms)))
            .unwrap()
            .served()
            .expect("High is never shed");
        // A widened Low margin sheds even a request whose deadline is
        // comfortably feasible at zero margin.
        h.set_admission_margin(Priority::Low, Some(10.0 * ms));
        let generous = r_high.completed_ms + 2.0 * ms;
        let out = h
            .call_qos(req(13, &t).with_qos(Priority::Low, 0.0, Some(generous)))
            .unwrap();
        assert!(out.is_shed(), "widened Low margin must shed feasible-at-zero requests");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 5);
        assert_eq!(fleet.totals.slo.shed[Priority::Normal.index()], 1);
        assert_eq!(fleet.totals.slo.shed[Priority::Low.index()], 1);
    }

    #[test]
    fn telemetry_frames_capture_the_request_stream() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = Cluster::start(
            vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(std::slice::from_ref(&t)),
            ClusterConfig {
                telemetry: TelemetryConfig { window_ms: 1.0, ..TelemetryConfig::default() },
                ..ClusterConfig::qos()
            },
        )
        .unwrap();
        let h = cluster.handle();
        for i in 0..6u64 {
            let arrival = i as f64 * 0.75;
            h.call_qos(req(i, &t).with_qos(Priority::Normal, arrival, None)).unwrap();
        }
        cluster.seal_telemetry();
        let snap = cluster.telemetry();
        assert_eq!(snap.sealed.arrivals_total(), 6);
        assert_eq!(snap.sealed.completed, 6);
        assert_eq!(snap.sealed.best_effort[Priority::Normal.index()], 6);
        assert_eq!(snap.sealed.dispatches(), 6);
        assert_eq!(snap.late_events, 0);
        assert!(snap.sealed.frames >= 4, "0.75 ms spacing over 1 ms windows");
        let jsonl = snap.to_jsonl();
        assert_eq!(jsonl.lines().count(), snap.frames.len());
        assert!(jsonl.contains("\"arrivals\""), "{jsonl}");
        // Conservation: ring + evicted == sealed (nothing evicted here).
        let mut refold = snap.evicted.clone();
        for f in &snap.frames {
            refold.fold(f);
        }
        assert_eq!(refold, snap.sealed);
    }

    #[test]
    fn bounce_backoff_bounded_exponential_with_jitter() {
        for attempt in 1..20u64 {
            let us = bounce_backoff(attempt, 42).as_micros() as u64;
            let base = (50u64 << (attempt - 1).min(16)).min(2_000);
            assert!(us >= base, "attempt {attempt}: {us} µs under base {base}");
            assert!(us <= base + base / 2, "attempt {attempt}: {us} µs over jitter cap");
        }
        // Deterministic for a (attempt, id) pair — two runs of the same
        // trace sleep identically.
        assert_eq!(bounce_backoff(3, 9), bounce_backoff(3, 9));
    }

    #[test]
    fn restart_device_restores_routing_capacity() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let primary = h.call(req(0, &t)).unwrap().devices[0];
        cluster.stop_device(primary).unwrap();
        assert!(!cluster.restart_device(1 - primary), "live device must not restart");
        assert!(cluster.restart_device(primary), "drained device restarts");
        assert!(!cluster.restart_device(primary), "double restart is a no-op");
        // The restored device is cold (empty horizon, no affinity): the
        // next request ranks it exactly as it ranked at boot, so the
        // fleet serves on — and through the restarted worker.
        let mut seen = std::collections::HashSet::new();
        for i in 1..6u64 {
            seen.insert(h.call(req(i, &t)).unwrap().devices[0]);
        }
        assert!(seen.contains(&primary), "restarted device never re-entered routing");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a dead handle");
        assert_eq!(fleet.totals.completed, 6);
        assert!(fleet.devices.iter().all(|d| d.health == DeviceHealth::Live));
    }

    #[test]
    fn corrupt_device_contained_by_cross_device_reroute() {
        let t = Topology::new(16, 256, 4, 64);
        // Device 0 carries a persistent (stuck-at) fault plan: the
        // coordinator's local scrub-retry re-draws the same flips, so it
        // escalates `Corrupt` and the router must re-execute the request
        // on device 1 from the handed-back operands.
        let faulty =
            DeviceSpec::u55c(0).with_fault_plan(crate::sim::FaultPlan::seu(0xBAD5EED, 0.01));
        let cluster = Cluster::start(
            vec![faulty, DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(std::slice::from_ref(&t)),
            ClusterConfig::default(),
        )
        .unwrap();
        let h = cluster.handle();
        let inputs = MhaInputs::generate(&t);
        let mut accel = FamousAccelerator::with_sim_datapath(crate::sim::SimConfig::u55c());
        let want = accel.run(&t, &inputs).unwrap().output;
        let resp = h.call(Request::new(0, t.clone(), inputs)).unwrap();
        assert_eq!(resp.verdict, IntegrityVerdict::Recovered, "reroute must relabel");
        assert_eq!(resp.devices, vec![1], "must re-execute on the clean device");
        assert_eq!(resp.output, want, "recovered output must be bit-identical to clean");
        let fleet = cluster.shutdown();
        assert!(fleet.totals.integrity_detected >= 1);
        assert_eq!(fleet.totals.integrity_rerouted, 1);
        assert_eq!(fleet.totals.integrity_failed, 0, "zero corrupt outputs served");
        assert!(fleet.render().contains("integrity"), "fleet report must surface ABFT");
    }

    #[test]
    fn concurrent_clients_all_served() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone()]);
        let mut joins = Vec::new();
        for i in 0..12u64 {
            let h = cluster.handle();
            let t = if i % 2 == 0 { t1.clone() } else { t2.clone() };
            joins.push(std::thread::spawn(move || h.call(req(i, &t)).unwrap()));
        }
        let mut ids: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap().id).collect();
        ids.sort();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 12);
        assert_eq!(fleet.served(), 12);
        assert_eq!(fleet.totals.rejected, 0);
    }
}
