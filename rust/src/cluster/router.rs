//! The cluster router/dispatcher: one ingress over N device servers.
//!
//! Routing policy, in priority order (see [`order_candidates`]):
//!
//! 1. **Hot affinity** — the device the router last sent this topology
//!    to needs no reprogramming; keeping a topology on its device is
//!    `BatchPolicy::GroupByTopology` lifted to the fleet.
//! 2. **Placement affinity** — the planner's preferred device order
//!    (weight tiles pinned in BRAM).
//! 3. **Least-loaded** — fewest requests waiting in the device's
//!    ingress queue.
//!
//! Backpressure is failover, not failure: a full device queue bounces
//! the request (operands returned, not cloned) to the next candidate,
//! up to `max_retries` bounces, after which the router blocks on the
//! best candidate rather than spin.  A topology no single device admits
//! is head-sharded per the placement plan: two half-requests on two
//! devices, rejoined with a host-side column concat ([`super::shard`]).

use super::fleet::{DeviceHealth, FleetStats, RouterTotals};
use super::placement::{PlacementPlan, PlacementPlanner, WorkloadProfile};
use super::shard::ShardPlan;
use super::DeviceSpec;
use crate::accel::FamousAccelerator;
use crate::config::Topology;
use crate::coordinator::{
    Coordinator, CoordinatorStats, Request, Response, SchedulerConfig, Server, ServerConfig,
    ServerHandle, SubmitError,
};
use crate::metrics::OpCount;
use anyhow::{anyhow, bail, Result};
use std::sync::{Arc, Mutex};

/// Cluster tuning.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Per-device scheduler (batching) configuration.
    pub scheduler: SchedulerConfig,
    /// Per-device server (ingress queue) configuration.
    pub server: ServerConfig,
    /// Backpressure bounces before blocking on the best candidate.
    pub max_retries: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            scheduler: SchedulerConfig::default(),
            server: ServerConfig::default(),
            max_retries: 3,
        }
    }
}

/// One completed cluster request.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    pub id: u64,
    /// The topology as the client requested it (the full shape for
    /// sharded requests).
    pub topology: Topology,
    /// Functional output, `SL × d_model` of the requested topology.
    pub output: Vec<f32>,
    /// Modeled fabric latency: the slower half for sharded requests
    /// (halves run concurrently).
    pub fabric_ms: f64,
    /// Modeled throughput for this request's work.
    pub gops: f64,
    /// Whether any serving device reprogrammed for this request's batch.
    pub reprogrammed: bool,
    /// Devices that served it (two when sharded).
    pub devices: Vec<usize>,
    pub sharded: bool,
}

struct DeviceEndpoint {
    spec: DeviceSpec,
    handle: ServerHandle,
}

#[derive(Default)]
struct RouterState {
    /// Router's view of each device's currently-programmed topology.
    last_topology: Vec<Option<Topology>>,
    totals: RouterTotals,
}

struct Shared {
    devices: Vec<DeviceEndpoint>,
    plan: PlacementPlan,
    max_retries: usize,
    state: Mutex<RouterState>,
}

/// A running fleet: per-device servers plus the routing front-end.
pub struct Cluster {
    shared: Arc<Shared>,
    /// `None` once a device has been drained via [`Cluster::stop_device`].
    servers: Vec<Option<Server>>,
    early_stats: Vec<Option<CoordinatorStats>>,
}

/// Cloneable client handle (safe to share across request threads).
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

impl Cluster {
    /// Start one coordinator server per device (sim-datapath backend —
    /// the PJRT path needs per-process artifacts and is stubbed offline)
    /// and plan placement for the expected workload.
    pub fn start(
        devices: Vec<DeviceSpec>,
        workload: &WorkloadProfile,
        config: ClusterConfig,
    ) -> Result<Cluster> {
        if devices.is_empty() {
            bail!("cluster needs at least one device");
        }
        // Routing indexes devices by id; renumber to be safe.
        let mut devices = devices;
        for (i, d) in devices.iter_mut().enumerate() {
            d.id = i;
        }
        let plan = PlacementPlanner::default().plan(&devices, workload);
        let mut endpoints = Vec::with_capacity(devices.len());
        let mut servers = Vec::with_capacity(devices.len());
        for spec in devices {
            let sim = spec.sim.clone();
            let sched = config.scheduler;
            let server = Server::start(
                move || {
                    let accel = FamousAccelerator::with_sim_datapath(sim);
                    Coordinator::new(accel, sched)
                },
                config.server,
            );
            endpoints.push(DeviceEndpoint { spec, handle: server.handle() });
            servers.push(Some(server));
        }
        let n = endpoints.len();
        let shared = Arc::new(Shared {
            devices: endpoints,
            plan,
            max_retries: config.max_retries,
            state: Mutex::new(RouterState {
                last_topology: vec![None; n],
                totals: RouterTotals::default(),
            }),
        });
        Ok(Cluster { shared, servers, early_stats: vec![None; n] })
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: Arc::clone(&self.shared) }
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.shared.plan
    }

    pub fn device_count(&self) -> usize {
        self.shared.devices.len()
    }

    /// Drain one device (elasticity / maintenance): its server shuts
    /// down and subsequent routing fails over to the rest of the fleet.
    /// Returns its stats, or None if already stopped.
    pub fn stop_device(&mut self, id: usize) -> Option<CoordinatorStats> {
        let server = self.servers.get_mut(id)?.take()?;
        let stats = server.shutdown();
        self.early_stats[id] = Some(stats.clone());
        // Drop the router's affinity memory for the drained device so it
        // stops ranking as "hot" for the topology it last served.
        self.shared.state.lock().unwrap().last_topology[id] = None;
        Some(stats)
    }

    /// Live (pre-shutdown) fleet snapshot: per-device stats fetched from
    /// the running servers (each answers after its current serving
    /// round), merged with the router's current totals.  Lets operators
    /// observe cluster GOPS / reconfigurations / cache hit rates mid-run
    /// without draining anything.  Requests fan out to every device
    /// before any reply is awaited, so absent ingress backpressure the
    /// snapshot costs the slowest device's round, not the sum (a device
    /// with a full ingress queue still blocks its send — the request
    /// shares the bounded job channel).  Each device carries a
    /// [`DeviceHealth`] flag: a deliberately drained device reports
    /// `Stopped` with its final stats, while one whose worker died
    /// reports `Failed` with default (zero) stats — zeroed *unknowns*,
    /// no longer indistinguishable from an idle device.
    pub fn fleet_snapshot(&self) -> FleetStats {
        let mut health = Vec::with_capacity(self.servers.len());
        let pending: Vec<Option<std::sync::mpsc::Receiver<CoordinatorStats>>> = self
            .servers
            .iter()
            .map(|server| match server {
                None => {
                    health.push(DeviceHealth::Stopped);
                    None
                }
                Some(s) => match s.handle().request_stats() {
                    Ok(rx) => {
                        health.push(DeviceHealth::Live);
                        Some(rx)
                    }
                    Err(_) => {
                        health.push(DeviceHealth::Failed);
                        None
                    }
                },
            })
            .collect();
        let coord: Vec<CoordinatorStats> = pending
            .into_iter()
            .enumerate()
            .map(|(i, rx)| match rx {
                Some(rx) => rx.recv().unwrap_or_else(|_| {
                    // Worker died between the request and the reply.
                    health[i] = DeviceHealth::Failed;
                    CoordinatorStats::default()
                }),
                None => self.early_stats[i].clone().unwrap_or_default(),
            })
            .collect();
        let specs: Vec<DeviceSpec> = self.shared.devices.iter().map(|d| d.spec.clone()).collect();
        let totals = self.shared.state.lock().unwrap().totals.clone();
        FleetStats::assemble_with_health(&specs, coord, health, totals)
    }

    /// Stop every device and assemble the fleet report.  Devices that
    /// served until this clean shutdown report `Live`; ones drained
    /// earlier via [`Self::stop_device`] report `Stopped`; ones whose
    /// worker had already died (engine failure) report `Failed` — their
    /// joined stats stop at the crash.
    pub fn shutdown(mut self) -> FleetStats {
        let mut coord = Vec::with_capacity(self.servers.len());
        let mut health = Vec::with_capacity(self.servers.len());
        for (i, server) in self.servers.into_iter().enumerate() {
            let stats = match server {
                Some(s) => {
                    // Probe before sending the shutdown message: a closed
                    // ingress here means the worker exited on its own.
                    health.push(if s.handle().is_alive() {
                        DeviceHealth::Live
                    } else {
                        DeviceHealth::Failed
                    });
                    s.shutdown()
                }
                None => {
                    health.push(DeviceHealth::Stopped);
                    self.early_stats[i].take().unwrap_or_default()
                }
            };
            coord.push(stats);
        }
        let specs: Vec<DeviceSpec> =
            self.shared.devices.iter().map(|d| d.spec.clone()).collect();
        let totals = self.shared.state.lock().unwrap().totals.clone();
        FleetStats::assemble_with_health(&specs, coord, health, totals)
    }
}

/// Pure ranking input: one candidate device's routing signals.
#[derive(Clone, Debug)]
pub struct CandidateView {
    pub id: usize,
    /// Router last routed this topology here (no reprogramming needed).
    pub hot: bool,
    /// Position in the placement plan's preference list (usize::MAX if
    /// the plan does not mention this device for the topology).
    pub preference: usize,
    /// Requests waiting in the device's ingress queue.
    pub pending: usize,
}

/// Order candidates best-first: hot, then planner preference, then
/// least-loaded, then id (determinism).  Pure — unit-tested directly.
pub fn order_candidates(mut views: Vec<CandidateView>) -> Vec<usize> {
    views.sort_by_key(|v| (!v.hot as u8, v.preference, v.pending, v.id));
    views.into_iter().map(|v| v.id).collect()
}

impl ClusterHandle {
    /// Serve one request, blocking until the response: routes to a
    /// single device when possible, transparently head-shards otherwise.
    pub fn call(&self, req: Request) -> Result<ClusterResponse> {
        let topo = req.topology.clone();
        if self.shared.devices.iter().any(|d| d.spec.admits(&topo)) {
            let (resp, dev) = self.call_single(req, None)?;
            let gops = resp.gops;
            let mut st = self.shared.state.lock().unwrap();
            st.totals.completed += 1;
            drop(st);
            return Ok(ClusterResponse {
                id: resp.id,
                topology: topo,
                output: resp.output,
                fabric_ms: resp.fabric_ms,
                gops,
                reprogrammed: resp.reprogrammed,
                devices: vec![dev],
                sharded: false,
            });
        }
        let shard = self
            .shared
            .plan
            .placement(&topo)
            .and_then(|p| p.shard.clone())
            .or_else(|| ShardPlan::plan(&topo));
        match shard {
            Some(s) if self.shared.devices.iter().any(|d| d.spec.admits(&s.half)) => {
                self.call_sharded(req, s)
            }
            _ => {
                self.shared.state.lock().unwrap().totals.rejected += 1;
                bail!(
                    "no device admits topology {topo} and no head-shard of it is servable"
                );
            }
        }
    }

    /// Rank admitting devices for `topo`, best first.
    fn rank(&self, topo: &Topology, exclude: Option<usize>) -> Vec<usize> {
        let preferred = preferred_devices(&self.shared.plan, topo);
        let st = self.shared.state.lock().unwrap();
        let views: Vec<CandidateView> = self
            .shared
            .devices
            .iter()
            .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
            .map(|d| CandidateView {
                id: d.spec.id,
                hot: st.last_topology[d.spec.id].as_ref() == Some(topo),
                preference: preferred
                    .iter()
                    .position(|&p| p == d.spec.id)
                    .unwrap_or(usize::MAX),
                pending: d.handle.pending(),
            })
            .collect();
        drop(st);
        order_candidates(views)
    }

    /// Route one single-device request with backpressure failover.
    fn call_single(&self, req: Request, exclude: Option<usize>) -> Result<(Response, usize)> {
        let topo = req.topology.clone();
        let mut candidates = self.rank(&topo, exclude);
        if candidates.is_empty() {
            // Exclusion left nothing; fall back to the full fleet.
            candidates = self.rank(&topo, None);
        }
        if candidates.is_empty() {
            self.shared.state.lock().unwrap().totals.rejected += 1;
            bail!("no device in the fleet admits topology {topo}");
        }
        let mut req = req;
        let mut bounces = 0usize;
        let mut idx = 0usize;
        let mut bounced: Vec<usize> = Vec::new();
        loop {
            if bounces >= self.shared.max_retries {
                // Enough spinning: block for queue space on the best
                // candidate (backpressure propagates to the client).
                // Prefer one that did not just bounce us — a bounce can
                // mean the device is gone, not merely full, and blocking
                // on a dead channel fails a still-servable request.
                let dev = candidates
                    .iter()
                    .copied()
                    .find(|d| !bounced.contains(d))
                    .unwrap_or(candidates[0]);
                let resp = self.shared.devices[dev]
                    .handle
                    .call_blocking(req)
                    .map_err(|e| anyhow!("device {dev}: {e}"))?;
                return Ok(self.record(resp, dev, &topo));
            }
            let dev = candidates[idx % candidates.len()];
            match self.shared.devices[dev].handle.try_call(req) {
                Ok(resp) => return Ok(self.record(resp, dev, &topo)),
                Err(SubmitError::Busy(returned)) => {
                    req = returned;
                    bounces += 1;
                    idx += 1;
                    if !bounced.contains(&dev) {
                        bounced.push(dev);
                    }
                    self.shared.state.lock().unwrap().totals.retries += 1;
                }
                Err(SubmitError::Failed(e)) => bail!("device {dev}: {e}"),
            }
        }
    }

    /// Two half-requests on (preferably) two devices, concat on the host.
    fn call_sharded(&self, req: Request, shard: ShardPlan) -> Result<ClusterResponse> {
        let (lo, hi) = shard.split_inputs(&req.inputs)?;
        let req_lo = Request { id: req.id, topology: shard.half.clone(), inputs: lo };
        let req_hi = Request { id: req.id, topology: shard.half.clone(), inputs: hi };
        // Steer the high half away from the low half's likely device so
        // the halves actually run concurrently when the fleet allows.
        let low_primary = self.rank(&shard.half, None).first().copied();
        let other = self.clone();
        let hi_worker = std::thread::spawn(move || other.call_single(req_hi, low_primary));
        let lo_result = self.call_single(req_lo, None);
        let hi_result =
            hi_worker.join().map_err(|_| anyhow!("shard worker thread panicked"))?;
        let (lo_resp, lo_dev) = lo_result?;
        let (hi_resp, hi_dev) = hi_result?;
        let output = shard.concat_outputs(&lo_resp.output, &hi_resp.output)?;
        let fabric_ms = lo_resp.fabric_ms.max(hi_resp.fabric_ms);
        let gop = 2.0 * OpCount::paper_convention(&shard.half);
        let mut st = self.shared.state.lock().unwrap();
        st.totals.completed += 1;
        st.totals.sharded += 1;
        drop(st);
        Ok(ClusterResponse {
            id: req.id,
            topology: shard.full.clone(),
            output,
            fabric_ms,
            gops: gop / (fabric_ms * 1e-3),
            reprogrammed: lo_resp.reprogrammed || hi_resp.reprogrammed,
            devices: vec![lo_dev, hi_dev],
            sharded: true,
        })
    }

    /// Book-keeping after a device served a (sub-)request.
    fn record(&self, resp: Response, dev: usize, topo: &Topology) -> (Response, usize) {
        let preferred = preferred_devices(&self.shared.plan, topo);
        let mut st = self.shared.state.lock().unwrap();
        let hot = st.last_topology[dev].as_ref() == Some(topo);
        let planned = preferred.first() == Some(&dev) || self.shared.plan.is_pinned(dev, topo);
        if hot || planned {
            st.totals.affinity_hits += 1;
        } else {
            st.totals.affinity_misses += 1;
        }
        st.last_topology[dev] = Some(topo.clone());
        st.totals.total_gop += OpCount::paper_convention(topo);
        (resp, dev)
    }
}

/// The plan's device preference list for `topo` — including when `topo`
/// is the half shape of a sharded placement.
fn preferred_devices<'a>(plan: &'a PlacementPlan, topo: &Topology) -> &'a [usize] {
    if let Some(p) = plan.placement(topo) {
        return &p.devices;
    }
    for p in &plan.placements {
        if let Some(s) = &p.shard {
            if &s.half == topo {
                return &p.devices;
            }
        }
    }
    &[]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testdata::MhaInputs;

    fn req(id: u64, topo: &Topology) -> Request {
        Request { id, topology: topo.clone(), inputs: MhaInputs::generate(topo) }
    }

    fn two_u55c(workload: &[Topology]) -> Cluster {
        Cluster::start(
            vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(workload),
            ClusterConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn order_prefers_hot_then_plan_then_load() {
        let v = |id, hot, preference, pending| CandidateView { id, hot, preference, pending };
        // Hot beats everything, even a deep queue.
        assert_eq!(
            order_candidates(vec![v(0, false, 0, 0), v(1, true, usize::MAX, 9)]),
            vec![1, 0]
        );
        // Plan preference beats load...
        assert_eq!(
            order_candidates(vec![v(0, false, usize::MAX, 0), v(1, false, 0, 5)]),
            vec![1, 0]
        );
        // ...and load breaks preference ties, id breaks full ties.
        assert_eq!(
            order_candidates(vec![v(0, false, 1, 7), v(1, false, 1, 2), v(2, false, 1, 7)]),
            vec![1, 0, 2]
        );
    }

    #[test]
    fn affinity_keeps_topologies_on_their_devices() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone()]);
        let h = cluster.handle();
        // Interleaved sequential stream: affinity must pin each topology
        // to one device, so per-device streams are homogeneous.
        let mut device_of = std::collections::HashMap::new();
        for i in 0..8u64 {
            let t = if i % 2 == 0 { &t1 } else { &t2 };
            let resp = h.call(req(i, t)).unwrap();
            assert_eq!(resp.devices.len(), 1);
            let prev = device_of.insert(t.clone(), resp.devices[0]);
            if let Some(p) = prev {
                assert_eq!(p, resp.devices[0], "topology moved devices");
            }
        }
        assert_ne!(device_of[&t1], device_of[&t2], "both topologies on one device");
        let fleet = cluster.shutdown();
        // One reprogram per device, ever — the whole point of affinity.
        assert_eq!(fleet.reconfigurations(), 2);
        assert_eq!(fleet.totals.completed, 8);
        assert_eq!(fleet.totals.affinity_hits, 8);
        assert_eq!(fleet.totals.affinity_misses, 0);
    }

    #[test]
    fn failover_when_device_unavailable() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        // Prime affinity onto the planner's primary.
        let first = h.call(req(0, &t)).unwrap();
        let primary = first.devices[0];
        // Drain that device: its ingress now bounces everything.
        cluster.stop_device(primary).unwrap();
        let resp = h.call(req(1, &t)).unwrap();
        assert_eq!(resp.devices.len(), 1);
        assert_ne!(resp.devices[0], primary, "must fail over to the live device");
        let fleet = cluster.shutdown();
        assert!(fleet.totals.retries >= 1, "failover goes through the bounce path");
        assert_eq!(fleet.totals.completed, 2);
    }

    #[test]
    fn sharded_request_served_and_reassembled() {
        let large = Topology::new(16, 1024, 16, 64);
        let cluster = two_u55c(std::slice::from_ref(&large));
        let h = cluster.handle();
        let inputs = MhaInputs::generate(&large);
        let resp = h.call(Request { id: 7, topology: large.clone(), inputs: inputs.clone() }).unwrap();
        assert!(resp.sharded);
        assert_eq!(resp.devices.len(), 2);
        assert_ne!(resp.devices[0], resp.devices[1], "halves should use both devices");
        assert_eq!(resp.output.len(), 16 * 1024);
        // Reference: the same two halves on one local accelerator.
        let plan = ShardPlan::plan(&large).unwrap();
        let (lo, hi) = plan.split_inputs(&inputs).unwrap();
        let mut accel = FamousAccelerator::with_sim_datapath(crate::sim::SimConfig::u55c());
        let lo_out = accel.run(&plan.half, &lo).unwrap().output;
        let hi_out = accel.run(&plan.half, &hi).unwrap().output;
        let want = plan.concat_outputs(&lo_out, &hi_out).unwrap();
        assert_eq!(resp.output, want, "sharded output must be bit-identical");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.sharded, 1);
        assert_eq!(fleet.served(), 2, "one request, two device invocations");
    }

    #[test]
    fn live_snapshot_observes_mid_run_state() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        h.call(req(0, &t)).unwrap();
        h.call(req(1, &t)).unwrap();
        let snap = cluster.fleet_snapshot();
        assert_eq!(snap.totals.completed, 2);
        assert_eq!(snap.served(), 2);
        assert!(snap.makespan_ms() > 0.0);
        assert!(snap.timing_sims() >= 1);
        assert_eq!(snap.live_devices(), 2, "both devices up -> both live");
        // Snapshots keep working after a device drains (early stats),
        // and the drained device is flagged, not shown as a zeroed peer.
        cluster.stop_device(0).unwrap();
        let snap2 = cluster.fleet_snapshot();
        assert_eq!(snap2.totals.completed, 2);
        assert_eq!(snap2.served(), 2);
        assert_eq!(snap2.devices[0].health, DeviceHealth::Stopped);
        assert_eq!(snap2.devices[1].health, DeviceHealth::Live);
        assert_eq!(snap2.live_devices(), 1);
        assert_eq!(snap2.failed_devices(), 0);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 2);
        assert_eq!(fleet.served(), snap.served());
        assert_eq!(fleet.devices[0].health, DeviceHealth::Stopped);
        assert_eq!(fleet.devices[1].health, DeviceHealth::Live);
    }

    #[test]
    fn unservable_topology_rejected() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        // SL 256 exceeds every synthesized max and head-sharding cannot
        // reduce SL.
        let err = h.call(req(0, &Topology::new(256, 768, 8, 64))).unwrap_err();
        assert!(err.to_string().contains("no device admits"), "{err}");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.rejected, 1);
        assert_eq!(fleet.totals.completed, 0);
    }

    #[test]
    fn concurrent_clients_all_served() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone()]);
        let mut joins = Vec::new();
        for i in 0..12u64 {
            let h = cluster.handle();
            let t = if i % 2 == 0 { t1.clone() } else { t2.clone() };
            joins.push(std::thread::spawn(move || h.call(req(i, &t)).unwrap()));
        }
        let mut ids: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap().id).collect();
        ids.sort();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 12);
        assert_eq!(fleet.served(), 12);
        assert_eq!(fleet.totals.rejected, 0);
    }
}
