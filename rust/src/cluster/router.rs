//! The cluster router/dispatcher: one ingress over N device servers.
//!
//! Routing policy, in priority order (see [`order_candidates`]):
//!
//! 1. **Hot affinity** — the device the router last sent this topology
//!    to needs no reprogramming; keeping a topology on its device is
//!    `BatchPolicy::GroupByTopology` lifted to the fleet.
//! 2. **Placement affinity** — the planner's preferred device order
//!    (weight tiles pinned in BRAM).
//! 3. **Least-loaded** — fewest requests waiting in the device's
//!    ingress queue.
//!
//! Backpressure is failover, not failure: a full device queue bounces
//! the request (operands returned, not cloned) to the next candidate,
//! up to `max_retries` bounces, after which the router blocks on the
//! best candidate rather than spin.  A topology no single device admits
//! is head-sharded per the placement plan: two half-requests on two
//! devices, rejoined with a host-side column concat ([`super::shard`]).

use super::fleet::{DeviceHealth, FleetStats, RouterTotals};
use super::placement::{PlacementPlan, PlacementPlanner, WorkloadProfile};
use super::shard::ShardPlan;
use super::DeviceSpec;
use crate::accel::FamousAccelerator;
use crate::config::Topology;
use crate::coordinator::{
    BatchPolicy, Coordinator, CoordinatorStats, Priority, Request, Response, SchedulerConfig,
    Server, ServerConfig, ServerHandle, SubmitError,
};
use crate::metrics::OpCount;
use anyhow::{anyhow, bail, Result};
use std::sync::{Arc, Mutex};

/// Fleet-level QoS routing policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QosPolicy {
    /// PR-1 routing: hot affinity, then placement preference, then
    /// least-loaded.  Deadlines are accounted but never acted on.
    #[default]
    Affinity,
    /// Slack-aware routing: candidates that can meet the deadline under
    /// the backlog model come first (hot/planned/earliest-completion
    /// among them), and a `Low` request no device can serve in time is
    /// shed with an explicit [`QosOutcome::Shed`] instead of queueing
    /// to die.  Pair with `BatchPolicy::EdfWithinWindow` per device
    /// ([`ClusterConfig::qos`]).
    SlackEdf,
}

/// Cluster tuning.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Per-device scheduler (batching) configuration.
    pub scheduler: SchedulerConfig,
    /// Per-device server (ingress queue) configuration.
    pub server: ServerConfig,
    /// Backpressure bounces before blocking on the best candidate.
    pub max_retries: usize,
    /// Fleet-level routing policy (DESIGN.md §11).
    pub qos: QosPolicy,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            scheduler: SchedulerConfig::default(),
            server: ServerConfig::default(),
            max_retries: 3,
            qos: QosPolicy::Affinity,
        }
    }
}

impl ClusterConfig {
    /// QoS serving preset: slack-aware routing at the fleet level plus
    /// EDF-within-window batching on every device.
    pub fn qos() -> Self {
        ClusterConfig {
            scheduler: SchedulerConfig {
                policy: BatchPolicy::EdfWithinWindow,
                ..SchedulerConfig::default()
            },
            qos: QosPolicy::SlackEdf,
            ..ClusterConfig::default()
        }
    }
}

/// One completed cluster request.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    pub id: u64,
    /// The topology as the client requested it (the full shape for
    /// sharded requests).
    pub topology: Topology,
    /// Functional output, `SL × d_model` of the requested topology.
    pub output: Vec<f32>,
    /// Modeled fabric latency: the slower half for sharded requests
    /// (halves run concurrently).
    pub fabric_ms: f64,
    /// Modeled throughput for this request's work.
    pub gops: f64,
    /// Whether any serving device reprogrammed for this request's batch.
    pub reprogrammed: bool,
    /// Devices that served it (two when sharded).
    pub devices: Vec<usize>,
    pub sharded: bool,
    /// QoS class the request carried.
    pub priority: Priority,
    /// Absolute deadline on the virtual clock, if any.
    pub deadline_ms: Option<f64>,
    /// Modeled completion time on the virtual clock (arrival + queue
    /// wait under the backlog model + fabric service).
    pub completed_ms: f64,
    /// `completed_ms > deadline_ms` (always false for best-effort).
    pub deadline_missed: bool,
}

/// Outcome of a QoS-routed request: served, or explicitly shed at
/// ingress because no device could meet its deadline under the backlog
/// model (only `Low` priority is ever shed).
#[derive(Clone, Debug)]
pub enum QosOutcome {
    Served(ClusterResponse),
    Shed(ShedNotice),
}

impl QosOutcome {
    pub fn served(self) -> Option<ClusterResponse> {
        match self {
            QosOutcome::Served(r) => Some(r),
            QosOutcome::Shed(_) => None,
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, QosOutcome::Shed(_))
    }
}

/// Why a request was shed (returned to the client, never silent).
#[derive(Clone, Debug)]
pub struct ShedNotice {
    pub id: u64,
    pub priority: Priority,
    pub deadline_ms: f64,
    /// Best completion any admitting device could offer under the
    /// backlog model — already past the deadline.
    pub predicted_completion_ms: f64,
}

struct DeviceEndpoint {
    spec: DeviceSpec,
    handle: ServerHandle,
}

#[derive(Default)]
struct RouterState {
    /// Router's view of each device's currently-programmed topology.
    last_topology: Vec<Option<Topology>>,
    /// Modeled completion horizon per device, in absolute virtual-clock
    /// ms: the time the device would finish everything the router has
    /// dispatched to it, under the analytical service model (DESIGN.md
    /// §11).  Queue delay for a request arriving at `t` is
    /// `max(backlog, t) − t`.
    backlog_ms: Vec<f64>,
    /// Devices known dead to the router (`Cluster::fail_device` /
    /// `Cluster::stop_device`).  A dead device's frozen `backlog_ms`
    /// horizon would otherwise look ever more attractive as the live
    /// fleet's horizons advance; the backlog model observes health so
    /// `SlackEdf` ranks a dead horizon as infeasible instead of routing
    /// to it (ROADMAP PR-4 follow-up).
    down: Vec<bool>,
    totals: RouterTotals,
}

struct Shared {
    devices: Vec<DeviceEndpoint>,
    plan: PlacementPlan,
    max_retries: usize,
    qos: QosPolicy,
    state: Mutex<RouterState>,
}

/// A running fleet: per-device servers plus the routing front-end.
pub struct Cluster {
    shared: Arc<Shared>,
    /// `None` once a device has been drained via [`Cluster::stop_device`].
    servers: Vec<Option<Server>>,
    early_stats: Vec<Option<CoordinatorStats>>,
    /// Devices killed via [`Cluster::fail_device`] (reported `Failed`,
    /// not `Stopped`).
    failed: Vec<bool>,
}

/// Cloneable client handle (safe to share across request threads).
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

impl Cluster {
    /// Start one coordinator server per device (sim-datapath backend —
    /// the PJRT path needs per-process artifacts and is stubbed offline)
    /// and plan placement for the expected workload.
    pub fn start(
        devices: Vec<DeviceSpec>,
        workload: &WorkloadProfile,
        config: ClusterConfig,
    ) -> Result<Cluster> {
        if devices.is_empty() {
            bail!("cluster needs at least one device");
        }
        // Routing indexes devices by id; renumber to be safe.
        let mut devices = devices;
        for (i, d) in devices.iter_mut().enumerate() {
            d.id = i;
        }
        let plan = PlacementPlanner::default().plan(&devices, workload);
        let mut endpoints = Vec::with_capacity(devices.len());
        let mut servers = Vec::with_capacity(devices.len());
        for spec in devices {
            let sim = spec.sim.clone();
            let sched = config.scheduler;
            let server = Server::start(
                move || {
                    let accel = FamousAccelerator::with_sim_datapath(sim);
                    Coordinator::new(accel, sched)
                },
                config.server,
            );
            endpoints.push(DeviceEndpoint { spec, handle: server.handle() });
            servers.push(Some(server));
        }
        let n = endpoints.len();
        let shared = Arc::new(Shared {
            devices: endpoints,
            plan,
            max_retries: config.max_retries,
            qos: config.qos,
            state: Mutex::new(RouterState {
                last_topology: vec![None; n],
                backlog_ms: vec![0.0; n],
                down: vec![false; n],
                totals: RouterTotals::default(),
            }),
        });
        Ok(Cluster { shared, servers, early_stats: vec![None; n], failed: vec![false; n] })
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: Arc::clone(&self.shared) }
    }

    pub fn plan(&self) -> &PlacementPlan {
        &self.shared.plan
    }

    pub fn device_count(&self) -> usize {
        self.shared.devices.len()
    }

    /// Drain one device (elasticity / maintenance): its server shuts
    /// down and subsequent routing fails over to the rest of the fleet.
    /// Returns its stats, or None if already stopped.
    pub fn stop_device(&mut self, id: usize) -> Option<CoordinatorStats> {
        let server = self.servers.get_mut(id)?.take()?;
        let stats = server.shutdown();
        self.early_stats[id] = Some(stats.clone());
        // Drop the router's affinity memory for the drained device so it
        // stops ranking as "hot" for the topology it last served, and
        // mark it down so the backlog model stops treating its frozen
        // horizon as feasible capacity.
        let mut st = self.shared.state.lock().unwrap();
        st.last_topology[id] = None;
        st.down[id] = true;
        drop(st);
        Some(stats)
    }

    /// Simulate a device crash (chaos hook for the soak suite): the
    /// worker is killed without a drain — queued work is dropped exactly
    /// as a process death would drop it — and fleet reports flag the
    /// device `Failed` rather than `Stopped`.  The router is told (both
    /// ranking arms demote the corpse to last resort, the backlog model
    /// marks its horizon infeasible), so accepted requests reroute
    /// without probing the dead ingress; the bounce path remains the
    /// backstop for deaths the router was never told about.
    pub fn fail_device(&mut self, id: usize) -> bool {
        let Some(server) = self.servers.get_mut(id).and_then(|s| s.take()) else {
            return false;
        };
        server.kill();
        self.failed[id] = true;
        let mut st = self.shared.state.lock().unwrap();
        st.last_topology[id] = None;
        st.down[id] = true;
        drop(st);
        true
    }

    /// Live (pre-shutdown) fleet snapshot: per-device stats fetched from
    /// the running servers (each answers after its current serving
    /// round), merged with the router's current totals.  Lets operators
    /// observe cluster GOPS / reconfigurations / cache hit rates mid-run
    /// without draining anything.  Requests fan out to every device
    /// before any reply is awaited, so absent ingress backpressure the
    /// snapshot costs the slowest device's round, not the sum (a device
    /// with a full ingress queue still blocks its send — the request
    /// shares the bounded job channel).  Each device carries a
    /// [`DeviceHealth`] flag: a deliberately drained device reports
    /// `Stopped` with its final stats, while one whose worker died
    /// reports `Failed` with default (zero) stats — zeroed *unknowns*,
    /// no longer indistinguishable from an idle device.
    pub fn fleet_snapshot(&self) -> FleetStats {
        let mut health = Vec::with_capacity(self.servers.len());
        let pending: Vec<Option<std::sync::mpsc::Receiver<CoordinatorStats>>> = self
            .servers
            .iter()
            .enumerate()
            .map(|(i, server)| match server {
                None => {
                    health.push(if self.failed[i] {
                        DeviceHealth::Failed
                    } else {
                        DeviceHealth::Stopped
                    });
                    None
                }
                Some(s) => match s.handle().request_stats() {
                    Ok(rx) => {
                        health.push(DeviceHealth::Live);
                        Some(rx)
                    }
                    Err(_) => {
                        health.push(DeviceHealth::Failed);
                        None
                    }
                },
            })
            .collect();
        let coord: Vec<CoordinatorStats> = pending
            .into_iter()
            .enumerate()
            .map(|(i, rx)| match rx {
                Some(rx) => rx.recv().unwrap_or_else(|_| {
                    // Worker died between the request and the reply.
                    health[i] = DeviceHealth::Failed;
                    CoordinatorStats::default()
                }),
                None => self.early_stats[i].clone().unwrap_or_default(),
            })
            .collect();
        let specs: Vec<DeviceSpec> = self.shared.devices.iter().map(|d| d.spec.clone()).collect();
        let totals = self.shared.state.lock().unwrap().totals.clone();
        FleetStats::assemble_with_health(&specs, coord, health, totals)
    }

    /// Stop every device and assemble the fleet report.  Devices that
    /// served until this clean shutdown report `Live`; ones drained
    /// earlier via [`Self::stop_device`] report `Stopped`; ones whose
    /// worker had already died (engine failure) report `Failed` — their
    /// joined stats stop at the crash.
    pub fn shutdown(mut self) -> FleetStats {
        let mut coord = Vec::with_capacity(self.servers.len());
        let mut health = Vec::with_capacity(self.servers.len());
        for (i, server) in self.servers.into_iter().enumerate() {
            let stats = match server {
                Some(s) => {
                    // Probe before sending the shutdown message: a closed
                    // ingress here means the worker exited on its own.
                    health.push(if s.handle().is_alive() {
                        DeviceHealth::Live
                    } else {
                        DeviceHealth::Failed
                    });
                    s.shutdown()
                }
                None => {
                    health.push(if self.failed[i] {
                        DeviceHealth::Failed
                    } else {
                        DeviceHealth::Stopped
                    });
                    self.early_stats[i].take().unwrap_or_default()
                }
            };
            coord.push(stats);
        }
        let specs: Vec<DeviceSpec> =
            self.shared.devices.iter().map(|d| d.spec.clone()).collect();
        let totals = self.shared.state.lock().unwrap().totals.clone();
        FleetStats::assemble_with_health(&specs, coord, health, totals)
    }
}

/// Pure ranking input: one candidate device's routing signals.
#[derive(Clone, Debug)]
pub struct CandidateView {
    pub id: usize,
    /// Router last routed this topology here (no reprogramming needed).
    pub hot: bool,
    /// Position in the placement plan's preference list (usize::MAX if
    /// the plan does not mention this device for the topology).
    pub preference: usize,
    /// Requests waiting in the device's ingress queue.
    pub pending: usize,
}

/// Order candidates best-first: hot, then planner preference, then
/// least-loaded, then id (determinism).  Pure — unit-tested directly.
pub fn order_candidates(mut views: Vec<CandidateView>) -> Vec<usize> {
    views.sort_by_key(|v| (!v.hot as u8, v.preference, v.pending, v.id));
    views.into_iter().map(|v| v.id).collect()
}

/// One candidate's slack-routing signals ([`QosPolicy::SlackEdf`]).
#[derive(Clone, Debug)]
pub struct SlackView {
    pub id: usize,
    /// Router last routed this topology here (no reprogramming needed).
    pub hot: bool,
    /// Position in the placement plan's preference list.
    pub preference: usize,
    /// Modeled completion time if dispatched now (virtual-clock ms).
    pub est_completion_ms: f64,
    /// `deadline − est_completion` (+∞ when the request has no
    /// deadline).
    pub slack_ms: f64,
}

/// Order slack-aware candidates best-first: devices that meet the
/// deadline come first (hot, then planned, then earliest completion
/// among them), then the provably-late ones by least lateness; id
/// breaks every tie (determinism).  Pure — unit-tested directly.
pub fn order_candidates_by_slack(mut views: Vec<SlackView>) -> Vec<usize> {
    use std::cmp::Ordering;
    views.sort_by(|a, b| {
        let fa = a.slack_ms >= 0.0;
        let fb = b.slack_ms >= 0.0;
        let key = fb.cmp(&fa).then_with(|| {
            if fa && fb {
                (!a.hot)
                    .cmp(&!b.hot)
                    .then(a.preference.cmp(&b.preference))
                    .then(
                        a.est_completion_ms
                            .partial_cmp(&b.est_completion_ms)
                            .unwrap_or(Ordering::Equal),
                    )
            } else {
                b.slack_ms.partial_cmp(&a.slack_ms).unwrap_or(Ordering::Equal)
            }
        });
        key.then(a.id.cmp(&b.id))
    });
    views.into_iter().map(|v| v.id).collect()
}

/// QoS metadata peeled off a request before it is moved into dispatch.
#[derive(Clone, Copy, Debug)]
struct QosMeta {
    priority: Priority,
    arrival_ms: f64,
    deadline_ms: Option<f64>,
}

impl QosMeta {
    fn of(req: &Request) -> Self {
        QosMeta {
            priority: req.priority,
            arrival_ms: req.arrival_ms,
            deadline_ms: req.deadline_ms,
        }
    }
}

impl ClusterHandle {
    /// Serve one request, blocking until the response: routes to a
    /// single device when possible, transparently head-shards otherwise.
    /// A shed request (QoS policies only) surfaces as an error here; use
    /// [`Self::call_qos`] to observe shedding as a typed outcome.
    pub fn call(&self, req: Request) -> Result<ClusterResponse> {
        match self.call_qos(req)? {
            QosOutcome::Served(resp) => Ok(resp),
            QosOutcome::Shed(s) => bail!(
                "request {} shed: deadline {:.3} ms unreachable (best completion {:.3} ms)",
                s.id,
                s.deadline_ms,
                s.predicted_completion_ms
            ),
        }
    }

    /// Serve one request with an explicit QoS outcome: `Served` with the
    /// response, or `Shed` when the request is `Low` priority and no
    /// admitting device can meet its deadline under the backlog model
    /// (`QosPolicy::SlackEdf` only — `Affinity` never sheds).
    pub fn call_qos(&self, req: Request) -> Result<QosOutcome> {
        let topo = req.topology.clone();
        let meta = QosMeta::of(&req);
        let single = self.shared.devices.iter().any(|d| d.spec.admits(&topo));
        let shard = if single {
            None
        } else {
            self.shared
                .plan
                .placement(&topo)
                .and_then(|p| p.shard.clone())
                .or_else(|| ShardPlan::plan(&topo))
                .filter(|s| self.shared.devices.iter().any(|d| d.spec.admits(&s.half)))
        };
        if !single && shard.is_none() {
            self.shared.state.lock().unwrap().totals.rejected += 1;
            bail!("no device admits topology {topo} and no head-shard of it is servable");
        }
        // Shed check: a Low request whose deadline no admitting device
        // can meet is rejected explicitly instead of queued to die.
        if self.shared.qos == QosPolicy::SlackEdf && meta.priority == Priority::Low {
            if let Some(deadline) = meta.deadline_ms {
                let check = shard.as_ref().map(|s| &s.half).unwrap_or(&topo);
                if let Some(best) = self.best_completion_ms(check, meta.arrival_ms) {
                    if best > deadline {
                        let mut st = self.shared.state.lock().unwrap();
                        st.totals.slo.record_shed(meta.priority);
                        drop(st);
                        return Ok(QosOutcome::Shed(ShedNotice {
                            id: req.id,
                            priority: meta.priority,
                            deadline_ms: deadline,
                            predicted_completion_ms: best,
                        }));
                    }
                }
            }
        }
        let resp = match shard {
            None => {
                let (resp, dev, done) = self.call_single(req, None)?;
                let gops = resp.gops;
                let missed = meta.deadline_ms.map(|dl| done > dl);
                let mut st = self.shared.state.lock().unwrap();
                st.totals.completed += 1;
                st.totals.slo.record_completion(meta.priority, done - meta.arrival_ms, missed);
                drop(st);
                ClusterResponse {
                    id: resp.id,
                    topology: topo,
                    output: resp.output,
                    fabric_ms: resp.fabric_ms,
                    gops,
                    reprogrammed: resp.reprogrammed,
                    devices: vec![dev],
                    sharded: false,
                    priority: meta.priority,
                    deadline_ms: meta.deadline_ms,
                    completed_ms: done,
                    deadline_missed: missed.unwrap_or(false),
                }
            }
            Some(s) => self.call_sharded(req, s, &meta)?,
        };
        Ok(QosOutcome::Served(resp))
    }

    /// Best modeled completion over *live* admitting devices for `topo`
    /// (None when nothing admits it): the shed test's "provably late"
    /// bound.  A dead device's frozen horizon is not capacity.
    fn best_completion_ms(&self, topo: &Topology, arrival_ms: f64) -> Option<f64> {
        let st = self.shared.state.lock().unwrap();
        self.shared
            .devices
            .iter()
            .filter(|d| !st.down[d.spec.id] && d.spec.admits(topo))
            .map(|d| st.backlog_ms[d.spec.id].max(arrival_ms) + d.spec.predicted_ms(topo))
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Rank admitting devices for `topo`, best first.  Under
    /// `SlackEdf` the ordering is slack-aware (deadline-feasible
    /// devices first, by modeled completion); under `Affinity` it is
    /// the PR-1 hot/planned/least-loaded order.
    fn rank(&self, topo: &Topology, exclude: Option<usize>, qos: Option<&QosMeta>) -> Vec<usize> {
        let preferred = preferred_devices(&self.shared.plan, topo);
        let st = self.shared.state.lock().unwrap();
        let position = |id: usize| preferred.iter().position(|&p| p == id).unwrap_or(usize::MAX);
        if let (QosPolicy::SlackEdf, Some(meta)) = (self.shared.qos, qos) {
            let views: Vec<SlackView> = self
                .shared
                .devices
                .iter()
                .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
                .map(|d| {
                    // A down device's horizon froze at its death: rank
                    // it infeasible (−∞ slack sorts after every live
                    // candidate, feasible or late) so SlackEdf never
                    // chases a frozen horizon; it stays a candidate of
                    // last resort only.
                    if st.down[d.spec.id] {
                        return SlackView {
                            id: d.spec.id,
                            hot: false,
                            preference: usize::MAX,
                            est_completion_ms: f64::INFINITY,
                            slack_ms: f64::NEG_INFINITY,
                        };
                    }
                    let est = st.backlog_ms[d.spec.id].max(meta.arrival_ms)
                        + d.spec.predicted_ms(topo);
                    SlackView {
                        id: d.spec.id,
                        hot: st.last_topology[d.spec.id].as_ref() == Some(topo),
                        preference: position(d.spec.id),
                        est_completion_ms: est,
                        slack_ms: meta.deadline_ms.map_or(f64::INFINITY, |dl| dl - est),
                    }
                })
                .collect();
            drop(st);
            return order_candidates_by_slack(views);
        }
        let views: Vec<CandidateView> = self
            .shared
            .devices
            .iter()
            .filter(|d| Some(d.spec.id) != exclude && d.spec.admits(topo))
            .map(|d| {
                // A known-down device's empty ingress would rank it
                // least-loaded first forever (one bounce per request);
                // demote it to a candidate of last resort here too.
                if st.down[d.spec.id] {
                    return CandidateView {
                        id: d.spec.id,
                        hot: false,
                        preference: usize::MAX,
                        pending: usize::MAX,
                    };
                }
                CandidateView {
                    id: d.spec.id,
                    hot: st.last_topology[d.spec.id].as_ref() == Some(topo),
                    preference: position(d.spec.id),
                    pending: d.handle.pending(),
                }
            })
            .collect();
        drop(st);
        order_candidates(views)
    }

    /// Route one single-device request with backpressure failover.
    /// Returns the response, the serving device, and the modeled
    /// completion time on the virtual clock.
    fn call_single(&self, req: Request, exclude: Option<usize>) -> Result<(Response, usize, f64)> {
        let topo = req.topology.clone();
        let meta = QosMeta::of(&req);
        let mut candidates = self.rank(&topo, exclude, Some(&meta));
        if candidates.is_empty() {
            // Exclusion left nothing; fall back to the full fleet.
            candidates = self.rank(&topo, None, Some(&meta));
        }
        if candidates.is_empty() {
            self.shared.state.lock().unwrap().totals.rejected += 1;
            bail!("no device in the fleet admits topology {topo}");
        }
        let mut req = req;
        let mut bounces = 0usize;
        let mut idx = 0usize;
        let mut bounced: Vec<usize> = Vec::new();
        loop {
            if bounces >= self.shared.max_retries {
                // Enough spinning: block for queue space on the best
                // candidate (backpressure propagates to the client).
                // Prefer one that did not just bounce us — a bounce can
                // mean the device is gone, not merely full, and blocking
                // on a dead channel fails a still-servable request.
                let dev = candidates
                    .iter()
                    .copied()
                    .find(|d| !bounced.contains(d))
                    .unwrap_or(candidates[0]);
                let resp = self.shared.devices[dev]
                    .handle
                    .call_blocking(req)
                    .map_err(|e| anyhow!("device {dev}: {e}"))?;
                return Ok(self.record(resp, dev, &topo, &meta));
            }
            let dev = candidates[idx % candidates.len()];
            match self.shared.devices[dev].handle.try_call(req) {
                Ok(resp) => return Ok(self.record(resp, dev, &topo, &meta)),
                Err(SubmitError::Busy(returned)) => {
                    req = returned;
                    bounces += 1;
                    idx += 1;
                    if !bounced.contains(&dev) {
                        bounced.push(dev);
                    }
                    self.shared.state.lock().unwrap().totals.retries += 1;
                }
                Err(SubmitError::Failed(e)) => bail!("device {dev}: {e}"),
            }
        }
    }

    /// Two half-requests on (preferably) two devices, concat on the host.
    fn call_sharded(
        &self,
        req: Request,
        shard: ShardPlan,
        meta: &QosMeta,
    ) -> Result<ClusterResponse> {
        let (lo, hi) = shard.split_inputs(&req.inputs)?;
        let req_lo = Request::new(req.id, shard.half.clone(), lo)
            .with_qos(req.priority, req.arrival_ms, req.deadline_ms);
        let req_hi = Request::new(req.id, shard.half.clone(), hi)
            .with_qos(req.priority, req.arrival_ms, req.deadline_ms);
        // Steer the high half away from the low half's likely device so
        // the halves actually run concurrently when the fleet allows.
        let low_primary = self.rank(&shard.half, None, Some(meta)).first().copied();
        let other = self.clone();
        let hi_worker = std::thread::spawn(move || other.call_single(req_hi, low_primary));
        let lo_result = self.call_single(req_lo, None);
        let hi_result =
            hi_worker.join().map_err(|_| anyhow!("shard worker thread panicked"))?;
        let (lo_resp, lo_dev, lo_done) = lo_result?;
        let (hi_resp, hi_dev, hi_done) = hi_result?;
        let output = shard.concat_outputs(&lo_resp.output, &hi_resp.output)?;
        let fabric_ms = lo_resp.fabric_ms.max(hi_resp.fabric_ms);
        let gop = 2.0 * OpCount::paper_convention(&shard.half);
        let done = lo_done.max(hi_done);
        let missed = meta.deadline_ms.map(|dl| done > dl);
        let mut st = self.shared.state.lock().unwrap();
        st.totals.completed += 1;
        st.totals.sharded += 1;
        st.totals.slo.record_completion(meta.priority, done - meta.arrival_ms, missed);
        drop(st);
        Ok(ClusterResponse {
            id: req.id,
            topology: shard.full.clone(),
            output,
            fabric_ms,
            gops: gop / (fabric_ms * 1e-3),
            reprogrammed: lo_resp.reprogrammed || hi_resp.reprogrammed,
            devices: vec![lo_dev, hi_dev],
            sharded: true,
            priority: meta.priority,
            deadline_ms: meta.deadline_ms,
            completed_ms: done,
            deadline_missed: missed.unwrap_or(false),
        })
    }

    /// Book-keeping after a device served a (sub-)request: affinity
    /// counters, the device's programmed-topology memory, and the
    /// backlog-model advance that yields the modeled completion time.
    fn record(
        &self,
        resp: Response,
        dev: usize,
        topo: &Topology,
        meta: &QosMeta,
    ) -> (Response, usize, f64) {
        let preferred = preferred_devices(&self.shared.plan, topo);
        let mut st = self.shared.state.lock().unwrap();
        let hot = st.last_topology[dev].as_ref() == Some(topo);
        let planned = preferred.first() == Some(&dev) || self.shared.plan.is_pinned(dev, topo);
        if hot || planned {
            st.totals.affinity_hits += 1;
        } else {
            st.totals.affinity_misses += 1;
        }
        st.last_topology[dev] = Some(topo.clone());
        st.totals.total_gop += OpCount::paper_convention(topo);
        let done = st.backlog_ms[dev].max(meta.arrival_ms) + resp.fabric_ms;
        st.backlog_ms[dev] = done;
        (resp, dev, done)
    }
}

/// The plan's device preference list for `topo` — including when `topo`
/// is the half shape of a sharded placement.
fn preferred_devices<'a>(plan: &'a PlacementPlan, topo: &Topology) -> &'a [usize] {
    if let Some(p) = plan.placement(topo) {
        return &p.devices;
    }
    for p in &plan.placements {
        if let Some(s) = &p.shard {
            if &s.half == topo {
                return &p.devices;
            }
        }
    }
    &[]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testdata::MhaInputs;

    fn req(id: u64, topo: &Topology) -> Request {
        Request::new(id, topo.clone(), MhaInputs::generate(topo))
    }

    fn two_u55c(workload: &[Topology]) -> Cluster {
        Cluster::start(
            vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(workload),
            ClusterConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn order_prefers_hot_then_plan_then_load() {
        let v = |id, hot, preference, pending| CandidateView { id, hot, preference, pending };
        // Hot beats everything, even a deep queue.
        assert_eq!(
            order_candidates(vec![v(0, false, 0, 0), v(1, true, usize::MAX, 9)]),
            vec![1, 0]
        );
        // Plan preference beats load...
        assert_eq!(
            order_candidates(vec![v(0, false, usize::MAX, 0), v(1, false, 0, 5)]),
            vec![1, 0]
        );
        // ...and load breaks preference ties, id breaks full ties.
        assert_eq!(
            order_candidates(vec![v(0, false, 1, 7), v(1, false, 1, 2), v(2, false, 1, 7)]),
            vec![1, 0, 2]
        );
    }

    #[test]
    fn affinity_keeps_topologies_on_their_devices() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone()]);
        let h = cluster.handle();
        // Interleaved sequential stream: affinity must pin each topology
        // to one device, so per-device streams are homogeneous.
        let mut device_of = std::collections::HashMap::new();
        for i in 0..8u64 {
            let t = if i % 2 == 0 { &t1 } else { &t2 };
            let resp = h.call(req(i, t)).unwrap();
            assert_eq!(resp.devices.len(), 1);
            let prev = device_of.insert(t.clone(), resp.devices[0]);
            if let Some(p) = prev {
                assert_eq!(p, resp.devices[0], "topology moved devices");
            }
        }
        assert_ne!(device_of[&t1], device_of[&t2], "both topologies on one device");
        let fleet = cluster.shutdown();
        // One reprogram per device, ever — the whole point of affinity.
        assert_eq!(fleet.reconfigurations(), 2);
        assert_eq!(fleet.totals.completed, 8);
        assert_eq!(fleet.totals.affinity_hits, 8);
        assert_eq!(fleet.totals.affinity_misses, 0);
    }

    #[test]
    fn failover_when_device_unavailable() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        // Prime affinity onto the planner's primary.
        let first = h.call(req(0, &t)).unwrap();
        let primary = first.devices[0];
        // Drain that device: the router is told, so failover is a
        // ranking decision — the drained ingress is never even probed.
        cluster.stop_device(primary).unwrap();
        let resp = h.call(req(1, &t)).unwrap();
        assert_eq!(resp.devices.len(), 1);
        assert_ne!(resp.devices[0], primary, "must fail over to the live device");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a drained device");
        assert_eq!(fleet.totals.completed, 2);
    }

    #[test]
    fn sharded_request_served_and_reassembled() {
        let large = Topology::new(16, 1024, 16, 64);
        let cluster = two_u55c(std::slice::from_ref(&large));
        let h = cluster.handle();
        let inputs = MhaInputs::generate(&large);
        let resp = h
            .call(Request::new(7, large.clone(), inputs.clone()))
            .unwrap();
        assert!(resp.sharded);
        assert_eq!(resp.devices.len(), 2);
        assert_ne!(resp.devices[0], resp.devices[1], "halves should use both devices");
        assert_eq!(resp.output.len(), 16 * 1024);
        // Reference: the same two halves on one local accelerator.
        let plan = ShardPlan::plan(&large).unwrap();
        let (lo, hi) = plan.split_inputs(&inputs).unwrap();
        let mut accel = FamousAccelerator::with_sim_datapath(crate::sim::SimConfig::u55c());
        let lo_out = accel.run(&plan.half, &lo).unwrap().output;
        let hi_out = accel.run(&plan.half, &hi).unwrap().output;
        let want = plan.concat_outputs(&lo_out, &hi_out).unwrap();
        assert_eq!(resp.output, want, "sharded output must be bit-identical");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.sharded, 1);
        assert_eq!(fleet.served(), 2, "one request, two device invocations");
    }

    #[test]
    fn live_snapshot_observes_mid_run_state() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        h.call(req(0, &t)).unwrap();
        h.call(req(1, &t)).unwrap();
        let snap = cluster.fleet_snapshot();
        assert_eq!(snap.totals.completed, 2);
        assert_eq!(snap.served(), 2);
        assert!(snap.makespan_ms() > 0.0);
        assert!(snap.timing_sims() >= 1);
        assert_eq!(snap.live_devices(), 2, "both devices up -> both live");
        // Snapshots keep working after a device drains (early stats),
        // and the drained device is flagged, not shown as a zeroed peer.
        cluster.stop_device(0).unwrap();
        let snap2 = cluster.fleet_snapshot();
        assert_eq!(snap2.totals.completed, 2);
        assert_eq!(snap2.served(), 2);
        assert_eq!(snap2.devices[0].health, DeviceHealth::Stopped);
        assert_eq!(snap2.devices[1].health, DeviceHealth::Live);
        assert_eq!(snap2.live_devices(), 1);
        assert_eq!(snap2.failed_devices(), 0);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 2);
        assert_eq!(fleet.served(), snap.served());
        assert_eq!(fleet.devices[0].health, DeviceHealth::Stopped);
        assert_eq!(fleet.devices[1].health, DeviceHealth::Live);
    }

    #[test]
    fn unservable_topology_rejected() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        // SL 256 exceeds every synthesized max and head-sharding cannot
        // reduce SL.
        let err = h.call(req(0, &Topology::new(256, 768, 8, 64))).unwrap_err();
        assert!(err.to_string().contains("no device admits"), "{err}");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.rejected, 1);
        assert_eq!(fleet.totals.completed, 0);
    }

    #[test]
    fn slack_order_prefers_feasible_then_hot_then_earliest() {
        let v = |id, hot, preference, est, slack| SlackView {
            id,
            hot,
            preference,
            est_completion_ms: est,
            slack_ms: slack,
        };
        // A feasible cold device beats an infeasible hot one.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, true, 0, 9.0, -1.0),
                v(1, false, usize::MAX, 3.0, 2.0),
            ]),
            vec![1, 0]
        );
        // Among feasible devices: hot first, then plan, then earliest
        // modeled completion.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, false, 0, 1.0, 5.0),
                v(1, true, usize::MAX, 4.0, 2.0),
                v(2, false, 0, 0.5, 5.5),
            ]),
            vec![1, 2, 0]
        );
        // All infeasible: least-late first.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, true, 0, 9.0, -5.0),
                v(1, false, 1, 7.0, -3.0),
            ]),
            vec![1, 0]
        );
        // A down device's view (−∞ slack, +∞ completion) ranks after
        // every live candidate — even a provably-late one.
        assert_eq!(
            order_candidates_by_slack(vec![
                v(0, false, usize::MAX, f64::INFINITY, f64::NEG_INFINITY),
                v(1, false, 1, 50.0, -40.0),
                v(2, false, 0, 3.0, 2.0),
            ]),
            vec![2, 1, 0]
        );
    }

    #[test]
    fn slack_routing_never_probes_a_failed_horizon() {
        // A dead device's backlog horizon freezes and would otherwise
        // become the "best" completion estimate as the live fleet
        // backs up; the backlog model must observe health so SlackEdf
        // routes around the corpse without a single bounce.
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = qos_two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        // Build a backlog on whichever device serves first.
        let live = h.call(req(0, &t)).unwrap().devices[0];
        let dead = 1 - live;
        assert!(cluster.fail_device(dead));
        // Tight-deadline traffic: the live device is provably late, the
        // dead one's frozen (empty) horizon would look feasible.  The
        // router must still pick the live device, with zero retries —
        // it never even probes the dead ingress.
        for i in 1..4u64 {
            let r = h
                .call_qos(req(i, &t).with_qos(Priority::High, 0.0, Some(1.2 * ms)))
                .unwrap()
                .served()
                .expect("high priority is never shed");
            assert_eq!(r.devices, vec![live], "routed toward a frozen horizon");
        }
        // The shed bound likewise ignores the dead horizon: a Low
        // request sheds on the live device's real backlog, not the
        // corpse's optimistic one.
        let out = h
            .call_qos(req(9, &t).with_qos(Priority::Low, 0.0, Some(1.2 * ms)))
            .unwrap();
        assert!(out.is_shed(), "dead horizon must not count as shed-saving capacity");
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a dead device");
        assert_eq!(fleet.totals.completed, 4);
        assert_eq!(fleet.devices[dead].health, DeviceHealth::Failed);
    }

    #[test]
    fn stopped_device_horizon_also_infeasible() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = qos_two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let live = h.call(req(0, &t)).unwrap().devices[0];
        let drained = 1 - live;
        cluster.stop_device(drained).unwrap();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        for i in 1..3u64 {
            let r = h
                .call_qos(req(i, &t).with_qos(Priority::High, 0.0, Some(1.2 * ms)))
                .unwrap()
                .served()
                .unwrap();
            assert_eq!(r.devices, vec![live]);
        }
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.retries, 0, "router probed a drained device");
        assert_eq!(fleet.devices[drained].health, DeviceHealth::Stopped);
    }

    fn qos_two_u55c(workload: &[Topology]) -> Cluster {
        Cluster::start(
            vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)],
            &WorkloadProfile::uniform(workload),
            ClusterConfig::qos(),
        )
        .unwrap()
    }

    #[test]
    fn qos_completions_track_backlog_and_deadlines() {
        let t = Topology::new(64, 768, 8, 64);
        let cluster = qos_two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        // Two same-arrival requests with a deadline only one device-slot
        // can meet: slack routing puts them on different devices, so
        // both meet it (affinity routing would stack them on one).
        let deadline = Some(1.5 * ms);
        let r1 = h
            .call_qos(req(1, &t).with_qos(Priority::High, 0.0, deadline))
            .unwrap()
            .served()
            .unwrap();
        let r2 = h
            .call_qos(req(2, &t).with_qos(Priority::High, 0.0, deadline))
            .unwrap()
            .served()
            .unwrap();
        assert!(!r1.deadline_missed && !r2.deadline_missed, "{r1:?} {r2:?}");
        assert_ne!(r1.devices, r2.devices, "slack routing must spread infeasible load");
        assert!((r1.completed_ms - ms).abs() < 1e-9);
        // A third request at t=0 now finds both devices backlogged: it
        // completes at 2·ms and misses the same deadline.
        let r3 = h
            .call_qos(req(3, &t).with_qos(Priority::High, 0.0, deadline))
            .unwrap()
            .served()
            .unwrap();
        assert!(r3.deadline_missed, "{r3:?}");
        assert!((r3.completed_ms - 2.0 * ms).abs() < 1e-9);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.slo.met[Priority::High.index()], 2);
        assert_eq!(fleet.totals.slo.missed[Priority::High.index()], 1);
        assert!(fleet.render().contains("QoS"));
    }

    #[test]
    fn provably_late_low_priority_is_shed_not_queued() {
        let t = Topology::new(64, 768, 8, 64);
        let one = |topos: &[Topology]| {
            Cluster::start(
                vec![DeviceSpec::u55c(0)],
                &WorkloadProfile::uniform(topos),
                ClusterConfig::qos(),
            )
            .unwrap()
        };
        let cluster = one(std::slice::from_ref(&t));
        let h = cluster.handle();
        let ms = DeviceSpec::u55c(0).predicted_ms(&t);
        // Fill the lone device's modeled backlog past the deadline.
        for i in 0..4u64 {
            h.call(req(i, &t)).unwrap();
        }
        let out = h
            .call_qos(req(9, &t).with_qos(Priority::Low, 0.0, Some(1.5 * ms)))
            .unwrap();
        match out {
            QosOutcome::Shed(n) => {
                assert_eq!(n.id, 9);
                assert_eq!(n.priority, Priority::Low);
                assert!(n.predicted_completion_ms > n.deadline_ms);
            }
            QosOutcome::Served(r) => panic!("expected shed, served: {r:?}"),
        }
        // High priority is never shed — it runs late instead.
        let r = h
            .call_qos(req(10, &t).with_qos(Priority::High, 0.0, Some(1.5 * ms)))
            .unwrap()
            .served()
            .expect("high priority must be served");
        assert!(r.deadline_missed);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.slo.shed[Priority::Low.index()], 1);
        assert_eq!(fleet.totals.completed, 5, "shed request never dispatched");
        // call() surfaces a shed as an error mentioning the deadline.
        let cluster2 = one(std::slice::from_ref(&t));
        let h2 = cluster2.handle();
        h2.call(req(0, &t)).unwrap();
        let err = h2.call(req(9, &t).with_qos(Priority::Low, 0.0, Some(0.1))).unwrap_err();
        assert!(err.to_string().contains("shed"), "{err}");
        cluster2.shutdown();
    }

    #[test]
    fn failed_device_flagged_and_rerouted() {
        let t = Topology::new(64, 768, 8, 64);
        let mut cluster = two_u55c(std::slice::from_ref(&t));
        let h = cluster.handle();
        let first = h.call(req(0, &t)).unwrap();
        let dead = first.devices[0];
        assert!(cluster.fail_device(dead));
        assert!(!cluster.fail_device(dead), "double-fail is a no-op");
        // Requests keep flowing: the router was told about the crash,
        // so it reroutes by ranking — no probe of the dead ingress.
        for i in 1..4u64 {
            let resp = h.call(req(i, &t)).unwrap();
            assert_ne!(resp.devices[0], dead, "routed to the dead device");
        }
        let snap = cluster.fleet_snapshot();
        assert_eq!(snap.devices[dead].health, DeviceHealth::Failed);
        assert_eq!(snap.failed_devices(), 1);
        let fleet = cluster.shutdown();
        assert_eq!(fleet.devices[dead].health, DeviceHealth::Failed);
        assert_eq!(fleet.totals.completed, 4);
        assert_eq!(fleet.totals.retries, 0, "router probed a failed device");
        assert!(fleet.render().contains("FAILED"));
    }

    #[test]
    fn concurrent_clients_all_served() {
        let t1 = Topology::new(64, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let cluster = two_u55c(&[t1.clone(), t2.clone()]);
        let mut joins = Vec::new();
        for i in 0..12u64 {
            let h = cluster.handle();
            let t = if i % 2 == 0 { t1.clone() } else { t2.clone() };
            joins.push(std::thread::spawn(move || h.call(req(i, &t)).unwrap()));
        }
        let mut ids: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap().id).collect();
        ids.sort();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed, 12);
        assert_eq!(fleet.served(), 12);
        assert_eq!(fleet.totals.rejected, 0);
    }
}
