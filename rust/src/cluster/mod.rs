//! Layer-4 cluster: serving across a fleet of heterogeneous FPGAs.
//!
//! FAMOUS scales *up* to one card's DSP/BRAM budget; this layer scales
//! *out*.  A fleet of simulated devices (mixed U55C + U200 builds, each
//! with its own [`crate::sim::SimConfig`] resource envelope) sits behind
//! one ingress, in the spirit of FTRANS's cross-FPGA partitioning and the
//! length-adaptive routing of Peng et al. (PAPERS.md):
//!
//! * [`placement`] — synthesis-time planning: which topologies each
//!   device pins (weight tiles staged in BRAM, sized by
//!   [`crate::fpga::resources`]), load-balanced by the
//!   [`crate::analytical`] latency model; decides when an oversized
//!   `d_model` is head-sharded across two devices.
//! * [`shard`] — the head-group split itself: operand slicing on the way
//!   in, host-side concat on the way out.
//! * [`router`] — the runtime dispatcher fronting N
//!   [`crate::coordinator::Server`] workers: topology-affinity routing
//!   (the fleet-wide analogue of `BatchPolicy::GroupByTopology` — keep a
//!   topology on the device already programmed for it), least-loaded
//!   fallback, and backpressure-aware failover when a device queue is
//!   full.
//! * [`fleet`] — metrics: per-device `CoordinatorStats` aggregated into
//!   cluster GOPS (over batch makespans — max-of-batch, DESIGN.md §9),
//!   occupancy, p50/p99 fabric latency, program-cache hit rates,
//!   reconfigurations per request, and per-priority SLO stats
//!   (sojourn percentiles, deadline-miss rate, shed counts — DESIGN.md
//!   §11); available mid-run via [`router::Cluster::fleet_snapshot`]
//!   as well as at shutdown.
//! * [`loadgen`] — seeded arrival-process load generation (Poisson and
//!   two-state bursty MMPP) with mixed priority classes and deadline
//!   budgets, replacing the uniform closed-loop replay in the cluster
//!   bench and the QoS soak suite; [`loadgen::fit_mmpp`] closes the
//!   loop by recovering MMPP parameters from a recorded frame trace.
//! * [`telemetry`] — streaming observability: router events aggregated
//!   into per-window sealed [`telemetry::TelemetryFrame`]s (bounded
//!   ring, late stragglers counted, deterministic under the virtual
//!   clock) plus the threshold-rule [`telemetry::ControlPlane`] that
//!   drains drifting devices and tightens admission through `Cluster`
//!   hooks — DESIGN.md §13.
//! * [`des`] — the virtual-time discrete-event fleet simulator
//!   (DESIGN.md §16): a timestamp-ordered event heap drives the same
//!   routing/QoS/telemetry pipeline with service times drawn from the
//!   cached `ProgramImage` traces, so million-request capacity studies
//!   simulate in wall-clock seconds, bit-reproducibly.
//!
//! Invariants (tested in `rust/tests/cluster.rs`, DESIGN.md §7): every
//! cluster response is bit-identical to a single-device run of the same
//! request, modeled aggregate throughput on N>1 devices strictly exceeds
//! one device, and affinity routing performs fewer reconfigurations per
//! request than a lone coordinator on the same interleaved stream.

pub mod des;
pub mod fleet;
pub mod loadgen;
pub mod placement;
pub mod router;
pub mod shard;
pub mod telemetry;

pub use des::{DesConfig, DesReport, EventQueue, FleetSim};
pub use fleet::{DeviceHealth, DeviceReport, FleetStats, SloStats};
pub use loadgen::{Arrival, ArrivalProcess, LoadGen, LoadGenConfig, MmppFit, QosClass};
pub use placement::{PlacementPlan, PlacementPlanner, TopologyPlacement, WorkloadProfile};
pub use router::{
    bounce_backoff, Clock, ClockMode, Cluster, ClusterConfig, ClusterHandle, ClusterResponse,
    QosOutcome, QosPolicy, SaturationNotice, SaturationPolicy, ShedNotice, VirtualClock, WallClock,
};
pub use shard::ShardPlan;
pub use telemetry::{
    ActionRecord, ControlAction, ControlPlane, ControlRule, FrameAggregator, FrameTotals, Heat,
    RuleScope, RuleSignal, TelemetryConfig, TelemetryEvent, TelemetryFrame, TelemetrySnapshot,
};

use crate::config::Topology;
use crate::sim::SimConfig;
use anyhow::{bail, Result};

/// One fleet member: a synthesized build plus its identity.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    /// Index into the fleet (stable routing id).
    pub id: usize,
    /// Human-readable name, e.g. `u55c-0`.
    pub name: String,
    /// The device's synthesized build + simulator configuration.
    pub sim: SimConfig,
    /// Silent fabric-clock derate applied to the *actual* device the
    /// cluster boots but not to the advertised model the router plans
    /// with ([`DeviceSpec::predicted_ms`]) — thermal throttling the
    /// scheduler has not been told about.  The telemetry control
    /// plane's job is to notice the drift and drain the device
    /// (DESIGN.md §13).  `1.0` = healthy.
    pub silent_derate: f64,
}

impl DeviceSpec {
    pub fn u55c(id: usize) -> Self {
        DeviceSpec { id, name: format!("u55c-{id}"), sim: SimConfig::u55c(), silent_derate: 1.0 }
    }

    pub fn u200(id: usize) -> Self {
        DeviceSpec { id, name: format!("u200-{id}"), sim: SimConfig::u200(), silent_derate: 1.0 }
    }

    /// The long-sequence U55C build (fused streaming attention unit,
    /// SL up to 1024 — DESIGN.md §12).
    pub fn u55c_long(id: usize) -> Self {
        DeviceSpec {
            id,
            name: format!("u55c-long-{id}"),
            sim: SimConfig::u55c_long(),
            silent_derate: 1.0,
        }
    }

    /// Degrade the device's real fabric clock to `factor` of nominal
    /// without updating the advertised model (`0 < factor <= 1`).
    pub fn with_silent_derate(mut self, factor: f64) -> Self {
        assert!(factor > 0.0 && factor <= 1.0, "derate factor must be in (0, 1]");
        self.silent_derate = factor;
        self
    }

    /// Seed this device with a deterministic SEU injection plan
    /// (DESIGN.md §15) — the data-corruption sibling of
    /// [`Self::with_silent_derate`]'s silent clock drift.  The plan
    /// rides on the device's `SimConfig` into its backend, so the
    /// router's advertised model stays oblivious; detection is the ABFT
    /// layer's job.
    pub fn with_fault_plan(mut self, plan: crate::sim::FaultPlan) -> Self {
        self.sim.fault_plan = Some(plan);
        self
    }

    /// Can this device serve `topo` without re-synthesis?
    pub fn admits(&self, topo: &Topology) -> bool {
        self.sim.build.admits(topo).is_ok()
    }

    /// Modeled fabric latency of `topo` on this device (analytical model
    /// cycles at the device's clock).
    pub fn predicted_ms(&self, topo: &Topology) -> f64 {
        let cycles = crate::analytical::LatencyModel::default().predict(topo).total_cycles();
        self.sim.build.cycles_to_ms(cycles)
    }
}

/// Parse a fleet spec like `"u55c:2,u200:2"` into device specs.
pub fn parse_fleet(spec: &str) -> Result<Vec<DeviceSpec>> {
    let mut devices = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (kind, count) = match part.split_once(':') {
            Some((k, c)) => {
                let n: usize =
                    c.parse().map_err(|_| anyhow::anyhow!("bad device count '{c}' in '{part}'"))?;
                (k, n)
            }
            None => (part, 1),
        };
        for _ in 0..count {
            let id = devices.len();
            match kind {
                "u55c" => devices.push(DeviceSpec::u55c(id)),
                "u200" => devices.push(DeviceSpec::u200(id)),
                "u55c-long" => devices.push(DeviceSpec::u55c_long(id)),
                other => bail!("unknown device kind '{other}' (u55c | u200 | u55c-long)"),
            }
        }
    }
    if devices.is_empty() {
        bail!("fleet spec '{spec}' names no devices");
    }
    Ok(devices)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_fleet_mixed() {
        let f = parse_fleet("u55c:2,u200:1").unwrap();
        assert_eq!(f.len(), 3);
        assert_eq!(f[0].name, "u55c-0");
        assert_eq!(f[2].name, "u200-2");
        assert_eq!(f[2].sim.build.max_topology.heads, 6);
        assert_eq!(f[1].id, 1);
    }

    #[test]
    fn parse_fleet_bare_kind_and_errors() {
        assert_eq!(parse_fleet("u55c").unwrap().len(), 1);
        assert!(parse_fleet("v100:2").is_err());
        assert!(parse_fleet("").is_err());
        assert!(parse_fleet("u55c:x").is_err());
    }

    #[test]
    fn heterogeneous_admission() {
        let u55c = DeviceSpec::u55c(0);
        let u200 = DeviceSpec::u200(1);
        let h8 = Topology::new(64, 768, 8, 64);
        let h6 = Topology::new(64, 768, 6, 64);
        assert!(u55c.admits(&h8) && u55c.admits(&h6));
        assert!(!u200.admits(&h8), "U200 caps at 6 heads");
        assert!(u200.admits(&h6));
    }

    #[test]
    fn predicted_latency_matches_analytical_headline() {
        let d = DeviceSpec::u55c(0);
        let ms = d.predicted_ms(&Topology::new(64, 768, 8, 64));
        assert!((ms - 0.94).abs() < 0.005, "{ms}");
    }

    #[test]
    fn silent_derate_leaves_advertised_model_alone() {
        let t = Topology::new(64, 768, 8, 64);
        let healthy = DeviceSpec::u55c(0);
        let throttled = DeviceSpec::u55c(0).with_silent_derate(0.25);
        // The router's planning model must not see the derate — that is
        // what makes the degradation "silent".
        assert_eq!(healthy.predicted_ms(&t), throttled.predicted_ms(&t));
        assert_eq!(throttled.silent_derate, 0.25);
    }
}
