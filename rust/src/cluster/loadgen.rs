//! Arrival-process load generation for the cluster layer.
//!
//! The PR 1–3 benches replay uniform *closed-loop* batches: every client
//! keeps exactly one request in flight, so the offered load adapts
//! itself to the service rate and the tail behavior the paper's
//! throughput claims imply is never exercised.  This module generates
//! *open-loop* traffic on the serving layer's virtual clock instead: a
//! seeded arrival process (Poisson, or a two-state Markov-modulated
//! burst process), a topology mix (the SL distribution lever of Peng et
//! al., PAPERS.md), and per-priority QoS classes with deadline budgets.
//!
//! Everything is deterministic per seed — the soak suite
//! (`rust/tests/qos_soak.rs`) asserts exact run-to-run reproducibility
//! of deadline-miss and shed counts, and the in-module statistical
//! self-tests check the Poisson process actually delivers its
//! configured rate (so bench numbers are trustworthy).

use super::telemetry::TelemetryFrame;
use super::DeviceSpec;
use crate::config::Topology;
use crate::coordinator::{Priority, Request};
use crate::rng::XorShift64;
use crate::testdata::MhaInputs;

/// The arrival process (inter-arrival time distribution).
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_hz` (exponential inter-arrivals).
    Poisson { rate_hz: f64 },
    /// Two-state Markov-modulated Poisson process: dwell in a calm or a
    /// burst state (exponential dwell times with the given means, in
    /// virtual-clock ms) and emit Poisson arrivals at the state's rate.
    Bursty {
        calm_rate_hz: f64,
        burst_rate_hz: f64,
        mean_calm_ms: f64,
        mean_burst_ms: f64,
    },
}

/// One QoS class in the traffic mix.
#[derive(Clone, Copy, Debug)]
pub struct QosClass {
    pub priority: Priority,
    /// Relative traffic share (need not be normalized).
    pub share: f64,
    /// Relative deadline: `arrival + budget` becomes the absolute
    /// deadline on the virtual clock.  `None` = best-effort traffic.
    pub deadline_budget_ms: Option<f64>,
}

/// Load-generator configuration: process + topology mix + class mix.
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    pub process: ArrivalProcess,
    /// Topology mix with relative shares (the SL distribution).
    pub mix: Vec<(Topology, f64)>,
    pub classes: Vec<QosClass>,
    pub seed: u64,
}

impl LoadGenConfig {
    /// The standard QoS workload preset shared by the cluster bench,
    /// the soak suite, `examples/qos_serve.rs` and `cluster --qos`: a
    /// two-state MMPP averaging exactly `rho` of the fleet's modeled
    /// capacity for `mix` (calm at 0.6×, bursts at 2.2×, dwell means
    /// 30:10 mean-service-times → (0.6·30 + 2.2·10)/40 = 1), with
    /// High/Normal/Low classes in 2:5:3 shares on 4×/8×/12×
    /// mean-service deadline budgets.
    pub fn bursty_preset(
        devices: &[DeviceSpec],
        mix: Vec<(Topology, f64)>,
        rho: f64,
        seed: u64,
    ) -> LoadGenConfig {
        let rate_hz = rate_for_utilization(devices, &mix, rho);
        let base_ms = mean_service_ms(devices, &mix);
        LoadGenConfig {
            process: ArrivalProcess::Bursty {
                calm_rate_hz: rate_hz * 0.6,
                burst_rate_hz: rate_hz * 2.2,
                mean_calm_ms: 30.0 * base_ms,
                mean_burst_ms: 10.0 * base_ms,
            },
            mix,
            classes: vec![
                QosClass {
                    priority: Priority::High,
                    share: 2.0,
                    deadline_budget_ms: Some(4.0 * base_ms),
                },
                QosClass {
                    priority: Priority::Normal,
                    share: 5.0,
                    deadline_budget_ms: Some(8.0 * base_ms),
                },
                QosClass {
                    priority: Priority::Low,
                    share: 3.0,
                    deadline_budget_ms: Some(12.0 * base_ms),
                },
            ],
            seed,
        }
    }
}

/// One generated arrival (operands not yet materialized).
#[derive(Clone, Debug)]
pub struct Arrival {
    /// Absolute arrival time on the virtual clock, ms.
    pub arrival_ms: f64,
    pub topology: Topology,
    pub priority: Priority,
    /// Absolute deadline (arrival + class budget), if the class has one.
    pub deadline_ms: Option<f64>,
}

impl Arrival {
    /// Build the serving-layer request.  Operands are the deterministic
    /// per-topology test vectors, so bit-identity checks need exactly
    /// one reference run per distinct topology in the mix.
    pub fn materialize(&self, id: u64) -> Request {
        Request::new(id, self.topology.clone(), MhaInputs::generate(&self.topology)).with_qos(
            self.priority,
            self.arrival_ms,
            self.deadline_ms,
        )
    }
}

/// The seeded generator.  Stateful: consecutive `generate*` calls
/// continue the same arrival stream — windowed generation emits exactly
/// the arrivals one long `generate` would (an arrival drawn past a
/// window edge is held, not discarded, so the MMPP dwell bookkeeping
/// stays in step with the virtual clock).
pub struct LoadGen {
    config: LoadGenConfig,
    rng: XorShift64,
    /// Virtual time generated up to (last arrival or window edge).
    now_ms: f64,
    /// Instant of the last emitted arrival (gap reference point).
    cursor_ms: f64,
    /// An arrival drawn past the previous window edge, pending emission.
    next_at_ms: Option<f64>,
    bursting: bool,
    state_left_ms: f64,
}

impl LoadGen {
    pub fn new(config: LoadGenConfig) -> Self {
        assert!(!config.mix.is_empty(), "loadgen needs a topology mix");
        assert!(!config.classes.is_empty(), "loadgen needs at least one QoS class");
        assert!(config.mix.iter().all(|(_, s)| *s > 0.0), "topology shares must be positive");
        assert!(config.classes.iter().all(|c| c.share > 0.0), "class shares must be positive");
        match config.process {
            ArrivalProcess::Poisson { rate_hz } => assert!(rate_hz > 0.0),
            ArrivalProcess::Bursty {
                calm_rate_hz,
                burst_rate_hz,
                mean_calm_ms,
                mean_burst_ms,
            } => {
                assert!(calm_rate_hz > 0.0 && burst_rate_hz > 0.0);
                assert!(mean_calm_ms > 0.0 && mean_burst_ms > 0.0);
            }
        }
        let mut rng = XorShift64::new(config.seed);
        let state_left_ms = match config.process {
            ArrivalProcess::Poisson { .. } => f64::INFINITY,
            ArrivalProcess::Bursty { mean_calm_ms, .. } => exp_ms(&mut rng, mean_calm_ms),
        };
        LoadGen {
            config,
            rng,
            now_ms: 0.0,
            cursor_ms: 0.0,
            next_at_ms: None,
            bursting: false,
            state_left_ms,
        }
    }

    /// Current position of the virtual clock (end of what has been
    /// generated so far).
    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Next inter-arrival gap, advancing the modulation state.
    fn next_gap_ms(&mut self) -> f64 {
        match self.config.process {
            ArrivalProcess::Poisson { rate_hz } => exp_ms(&mut self.rng, 1000.0 / rate_hz),
            ArrivalProcess::Bursty {
                calm_rate_hz,
                burst_rate_hz,
                mean_calm_ms,
                mean_burst_ms,
            } => {
                let mut gap = 0.0;
                loop {
                    let rate = if self.bursting { burst_rate_hz } else { calm_rate_hz };
                    let dt = exp_ms(&mut self.rng, 1000.0 / rate);
                    // Exponential gaps are memoryless, so resampling at
                    // a state switch is exactly the MMPP.
                    if dt <= self.state_left_ms {
                        self.state_left_ms -= dt;
                        return gap + dt;
                    }
                    gap += self.state_left_ms;
                    self.bursting = !self.bursting;
                    let mean = if self.bursting { mean_burst_ms } else { mean_calm_ms };
                    self.state_left_ms = exp_ms(&mut self.rng, mean);
                }
            }
        }
    }

    /// The instant of the next arrival, drawing it if not yet pending.
    fn next_arrival_at(&mut self) -> f64 {
        match self.next_at_ms {
            Some(t) => t,
            None => {
                let t = self.cursor_ms + self.next_gap_ms();
                self.next_at_ms = Some(t);
                t
            }
        }
    }

    /// Emit the pending arrival (must exist) at instant `t`.
    fn emit(&mut self, t: f64) -> Arrival {
        self.next_at_ms = None;
        self.cursor_ms = t;
        let topology = pick_share(&mut self.rng, &self.config.mix, |(_, s)| *s).0.clone();
        let class = *pick_share(&mut self.rng, &self.config.classes, |c| c.share);
        Arrival {
            arrival_ms: t,
            topology,
            priority: class.priority,
            deadline_ms: class.deadline_budget_ms.map(|b| t + b),
        }
    }

    /// Generate every arrival in the next `duration_ms` of virtual time.
    pub fn generate(&mut self, duration_ms: f64) -> Vec<Arrival> {
        let end = self.now_ms + duration_ms;
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival_at();
            if t > end {
                // Held for the next window — dwell time already spent on
                // it stays spent, keeping chained windows identical to
                // one long generate().
                self.now_ms = end;
                return out;
            }
            self.now_ms = t;
            let a = self.emit(t);
            out.push(a);
        }
    }

    /// Generate exactly `n` arrivals.
    pub fn generate_n(&mut self, n: usize) -> Vec<Arrival> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let t = self.next_arrival_at();
            self.now_ms = self.now_ms.max(t);
            let a = self.emit(t);
            out.push(a);
        }
        out
    }
}

/// Exponential sample with the given mean (inverse-CDF over a uniform
/// draw; `1 − u` keeps the argument of `ln` in `(0, 1]`).
fn exp_ms(rng: &mut XorShift64, mean_ms: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() * mean_ms
}

/// Share-weighted pick (shares need not be normalized).
fn pick_share<'a, T>(rng: &mut XorShift64, items: &'a [T], share: impl Fn(&T) -> f64) -> &'a T {
    let total: f64 = items.iter().map(&share).sum();
    let mut x = rng.next_f64() * total;
    for item in items {
        x -= share(item);
        if x <= 0.0 {
            return item;
        }
    }
    items.last().expect("non-empty items")
}

/// Share-weighted mean modeled service time of `mix` in ms
/// (per-topology service = the analytical model on the first admitting
/// device; topologies nothing admits are skipped).
pub fn mean_service_ms(devices: &[DeviceSpec], mix: &[(Topology, f64)]) -> f64 {
    let mut share_sum = 0.0;
    let mut weighted_ms = 0.0;
    for (topo, share) in mix {
        if let Some(d) = devices.iter().find(|d| d.admits(topo)) {
            share_sum += share;
            weighted_ms += share * d.predicted_ms(topo);
        }
    }
    assert!(share_sum > 0.0, "no device admits any topology in the mix");
    weighted_ms / share_sum
}

/// Offered-load helper: the arrival rate (req/s) that drives `devices`
/// at `rho` times their modeled aggregate capacity for the given mix.
pub fn rate_for_utilization(devices: &[DeviceSpec], mix: &[(Topology, f64)], rho: f64) -> f64 {
    assert!(rho > 0.0);
    rho * 1000.0 * devices.len() as f64 / mean_service_ms(devices, mix)
}

/// A two-state MMPP fitted from windowed arrival counts (the inverse of
/// [`ArrivalProcess::Bursty`], recovered from a telemetry frame trace).
#[derive(Clone, Copy, Debug)]
pub struct MmppFit {
    pub calm_rate_hz: f64,
    pub burst_rate_hz: f64,
    pub mean_calm_ms: f64,
    pub mean_burst_ms: f64,
    /// Arrivals-per-window count separating the two states (windows
    /// above it were labeled burst).
    pub threshold: f64,
}

impl MmppFit {
    /// The fitted parameters as a generator process, closing the
    /// generate → record → fit → regenerate loop.
    pub fn process(&self) -> ArrivalProcess {
        ArrivalProcess::Bursty {
            calm_rate_hz: self.calm_rate_hz,
            burst_rate_hz: self.burst_rate_hz,
            mean_calm_ms: self.mean_calm_ms,
            mean_burst_ms: self.mean_burst_ms,
        }
    }

    /// Dwell-weighted average arrival rate of the fitted process.
    pub fn average_rate_hz(&self) -> f64 {
        (self.calm_rate_hz * self.mean_calm_ms + self.burst_rate_hz * self.mean_burst_ms)
            / (self.mean_calm_ms + self.mean_burst_ms)
    }
}

/// Fit MMPP burst/calm parameters from a recorded telemetry frame trace
/// (closes the stale QoS follow-up).  Frames must be contiguous
/// same-width windows — exactly what the telemetry
/// [`FrameAggregator`](super::telemetry::FrameAggregator) seals.
/// Returns `None` when the trace shows no modulation (all windows
/// alike) or is too short to label states.
pub fn fit_mmpp(frames: &[TelemetryFrame]) -> Option<MmppFit> {
    let first = frames.first()?;
    let window_ms = first.end_ms - first.start_ms;
    let counts: Vec<u64> = frames.iter().map(TelemetryFrame::arrivals_total).collect();
    fit_mmpp_counts(window_ms, &counts)
}

/// The count-series core of [`fit_mmpp`]: 2-means (Lloyd) clustering of
/// per-window arrival counts into a calm and a burst state, then
/// state-rate and mean-dwell estimates from the labeled windows.
///
/// * Rates: cluster centroid counts over the window length.
/// * Dwells: mean run length of consecutive same-state windows times the
///   window length — an upper-biased but seed-stable estimator (dwell
///   fragments shorter than a window are invisible at this resolution).
pub fn fit_mmpp_counts(window_ms: f64, counts: &[u64]) -> Option<MmppFit> {
    assert!(window_ms > 0.0, "window must be positive");
    if counts.len() < 2 {
        return None;
    }
    let xs: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if hi <= lo {
        return None; // constant series: no modulation to fit
    }
    // Lloyd's algorithm, k = 2, centroids seeded at the extremes (both
    // clusters start non-empty).  Deterministic: no random restarts.
    let (mut c0, mut c1) = (lo, hi);
    for _ in 0..64 {
        let (mut sum0, mut n0, mut sum1, mut n1) = (0.0, 0u64, 0.0, 0u64);
        let mid = 0.5 * (c0 + c1);
        for &x in &xs {
            if x <= mid {
                sum0 += x;
                n0 += 1;
            } else {
                sum1 += x;
                n1 += 1;
            }
        }
        if n0 == 0 || n1 == 0 {
            return None;
        }
        let (new0, new1) = (sum0 / n0 as f64, sum1 / n1 as f64);
        let moved = (new0 - c0).abs() + (new1 - c1).abs();
        c0 = new0;
        c1 = new1;
        if moved < 1e-12 {
            break;
        }
    }
    let threshold = 0.5 * (c0 + c1);
    // Label windows and measure mean run lengths per state.
    let labels: Vec<bool> = xs.iter().map(|&x| x > threshold).collect();
    let (mut runs, mut windows) = ([0u64; 2], [0u64; 2]);
    let mut i = 0;
    while i < labels.len() {
        let state = labels[i] as usize;
        let mut len = 1;
        while i + len < labels.len() && labels[i + len] == labels[i] {
            len += 1;
        }
        runs[state] += 1;
        windows[state] += len as u64;
        i += len;
    }
    if runs[0] == 0 || runs[1] == 0 {
        return None;
    }
    Some(MmppFit {
        calm_rate_hz: c0 / window_ms * 1000.0,
        burst_rate_hz: c1 / window_ms * 1000.0,
        mean_calm_ms: windows[0] as f64 / runs[0] as f64 * window_ms,
        mean_burst_ms: windows[1] as f64 / runs[1] as f64 * window_ms,
        threshold,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> Vec<(Topology, f64)> {
        vec![(Topology::new(64, 768, 8, 64), 3.0), (Topology::new(32, 768, 8, 64), 1.0)]
    }

    fn classes() -> Vec<QosClass> {
        vec![
            QosClass { priority: Priority::High, share: 1.0, deadline_budget_ms: Some(2.0) },
            QosClass { priority: Priority::Normal, share: 2.0, deadline_budget_ms: Some(5.0) },
            QosClass { priority: Priority::Low, share: 1.0, deadline_budget_ms: None },
        ]
    }

    fn poisson(seed: u64, rate_hz: f64) -> LoadGen {
        LoadGen::new(LoadGenConfig {
            process: ArrivalProcess::Poisson { rate_hz },
            mix: mix(),
            classes: classes(),
            seed,
        })
    }

    #[test]
    fn deterministic_per_seed() {
        let a = poisson(42, 1000.0).generate_n(200);
        let b = poisson(42, 1000.0).generate_n(200);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms.to_bits(), y.arrival_ms.to_bits());
            assert_eq!(x.topology, y.topology);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.deadline_ms.map(f64::to_bits), y.deadline_ms.map(f64::to_bits));
        }
        let c = poisson(43, 1000.0).generate_n(200);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival_ms != y.arrival_ms));
    }

    #[test]
    fn arrivals_are_monotone_and_deadlines_absolute() {
        let arrivals = poisson(7, 2000.0).generate_n(300);
        for w in arrivals.windows(2) {
            assert!(w[1].arrival_ms >= w[0].arrival_ms);
        }
        for a in &arrivals {
            if let Some(d) = a.deadline_ms {
                assert!(d > a.arrival_ms, "deadline must lie after arrival");
            }
            match a.priority {
                Priority::High => assert_eq!(a.deadline_ms, Some(a.arrival_ms + 2.0)),
                Priority::Normal => assert_eq!(a.deadline_ms, Some(a.arrival_ms + 5.0)),
                Priority::Low => assert_eq!(a.deadline_ms, None),
            }
        }
    }

    #[test]
    fn poisson_rate_matches_configuration() {
        // Statistical self-test: the empirical mean inter-arrival of a
        // 1 kHz process is 1 ms.  n = 4000 puts the standard error of
        // the mean at ~1.6%, so 6% is a > 3σ acceptance band — and a
        // mis-scaled generator (s vs ms, rate vs mean) is off by 1000×.
        for seed in [1u64, 99, 12345] {
            let arrivals = poisson(seed, 1000.0).generate_n(4000);
            let total = arrivals.last().unwrap().arrival_ms;
            let mean = total / arrivals.len() as f64;
            assert!((mean - 1.0).abs() < 0.06, "seed {seed}: mean inter-arrival {mean} ms");
        }
    }

    #[test]
    fn poisson_interarrivals_fit_exponential_chi_squared() {
        // Chi-squared goodness of fit against Exp(mean=1ms) over eight
        // equal-probability bins (boundaries −ln(1 − i/8)).  df = 7; the
        // 99.9% critical value is 24.3 — we accept under 30 to keep the
        // fixed-seed test robust, while a uniform or constant generator
        // scores in the hundreds.
        let k = 8usize;
        let bounds: Vec<f64> = (1..k).map(|i| -(1.0 - i as f64 / k as f64).ln()).collect();
        for seed in [2u64, 777, 31415] {
            let n = 4000usize;
            let arrivals = poisson(seed, 1000.0).generate_n(n);
            let mut counts = vec![0usize; k];
            let mut prev = 0.0;
            for a in &arrivals {
                let gap = a.arrival_ms - prev;
                prev = a.arrival_ms;
                let bin = bounds.iter().position(|b| gap < *b).unwrap_or(k - 1);
                counts[bin] += 1;
            }
            let expected = n as f64 / k as f64;
            let chi2: f64 =
                counts.iter().map(|&c| (c as f64 - expected).powi(2) / expected).sum();
            assert!(chi2 < 30.0, "seed {seed}: chi² = {chi2:.1}, counts {counts:?}");
        }
    }

    #[test]
    fn bursty_rate_lies_between_state_rates() {
        let mk = |seed| {
            LoadGen::new(LoadGenConfig {
                process: ArrivalProcess::Bursty {
                    calm_rate_hz: 500.0,
                    burst_rate_hz: 5000.0,
                    mean_calm_ms: 20.0,
                    mean_burst_ms: 10.0,
                },
                mix: mix(),
                classes: classes(),
                seed,
            })
        };
        let duration_ms = 2000.0;
        let n = mk(5).generate(duration_ms).len() as f64;
        let rate_hz = n / (duration_ms / 1000.0);
        assert!(rate_hz > 600.0, "{rate_hz} Hz: too slow for the calm floor");
        assert!(rate_hz < 4800.0, "{rate_hz} Hz: faster than the burst ceiling");
    }

    #[test]
    fn bursty_is_overdispersed_vs_poisson() {
        // Index of dispersion of window counts: ≈ 1 for Poisson, well
        // above 1 for a strongly modulated MMPP.
        let idc = |process, seed| {
            let mut g = LoadGen::new(LoadGenConfig {
                process,
                mix: mix(),
                classes: classes(),
                seed,
            });
            let window_ms = 10.0;
            let counts: Vec<f64> =
                (0..200).map(|_| g.generate(window_ms).len() as f64).collect();
            let mean = counts.iter().sum::<f64>() / counts.len() as f64;
            let var = counts.iter().map(|c| (c - mean).powi(2)).sum::<f64>()
                / counts.len() as f64;
            var / mean
        };
        let poisson_idc = idc(ArrivalProcess::Poisson { rate_hz: 1000.0 }, 11);
        let bursty_idc = idc(
            ArrivalProcess::Bursty {
                calm_rate_hz: 200.0,
                burst_rate_hz: 5000.0,
                mean_calm_ms: 40.0,
                mean_burst_ms: 20.0,
            },
            11,
        );
        assert!(poisson_idc < 2.0, "poisson IDC {poisson_idc}");
        assert!(bursty_idc > 3.0, "bursty IDC {bursty_idc}");
        assert!(bursty_idc > poisson_idc);
    }

    #[test]
    fn windowed_generation_matches_one_long_generate() {
        // Chained generate() windows must reproduce exactly the arrivals
        // of a single long call — in particular across window edges,
        // where a drawn-but-not-yet-due arrival is held, not discarded
        // (holding also keeps the MMPP dwell bookkeeping in step with
        // the virtual clock).
        let process = ArrivalProcess::Bursty {
            calm_rate_hz: 200.0,
            burst_rate_hz: 5000.0,
            mean_calm_ms: 40.0,
            mean_burst_ms: 20.0,
        };
        let cfg = |seed| LoadGenConfig { process, mix: mix(), classes: classes(), seed };
        let whole = LoadGen::new(cfg(21)).generate(500.0);
        let mut chunked = LoadGen::new(cfg(21));
        let mut windows = Vec::new();
        for _ in 0..50 {
            windows.extend(chunked.generate(10.0));
        }
        assert_eq!(whole.len(), windows.len());
        for (a, b) in whole.iter().zip(&windows) {
            assert_eq!(a.arrival_ms.to_bits(), b.arrival_ms.to_bits());
            assert_eq!(a.topology, b.topology);
            assert_eq!(a.priority, b.priority);
        }
    }

    #[test]
    fn bursty_preset_averages_rho_and_scales_budgets() {
        let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        let cfg = LoadGenConfig::bursty_preset(&devices, mix(), 0.9, 1);
        let rate = rate_for_utilization(&devices, &mix(), 0.9);
        let base = mean_service_ms(&devices, &mix());
        match cfg.process {
            ArrivalProcess::Bursty {
                calm_rate_hz,
                burst_rate_hz,
                mean_calm_ms,
                mean_burst_ms,
            } => {
                // Time-weighted average rate equals the target exactly.
                let avg = (calm_rate_hz * mean_calm_ms + burst_rate_hz * mean_burst_ms)
                    / (mean_calm_ms + mean_burst_ms);
                assert!((avg - rate).abs() < 1e-6 * rate, "{avg} vs {rate}");
            }
            ArrivalProcess::Poisson { .. } => panic!("preset must be bursty"),
        }
        assert_eq!(cfg.classes.len(), 3);
        assert_eq!(cfg.classes[0].deadline_budget_ms, Some(4.0 * base));
        assert_eq!(cfg.classes[2].deadline_budget_ms, Some(12.0 * base));
    }

    #[test]
    fn class_and_topology_shares_are_respected() {
        let arrivals = poisson(3, 1000.0).generate_n(4000);
        let highs = arrivals.iter().filter(|a| a.priority == Priority::High).count() as f64;
        let normals =
            arrivals.iter().filter(|a| a.priority == Priority::Normal).count() as f64;
        let lows = arrivals.iter().filter(|a| a.priority == Priority::Low).count() as f64;
        let n = arrivals.len() as f64;
        // Shares 1:2:1 within ±4 points (binomial σ ≈ 0.7 points).
        assert!((highs / n - 0.25).abs() < 0.04, "{}", highs / n);
        assert!((normals / n - 0.5).abs() < 0.04, "{}", normals / n);
        assert!((lows / n - 0.25).abs() < 0.04, "{}", lows / n);
        let sl64 = arrivals.iter().filter(|a| a.topology.seq_len == 64).count() as f64;
        assert!((sl64 / n - 0.75).abs() < 0.04, "{}", sl64 / n);
    }

    #[test]
    fn materialize_carries_qos_onto_request() {
        let arrivals = poisson(9, 1000.0).generate_n(20);
        for (i, a) in arrivals.iter().enumerate() {
            let r = a.materialize(i as u64);
            assert_eq!(r.id, i as u64);
            assert_eq!(r.topology, a.topology);
            assert_eq!(r.priority, a.priority);
            assert_eq!(r.arrival_ms, a.arrival_ms);
            assert_eq!(r.deadline_ms, a.deadline_ms);
            assert_eq!(r.inputs.x.len(), a.topology.seq_len * a.topology.d_model);
        }
    }

    #[test]
    fn fit_mmpp_round_trips_the_bursty_generator() {
        use super::super::telemetry::{FrameAggregator, TelemetryConfig, TelemetryEvent};
        // Ground truth: strongly modulated MMPP (25× rate ratio).
        let truth = ArrivalProcess::Bursty {
            calm_rate_hz: 200.0,
            burst_rate_hz: 5000.0,
            mean_calm_ms: 40.0,
            mean_burst_ms: 20.0,
        };
        let mut g = LoadGen::new(LoadGenConfig {
            process: truth,
            mix: mix(),
            classes: classes(),
            seed: 11,
        });
        // Record the trace through the real telemetry pipeline: ingress
        // events into 5 ms windows (fleet counters only, no devices).
        let mut agg = FrameAggregator::new(
            TelemetryConfig { window_ms: 5.0, grace_windows: 0, ring_capacity: 1024 },
            0,
        );
        for a in g.generate(4000.0) {
            agg.advance(a.arrival_ms);
            agg.record(TelemetryEvent::Ingress { t_ms: a.arrival_ms, priority: a.priority });
        }
        agg.seal_all();
        let frames: Vec<_> = agg.frames().cloned().collect();
        assert!(frames.len() >= 700, "{} frames", frames.len());
        let fit = fit_mmpp(&frames).expect("modulated trace must fit");
        // Generous bands: windowing quantizes dwells and mixes states
        // within a window, but the two rates must separate cleanly and
        // the dwell structure must be the right shape.
        assert!(
            fit.calm_rate_hz > 100.0 && fit.calm_rate_hz < 450.0,
            "calm {} Hz",
            fit.calm_rate_hz
        );
        assert!(
            fit.burst_rate_hz > 3000.0 && fit.burst_rate_hz < 6800.0,
            "burst {} Hz",
            fit.burst_rate_hz
        );
        assert!(fit.burst_rate_hz > 5.0 * fit.calm_rate_hz, "states must separate");
        assert!(
            fit.mean_calm_ms > 15.0 && fit.mean_calm_ms < 100.0,
            "calm dwell {} ms",
            fit.mean_calm_ms
        );
        assert!(
            fit.mean_burst_ms > 8.0 && fit.mean_burst_ms < 50.0,
            "burst dwell {} ms",
            fit.mean_burst_ms
        );
        // The fitted process offers roughly the same average load.
        let truth_avg = (200.0 * 40.0 + 5000.0 * 20.0) / 60.0;
        let rel = (fit.average_rate_hz() - truth_avg).abs() / truth_avg;
        assert!(rel < 0.4, "average rate off by {:.0}%", rel * 100.0);
        // And it regenerates: a LoadGen accepts the fitted process.
        let n = LoadGen::new(LoadGenConfig {
            process: fit.process(),
            mix: mix(),
            classes: classes(),
            seed: 12,
        })
        .generate(1000.0)
        .len();
        assert!(n > 200, "refitted generator produced {n} arrivals");
    }

    #[test]
    fn fit_mmpp_rejects_unmodulated_traces() {
        assert!(fit_mmpp_counts(5.0, &[3, 3, 3, 3]).is_none(), "constant series");
        assert!(fit_mmpp_counts(5.0, &[7]).is_none(), "too short");
        assert!(fit_mmpp_counts(5.0, &[]).is_none(), "empty");
        assert!(fit_mmpp(&[]).is_none());
        // A cleanly bimodal series fits exactly.
        let counts = [1u64, 1, 1, 25, 25, 1, 1, 25, 25, 25, 1];
        let fit = fit_mmpp_counts(10.0, &counts).unwrap();
        assert!((fit.calm_rate_hz - 100.0).abs() < 1e-9, "{}", fit.calm_rate_hz);
        assert!((fit.burst_rate_hz - 2500.0).abs() < 1e-9, "{}", fit.burst_rate_hz);
        // Calm runs: 3, 2, 1 windows → mean 2 windows = 20 ms.
        assert!((fit.mean_calm_ms - 20.0).abs() < 1e-9, "{}", fit.mean_calm_ms);
        // Burst runs: 2, 3 windows → mean 2.5 windows = 25 ms.
        assert!((fit.mean_burst_ms - 25.0).abs() < 1e-9, "{}", fit.mean_burst_ms);
        assert!(fit.threshold > 1.0 && fit.threshold < 25.0);
    }

    #[test]
    fn rate_for_utilization_scales_with_fleet_and_rho() {
        let one = vec![DeviceSpec::u55c(0)];
        let four: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        let m = mix();
        let r1 = rate_for_utilization(&one, &m, 1.0);
        let r4 = rate_for_utilization(&four, &m, 1.0);
        assert!((r4 / r1 - 4.0).abs() < 1e-9, "capacity scales with devices");
        let r_half = rate_for_utilization(&four, &m, 0.5);
        assert!((r4 / r_half - 2.0).abs() < 1e-9);
        // Sanity: one U55C serves the SL64 headline shape in ~0.94 ms,
        // so ρ=1 for this mix sits near 1/mean_service ≈ 1.2 kHz.
        assert!(r1 > 800.0 && r1 < 1600.0, "{r1}");
    }
}
