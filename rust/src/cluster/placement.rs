//! Placement planning: which topologies live where, before traffic flows.
//!
//! Reconfiguring a device between topologies flushes the weight tiles
//! staged in BRAM (the cost `GroupByTopology` amortizes on one card), so
//! the fleet-level planner tries to give every expected topology a home
//! device whose BRAM still has room to keep its tiles staged:
//!
//! 1. Rank workload entries by expected load (traffic share × modeled
//!    latency from [`crate::analytical::LatencyModel`]).
//! 2. Assign each topology a primary device among those that admit it,
//!    balancing accumulated modeled load across the fleet; pin its
//!    weight tiles there if the device's BRAM envelope (from the
//!    [`crate::fpga::resources`] coefficients) has room.
//! 3. Topologies no single device admits (e.g. BERT-large's d_model
//!    1024 against builds synthesized for 768) get a [`ShardPlan`]: two
//!    half-topologies placed on the two least-loaded admitting devices.
//!
//! The output is consumed by the router as its affinity table; it is a
//! plan, not a cage — the router still falls back to any admitting
//! device under load.

use super::shard::ShardPlan;
use super::DeviceSpec;
use crate::config::Topology;
use crate::fpga::resources::ResourceModel;

/// Expected traffic mix: topologies with relative request shares.
#[derive(Clone, Debug, Default)]
pub struct WorkloadProfile {
    pub entries: Vec<(Topology, f64)>,
}

impl WorkloadProfile {
    /// Equal share for every topology.
    pub fn uniform(topos: &[Topology]) -> Self {
        WorkloadProfile { entries: topos.iter().map(|t| (t.clone(), 1.0)).collect() }
    }

    pub fn push(&mut self, topo: Topology, share: f64) {
        self.entries.push((topo, share));
    }
}

/// Where one topology should run.
#[derive(Clone, Debug)]
pub struct TopologyPlacement {
    pub topology: Topology,
    /// Admitting devices, primary (affinity target) first.  Empty when
    /// nothing admits the topology and no shard is possible.
    pub devices: Vec<usize>,
    /// Set when no single device admits the topology: serve as two
    /// half-requests (each half routed like a normal topology).
    pub shard: Option<ShardPlan>,
    /// Modeled fabric latency on the primary device (per half-request
    /// when sharded).
    pub predicted_ms: f64,
}

/// The planner's output: per-topology routing preferences plus the
/// per-device pinned (BRAM-staged) topology sets.
#[derive(Clone, Debug, Default)]
pub struct PlacementPlan {
    pub placements: Vec<TopologyPlacement>,
    /// `pinned[d]` = topologies whose weight tiles stay staged on
    /// device `d`.
    pub pinned: Vec<Vec<Topology>>,
}

impl PlacementPlan {
    pub fn placement(&self, topo: &Topology) -> Option<&TopologyPlacement> {
        self.placements.iter().find(|p| &p.topology == topo)
    }

    pub fn is_pinned(&self, device: usize, topo: &Topology) -> bool {
        self.pinned.get(device).map(|v| v.contains(topo)).unwrap_or(false)
    }
}

/// The planner: resource coefficients + modeled latency.
#[derive(Clone, Debug, Default)]
pub struct PlacementPlanner {
    pub resources: ResourceModel,
}

impl PlacementPlanner {
    /// BRAM18k banks one pinned topology keeps occupied: the three
    /// weight tiles plus the Q/K projection buffers, per head — the
    /// `h·(2·TS + d_k)` share of the calibrated BRAM formula (the SL
    /// terms are transient score/V buffers, not staged weights).
    pub fn pin_cost_bram18k(&self, topo: &Topology) -> u64 {
        let h = topo.heads as f64;
        let cost = h * (self.resources.bram_per_ts * topo.tile_size as f64 + topo.d_k() as f64);
        cost.round() as u64
    }

    /// BRAM18k banks available for pinning on `spec` beyond the build's
    /// fixed allocation.
    pub fn pin_budget_bram18k(&self, spec: &DeviceSpec) -> u64 {
        let total = spec.sim.build.device.bram18k;
        total.saturating_sub(self.resources.bram_fixed.round() as u64)
    }

    /// Plan the fleet for an expected workload.
    pub fn plan(&self, devices: &[DeviceSpec], workload: &WorkloadProfile) -> PlacementPlan {
        let mut load_ms = vec![0.0f64; devices.len()];
        let mut bram_free: Vec<u64> =
            devices.iter().map(|d| self.pin_budget_bram18k(d)).collect();
        let mut pinned: Vec<Vec<Topology>> = vec![Vec::new(); devices.len()];

        // Most-constrained first (fewest admitting devices), then
        // heaviest expected load: topologies that can only live on a few
        // devices claim them before flexible ones spread across the
        // rest — classic bin-packing order.  Keys are precomputed once
        // per entry (the latency model run is not free).
        let mut keyed: Vec<(usize, f64, Topology, f64)> = workload
            .entries
            .iter()
            .map(|(topo, share)| {
                let count = devices.iter().filter(|d| d.admits(topo)).count();
                let load = share * mean_predicted_ms(devices, topo);
                (count, load, topo.clone(), *share)
            })
            .collect();
        keyed.sort_by(|a, b| {
            a.0.cmp(&b.0).then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
        });
        let entries: Vec<(Topology, f64)> =
            keyed.into_iter().map(|(_, _, topo, share)| (topo, share)).collect();

        let mut placements = Vec::with_capacity(entries.len());
        for (topo, share) in entries {
            let mut admitting: Vec<usize> =
                devices.iter().filter(|d| d.admits(&topo)).map(|d| d.id).collect();
            if admitting.is_empty() {
                placements.push(self.plan_sharded(
                    devices,
                    &topo,
                    share,
                    &mut load_ms,
                    &mut bram_free,
                    &mut pinned,
                ));
                continue;
            }
            admitting.sort_by(|&a, &b| {
                load_ms[a].partial_cmp(&load_ms[b]).unwrap_or(std::cmp::Ordering::Equal)
            });
            let primary = admitting[0];
            let ms = devices[primary].predicted_ms(&topo);
            load_ms[primary] += share * ms;
            let cost = self.pin_cost_bram18k(&topo);
            if bram_free[primary] >= cost {
                bram_free[primary] -= cost;
                pinned[primary].push(topo.clone());
            }
            placements.push(TopologyPlacement {
                topology: topo,
                devices: admitting,
                shard: None,
                predicted_ms: ms,
            });
        }
        PlacementPlan { placements, pinned }
    }

    fn plan_sharded(
        &self,
        devices: &[DeviceSpec],
        topo: &Topology,
        share: f64,
        load_ms: &mut [f64],
        bram_free: &mut [u64],
        pinned: &mut [Vec<Topology>],
    ) -> TopologyPlacement {
        let Some(shard) = ShardPlan::plan(topo) else {
            return TopologyPlacement {
                topology: topo.clone(),
                devices: Vec::new(),
                shard: None,
                predicted_ms: 0.0,
            };
        };
        let mut admitting: Vec<usize> =
            devices.iter().filter(|d| d.admits(&shard.half)).map(|d| d.id).collect();
        if admitting.is_empty() {
            // Splittable in shape, but the halves fit nowhere either.
            return TopologyPlacement {
                topology: topo.clone(),
                devices: Vec::new(),
                shard: None,
                predicted_ms: 0.0,
            };
        }
        admitting.sort_by(|&a, &b| {
            load_ms[a].partial_cmp(&load_ms[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let ms = devices[admitting[0]].predicted_ms(&shard.half);
        // Both halves run concurrently; each consumes load and (when
        // possible) a pinned slot on its device.  With one admitting
        // device the two halves time-share it, so it carries both
        // halves' load.
        let cost = self.pin_cost_bram18k(&shard.half);
        let halves_per_device = if admitting.len() == 1 { 2.0 } else { 1.0 };
        for &d in admitting.iter().take(2) {
            load_ms[d] += share * ms * halves_per_device;
            if bram_free[d] >= cost {
                bram_free[d] -= cost;
                pinned[d].push(shard.half.clone());
            }
        }
        TopologyPlacement {
            topology: topo.clone(),
            devices: admitting,
            shard: Some(shard),
            predicted_ms: ms,
        }
    }
}

fn mean_predicted_ms(devices: &[DeviceSpec], topo: &Topology) -> f64 {
    let admitting: Vec<f64> =
        devices.iter().filter(|d| d.admits(topo)).map(|d| d.predicted_ms(topo)).collect();
    if admitting.is_empty() {
        // Oversized topologies still need a rank; use the half estimate.
        return ShardPlan::plan(topo)
            .and_then(|s| {
                devices.iter().find(|d| d.admits(&s.half)).map(|d| d.predicted_ms(&s.half))
            })
            .unwrap_or(0.0);
    }
    admitting.iter().sum::<f64>() / admitting.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet4() -> Vec<DeviceSpec> {
        vec![
            DeviceSpec::u55c(0),
            DeviceSpec::u55c(1),
            DeviceSpec::u200(2),
            DeviceSpec::u200(3),
        ]
    }

    #[test]
    fn distinct_topologies_spread_across_devices() {
        let devices = fleet4();
        // Two U55C-only (h=8) and two fleet-wide (h=6) topologies: the
        // constrained pair must claim the U55Cs, the flexible pair the
        // U200s, giving four distinct primaries.
        let topos = [
            Topology::new(64, 768, 8, 64),
            Topology::new(32, 768, 8, 64),
            Topology::new(64, 768, 6, 64),
            Topology::new(32, 768, 6, 64),
        ];
        let plan = PlacementPlanner::default().plan(&devices, &WorkloadProfile::uniform(&topos));
        assert_eq!(plan.placements.len(), 4);
        let primaries: Vec<usize> =
            plan.placements.iter().map(|p| p.devices[0]).collect();
        let distinct: std::collections::BTreeSet<usize> = primaries.iter().copied().collect();
        assert_eq!(distinct.len(), 4, "primaries {primaries:?}");
        // Every placement is admitted by its primary.
        for p in &plan.placements {
            assert!(devices[p.devices[0]].admits(&p.topology));
            assert!(p.shard.is_none());
            assert!(p.predicted_ms > 0.0);
        }
    }

    #[test]
    fn h8_topologies_avoid_u200() {
        let devices = fleet4();
        let t = Topology::new(64, 768, 8, 64);
        let plan = PlacementPlanner::default()
            .plan(&devices, &WorkloadProfile::uniform(std::slice::from_ref(&t)));
        let p = plan.placement(&t).unwrap();
        // Only the two U55Cs admit h=8.
        assert_eq!(p.devices.len(), 2);
        assert!(p.devices.iter().all(|&d| d < 2), "{:?}", p.devices);
    }

    #[test]
    fn oversized_d_model_gets_sharded() {
        let devices = fleet4();
        let large = Topology::new(64, 1024, 16, 64); // BERT-large
        let plan = PlacementPlanner::default()
            .plan(&devices, &WorkloadProfile::uniform(std::slice::from_ref(&large)));
        let p = plan.placement(&large).unwrap();
        let shard = p.shard.as_ref().expect("must shard");
        assert_eq!(shard.half, Topology::new(64, 512, 8, 64));
        // Halves land on at least two devices for concurrent halves.
        assert!(p.devices.len() >= 2);
    }

    #[test]
    fn unservable_topology_yields_empty_placement() {
        let devices = fleet4();
        // d_model 1536 halves to 768 but h=6 halves to 3 (odd d_k ratio):
        // 768 % 3 = 0 and 768 % 64 = 0, so the half IS valid — pick a
        // truly unservable one instead: SL beyond every synthesized max,
        // which sharding (a d_model split) cannot fix.
        let long = Topology::new(256, 768, 8, 64);
        let plan = PlacementPlanner::default()
            .plan(&devices, &WorkloadProfile::uniform(std::slice::from_ref(&long)));
        let p = plan.placement(&long).unwrap();
        assert!(p.devices.is_empty());
        assert!(p.shard.is_none());
    }

    #[test]
    fn pinning_respects_bram_budget() {
        let planner = PlacementPlanner::default();
        let one = vec![DeviceSpec::u200(0)];
        // Each h=6 pin costs 6·(2·64 + 128) = 1536 banks; the U200 pin
        // budget is 4320 − 832 = 3488, so only two of three fit.
        let topos = [
            Topology::new(64, 768, 6, 64),
            Topology::new(32, 768, 6, 64),
            Topology::new(128, 768, 6, 64),
        ];
        assert_eq!(planner.pin_cost_bram18k(&topos[0]), 1536);
        let plan = planner.plan(&one, &WorkloadProfile::uniform(&topos));
        assert_eq!(plan.pinned[0].len(), 2, "{:?}", plan.pinned[0]);
        // Unpinned topologies are still routable (admission unaffected).
        for t in &topos {
            assert_eq!(plan.placement(t).unwrap().devices, vec![0]);
        }
    }

    #[test]
    fn load_share_weights_bias_primary_choice() {
        let devices = vec![DeviceSpec::u55c(0), DeviceSpec::u55c(1)];
        let hot = Topology::new(128, 768, 8, 64);
        let cold = Topology::new(32, 768, 8, 64);
        let mut w = WorkloadProfile::default();
        w.push(hot.clone(), 10.0);
        w.push(cold.clone(), 1.0);
        let plan = PlacementPlanner::default().plan(&devices, &w);
        // The hot topology is placed first (heavier), the cold one goes
        // to the other device.
        let ph = plan.placement(&hot).unwrap().devices[0];
        let pc = plan.placement(&cold).unwrap().devices[0];
        assert_ne!(ph, pc);
    }
}
