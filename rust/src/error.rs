//! Error-handling surface for the crate (DESIGN.md §2).
//!
//! The codebase standardizes on the `anyhow` API.  Offline, `anyhow`
//! resolves to the vendored shim in `rust/vendor/anyhow` — this module
//! re-exports the full surface under a crate-local name so downstream
//! code (and any future swap back to the real crate) can write
//! `use famous::error::{Result, bail}` without caring which
//! implementation is underneath.

pub use anyhow::{anyhow, bail, Context, Error, Result};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reexported_surface_is_usable() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged");
            }
            let v: Option<u32> = Some(9);
            v.context("missing")
        }
        assert_eq!(f(false).unwrap(), 9);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged");
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
