//! Tiny CLI argument parser (no clap in the offline image).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Declarative spec for one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("--{key}: expected integer, got '{v}'")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("--{key}: expected number, got '{v}'")))
            .transpose()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Command parser: subcommands + options.
pub struct Parser {
    pub program: &'static str,
    pub about: &'static str,
    pub subcommands: Vec<(&'static str, &'static str)>,
    pub opts: Vec<OptSpec>,
}

impl Parser {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Parser { program, about, subcommands: Vec::new(), opts: Vec::new() }
    }

    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} ", self.program, self.about, self.program);
        if !self.subcommands.is_empty() {
            s.push_str("<SUBCOMMAND> ");
        }
        s.push_str("[OPTIONS]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for (name, help) in &self.subcommands {
                s.push_str(&format!("  {name:<16} {help}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let lhs = if o.takes_value {
                    format!("--{} <v>", o.name)
                } else {
                    format!("--{}", o.name)
                };
                let dflt = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  {lhs:<20} {}{dflt}\n", o.help));
            }
        }
        s
    }

    /// Parse argv (excluding argv[0]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        if !self.subcommands.is_empty() {
            match it.peek() {
                Some(first) if !first.starts_with('-') => {
                    let name = it.next().unwrap();
                    if !self.subcommands.iter().any(|(n, _)| n == name) {
                        return Err(format!("unknown subcommand '{name}'\n\n{}", self.usage()));
                    }
                    args.subcommand = Some(name.clone());
                }
                _ => {}
            }
        }
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option '--{key}'\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option '--{key}' expects a value"))?
                            .clone(),
                    };
                    args.values.insert(key.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag '--{key}' does not take a value"));
                    }
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn parser() -> Parser {
        Parser::new("famous", "test")
            .subcommand("serve", "run server")
            .subcommand("bench", "run benches")
            .opt_default("topology", "64,768,8", "workload")
            .opt("device", "fpga device")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_subcommand_and_opts() {
        let a = parser()
            .parse(&sv(&["serve", "--device", "u55c", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("device"), Some("u55c"));
        assert_eq!(a.get("topology"), Some("64,768,8")); // default
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parser().parse(&sv(&["bench", "--device=u200"])).unwrap();
        assert_eq!(a.get("device"), Some("u200"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(parser().parse(&sv(&["serve", "--nope"])).is_err());
        assert!(parser().parse(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parser().parse(&sv(&["serve", "--device"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = parser().parse(&sv(&["--help"])).unwrap_err();
        assert!(err.contains("SUBCOMMANDS"));
        assert!(err.contains("--topology"));
    }

    #[test]
    fn typed_getters() {
        let p = Parser::new("x", "y").opt("n", "count").opt("r", "rate");
        let a = p.parse(&sv(&["--n", "42", "--r", "1.5"])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), Some(42));
        assert_eq!(a.get_f64("r").unwrap(), Some(1.5));
        let bad = p.parse(&sv(&["--n", "xyz"])).unwrap();
        assert!(bad.get_usize("n").is_err());
    }
}
