//! Recursive-descent JSON parser with position-aware errors.

use super::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Parse failure with 1-based line/column of the offending byte.
#[derive(Clone, Debug, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { msg: msg.into(), line, col }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs: JSON encodes astral chars as two \u escapes.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{text}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().idx(1).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn error_positions() {
        let e = parse("{\n  \"a\": !\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 8, "col={}", e.col);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"π\"").unwrap(), Json::Str("π".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{"arg_order": ["x", "wq"], "entries": [{"name": "t", "seq_len": 64}], "grid_scale": 0.015625}"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("grid_scale").unwrap().as_f64(), Some(0.015625));
        assert_eq!(
            j.get("entries").unwrap().idx(0).unwrap().get("seq_len").unwrap().as_usize(),
            Some(64)
        );
    }
}
