//! Minimal JSON substrate (the offline image has no serde).
//!
//! Full JSON data model, recursive-descent parser with line/column
//! diagnostics, and a serializer.  Used for `artifacts/manifest.json`,
//! model descriptors (the paper's `.pth`-extraction flow), and report
//! emission.  Numbers are f64 (ample for every integer we exchange —
//! shapes, counts — all < 2^53).

mod parse;

pub use parse::{parse, ParseError};

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for golden-file comparisons.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Convenience object constructor from (key, value) pairs.
    pub fn obj<K: Into<String>, I: IntoIterator<Item = (K, Json)>>(pairs: I) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_access() {
        let j = Json::obj([
            ("name", Json::from("famous")),
            ("heads", Json::from(8.0)),
            ("dims", Json::arr([Json::from(64.0), Json::from(768.0)])),
        ]);
        assert_eq!(j.get("name").unwrap().as_str(), Some("famous"));
        assert_eq!(j.get("heads").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("dims").unwrap().idx(1).unwrap().as_f64(), Some(768.0));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn serialize_deterministic() {
        let j = Json::obj([("b", Json::from(1.0)), ("a", Json::from(2.0))]);
        assert_eq!(j.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn serialize_escapes() {
        let j = Json::from("a\"b\\c\nd");
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj([
            ("x", Json::arr([Json::Null, Json::from(true), Json::from(-1.5)])),
            ("y", Json::obj([("nested", Json::from("val"))])),
        ]);
        let parsed = parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-2.0).as_usize(), None);
        assert_eq!(Json::Num(42.0).as_usize(), Some(42));
    }
}
