//! Seeded fault injection for the simulated BRAM banks (DESIGN.md §15).
//!
//! A [`FaultPlan`] is the SEU (single-event upset) analogue for this
//! repository's staged operands: at `PreparedWeights::prepare` time it
//! deterministically flips bits in the freshly staged weight copies
//! (the i8 tier operands and their i16-widened twins — one flipped bit
//! in an 8-bit BRAM cell, inherited by the widened copy exactly as a
//! corrupted bank read would be) and/or arms per-head accumulator
//! upsets applied after a projection GEMM (the output-stripe analogue).
//! It composes with `DeviceSpec::silent_derate`: derate corrupts the
//! *clock* silently, a fault plan corrupts the *data* silently.
//!
//! Everything is a pure function of `(seed, epoch)`, so soaks are
//! byte-reproducible.  `persistent` faults model stuck-at cells: every
//! prepare of the same epoch-0 plan draws identical faults, so a local
//! re-prepare cannot help and recovery must go cross-device.  Transient
//! (non-persistent) faults re-draw per prepare epoch — the scrub-retry
//! analogue, where re-staging from the pristine host copy clears the
//! upset.

use crate::rng::XorShift64;

/// Deterministic SEU injection plan for one simulated device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for every draw this plan makes (position, bit, arming).
    pub seed: u64,
    /// Probability that one staged weight *matrix* (per head, per
    /// projection) takes a single-bit upset at prepare time.
    pub weight_flip_rate: f64,
    /// Probability that one projection's accumulator stripe (per head,
    /// per projection) takes a single-bit upset per invocation.
    pub stripe_rate: f64,
    /// Stuck-at faults: every prepare draws the same upsets, so local
    /// scrubbing (re-prepare) cannot clear them.  Non-persistent plans
    /// re-draw per prepare epoch and clear with high probability.
    pub persistent: bool,
    /// Prepare epoch (scrub generation).  The owning `SimBackend` bumps
    /// this per prepare on transient plans; persistent plans ignore it.
    pub epoch: u64,
}

impl FaultPlan {
    /// A persistent (stuck-at) weight-upset plan — the quarantine
    /// soak's configuration: every prepare of every topology corrupts
    /// staged weights, local scrubbing never helps.
    pub fn seu(seed: u64, weight_flip_rate: f64) -> FaultPlan {
        FaultPlan { seed, weight_flip_rate, stripe_rate: 0.0, persistent: true, epoch: 0 }
    }

    /// A transient plan: faults re-draw per prepare epoch, so the
    /// coordinator's scrub-retry (re-prepare from the pristine host
    /// copy) recovers with probability `1 − rate`.
    pub fn transient(seed: u64, weight_flip_rate: f64) -> FaultPlan {
        FaultPlan { seed, weight_flip_rate, stripe_rate: 0.0, persistent: false, epoch: 0 }
    }

    /// This plan at an explicit prepare epoch.
    pub fn at_epoch(mut self, epoch: u64) -> FaultPlan {
        self.epoch = epoch;
        self
    }

    /// The RNG for this plan's current epoch.  Persistent plans ignore
    /// the epoch (same faults forever); transient plans fold it in.
    pub fn rng(&self) -> XorShift64 {
        let e = if self.persistent { 0 } else { self.epoch };
        XorShift64::new(self.seed ^ e.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Does this plan ever inject anything?
    pub fn active(&self) -> bool {
        self.weight_flip_rate > 0.0 || self.stripe_rate > 0.0
    }
}

/// Flip one seeded bit in a staged i8 weight bank and mirror the flip
/// into its i16-widened twin (when the tier keeps one).  The upset hits
/// one 8-bit BRAM cell, so only bits 0..8 of the widened copy can
/// change — sign-extension of the corrupted byte keeps the value in
/// `[-255, 255]`, far inside the i32 accumulation headroom.
pub fn flip_weight_bank(w8: &mut [i8], w16: &mut [i16], rng: &mut XorShift64) -> Option<usize> {
    if w8.is_empty() && w16.is_empty() {
        return None;
    }
    let n = if w8.is_empty() { w16.len() } else { w8.len() };
    let pos = rng.below(n as u64) as usize;
    let bit = rng.below(8) as u32;
    flip_bit(w8, w16, pos, bit);
    Some(pos)
}

/// Flip bit `bit` (0..8) of the 8-bit cell at `pos` in whichever staged
/// copies exist — the deterministic core of [`flip_weight_bank`], public
/// for the single-fault property suite.
pub fn flip_bit(w8: &mut [i8], w16: &mut [i16], pos: usize, bit: u32) {
    if !w8.is_empty() {
        w8[pos] = (w8[pos] as u8 ^ (1u8 << bit)) as i8;
    }
    if !w16.is_empty() {
        // The widened copy re-reads the corrupted cell: re-derive it by
        // sign-extending the flipped byte (exactly what `widen_i16`
        // would produce from the corrupted i8 bank).
        let byte = (w16[pos] as u8) ^ (1u8 << bit);
        w16[pos] = byte as i8 as i16;
    }
}

/// One armed accumulator upset: element index and XOR mask, applied to
/// a projection's i32 accumulator stripe after the GEMM (and before the
/// ABFT verify, which therefore catches it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccFault {
    pub pos: usize,
    pub mask: i32,
}

impl AccFault {
    /// Draw one upset for a stripe of `len` accumulators.  Bits 0..24
    /// keep the dequantized perturbation finite but visible.
    pub fn draw(len: usize, rng: &mut XorShift64) -> AccFault {
        AccFault { pos: rng.below(len as u64) as usize, mask: 1i32 << rng.below(24) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_per_epoch() {
        let p = FaultPlan::transient(7, 0.5).at_epoch(3);
        let a: Vec<u64> = (0..4).map(|_| p.rng().next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "same epoch, same draws");
        assert_ne!(p.rng().next_u64(), p.at_epoch(4).rng().next_u64(), "epochs decorrelate");
        let s = FaultPlan::seu(7, 0.5);
        assert_eq!(
            s.at_epoch(3).rng().next_u64(),
            s.at_epoch(4).rng().next_u64(),
            "persistent ignores epoch"
        );
    }

    #[test]
    fn flip_mirrors_i8_into_widened_copy() {
        let base: Vec<i8> = (0..64).map(|i| (i * 3 - 90) as i8).collect();
        let mut w8 = base.clone();
        let mut w16: Vec<i16> = base.iter().map(|&v| v as i16).collect();
        let mut rng = XorShift64::new(11);
        let pos = flip_weight_bank(&mut w8, &mut w16, &mut rng).unwrap();
        assert_ne!(w8[pos], base[pos]);
        assert_eq!(w16[pos], w8[pos] as i16, "widened copy re-reads the corrupted cell");
        assert_eq!(w8.iter().zip(&base).filter(|(a, b)| a != b).count(), 1);
    }

    #[test]
    fn acc_fault_in_range() {
        let mut rng = XorShift64::new(5);
        for _ in 0..32 {
            let f = AccFault::draw(100, &mut rng);
            assert!(f.pos < 100);
            assert!(f.mask.count_ones() == 1 && f.mask > 0);
        }
    }
}
