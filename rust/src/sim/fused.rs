//! Fused tile-streaming attention: QKᵀ → online softmax → S·V in one
//! pass over key/value column tiles, never materializing the SL×SL
//! score matrix.
//!
//! FAMOUS's core idea is tiling large operands down to what fits
//! on-chip; the reference execute path nevertheless stages the full
//! `SL×SL` score matrix per head and walks it three times
//! (`QkPm::run_into` → `SoftmaxUnit::rows` → `SvPm::run_into`), so the
//! per-head score footprint and memory traffic grow quadratically with
//! sequence length.  [`FusedAttnPm`] instead streams the paper's tile
//! size `TS` worth of key/value columns at a time:
//!
//! ```text
//! for each column tile T of width ≤ TS:          (score stripe: SL×TS)
//!     S_T   = scale · Q · K_Tᵀ                   (same blocked dot as QkPm)
//!     per row i:  α = online-softmax absorb of S_T[i]   (running m, l)
//!                 O[i] = α·O[i] + Σ_j w_j · V[row j]    (rescaled axpy)
//! finally:       O[i] /= l[i]                    (streamed denominator)
//! ```
//!
//! The standard online-softmax rescale (Milakov & Gimelshein; the flash
//! attention recurrence): absorbing a tile raises the row maximum from
//! `m_old` to `m_new`, so the partial output accumulated under `m_old`
//! is multiplied by `α = exp(m_old − m_new)` before the tile's
//! contribution is added.  The score footprint drops from `O(SL²)` to
//! `O(SL×TS)` per head — the lever that makes SL ∈ {256, 512, 1024}
//! serving first-class (cf. the length-adaptive co-design of Peng et
//! al. and FTRANS's on-chip working sets, PAPERS.md).
//!
//! **Numerics policy (DESIGN.md §12).**  The fused path is
//! *tolerance-equivalent* to the reference path, not bit-identical: the
//! pre-softmax scores are bit-identical (same blocked dot kernel, same
//! per-dot reduction order), but the softmax normalization and the SV
//! accumulation are reassociated (running rescales; divide once by the
//! streamed denominator instead of normalizing every probability).  The
//! reference path remains the bit-identity oracle for every existing
//! test; [`tolerance`] gives the documented bound the property tests
//! and benches assert.

use super::modules::blocked_score_row;
use super::softmax_unit::{OnlineRow, SoftmaxKind, SoftmaxUnit};
use crate::fixed::simd;
use crate::fixed::KernelTier;

/// Which functional attention datapath an execute call runs.
///
/// `Reference` is the bit-identity oracle (`QkPm` → `SoftmaxUnit::rows`
/// → `SvPm`, materializing SL×SL scores); `FusedTiled` is the
/// tolerance-equivalent streaming path above.  Selected per request by
/// `runtime::SimBackend`'s policy (SL threshold / score-memory
/// pressure) or forced by callers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecPath {
    #[default]
    Reference,
    FusedTiled,
}

/// Fused streaming attention module for one head: the functional
/// counterpart of running QK_PM, the softmax unit and SV_PM as one
/// pipelined dataflow over column tiles.
#[derive(Clone, Debug)]
pub struct FusedAttnPm {
    pub seq_len: usize,
    pub d_k: usize,
    /// Key/value column tile width (the paper's synthesized TS).
    pub tile: usize,
    /// Score scaling multiplier (same convention as `QkPm::scale`).
    pub scale: f32,
    /// Decoder masking: row i attends only to columns ≤ i (masked
    /// scores take the reference path's −1e9 sentinel, so the LUT and
    /// Exact realizations treat them exactly as `SoftmaxUnit::rows`
    /// does).
    pub causal: bool,
    pub softmax: SoftmaxUnit,
    /// Kernel tier for the score dots and the rescaled axpy
    /// (DESIGN.md §14).  Scalar by default; the same tier as the
    /// reference path's `QkPm`, so fused-vs-reference pre-softmax
    /// bit-identity holds per tier.
    pub tier: KernelTier,
}

impl FusedAttnPm {
    pub fn new(
        seq_len: usize,
        d_k: usize,
        tile: usize,
        scale: f32,
        softmax: SoftmaxUnit,
        causal: bool,
    ) -> Self {
        assert!(tile > 0, "fused attention needs a positive tile width");
        FusedAttnPm { seq_len, d_k, tile, scale, causal, softmax, tier: KernelTier::Scalar }
    }

    /// Select the kernel tier (builder style; prepare-time plumbing).
    pub fn with_tier(mut self, tier: KernelTier) -> Self {
        self.tier = tier;
        self
    }

    /// Elements of the SL×TS score stripe a workspace lane must hold.
    pub fn stripe_elems(&self) -> usize {
        self.seq_len * self.tile
    }

    /// O = softmax(scale·Q·Kᵀ)·V streamed over column tiles.
    ///
    /// `q`, `k`, `v` are (SL × d_k) row-major; `stripe` is the SL×TS
    /// score tile lane; `rows` the SL per-row online states; `out` the
    /// (SL × d_k) head output.  Allocation-free: everything lives in
    /// caller-owned buffers (the workspace's fused tile lanes).
    pub fn run_into(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        stripe: &mut [f32],
        rows: &mut [OnlineRow],
        out: &mut [f32],
    ) {
        let (sl, dk, ts) = (self.seq_len, self.d_k, self.tile);
        assert_eq!(q.len(), sl * dk);
        assert_eq!(k.len(), sl * dk);
        assert_eq!(v.len(), sl * dk);
        assert!(stripe.len() >= sl * ts, "score stripe lane under-sized");
        assert_eq!(rows.len(), sl);
        assert_eq!(out.len(), sl * dk);
        rows.fill(OnlineRow::new());
        out.fill(0.0);

        let mut j0 = 0;
        while j0 < sl {
            let tw = ts.min(sl - j0);
            // Phase 1 — the tile's score stripe S[:, j0..j0+tw], packed
            // tw-wide, through the same `blocked_score_row` kernel as
            // `QkPm::run_into` (one caveat of fusion — that pre-softmax
            // scores stay bit-identical to the reference path's — holds
            // by construction, not by parallel maintenance).
            for i in 0..sl {
                let qrow = &q[i * dk..(i + 1) * dk];
                let srow = &mut stripe[i * tw..(i + 1) * tw];
                blocked_score_row(qrow, k, dk, j0, srow, |j, acc| self.score(i, j, acc), self.tier);
            }
            // Phase 2 — per row: online-softmax absorb (scores become
            // un-normalized weights in place), rescale the partial
            // output, accumulate the tile's weighted V rows.  The axpy
            // is the same branch-free streaming form as
            // `SvPm::run_into`.
            for i in 0..sl {
                let srow = &mut stripe[i * tw..(i + 1) * tw];
                let alpha = self.softmax.absorb_tile(&mut rows[i], srow);
                let orow = &mut out[i * dk..(i + 1) * dk];
                if alpha != 1.0 {
                    // Common case after the row max stabilizes is α = 1
                    // exactly (`exp(0.0)`): skipping the multiply is a
                    // bitwise no-op on the accumulator.  `scale_f32` is
                    // one multiply per element in every tier —
                    // bit-identical across tiers (DESIGN.md §14).
                    simd::scale_f32(self.tier, alpha, orow);
                }
                for (jj, &w) in srow.iter().enumerate() {
                    let vrow = &v[(j0 + jj) * dk..(j0 + jj + 1) * dk];
                    simd::axpy_f32(self.tier, w, vrow, orow);
                }
            }
            j0 += tw;
        }

        // Finalize: one division per output element by the streamed
        // denominator (vs the reference path's SL² probability
        // normalizations).  `l ≥ exp_unit(0) = 1` always — the row
        // maximum itself contributes weight 1 under either realization —
        // so this never divides by zero.
        for i in 0..sl {
            let inv = 1.0 / rows[i].l;
            simd::scale_f32(self.tier, inv, &mut out[i * dk..(i + 1) * dk]);
        }
    }

    /// The `SimdInt8Attn` realization of [`Self::run_into`]: int8 operand
    /// streams through the attention stage itself (DESIGN.md §17).
    ///
    /// Q/K/V arrive as the same (SL × d_k) f32 rows the f32 path
    /// consumes; per-head symmetric scales are fitted to their actual
    /// maxima (`s = max|·|/127` — dynamic activation quantization, so
    /// the i8 grid always covers the operands and the quantizer never
    /// saturates), the operands snap once into the caller's resident i8
    /// lanes, and then per column tile:
    ///
    /// * the whole SL×tw score stripe comes from ONE int8×int8→i32 GEMM
    ///   (`matmul_i32_i8_into` — exact integer accumulation, the same
    ///   kernel family as the projections);
    /// * each score row dequantizes once (`· sq·sk`) into the f32
    ///   stripe, so [`SoftmaxUnit`] and the online-softmax recurrence
    ///   run unchanged — the tolerance contract stays f32;
    /// * the SV accumulation streams the i8 V tile through the
    ///   dequantizing axpy (`axpy_i8_f32`, V's scale folded into the
    ///   softmax weight) — half the V stream bytes of the f32 path.
    ///
    /// Returns the fitted `(sq, sk, sv)` scales (the inputs to
    /// [`attn_quant_tolerance`]).  Bit-deterministic: scales and snaps
    /// are pure functions of the operands, and every kernel below is
    /// bit-identical across lanes/batching (integer GEMM exact, axpy
    /// one-mul-one-add).
    #[allow(clippy::too_many_arguments)]
    pub fn run_into_quant(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        q8: &mut [i8],
        k8: &mut [i8],
        v8: &mut [i8],
        s32: &mut [i32],
        stripe: &mut [f32],
        rows: &mut [OnlineRow],
        out: &mut [f32],
    ) -> (f32, f32, f32) {
        let (sl, dk, ts) = (self.seq_len, self.d_k, self.tile);
        assert_eq!(q.len(), sl * dk);
        assert_eq!(k.len(), sl * dk);
        assert_eq!(v.len(), sl * dk);
        assert!(q8.len() >= sl * dk, "q8 lane under-sized");
        assert!(k8.len() >= sl * dk, "k8 lane under-sized");
        assert!(v8.len() >= sl * dk, "v8 lane under-sized");
        assert!(s32.len() >= sl * ts, "s32 stripe lane under-sized");
        assert!(stripe.len() >= sl * ts, "score stripe lane under-sized");
        assert_eq!(rows.len(), sl);
        assert_eq!(out.len(), sl * dk);

        let max_abs = |xs: &[f32]| xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let sq = max_abs(q).max(1e-8) / 127.0;
        let sk = max_abs(k).max(1e-8) / 127.0;
        let sv = max_abs(v).max(1e-8) / 127.0;
        simd::quantize_i8_into(q, sq, &mut q8[..sl * dk]);
        simd::quantize_i8_into(k, sk, &mut k8[..sl * dk]);
        simd::quantize_i8_into(v, sv, &mut v8[..sl * dk]);

        rows.fill(OnlineRow::new());
        out.fill(0.0);
        let dq = sq * sk;
        let mut j0 = 0;
        while j0 < sl {
            let tw = ts.min(sl - j0);
            // Phase 1 — the whole tile's scores in one integer GEMM over
            // the i8 operands (vs the f32 path's per-row blocked dots).
            simd::matmul_i32_i8_into(
                &q8[..sl * dk],
                &k8[j0 * dk..(j0 + tw) * dk],
                sl,
                dk,
                tw,
                &mut s32[..sl * tw],
            );
            for i in 0..sl {
                let srow = &mut stripe[i * tw..(i + 1) * tw];
                for (jj, s) in srow.iter_mut().enumerate() {
                    // One dequant per score: the i32 accumulator is
                    // exact, so sq·sk is the only scale the f32 stage
                    // ever sees; masking applies after, same sentinel.
                    *s = self.score(i, j0 + jj, s32[i * tw + jj] as f32 * dq);
                }
            }
            // Phase 2 — unchanged online-softmax absorb; the SV axpy
            // streams i8 V rows with sv folded into the weight.
            for i in 0..sl {
                let srow = &mut stripe[i * tw..(i + 1) * tw];
                let alpha = self.softmax.absorb_tile(&mut rows[i], srow);
                let orow = &mut out[i * dk..(i + 1) * dk];
                if alpha != 1.0 {
                    simd::scale_f32(self.tier, alpha, orow);
                }
                for (jj, &w) in srow.iter().enumerate() {
                    let vrow = &v8[(j0 + jj) * dk..(j0 + jj + 1) * dk];
                    simd::axpy_i8_f32(self.tier, w * sv, vrow, orow);
                }
            }
            j0 += tw;
        }
        for i in 0..sl {
            let inv = 1.0 / rows[i].l;
            simd::scale_f32(self.tier, inv, &mut out[i * dk..(i + 1) * dk]);
        }
        (sq, sk, sv)
    }

    #[inline]
    fn score(&self, i: usize, j: usize, acc: f32) -> f32 {
        if self.causal && j > i {
            -1e9 // decoder mask, same sentinel as QkPm
        } else {
            acc * self.scale
        }
    }

    /// Useful MACs per full run — identical to QK_PM + SV_PM (fusion
    /// changes the schedule and the score residency, not the arithmetic
    /// count).
    pub fn macs(&self) -> u64 {
        2 * (self.seq_len * self.seq_len * self.d_k) as u64
    }
}

/// Documented max-abs-diff bound of the fused path against the
/// reference path (DESIGN.md §12), for outputs whose magnitude is
/// bounded by `mag` (attention outputs are convex combinations of V
/// rows, so `max|O_reference|` is a valid magnitude proxy):
///
/// * **Exact** — pure f32 reassociation error of the online rescale and
///   the deferred normalization, linear in the number of accumulated
///   terms: `8·SL·ε·max(mag, 1)`.
/// * **LUT(bits)** — two terms.  (a) Step quantization: each streamed
///   weight is `exp_lut` at the then-current max times an exact
///   telescoped rescale, i.e. within one LUT step of the batch weight;
///   with step `s = 8/(2^bits − 1)` the per-weight relative error is
///   ≤ `e^s − 1`, contributing `4·(e^s − 1)·mag` after normalization
///   (numerator + denominator each ≤ 2× the per-weight bound).
///   (b) Clamp floor: the batch pass clamps `score − m_final` to the
///   LUT domain `[x_min, 0]`, flooring far-below-max weights at
///   `exp(x_min)`, while the streaming pass absorbs a score against the
///   *then-current* max and rescales exactly — giving it its true
///   (smaller) weight when the max later rises past the clamp range.
///   The per-element discrepancy is absolute, ≤ `exp(x_min)`, and up to
///   SL elements can sit below the floor: `SL·exp(−8)·mag`.
pub fn tolerance(kind: SoftmaxKind, seq_len: usize, mag: f32) -> f32 {
    let mag = mag.abs().max(1.0);
    match kind {
        SoftmaxKind::Exact => 8.0 * seq_len as f32 * f32::EPSILON * mag,
        SoftmaxKind::Lut { bits } => {
            let step = 8.0 / ((1u64 << bits) as f32 - 1.0);
            // x_min = −8.0 in both SoftmaxUnit constructors.
            let clamp_floor = seq_len as f32 * (-8.0f32).exp();
            (4.0 * (step.exp() - 1.0) + clamp_floor) * mag
        }
    }
}

/// Documented max-abs-diff bound between kernel *tiers* of the same
/// exec path (DESIGN.md §14).  The only tier-variant kernel is the f32
/// score dot (8-lane pinned-tree reduction vs the scalar chains): a
/// per-score perturbation linear in `d_k`, passed once through softmax
/// normalization and an SL-term weighted sum — first-order linear in
/// `seq_len + d_k` with a generous safety factor, stacked on top of
/// [`tolerance`] (which already carries the LUT step/clamp machinery a
/// perturbed score can trip).
pub fn tier_tolerance(kind: SoftmaxKind, seq_len: usize, d_k: usize, mag: f32) -> f32 {
    let mag = mag.abs().max(1.0);
    tolerance(kind, seq_len, mag) + 64.0 * (seq_len + d_k) as f32 * f32::EPSILON * mag
}

/// Documented max-abs-diff bound of the int8 datapath against the f32
/// reference evaluated on the *same fake-quantized operands*
/// (DESIGN.md §14, mirroring [`tolerance`]'s role for fusion).  On the
/// shared operands the integer GEMM is *exact* — i8 levels times the
/// power-of-two grid step are exact in f32, and the i32 accumulator
/// never rounds — so the datapath-vs-f32 difference is pure f32
/// summation-order error: `d_model`-long projection sums and `SL`-long
/// attention sums, passed once through softmax normalization.  Linear
/// with a generous safety factor (the raw-f32-weights comparison is a
/// different question: that error is dominated by the half-step operand
/// snap itself and is asserted separately via the convex-combination
/// bound — see `tests/properties.rs`).
pub fn quant_tolerance(kind: SoftmaxKind, seq_len: usize, d_model: usize, mag: f32) -> f32 {
    let mag = mag.abs().max(1.0);
    tolerance(kind, seq_len, mag) + 256.0 * (d_model + seq_len) as f32 * f32::EPSILON * mag
}

/// Documented max-abs-diff bound of the `SimdInt8Attn` fused path
/// ([`FusedAttnPm::run_into_quant`]) against the f32 fused path on the
/// same operands, extending [`quant_tolerance`] to cover score-stage
/// quantization (DESIGN.md §17).  Parametric in the fitted per-head
/// scales: `qmax`/`kmax`/`vmax` are the operand maxima the quantizer
/// fitted to (scale = max/127), `score_scale` and `d_k` come from the
/// topology.
///
/// Derivation, worst case (every bound is an L∞ sum, not a random-walk
/// expectation):
///
/// * **Score perturbation** — per product term, `|q·Δk| + |k̂·Δq| ≤
///   qmax·(kmax/254) + (kmax + sk/2)·(qmax/254) ≈ qmax·kmax/127`;
///   summed over `d_k` terms and scaled: `Δs = score_scale · d_k ·
///   qmax·kmax/127 · 1.1` (the 1.1 absorbs the half-step cross terms).
/// * **Softmax sensitivity** — every un-normalized weight moves by a
///   factor within `e^{±Δs}` and the denominator likewise, so a convex
///   combination of rows bounded by `vmax` moves by at most
///   `(e^{2Δs} − 1)·vmax`.
/// * **V snap** — `|v̂ − v| ≤ sv/2` through a convex combination:
///   `+ sv/2`.
/// * **Saturation** — both outputs are convex combinations of rows
///   bounded by `vmax` (+ half a V step), so their difference can never
///   exceed the range diameter `2·vmax + sv`; the exponential term is
///   clamped there.  For coarse effective score steps (large
///   `score_scale·d_k·qmax·kmax`) the bound deliberately saturates at
///   this diameter — sound, not tight; EXPERIMENTS.md documents the
///   observed error alongside.
///
/// A 2× margin stacks the f32 machinery of [`quant_tolerance`] /
/// [`tolerance`] (LUT step/clamp, reassociation) on top.
pub fn attn_quant_tolerance(
    kind: SoftmaxKind,
    seq_len: usize,
    d_model: usize,
    d_k: usize,
    score_scale: f32,
    qmax: f32,
    kmax: f32,
    vmax: f32,
) -> f32 {
    let vmax = vmax.abs();
    let base = quant_tolerance(kind, seq_len, d_model, vmax);
    let sv = vmax.max(1e-8) / 127.0;
    let ds = score_scale.abs() * d_k as f32 * (qmax.abs() * kmax.abs() / 127.0) * 1.1;
    // Clamp the exponent before evaluating so the saturated arm never
    // sees an f32 overflow (inf would poison the min below).
    let soft = ((2.0 * ds).min(30.0).exp_m1()) * vmax;
    let attn = (soft + 0.5 * sv).min(2.0 * vmax + sv);
    base + 2.0 * attn
}

/// Assert `got` is within the documented [`tolerance`] of the
/// reference-path `want` (magnitude proxy: `max(1, max|want|)`);
/// returns the observed `(max_abs_diff, tolerance)` for reporting.
/// The single enforcement point shared by the property tests, the
/// engine/runtime tests, the long-SL soak and the exec bench — a bound
/// change propagates everywhere from here.
pub fn assert_within_tolerance(
    kind: SoftmaxKind,
    seq_len: usize,
    want: &[f32],
    got: &[f32],
    what: &str,
) -> (f32, f32) {
    assert_eq!(want.len(), got.len(), "{what}: output length diverged");
    let mag = want.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let tol = tolerance(kind, seq_len, mag);
    let diff = want.iter().zip(got).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(diff <= tol, "{what}: fused-vs-reference diff {diff} > tolerance {tol}");
    (diff, tol)
}

#[cfg(test)]
mod tests {
    use super::super::modules::{QkPm, SvPm};
    use super::*;

    fn gen(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).max(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s % 2048) as f32 - 1024.0) / 1024.0
            })
            .collect()
    }

    fn reference(qk: &QkPm, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let s = qk.run(q, k);
        SvPm::new(qk.seq_len, qk.d_k).run(&s, v)
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    }

    fn run_fused(pm: &FusedAttnPm, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let mut stripe = vec![0f32; pm.stripe_elems()];
        let mut rows = vec![OnlineRow::new(); pm.seq_len];
        let mut out = vec![0f32; pm.seq_len * pm.d_k];
        pm.run_into(q, k, v, &mut stripe, &mut rows, &mut out);
        out
    }

    #[test]
    fn fused_matches_reference_within_tolerance() {
        // Every (tile residue × softmax kind × masking) combination on
        // small shapes, against the materializing reference pipeline.
        for sl in [3usize, 4, 7, 8, 12, 16] {
            let dk = 5;
            let q = gen(1, sl * dk);
            let k = gen(2, sl * dk);
            let v = gen(3, sl * dk);
            for tile in [1usize, 3, 4, 8, 64] {
                for causal in [false, true] {
                    for unit in [SoftmaxUnit::exact(), SoftmaxUnit::lut(8)] {
                        let qk = if causal {
                            QkPm::causal(sl, dk, 0.37, unit.clone())
                        } else {
                            QkPm::new(sl, dk, 0.37, unit.clone())
                        };
                        let want = reference(&qk, &q, &k, &v);
                        let pm = FusedAttnPm::new(sl, dk, tile, 0.37, unit.clone(), causal);
                        let got = run_fused(&pm, &q, &k, &v);
                        assert_within_tolerance(
                            unit.kind,
                            sl,
                            &want,
                            &got,
                            &format!("sl={sl} tile={tile} causal={causal} {:?}", unit.kind),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_single_tile_is_deterministic_and_tile_invariant() {
        // Different tile widths must agree with each other within the
        // exact-kind tolerance (the result is mathematically
        // tile-independent), and each width is bit-deterministic.
        let (sl, dk) = (11usize, 4usize);
        let q = gen(7, sl * dk);
        let k = gen(8, sl * dk);
        let v = gen(9, sl * dk);
        let base = run_fused(
            &FusedAttnPm::new(sl, dk, 64, 1.0, SoftmaxUnit::exact(), false),
            &q,
            &k,
            &v,
        );
        for tile in [1usize, 2, 3, 5, 11] {
            let pm = FusedAttnPm::new(sl, dk, tile, 1.0, SoftmaxUnit::exact(), false);
            let a = run_fused(&pm, &q, &k, &v);
            let b = run_fused(&pm, &q, &k, &v);
            assert_eq!(a, b, "tile={tile} not deterministic");
            let mag = base.iter().fold(0f32, |m, &x| m.max(x.abs()));
            assert!(
                max_abs_diff(&a, &base) <= tolerance(SoftmaxKind::Exact, sl, mag),
                "tile={tile} diverged across tile widths"
            );
        }
    }

    #[test]
    fn fused_rows_are_convex_combinations() {
        // Output rows must stay inside the V value range (softmax rows
        // are stochastic), streamed or not.
        let (sl, dk) = (9usize, 3usize);
        let q = gen(11, sl * dk);
        let k = gen(12, sl * dk);
        let v = gen(13, sl * dk);
        let pm = FusedAttnPm::new(sl, dk, 4, 0.7, SoftmaxUnit::exact(), false);
        let out = run_fused(&pm, &q, &k, &v);
        let vmax = v.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let vmin = v.iter().fold(f32::INFINITY, |m, &x| m.min(x));
        for &o in &out {
            assert!(o <= vmax + 1e-5 && o >= vmin - 1e-5, "{o} outside [{vmin}, {vmax}]");
        }
    }

    #[test]
    fn fused_causal_first_row_is_v_row0() {
        let (sl, dk) = (6usize, 4usize);
        let q = gen(21, sl * dk);
        let k = gen(22, sl * dk);
        let v = gen(23, sl * dk);
        let pm = FusedAttnPm::new(sl, dk, 4, 0.5, SoftmaxUnit::exact(), true);
        let out = run_fused(&pm, &q, &k, &v);
        for j in 0..dk {
            assert!((out[j] - v[j]).abs() < 1e-6, "row 0 must attend only to position 0");
        }
    }

    #[test]
    fn tolerance_is_monotone_and_positive() {
        assert!(tolerance(SoftmaxKind::Exact, 64, 1.0) > 0.0);
        assert!(
            tolerance(SoftmaxKind::Exact, 1024, 1.0) > tolerance(SoftmaxKind::Exact, 64, 1.0)
        );
        assert!(
            tolerance(SoftmaxKind::Lut { bits: 8 }, 64, 1.0)
                > tolerance(SoftmaxKind::Lut { bits: 10 }, 64, 1.0)
        );
        assert!(
            tolerance(SoftmaxKind::Exact, 64, 10.0) > tolerance(SoftmaxKind::Exact, 64, 1.0)
        );
    }

    #[test]
    #[should_panic(expected = "positive tile width")]
    fn zero_tile_rejected() {
        FusedAttnPm::new(4, 4, 0, 1.0, SoftmaxUnit::exact(), false);
    }

    #[test]
    fn simd_tier_within_tier_tolerance_and_deterministic() {
        // The SIMD tier reassociates the score dots (pinned tree), so it
        // is tolerance-equivalent to the scalar oracle — and must be
        // bit-deterministic run to run.  On non-AVX2 hosts the tier
        // clamps to scalar inside the kernels and the diff is zero,
        // which the bound also covers.
        for sl in [5usize, 8, 13] {
            let dk = 9; // 8-lane body + 1-wide ordered tail
            let q = gen(31, sl * dk);
            let k = gen(32, sl * dk);
            let v = gen(33, sl * dk);
            for causal in [false, true] {
                let scalar = FusedAttnPm::new(sl, dk, 4, 0.37, SoftmaxUnit::exact(), causal);
                let simd = scalar.clone().with_tier(KernelTier::Simd);
                let want = run_fused(&scalar, &q, &k, &v);
                let got = run_fused(&simd, &q, &k, &v);
                let mag = want.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let tol = tier_tolerance(SoftmaxKind::Exact, sl, dk, mag);
                let diff = max_abs_diff(&want, &got);
                assert!(diff <= tol, "sl={sl} causal={causal}: {diff} > {tol}");
                let again = run_fused(&simd, &q, &k, &v);
                assert_eq!(got, again, "sl={sl} causal={causal}: SIMD tier not deterministic");
            }
        }
    }

    #[test]
    fn tier_and_quant_tolerances_dominate_base() {
        for kind in [SoftmaxKind::Exact, SoftmaxKind::Lut { bits: 8 }] {
            assert!(tier_tolerance(kind, 64, 96, 2.0) > tolerance(kind, 64, 2.0));
            assert!(quant_tolerance(kind, 64, 768, 2.0) > tolerance(kind, 64, 2.0));
            // The attention-stage bound dominates the projection-only
            // bound, stays finite even for absurd scale products
            // (saturation arm), and grows with the fitted maxima.
            let a = attn_quant_tolerance(kind, 64, 768, 96, 0.102, 1.0, 1.0, 2.0);
            assert!(a > quant_tolerance(kind, 64, 768, 2.0));
            assert!(a.is_finite());
            let big = attn_quant_tolerance(kind, 64, 768, 96, 0.102, 1e6, 1e6, 2.0);
            assert!(big.is_finite(), "saturation arm must cap the exponential");
            assert!(big <= quant_tolerance(kind, 64, 768, 2.0) + 2.0 * (2.0 * 2.0 + 2.0 / 127.0) + 1.0);
            assert!(
                attn_quant_tolerance(kind, 64, 768, 96, 0.102, 0.5, 0.5, 2.0) < a,
                "tighter fitted maxima must tighten the bound"
            );
        }
    }

    fn run_fused_quant(
        pm: &FusedAttnPm,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, (f32, f32, f32)) {
        let n = pm.seq_len * pm.d_k;
        let mut q8 = vec![0i8; n];
        let mut k8 = vec![0i8; n];
        let mut v8 = vec![0i8; n];
        let mut s32 = vec![0i32; pm.stripe_elems()];
        let mut stripe = vec![0f32; pm.stripe_elems()];
        let mut rows = vec![OnlineRow::new(); pm.seq_len];
        let mut out = vec![0f32; n];
        let scales = pm.run_into_quant(
            q, k, v, &mut q8, &mut k8, &mut v8, &mut s32, &mut stripe, &mut rows, &mut out,
        );
        (out, scales)
    }

    #[test]
    fn int8_attn_within_attn_quant_tolerance() {
        // The quantized attention stage against the f32 fused path on
        // identical operands, every (tile × masking × softmax kind)
        // combination — the module-level pin of the DESIGN.md §17
        // numerics contract (end-to-end coverage: tests/properties.rs).
        for sl in [4usize, 7, 12] {
            let dk = 5;
            let q = gen(41, sl * dk);
            let k = gen(42, sl * dk);
            let v = gen(43, sl * dk);
            let max_abs = |xs: &[f32]| xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
            for tile in [1usize, 3, 8, 64] {
                for causal in [false, true] {
                    for unit in [SoftmaxUnit::exact(), SoftmaxUnit::lut(8)] {
                        let pm = FusedAttnPm::new(sl, dk, tile, 0.37, unit.clone(), causal)
                            .with_tier(KernelTier::SimdInt8Attn);
                        let want = run_fused(&pm, &q, &k, &v);
                        let (got, _) = run_fused_quant(&pm, &q, &k, &v);
                        let tol = attn_quant_tolerance(
                            unit.kind,
                            sl,
                            dk,
                            dk,
                            0.37,
                            max_abs(&q),
                            max_abs(&k),
                            max_abs(&v),
                        );
                        let diff = max_abs_diff(&want, &got);
                        assert!(
                            diff <= tol,
                            "sl={sl} tile={tile} causal={causal} {:?}: {diff} > {tol}",
                            unit.kind
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn int8_attn_deterministic_and_rows_stay_convex() {
        let (sl, dk) = (13usize, 4usize);
        let q = gen(51, sl * dk);
        let k = gen(52, sl * dk);
        let v = gen(53, sl * dk);
        let pm = FusedAttnPm::new(sl, dk, 4, 0.7, SoftmaxUnit::exact(), false)
            .with_tier(KernelTier::SimdInt8Attn);
        let (a, scales_a) = run_fused_quant(&pm, &q, &k, &v);
        let (b, scales_b) = run_fused_quant(&pm, &q, &k, &v);
        assert_eq!(a, b, "int8 attention must be bit-deterministic");
        assert_eq!(scales_a, scales_b);
        // Output rows are convex combinations of dequantized V rows —
        // they can exceed the raw V range by at most half a V step.
        let (_, _, sv) = scales_a;
        let vmax = v.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let vmin = v.iter().fold(f32::INFINITY, |m, &x| m.min(x));
        for &o in &a {
            assert!(
                o <= vmax + 0.5 * sv + 1e-5 && o >= vmin - 0.5 * sv - 1e-5,
                "{o} outside [{vmin}, {vmax}] ± sv/2"
            );
        }
        // Tile-width invariance within the documented bound: the math is
        // tile-independent; only f32 absorb order moves.
        let (wide, _) = run_fused_quant(
            &FusedAttnPm::new(sl, dk, 64, 0.7, SoftmaxUnit::exact(), false)
                .with_tier(KernelTier::SimdInt8Attn),
            &q,
            &k,
            &v,
        );
        let mag = wide.iter().fold(0f32, |m, &x| m.max(x.abs()));
        assert!(max_abs_diff(&a, &wide) <= tolerance(SoftmaxKind::Exact, sl, mag));
    }
}
