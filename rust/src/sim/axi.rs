//! AXI4-master / HBM load-path model.
//!
//! The accelerator fetches inputs and weights from off-chip memory (HBM on
//! U55C, DDR4 on U200) through AXI4 master interfaces (Fig. 5).  The
//! paper's PD_L decomposition gives the per-transfer pipeline:
//! 7 cc AXI setup + 1 cc address + 1 cc load + 1 cc store + 3 cc
//! float→fixed conversion, with II=1 streaming once the pipeline fills.
//!
//! Each load phase is therefore a pipelined loop (eq. 3) whose trip count
//! is the number of elements streamed per outer iteration.

use crate::fpga::hls::{LoopNest, PipelinedLoop};

/// Latency components of one AXI transfer pipeline (PD_L = 13 total).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AxiTimings {
    /// Cycles to establish communication with HBM over AXI (7 cc).
    pub setup: u64,
    /// Read address channel (1 cc).
    pub addr: u64,
    /// Data beat into on-chip register (1 cc).
    pub load: u64,
    /// Store to BRAM (1 cc).
    pub store: u64,
    /// Float→fixed conversion stage (3 cc).
    pub convert: u64,
}

impl Default for AxiTimings {
    fn default() -> Self {
        AxiTimings { setup: 7, addr: 1, load: 1, store: 1, convert: 3 }
    }
}

impl AxiTimings {
    /// Total pipeline depth PD_L.
    pub fn pd_l(&self) -> u64 {
        self.setup + self.addr + self.load + self.store + self.convert
    }
}

/// The AXI master serving one accelerator's load phases.
#[derive(Clone, Debug, Default)]
pub struct AxiMaster {
    pub timings: AxiTimings,
    /// Total data beats issued (statistics; drives bandwidth reporting).
    pub beats: u64,
    /// Total cycles spent in load phases.
    pub busy_cycles: u64,
}

impl AxiMaster {
    pub fn new(timings: AxiTimings) -> Self {
        AxiMaster { timings, beats: 0, busy_cycles: 0 }
    }

    /// Load a full `rows × cols` matrix, streaming `cols` elements per
    /// outer iteration (eq. 5's shape: `[(cols−1)·1 + PD_L] · rows`).
    pub fn load_matrix(&mut self, rows: u64, cols: u64) -> u64 {
        let cycles = LoopNest::new(
            PipelinedLoop::new(cols, 1, self.timings.pd_l()),
            rows,
        )
        .latency();
        self.beats += rows * cols;
        self.busy_cycles += cycles;
        cycles
    }

    /// Load a vector of `len` elements (eq. 6's shape: one pipeline pass).
    pub fn load_vector(&mut self, len: u64) -> u64 {
        let cycles = PipelinedLoop::new(len, 1, self.timings.pd_l()).latency();
        self.beats += len;
        self.busy_cycles += cycles;
        cycles
    }

    /// Effective bandwidth of the issued traffic in bytes/cycle
    /// (1 int8 element per beat).
    pub fn bytes_per_cycle(&self) -> f64 {
        if self.busy_cycles == 0 {
            0.0
        } else {
            self.beats as f64 / self.busy_cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pd_l_is_13() {
        assert_eq!(AxiTimings::default().pd_l(), 13);
    }

    #[test]
    fn matrix_load_matches_eq5() {
        // LI for test 1: [(768−1)·1 + 13] · 64 = 49 920.
        let mut axi = AxiMaster::default();
        assert_eq!(axi.load_matrix(64, 768), 49_920);
        assert_eq!(axi.beats, 64 * 768);
    }

    #[test]
    fn vector_load_matches_eq6() {
        // LB for test 1: (96−1)·1 + 13 = 108.
        let mut axi = AxiMaster::default();
        assert_eq!(axi.load_vector(96), 108);
    }

    #[test]
    fn stats_accumulate() {
        let mut axi = AxiMaster::default();
        axi.load_matrix(4, 16);
        axi.load_vector(8);
        assert_eq!(axi.beats, 64 + 8);
        assert!(axi.busy_cycles > 0);
        assert!(axi.bytes_per_cycle() > 0.0 && axi.bytes_per_cycle() < 1.0);
    }

    #[test]
    fn longer_bursts_amortize_setup() {
        // Streaming efficiency rises with burst length: the paper's reason
        // for preferring large tiles (Section VI, tests 9-10).
        let mut a = AxiMaster::default();
        let mut b = AxiMaster::default();
        a.load_matrix(1, 1024);
        b.load_matrix(16, 64); // same volume, shorter bursts
        assert!(a.busy_cycles < b.busy_cycles);
        assert!(a.bytes_per_cycle() > b.bytes_per_cycle());
    }
}
