//! Reusable scratch for the functional datapath.
//!
//! The FAMOUS fabric keeps every intermediate (`Q/K/V`, scores, head
//! outputs) resident in BRAM across invocations; the pre-PR-3 software
//! hot path instead re-allocated all of them per request.  A
//! [`Workspace`] is the host-side analogue of those resident buffers: a
//! per-worker arena [`PreparedWeights`](super::PreparedWeights) executes
//! into, so a *warm* request — same (or smaller) topology as one the
//! workspace has already served — performs **zero heap allocations** on
//! the execute path.  Tests pin this via [`Workspace::footprint`]
//! (buffer pointers and capacities must be stable across warm requests).
//!
//! Head-parallel execution gives each concurrent head lane its own
//! [`HeadScratch`], so lanes never share mutable state; the output is a
//! single buffer written in disjoint per-head column stripes (DESIGN.md
//! §10).
//!
//! Each lane carries scratch for both attention datapaths
//! (DESIGN.md §12): the reference path's `SL×SL` score matrix `s`, and
//! the fused tile-streaming path's `SL×TS` score stripe + per-row
//! online-softmax states.  Only the buffers of the path actually
//! executed are sized, so a workspace that has served only fused
//! requests never allocates an `SL×SL` buffer — the O(SL×TS) footprint
//! the long-sequence path exists for.
//!
//! Sizing is grow-only per request with a **high-water-mark decay**:
//! after [`SHRINK_WINDOW`] consecutive requests demanding less than
//! half the arena's retained bytes, buffers are released down to the
//! current demand (a fleet that served one burst of large topologies
//! does not pin their arenas forever).  Warm steady-state traffic keeps
//! demand at capacity, so the zero-allocation contract is untouched.

use super::fused::ExecPath;
use super::softmax_unit::OnlineRow;
use crate::config::Topology;
use crate::fixed::KernelTier;

/// Consecutive under-half-demand requests before a workspace releases
/// its surplus capacity (the pool-side analogue lives in
/// `runtime::SimBackend`).
pub const SHRINK_WINDOW: u32 = 64;

/// One head lane's scratch: everything a single head's pipeline touches.
#[derive(Clone, Debug, Default)]
pub struct HeadScratch {
    /// i32 GEMM accumulator (SL × d_k), reused for Q, K and V in turn.
    pub(crate) acc: Vec<i32>,
    /// Dequantized projections (SL × d_k each).
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    /// Score matrix (SL × SL) — reference path only.
    pub(crate) s: Vec<f32>,
    /// Score tile stripe (SL × TS) — fused streaming path only.
    pub(crate) stripe: Vec<f32>,
    /// int8 operand lanes for the fused attention stage (SL × d_k each)
    /// — `SimdInt8Attn` + fused path only (DESIGN.md §17): the per-head
    /// quantized Q/K/V the int8 score GEMM and dequantizing SV axpy
    /// stream.
    pub(crate) q8: Vec<i8>,
    pub(crate) k8: Vec<i8>,
    pub(crate) v8: Vec<i8>,
    /// i32 score-stripe accumulator (SL × TS) for the int8 score GEMM —
    /// `SimdInt8Attn` + fused path only.
    pub(crate) s32: Vec<i32>,
    /// Per-row online-softmax running (max, denominator) — fused only.
    pub(crate) rows: Vec<OnlineRow>,
    /// Head output (SL × d_k) before the stripe copy into the request
    /// output.
    pub(crate) o: Vec<f32>,
    /// ABFT row-checksum failures this lane observed for the current
    /// request (DESIGN.md §15).  Reset by `ensure`, summed by
    /// [`Workspace::integrity_faults`]; lanes are exclusively owned per
    /// worker, so plain counters suffice.
    pub(crate) faults: u32,
}

impl HeadScratch {
    fn ensure(&mut self, sl: usize, dk: usize, ts: usize, path: ExecPath, tier: KernelTier) {
        self.faults = 0;
        self.acc.resize(sl * dk, 0);
        self.q.resize(sl * dk, 0.0);
        self.k.resize(sl * dk, 0.0);
        self.v.resize(sl * dk, 0.0);
        self.o.resize(sl * dk, 0.0);
        // Only the executed path's score scratch is sized; the other
        // path's length drops to zero (its *capacity* — and therefore
        // the warm footprint — is untouched, but it counts as surplus
        // for the decay policy and is freed if a shrink fires).
        match path {
            ExecPath::Reference => {
                self.s.resize(sl * sl, 0.0);
                self.stripe.truncate(0);
                self.rows.truncate(0);
            }
            ExecPath::FusedTiled => {
                self.s.truncate(0);
                self.stripe.resize(sl * ts, 0.0);
                self.rows.resize(sl, OnlineRow::new());
            }
        }
        // The int8 attention lanes exist only where the int8 operand
        // stream actually runs: the SimdInt8Attn tier's fused path.
        // Everywhere else they follow the unused-path policy above.
        if tier == KernelTier::SimdInt8Attn && path == ExecPath::FusedTiled {
            self.q8.resize(sl * dk, 0);
            self.k8.resize(sl * dk, 0);
            self.v8.resize(sl * dk, 0);
            self.s32.resize(sl * ts, 0);
        } else {
            self.q8.truncate(0);
            self.k8.truncate(0);
            self.v8.truncate(0);
            self.s32.truncate(0);
        }
    }

    /// Bytes this lane's current request actually uses (lengths).
    fn demand_bytes(&self) -> usize {
        self.acc.len() * 4
            + (self.q.len() + self.k.len() + self.v.len()) * 4
            + self.s.len() * 4
            + self.stripe.len() * 4
            + self.rows.len() * std::mem::size_of::<OnlineRow>()
            + self.o.len() * 4
            + (self.q8.len() + self.k8.len() + self.v8.len())
            + self.s32.len() * 4
    }

    /// Bytes this lane retains (capacities).
    fn capacity_bytes(&self) -> usize {
        self.acc.capacity() * 4
            + (self.q.capacity() + self.k.capacity() + self.v.capacity()) * 4
            + self.s.capacity() * 4
            + self.stripe.capacity() * 4
            + self.rows.capacity() * std::mem::size_of::<OnlineRow>()
            + self.o.capacity() * 4
            + (self.q8.capacity() + self.k8.capacity() + self.v8.capacity())
            + self.s32.capacity() * 4
    }

    fn release_surplus(&mut self) {
        self.acc.shrink_to_fit();
        self.q.shrink_to_fit();
        self.k.shrink_to_fit();
        self.v.shrink_to_fit();
        self.s.shrink_to_fit();
        self.stripe.shrink_to_fit();
        self.rows.shrink_to_fit();
        self.o.shrink_to_fit();
        self.q8.shrink_to_fit();
        self.k8.shrink_to_fit();
        self.v8.shrink_to_fit();
        self.s32.shrink_to_fit();
    }
}

/// Reusable execute-path arena: widened input, per-head lanes, output.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    /// Input widened to i16 once per request (SL × d_model), shared by
    /// all three projections of every head.
    pub(crate) x16: Vec<i16>,
    /// Per-head scratch lanes; a head-parallel execute with `l` lanes
    /// uses the first `l`, the serial path uses lane 0 for every head.
    pub(crate) lanes: Vec<HeadScratch>,
    /// Request output (SL × d_model, heads concatenated).
    pub(crate) out: Vec<f32>,
    /// Consecutive ensures whose demand was under half the retained
    /// bytes (drives the high-water-mark decay).
    lean_streak: u32,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for `topo` with `lanes` head lanes on `path`
    /// under kernel `tier`.  `Vec::resize` never shrinks capacity, so a
    /// warm call with a previously-seen (or smaller) topology allocates
    /// nothing; sustained under-half demand eventually releases the
    /// surplus (see the module docs).
    ///
    /// The `SimdInt8` tier feeds the projections straight from the
    /// request's int8 operand — no i16 widening pass — so `x16` drops to
    /// zero length the same way the unused path's score scratch does: a
    /// workspace that has only ever served the int8 datapath never
    /// allocates the widened copy at all (DESIGN.md §14).
    pub(crate) fn ensure(
        &mut self,
        topo: &Topology,
        lanes: usize,
        path: ExecPath,
        tier: KernelTier,
    ) {
        let (sl, dm, dk, ts) = (topo.seq_len, topo.d_model, topo.d_k(), topo.tile_size);
        if tier.stages_i8() {
            // i8-staging tiers read the request's int8 operand directly —
            // no widened copy (DESIGN.md §14).
            self.x16.truncate(0);
        } else {
            self.x16.resize(sl * dm, 0);
        }
        self.out.resize(sl * dm, 0.0);
        if self.lanes.len() < lanes {
            self.lanes.resize_with(lanes, HeadScratch::default);
        }
        // Idle lanes keep their buffers but must not keep fault counts:
        // `integrity_faults` sums every lane, and a narrower request
        // after a wide faulty one must not inherit stale verdicts.
        for lane in &mut self.lanes[lanes..] {
            lane.faults = 0;
        }
        for lane in &mut self.lanes[..lanes] {
            lane.ensure(sl, dk, ts, path, tier);
        }
        // High-water-mark decay: idle lanes and the unused path's score
        // scratch count as surplus; demand is what this request sized.
        let demand = self.x16.len() * 2
            + self.out.len() * 4
            + self.lanes[..lanes].iter().map(HeadScratch::demand_bytes).sum::<usize>();
        if demand * 2 < self.footprint_bytes() {
            self.lean_streak += 1;
            if self.lean_streak >= SHRINK_WINDOW {
                self.lanes.truncate(lanes);
                self.lanes.shrink_to_fit();
                for lane in &mut self.lanes {
                    lane.release_surplus();
                }
                self.x16.shrink_to_fit();
                self.out.shrink_to_fit();
                self.lean_streak = 0;
            }
        } else {
            self.lean_streak = 0;
        }
    }

    /// The output of the most recent `execute_into`/`execute_parallel`.
    pub fn output(&self) -> &[f32] {
        &self.out
    }

    /// ABFT row-checksum failures across all lanes for the most recent
    /// execute (0 = every projection of every head verified clean).
    pub fn integrity_faults(&self) -> u64 {
        self.lanes.iter().map(|l| l.faults as u64).sum()
    }

    /// Move the output out, leaving an empty buffer (the next warm call
    /// re-grows it — used by the allocating `execute` wrapper, not by
    /// serving paths).
    pub fn take_output(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.out)
    }

    /// (pointer, capacity) of every buffer.  The workspace-reuse tests
    /// assert this is stable across warm requests — the "zero heap
    /// allocations on the warm execute path" contract.
    pub fn footprint(&self) -> Vec<(usize, usize)> {
        let mut fp = vec![
            (self.x16.as_ptr() as usize, self.x16.capacity()),
            (self.out.as_ptr() as usize, self.out.capacity()),
        ];
        for l in &self.lanes {
            fp.push((l.acc.as_ptr() as usize, l.acc.capacity()));
            fp.push((l.q.as_ptr() as usize, l.q.capacity()));
            fp.push((l.k.as_ptr() as usize, l.k.capacity()));
            fp.push((l.v.as_ptr() as usize, l.v.capacity()));
            fp.push((l.s.as_ptr() as usize, l.s.capacity()));
            fp.push((l.stripe.as_ptr() as usize, l.stripe.capacity()));
            fp.push((l.rows.as_ptr() as usize, l.rows.capacity()));
            fp.push((l.o.as_ptr() as usize, l.o.capacity()));
            fp.push((l.q8.as_ptr() as usize, l.q8.capacity()));
            fp.push((l.k8.as_ptr() as usize, l.k8.capacity()));
            fp.push((l.v8.as_ptr() as usize, l.v8.capacity()));
            fp.push((l.s32.as_ptr() as usize, l.s32.capacity()));
        }
        fp
    }

    /// Total bytes the arena retains (all buffer capacities) — the
    /// quantity the exec bench reports as peak workspace bytes and the
    /// O(SL×TS)-vs-O(SL²) scaling tests compare.
    pub fn footprint_bytes(&self) -> usize {
        self.x16.capacity() * 2
            + self.out.capacity() * 4
            + self.lanes.iter().map(HeadScratch::capacity_bytes).sum::<usize>()
    }

    /// Capacity of lane 0's reference-path SL×SL score buffer (0 when
    /// the workspace has only ever run the fused path) — test hook for
    /// the "fused never materializes SL×SL" contract.
    pub fn reference_score_capacity(&self) -> usize {
        self.lanes.first().map_or(0, |l| l.s.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_grows_then_stays_put() {
        let mut ws = Workspace::new();
        let small = Topology::new(8, 64, 2, 16);
        let large = Topology::new(16, 64, 2, 16);
        ws.ensure(&large, 2, ExecPath::Reference, KernelTier::Scalar);
        let fp = ws.footprint();
        assert_eq!(ws.lanes.len(), 2);
        assert_eq!(ws.x16.len(), 16 * 64);
        // Warm re-ensure (same + smaller topology): nothing moves.
        ws.ensure(&large, 2, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws.footprint(), fp);
        ws.ensure(&small, 1, ExecPath::Reference, KernelTier::Scalar);
        ws.ensure(&large, 2, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws.footprint(), fp, "shrink + regrow must stay in capacity");
    }

    #[test]
    fn fused_path_sizes_stripe_not_score_matrix() {
        let mut ws = Workspace::new();
        let topo = Topology::new(32, 64, 2, 16);
        ws.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::Scalar);
        assert_eq!(ws.lanes[0].stripe.len(), 32 * 16);
        assert_eq!(ws.lanes[0].rows.len(), 32);
        assert_eq!(ws.reference_score_capacity(), 0, "fused must not allocate SL×SL");
        let fused_bytes = ws.footprint_bytes();
        // The reference path at the same topology retains strictly more.
        let mut ws_ref = Workspace::new();
        ws_ref.ensure(&topo, 1, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws_ref.lanes[0].s.len(), 32 * 32);
        assert!(ws_ref.footprint_bytes() > fused_bytes);
        // Switching a fused workspace to reference sizes s lazily.
        ws.ensure(&topo, 1, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws.lanes[0].s.len(), 32 * 32);
        assert_eq!(ws.lanes[0].stripe.len(), 0);
        assert!(ws.lanes[0].stripe.capacity() >= 32 * 16, "capacity is retained");
    }

    #[test]
    fn int8_tier_never_sizes_the_widened_input() {
        // The SimdInt8 tier reads the request's i8 operand directly: a
        // workspace that has only served the int8 datapath must never
        // allocate the i16 copy (the "no widening pass" contract).
        let mut ws = Workspace::new();
        let topo = Topology::new(16, 64, 2, 16);
        ws.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::SimdInt8);
        assert_eq!(ws.x16.len(), 0);
        assert_eq!(ws.x16.capacity(), 0, "int8-only workspace allocated x16");
        // Switching tiers sizes it lazily; switching back truncates the
        // length but keeps the capacity (same policy as score scratch).
        ws.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::Scalar);
        assert_eq!(ws.x16.len(), 16 * 64);
        ws.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::SimdInt8);
        assert_eq!(ws.x16.len(), 0);
        assert!(ws.x16.capacity() >= 16 * 64, "capacity is retained");
    }

    #[test]
    fn attn_int8_lanes_sized_only_on_the_quantized_fused_path() {
        let mut ws = Workspace::new();
        let topo = Topology::new(32, 64, 2, 16);
        let (sl, dk, ts) = (32usize, 32usize, 16usize);
        // Fused + SimdInt8Attn: i8 lanes + i32 stripe live, x16 skipped.
        ws.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::SimdInt8Attn);
        assert_eq!(ws.lanes[0].q8.len(), sl * dk);
        assert_eq!(ws.lanes[0].k8.len(), sl * dk);
        assert_eq!(ws.lanes[0].v8.len(), sl * dk);
        assert_eq!(ws.lanes[0].s32.len(), sl * ts);
        assert_eq!(ws.x16.len(), 0, "attn-int8 tier must skip the widening pass");
        // Reference path under the same tier runs the f32 modules: the
        // attention lanes drop to zero length (capacity retained).
        ws.ensure(&topo, 1, ExecPath::Reference, KernelTier::SimdInt8Attn);
        assert_eq!(ws.lanes[0].q8.len(), 0);
        assert_eq!(ws.lanes[0].s32.len(), 0);
        assert!(ws.lanes[0].q8.capacity() >= sl * dk, "capacity is retained");
        // Other tiers on the fused path never size them at all.
        let mut plain = Workspace::new();
        plain.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::SimdInt8);
        assert_eq!(plain.lanes[0].q8.capacity(), 0);
        assert_eq!(plain.lanes[0].s32.capacity(), 0);
        // And the i8 lanes are part of the accounted footprint.
        let mut a = Workspace::new();
        a.ensure(&topo, 1, ExecPath::FusedTiled, KernelTier::SimdInt8Attn);
        assert!(
            a.footprint_bytes() > plain.footprint_bytes(),
            "i8 lanes must be visible in footprint_bytes"
        );
    }

    #[test]
    fn take_output_then_warm_up_again() {
        let mut ws = Workspace::new();
        let topo = Topology::new(4, 32, 2, 16);
        ws.ensure(&topo, 1, ExecPath::Reference, KernelTier::Scalar);
        ws.out[0] = 7.0;
        let out = ws.take_output();
        assert_eq!(out.len(), 4 * 32);
        assert_eq!(out[0], 7.0);
        assert!(ws.output().is_empty());
        ws.ensure(&topo, 1, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws.output().len(), 4 * 32);
    }

    #[test]
    fn high_water_mark_decays_after_sustained_small_demand() {
        let mut ws = Workspace::new();
        let big = Topology::new(64, 64, 2, 16);
        let small = Topology::new(4, 32, 2, 16);
        ws.ensure(&big, 4, ExecPath::Reference, KernelTier::Scalar);
        let peak = ws.footprint_bytes();
        // One small request is not enough: capacity must survive a blip
        // (the next big request would otherwise reallocate everything).
        ws.ensure(&small, 1, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws.footprint_bytes(), peak);
        ws.ensure(&big, 4, ExecPath::Reference, KernelTier::Scalar);
        assert_eq!(ws.footprint_bytes(), peak, "big demand resets the streak");
        // A sustained window of small demand releases the surplus.
        for _ in 0..SHRINK_WINDOW {
            ws.ensure(&small, 1, ExecPath::Reference, KernelTier::Scalar);
        }
        let shrunk = ws.footprint_bytes();
        assert!(shrunk < peak, "decay must release the high-water surplus");
        assert_eq!(ws.lanes.len(), 1, "idle lanes released");
        // Post-shrink steady state is warm again: zero allocations.
        let fp = ws.footprint();
        for _ in 0..4 {
            ws.ensure(&small, 1, ExecPath::Reference, KernelTier::Scalar);
        }
        assert_eq!(ws.footprint(), fp, "post-shrink warm request reallocated");
    }

    #[test]
    fn steady_state_demand_never_shrinks() {
        // Same-topology traffic keeps demand at capacity: no decay, and
        // every footprint snapshot is identical — the zero-allocation
        // warm contract is unaffected by the policy.
        let mut ws = Workspace::new();
        let topo = Topology::new(16, 64, 2, 16);
        ws.ensure(&topo, 2, ExecPath::Reference, KernelTier::Scalar);
        let fp = ws.footprint();
        for _ in 0..(2 * SHRINK_WINDOW) {
            ws.ensure(&topo, 2, ExecPath::Reference, KernelTier::Scalar);
            assert_eq!(ws.footprint(), fp);
        }
    }
}
