//! Reusable scratch for the functional datapath.
//!
//! The FAMOUS fabric keeps every intermediate (`Q/K/V`, scores, head
//! outputs) resident in BRAM across invocations; the pre-PR-3 software
//! hot path instead re-allocated all of them per request.  A
//! [`Workspace`] is the host-side analogue of those resident buffers: a
//! per-worker arena [`PreparedWeights`](super::PreparedWeights) executes
//! into, so a *warm* request — same (or smaller) topology as one the
//! workspace has already served — performs **zero heap allocations** on
//! the execute path.  Tests pin this via [`Workspace::footprint`]
//! (buffer pointers and capacities must be stable across warm requests).
//!
//! Head-parallel execution gives each concurrent head lane its own
//! [`HeadScratch`], so lanes never share mutable state; the output is a
//! single buffer written in disjoint per-head column stripes (DESIGN.md
//! §10).

use crate::config::Topology;

/// One head lane's scratch: everything a single head's pipeline touches.
#[derive(Clone, Debug, Default)]
pub struct HeadScratch {
    /// i32 GEMM accumulator (SL × d_k), reused for Q, K and V in turn.
    pub(crate) acc: Vec<i32>,
    /// Dequantized projections (SL × d_k each).
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    /// Score matrix (SL × SL).
    pub(crate) s: Vec<f32>,
    /// Head output (SL × d_k) before the stripe copy into the request
    /// output.
    pub(crate) o: Vec<f32>,
}

impl HeadScratch {
    fn ensure(&mut self, sl: usize, dk: usize) {
        self.acc.resize(sl * dk, 0);
        self.q.resize(sl * dk, 0.0);
        self.k.resize(sl * dk, 0.0);
        self.v.resize(sl * dk, 0.0);
        self.s.resize(sl * sl, 0.0);
        self.o.resize(sl * dk, 0.0);
    }
}

/// Reusable execute-path arena: widened input, per-head lanes, output.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    /// Input widened to i16 once per request (SL × d_model), shared by
    /// all three projections of every head.
    pub(crate) x16: Vec<i16>,
    /// Per-head scratch lanes; a head-parallel execute with `l` lanes
    /// uses the first `l`, the serial path uses lane 0 for every head.
    pub(crate) lanes: Vec<HeadScratch>,
    /// Request output (SL × d_model, heads concatenated).
    pub(crate) out: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for `topo` with `lanes` head lanes.  `Vec::resize`
    /// never shrinks capacity, so buffers only grow: a warm call with a
    /// previously-seen (or smaller) topology allocates nothing.
    pub(crate) fn ensure(&mut self, topo: &Topology, lanes: usize) {
        let (sl, dm, dk) = (topo.seq_len, topo.d_model, topo.d_k());
        self.x16.resize(sl * dm, 0);
        self.out.resize(sl * dm, 0.0);
        if self.lanes.len() < lanes {
            self.lanes.resize_with(lanes, HeadScratch::default);
        }
        for lane in &mut self.lanes[..lanes] {
            lane.ensure(sl, dk);
        }
    }

    /// The output of the most recent `execute_into`/`execute_parallel`.
    pub fn output(&self) -> &[f32] {
        &self.out
    }

    /// Move the output out, leaving an empty buffer (the next warm call
    /// re-grows it — used by the allocating `execute` wrapper, not by
    /// serving paths).
    pub fn take_output(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.out)
    }

    /// (pointer, capacity) of every buffer.  The workspace-reuse tests
    /// assert this is stable across warm requests — the "zero heap
    /// allocations on the warm execute path" contract.
    pub fn footprint(&self) -> Vec<(usize, usize)> {
        let mut fp = vec![
            (self.x16.as_ptr() as usize, self.x16.capacity()),
            (self.out.as_ptr() as usize, self.out.capacity()),
        ];
        for l in &self.lanes {
            fp.push((l.acc.as_ptr() as usize, l.acc.capacity()));
            fp.push((l.q.as_ptr() as usize, l.q.capacity()));
            fp.push((l.k.as_ptr() as usize, l.k.capacity()));
            fp.push((l.v.as_ptr() as usize, l.v.capacity()));
            fp.push((l.s.as_ptr() as usize, l.s.capacity()));
            fp.push((l.o.as_ptr() as usize, l.o.capacity()));
        }
        fp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_grows_then_stays_put() {
        let mut ws = Workspace::new();
        let small = Topology::new(8, 64, 2, 16);
        let large = Topology::new(16, 64, 2, 16);
        ws.ensure(&large, 2);
        let fp = ws.footprint();
        assert_eq!(ws.lanes.len(), 2);
        assert_eq!(ws.x16.len(), 16 * 64);
        // Warm re-ensure (same + smaller topology): nothing moves.
        ws.ensure(&large, 2);
        assert_eq!(ws.footprint(), fp);
        ws.ensure(&small, 1);
        ws.ensure(&large, 2);
        assert_eq!(ws.footprint(), fp, "shrink + regrow must stay in capacity");
    }

    #[test]
    fn take_output_then_warm_up_again() {
        let mut ws = Workspace::new();
        let topo = Topology::new(4, 32, 2, 16);
        ws.ensure(&topo, 1);
        ws.out[0] = 7.0;
        let out = ws.take_output();
        assert_eq!(out.len(), 4 * 32);
        assert_eq!(out[0], 7.0);
        assert!(ws.output().is_empty());
        ws.ensure(&topo, 1);
        assert_eq!(ws.output().len(), 4 * 32);
    }
}
