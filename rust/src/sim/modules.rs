//! The three processing modules (Fig. 3), each with its HLS-scheduled
//! timing and its functional int8 datapath.
//!
//! Per attention head the fabric instantiates one of each:
//!
//! * [`QkvPm`] — Algorithm 1: per tile, MAC the (SL×TS) input block
//!   against the three (d_k×TS) weight tiles, accumulating Q/K/V.
//! * [`QkPm`] — Algorithm 2: S = Q·Kᵀ with the scale division folded in,
//!   then the softmax unit.
//! * [`SvPm`] — Algorithm 3: attention score = S·V.
//!
//! Timing follows the paper's schedule exactly (outer loop un-pipelined,
//! second loop pipelined II=1, innermost fully unrolled); the cycle
//! formulas are the same `LoopNest` instances the analytical model uses,
//! so the two stay consistent by construction.

use crate::fixed::simd;
use crate::fixed::{matmul_i32_fast, FxMatrix, KernelTier};
use crate::fpga::hls::{LoopNest, PipelinedLoop};

use super::softmax_unit::SoftmaxUnit;

/// Quantized weights + float biases for one attention head.
/// Weight rows are output features (d_k), columns the reduction (d_model),
/// as in Algorithm 1's `w_q[k][j]`.
#[derive(Clone, Debug)]
pub struct HeadParams {
    pub wq: FxMatrix,
    pub wk: FxMatrix,
    pub wv: FxMatrix,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
}

/// Extra pipeline stages of QKV_PM beyond the tile count: load 1 + mul 2 +
/// add 1 + store 1 (Section VII).
pub const PD_MHA_CONST: u64 = 5;
/// Bias-add pipeline depth: load + add + store.
pub const PD_BA: u64 = 3;

// ------------------------------------------------------------------ QKV_PM

/// Q/K/V generation module (Algorithm 1).
pub struct QkvPm {
    pub seq_len: usize,
    pub d_k: usize,
    pub tile_size: usize,
    pub n_tiles: usize,
}

impl QkvPm {
    pub fn new(seq_len: usize, d_k: usize, tile_size: usize, n_tiles: usize) -> Self {
        QkvPm { seq_len, d_k, tile_size, n_tiles }
    }

    /// PE count: the three MAC chains, inner-unrolled over the tile width.
    pub fn pe_count(&self) -> usize {
        3 * self.tile_size
    }

    /// Compute cycles for ONE tile iteration (eq. 9 without the tile
    /// repetition): [(d_k−1)·1 + PD_MHA] · SL, PD_MHA = n_tiles + 5.
    pub fn cycles_per_tile(&self) -> u64 {
        let pd = self.n_tiles as u64 + PD_MHA_CONST;
        LoopNest::new(PipelinedLoop::new(self.d_k as u64, 1, pd), self.seq_len as u64).latency()
    }

    /// Bias addition cycles (eq. 10).
    pub fn bias_cycles(&self) -> u64 {
        LoopNest::new(PipelinedLoop::new(self.d_k as u64, 1, PD_BA), self.seq_len as u64)
            .latency()
    }

    /// Functional path: exact int8→i32 tiled GEMM (the DSP48 datapath),
    /// then dequantize + bias in f32.  `x` is (SL × d_model) int8;
    /// `scale2` is the product of the x and w grid steps.
    pub fn run(&self, x: &FxMatrix, p: &HeadParams, scale2: f32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let deq = |acc: Vec<i32>, bias: &[f32]| -> Vec<f32> {
            let n = self.d_k;
            acc.iter()
                .enumerate()
                .map(|(idx, &v)| v as f32 * scale2 + bias[idx % n])
                .collect()
        };
        // matmul_i32_fast is bit-identical to the tiled schedule (exact
        // integer arithmetic); the tile schedule only matters for timing.
        let q = deq(matmul_i32_fast(x, &p.wq), &p.bq);
        let k = deq(matmul_i32_fast(x, &p.wk), &p.bk);
        let v = deq(matmul_i32_fast(x, &p.wv), &p.bv);
        (q, k, v)
    }

    /// Useful MACs issued per full run (3 projections).
    pub fn macs(&self, d_model: usize) -> u64 {
        3 * self.seq_len as u64 * d_model as u64 * self.d_k as u64
    }
}

// ------------------------------------------------------------------- QK_PM

/// Score module (Algorithm 2) with fused scale + softmax.
#[derive(Clone, Debug)]
pub struct QkPm {
    pub seq_len: usize,
    pub d_k: usize,
    pub softmax: SoftmaxUnit,
    /// Score scaling: eq. 1 uses 1/√d_k; Algorithm 2 line 9 divides by
    /// d_model.  Stored as a multiplier.
    pub scale: f32,
    /// Decoder masking (Section II's Masked Attention): row i attends
    /// only to columns <= i.
    pub causal: bool,
    /// Which score-kernel implementation runs (DESIGN.md §14).  Scalar
    /// by default, so every pre-existing call site stays the oracle.
    pub tier: KernelTier,
}

impl QkPm {
    pub fn new(seq_len: usize, d_k: usize, scale: f32, softmax: SoftmaxUnit) -> Self {
        QkPm { seq_len, d_k, softmax, scale, causal: false, tier: KernelTier::Scalar }
    }

    pub fn causal(seq_len: usize, d_k: usize, scale: f32, softmax: SoftmaxUnit) -> Self {
        QkPm { causal: true, ..Self::new(seq_len, d_k, scale, softmax) }
    }

    /// Select the kernel tier (builder style; prepare-time plumbing).
    pub fn with_tier(mut self, tier: KernelTier) -> Self {
        self.tier = tier;
        self
    }

    /// PE count: the unrolled dot product over d_k.
    pub fn pe_count(&self) -> usize {
        self.d_k
    }

    /// eq. 11: [(SL−1)·1 + PD_S] · SL with PD_S = d_k.
    pub fn cycles(&self) -> u64 {
        LoopNest::new(
            PipelinedLoop::new(self.seq_len as u64, 1, self.d_k as u64),
            self.seq_len as u64,
        )
        .latency()
    }

    /// S = softmax(scale · Q Kᵀ); Q,K are (SL × d_k) row-major f32.
    pub fn run(&self, q: &[f32], k: &[f32]) -> Vec<f32> {
        let mut s = vec![0f32; self.seq_len * self.seq_len];
        self.run_into(q, k, &mut s);
        s
    }

    /// [`Self::run`] into a caller-owned score buffer (SL × SL) — the
    /// allocation-free workspace path, built on [`blocked_score_row`]
    /// (4-wide column chains, per-(i, j) reduction order unchanged, so
    /// results are bit-identical to the scalar form).
    pub fn run_into(&self, q: &[f32], k: &[f32], s: &mut [f32]) {
        let (sl, dk) = (self.seq_len, self.d_k);
        assert_eq!(q.len(), sl * dk);
        assert_eq!(k.len(), sl * dk);
        assert_eq!(s.len(), sl * sl);
        for i in 0..sl {
            let qrow = &q[i * dk..(i + 1) * dk];
            let srow = &mut s[i * sl..(i + 1) * sl];
            blocked_score_row(qrow, k, dk, 0, srow, |j, acc| self.score(i, j, acc), self.tier);
        }
        self.softmax.rows(s, sl, sl);
    }

    #[inline]
    fn score(&self, i: usize, j: usize, acc: f32) -> f32 {
        if self.causal && j > i {
            -1e9 // decoder mask: future positions excluded
        } else {
            acc * self.scale
        }
    }

    pub fn macs(&self) -> u64 {
        (self.seq_len * self.seq_len * self.d_k) as u64
    }
}

/// One query row's raw scores against the key rows `[j0, j0 + srow.len())`,
/// written into `srow`: four independent accumulator chains per pass
/// over the Q row (ILP — strict FP semantics forbid vectorizing a
/// single f32 reduction, but not running four side by side), scalar
/// tail for the residue.  `score(j, acc)` finalizes each dot (scaling,
/// masking).  The per-(i, j) reduction order over `d_k` is the plain
/// sequential dot.
///
/// The single source of score arithmetic: [`QkPm::run_into`] calls it
/// over full rows and the fused tile stream
/// ([`super::fused::FusedAttnPm`]) over column tiles, which is what
/// keeps their pre-softmax scores bit-identical *by construction*
/// (DESIGN.md §12) — per tier: both paths route through this one
/// dispatch point with the same `tier`, so the fused/reference
/// invariant survives every tier.
///
/// For SIMD tiers the dot runs on [`simd::dot_f32`] — 8-lane partials
/// in a pinned fixed tree plus the ordered scalar tail.  That order is
/// deterministic but different from the scalar chains below, so tiers
/// are tolerance-equivalent, not bit-equal, on this one kernel
/// (DESIGN.md §14).  The scalar body is untouched: the bit-identity
/// oracle and the non-AVX2 fallback.
pub(crate) fn blocked_score_row<F: Fn(usize, f32) -> f32>(
    qrow: &[f32],
    k: &[f32],
    dk: usize,
    j0: usize,
    srow: &mut [f32],
    score: F,
    tier: KernelTier,
) {
    if tier != KernelTier::Scalar && KernelTier::Simd.is_available() {
        for (jj, s) in srow.iter_mut().enumerate() {
            let j = j0 + jj;
            let krow = &k[j * dk..(j + 1) * dk];
            *s = score(j, simd::dot_f32(qrow, krow));
        }
        return;
    }
    let tw = srow.len();
    let mut jj = 0;
    while jj + 4 <= tw {
        let j = j0 + jj;
        let k0 = &k[j * dk..(j + 1) * dk];
        let k1 = &k[(j + 1) * dk..(j + 2) * dk];
        let k2 = &k[(j + 2) * dk..(j + 3) * dk];
        let k3 = &k[(j + 3) * dk..(j + 4) * dk];
        let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
        for ((((&qv, &b0), &b1), &b2), &b3) in qrow.iter().zip(k0).zip(k1).zip(k2).zip(k3) {
            a0 += qv * b0;
            a1 += qv * b1;
            a2 += qv * b2;
            a3 += qv * b3;
        }
        for (off, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
            srow[jj + off] = score(j + off, acc);
        }
        jj += 4;
    }
    while jj < tw {
        let j = j0 + jj;
        let krow = &k[j * dk..(j + 1) * dk];
        let acc: f32 = qrow.iter().zip(krow).map(|(&a, &b)| a * b).sum();
        srow[jj] = score(j, acc);
        jj += 1;
    }
}

// ------------------------------------------------------------------- SV_PM

/// Weighted-value module (Algorithm 3).
#[derive(Clone, Debug)]
pub struct SvPm {
    pub seq_len: usize,
    pub d_k: usize,
    /// Axpy kernel tier.  All tiers are bit-identical here — the axpy
    /// vectorizes across independent output accumulators with one mul +
    /// one add per element (DESIGN.md §14).
    pub tier: KernelTier,
}

impl SvPm {
    pub fn new(seq_len: usize, d_k: usize) -> Self {
        SvPm { seq_len, d_k, tier: KernelTier::Scalar }
    }

    /// Select the kernel tier (builder style; prepare-time plumbing).
    pub fn with_tier(mut self, tier: KernelTier) -> Self {
        self.tier = tier;
        self
    }

    /// PE count: the unrolled dot product over SL.
    pub fn pe_count(&self) -> usize {
        self.seq_len
    }

    /// eq. 12: [(d_k−1)·1 + PD_SV] · SL with PD_SV = SL.
    pub fn cycles(&self) -> u64 {
        LoopNest::new(
            PipelinedLoop::new(self.d_k as u64, 1, self.seq_len as u64),
            self.seq_len as u64,
        )
        .latency()
    }

    /// O = S · V; S is (SL × SL), V is (SL × d_k), both row-major f32.
    pub fn run(&self, s: &[f32], v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.seq_len * self.d_k];
        self.run_into(s, v, &mut out);
        out
    }

    /// [`Self::run`] into a caller-owned output buffer (SL × d_k) — a
    /// branch-free streaming axpy: each score scales one V row into the
    /// output row, with no per-score `w == 0` test (the data-dependent
    /// branch defeated vectorization; the output elements are independent
    /// accumulators, so the inner loop vectorizes even under strict FP
    /// semantics).  Adding a `w == 0` term contributes `±0.0`, which
    /// changes no finite sum except the sign of an exact negative zero —
    /// see DESIGN.md §10.
    pub fn run_into(&self, s: &[f32], v: &[f32], out: &mut [f32]) {
        let (sl, dk) = (self.seq_len, self.d_k);
        assert_eq!(s.len(), sl * sl);
        assert_eq!(v.len(), sl * dk);
        assert_eq!(out.len(), sl * dk);
        for i in 0..sl {
            let orow = &mut out[i * dk..(i + 1) * dk];
            orow.fill(0.0);
            for (l, &w) in s[i * sl..(i + 1) * sl].iter().enumerate() {
                let vrow = &v[l * dk..(l + 1) * dk];
                simd::axpy_f32(self.tier, w, vrow, orow);
            }
        }
    }

    pub fn macs(&self) -> u64 {
        (self.seq_len * self.seq_len * self.d_k) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Quantizer;

    fn fx(data: Vec<i8>, rows: usize, cols: usize) -> FxMatrix {
        FxMatrix { rows, cols, data }
    }

    #[test]
    fn qkv_cycles_match_eq9_test1() {
        // Test 1 shape: d_k=96, SL=64, 12 tiles → (95+17)·64 = 7 168/tile.
        let m = QkvPm::new(64, 96, 64, 12);
        assert_eq!(m.cycles_per_tile(), 7_168);
        assert_eq!(m.bias_cycles(), (95 + 3) * 64);
        assert_eq!(m.pe_count(), 192);
    }

    #[test]
    fn qk_sv_cycles_match_eq11_eq12_test1() {
        let qk = QkPm::new(64, 96, 1.0, SoftmaxUnit::exact());
        assert_eq!(qk.cycles(), (63 + 96) * 64); // 10 176
        let sv = SvPm::new(64, 96);
        assert_eq!(sv.cycles(), (95 + 64) * 64); // 10 176
    }

    #[test]
    fn qkv_functional_matches_direct_gemm() {
        // x (2×4) @ w (3×4).T with grid scale 1: exact small integers.
        let x = fx(vec![1, 2, 3, 4, -1, 0, 2, 1], 2, 4);
        let w = fx(vec![1, 0, 0, 0, 0, 1, 0, 0, 1, 1, 1, 1], 3, 4);
        let p = HeadParams {
            wq: w.clone(),
            wk: w.clone(),
            wv: w,
            bq: vec![0.5, 0.0, -0.5],
            bk: vec![0.0; 3],
            bv: vec![0.0; 3],
        };
        let m = QkvPm::new(2, 3, 2, 2);
        let (q, k, _v) = m.run(&x, &p, 1.0);
        // row0: [1, 2, 10] + bias
        assert_eq!(q, vec![1.5, 2.0, 9.5, -0.5, 0.0, 1.5]);
        assert_eq!(k, vec![1.0, 2.0, 10.0, -1.0, 0.0, 2.0]);
    }

    #[test]
    fn qk_run_is_row_softmaxed() {
        let qk = QkPm::new(2, 2, 0.5, SoftmaxUnit::exact());
        let q = vec![1.0, 0.0, 0.0, 1.0];
        let k = vec![1.0, 0.0, 0.0, 1.0];
        let s = qk.run(&q, &k);
        // scores: [[.5,0],[0,.5]] -> softmax rows
        let e = 0.5f32.exp();
        let p0 = e / (e + 1.0);
        assert!((s[0] - p0).abs() < 1e-6);
        assert!((s[1] - (1.0 - p0)).abs() < 1e-6);
        assert!((s[0] + s[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sv_run_weighted_average() {
        let sv = SvPm::new(2, 2);
        // S = identity -> output = V.
        let s = vec![1.0, 0.0, 0.0, 1.0];
        let v = vec![3.0, -1.0, 2.0, 5.0];
        assert_eq!(sv.run(&s, &v), v);
        // uniform S -> rows average
        let s = vec![0.5, 0.5, 0.5, 0.5];
        assert_eq!(sv.run(&s, &v), vec![2.5, 2.0, 2.5, 2.0]);
    }

    #[test]
    fn blocked_kernels_bit_match_scalar_reference() {
        // The blocked QK kernel and the branchless SV axpy must be
        // bit-identical to the straightforward scalar algorithms they
        // replaced, for every column-block residue (sl % 4 ∈ {0..3}).
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for sl in [3usize, 4, 5, 6, 7, 8] {
            let dk = 5;
            let q: Vec<f32> = (0..sl * dk).map(|i| ((i * 13 % 31) as f32 - 15.0) / 16.0).collect();
            let k: Vec<f32> = (0..sl * dk).map(|i| ((i * 7 % 29) as f32 - 14.0) / 16.0).collect();
            let v: Vec<f32> = (0..sl * dk).map(|i| ((i * 11 % 23) as f32 - 11.0) / 16.0).collect();
            for causal in [false, true] {
                let qk = if causal {
                    QkPm::causal(sl, dk, 0.37, SoftmaxUnit::exact())
                } else {
                    QkPm::new(sl, dk, 0.37, SoftmaxUnit::exact())
                };
                // Pre-PR-3 scalar score path: one ordered dot per (i, j).
                let mut want_s = vec![0f32; sl * sl];
                for i in 0..sl {
                    for j in 0..sl {
                        let acc: f32 = q[i * dk..(i + 1) * dk]
                            .iter()
                            .zip(&k[j * dk..(j + 1) * dk])
                            .map(|(&a, &b)| a * b)
                            .sum();
                        want_s[i * sl + j] =
                            if causal && j > i { -1e9 } else { acc * qk.scale };
                    }
                }
                qk.softmax.rows(&mut want_s, sl, sl);
                let got_s = qk.run(&q, &k);
                assert_eq!(bits(&got_s), bits(&want_s), "QK sl={sl} causal={causal}");

                // Scalar axpy reference for SV (same summation order).
                let mut want_o = vec![0f32; sl * dk];
                for i in 0..sl {
                    for l in 0..sl {
                        let w = want_s[i * sl + l];
                        for j in 0..dk {
                            want_o[i * dk + j] += w * v[l * dk + j];
                        }
                    }
                }
                let sv = SvPm::new(sl, dk);
                let got_o = sv.run(&want_s, &v);
                assert_eq!(bits(&got_o), bits(&want_o), "SV sl={sl} causal={causal}");
            }
        }
    }

    #[test]
    fn causal_masks_future_positions() {
        let qk = QkPm::causal(3, 2, 1.0, SoftmaxUnit::exact());
        let q = vec![1.0, 0.0, 0.5, 0.5, 0.0, 1.0];
        let k = q.clone();
        let s = qk.run(&q, &k);
        // Row 0 attends only to position 0.
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert_eq!(&s[1..3], &[0.0, 0.0]);
        // Row 1: positions 0,1 only.
        assert_eq!(s[3 + 2], 0.0);
        assert!((s[3] + s[4] - 1.0).abs() < 1e-6);
        // Row 2: full attention, still stochastic.
        let sum: f32 = s[6..9].iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn causal_first_output_row_is_v_row0() {
        let qk = QkPm::causal(4, 2, 0.5, SoftmaxUnit::exact());
        let q: Vec<f32> = (0..8).map(|i| i as f32 / 8.0).collect();
        let s = qk.run(&q, &q);
        let v = vec![3.0, -1.0, 2.0, 5.0, 0.0, 1.0, -2.0, 4.0];
        let out = SvPm::new(4, 2).run(&s, &v);
        assert!((out[0] - 3.0).abs() < 1e-6);
        assert!((out[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn full_head_matches_float_reference() {
        // End-to-end single head vs a straightforward float computation.
        let qz = Quantizer::grid64();
        let xs: Vec<f32> = (0..4 * 8).map(|i| ((i * 7 % 33) as f32 - 16.0) / 64.0).collect();
        let ws: Vec<f32> = (0..2 * 8).map(|i| ((i * 11 % 33) as f32 - 16.0) / 64.0).collect();
        let x = FxMatrix::from_f32(&xs, 4, 8, &qz);
        let w = FxMatrix::from_f32(&ws, 2, 8, &qz);
        let p = HeadParams {
            wq: w.clone(),
            wk: w.clone(),
            wv: w.clone(),
            bq: vec![0.0; 2],
            bk: vec![0.0; 2],
            bv: vec![0.0; 2],
        };
        let scale2 = qz.scale * qz.scale;
        let qkv = QkvPm::new(4, 2, 4, 2);
        let (q, k, v) = qkv.run(&x, &p, scale2);
        let qk = QkPm::new(4, 2, 1.0 / (2f32).sqrt(), SoftmaxUnit::exact());
        let s = qk.run(&q, &k);
        let out = SvPm::new(4, 2).run(&s, &v);

        // float reference
        let mut q_ref = vec![0f32; 8];
        for i in 0..4 {
            for j in 0..2 {
                for l in 0..8 {
                    q_ref[i * 2 + j] += xs[i * 8 + l] * ws[j * 8 + l];
                }
            }
        }
        for (a, b) in q.iter().zip(&q_ref) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(out.len(), 8);
        // attention output rows are convex combos of V rows: bounded.
        let vmax = v.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let vmin = v.iter().fold(f32::INFINITY, |m, &x| m.min(x));
        for &o in &out {
            assert!(o <= vmax + 1e-5 && o >= vmin - 1e-5);
        }
    }
}
