//! The simulation engine: schedules the phase timeline, drives the
//! functional datapath, and emits per-phase cycle traces.

use crate::config::{AcceleratorConfig, Topology};
use crate::exec::PoolHandle;
use crate::fixed::{
    fold_weights_i8, matmul_i32_i8_blocked_into, matmul_i32_widened_blocked_into,
    matmul_i32_widened_into, verify_rows_i16, verify_rows_i8, widen_i16, widen_i16_into, FxMatrix,
    KernelTier, PackedBi16, PackedBi8, Quantizer,
};
use crate::jsonlite::Json;
use crate::testdata::MhaInputs;

use super::axi::AxiMaster;
use super::controller::{Controller, CtrlError};
use super::fault::{AccFault, FaultPlan};
use super::fused::{ExecPath, FusedAttnPm};
use super::modules::{QkPm, QkvPm, SvPm};
use super::softmax_unit::SoftmaxUnit;
use super::workspace::{HeadScratch, Workspace};

/// Scale convention for the QKᵀ scores (see ref.py's `scale_mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleMode {
    /// 1/√d_k — eq. 1 (matches the AOT'd artifacts).
    SqrtDk,
    /// 1/d_model — Algorithm 2 line 9's literal reading.
    DModel,
}

/// Simulator configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub build: AcceleratorConfig,
    /// Overlap tile loads with the previous tile's compute (double
    /// buffering).  `false` reproduces the paper's sequential equations.
    pub double_buffer: bool,
    /// LUT softmax bits; None = exact exponential.
    pub softmax_lut_bits: Option<u32>,
    pub scale_mode: ScaleMode,
    /// Decoder masked attention (Section II): restrict each position to
    /// preceding positions.  Functional-path only; the mask is free in
    /// fabric (the PEs skip nothing — dense schedule, as in the paper).
    pub causal: bool,
    /// Fixed control overhead (µB + AXI-lite), shared with the analytical
    /// model's C0.
    pub control_overhead: u64,
    /// Seeded SEU injection into the staged operands (DESIGN.md §15);
    /// `None` disables injection entirely.  The owning backend bumps the
    /// plan's epoch per prepare so transient faults clear on scrub.
    pub fault_plan: Option<FaultPlan>,
    /// Run the ABFT checksum verify on every projection GEMM (DESIGN.md
    /// §15).  On by default; the exec bench flips it off to measure the
    /// verify overhead in isolation.
    pub integrity_checks: bool,
}

impl SimConfig {
    pub fn u55c() -> Self {
        SimConfig {
            build: AcceleratorConfig::u55c_ts64(),
            double_buffer: false,
            softmax_lut_bits: None,
            scale_mode: ScaleMode::SqrtDk,
            causal: false,
            control_overhead: crate::analytical::LatencyModel::default().c0,
            fault_plan: None,
            integrity_checks: true,
        }
    }

    pub fn u200() -> Self {
        SimConfig { build: AcceleratorConfig::u200_ts64(), ..SimConfig::u55c() }
    }

    /// The long-sequence U55C build (`AcceleratorConfig::u55c_ts64_sl1024`):
    /// admits SL up to 1024, the regime the fused tile-streaming
    /// execute path (DESIGN.md §12) makes first-class.
    pub fn u55c_long() -> Self {
        SimConfig { build: AcceleratorConfig::u55c_ts64_sl1024(), ..SimConfig::u55c() }
    }
}

/// One phase occupancy on the cycle timeline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PhaseEvent {
    pub name: &'static str,
    /// Tile index for per-tile phases (u32::MAX for whole-run phases).
    pub tile: u32,
    pub start: u64,
    pub end: u64,
}

impl PhaseEvent {
    pub fn cycles(&self) -> u64 {
        self.end - self.start
    }
}

/// Full cycle trace of one run.
#[derive(Clone, Debug, Default)]
pub struct CycleTrace {
    pub events: Vec<PhaseEvent>,
}

impl CycleTrace {
    pub fn total(&self) -> u64 {
        self.events.iter().map(|e| e.end).max().unwrap_or(0)
    }

    /// Sum of cycles of all events named `name`.
    pub fn phase_cycles(&self, name: &str) -> u64 {
        self.events.iter().filter(|e| e.name == name).map(PhaseEvent::cycles).sum()
    }

    /// Compute-only latency (Table IV convention): everything that is not
    /// an off-chip load phase.
    pub fn compute_only(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| !matches!(e.name, "LI" | "LB" | "LIA" | "LWA"))
            .map(PhaseEvent::cycles)
            .sum()
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.events.iter().map(|e| {
            Json::obj([
                ("name", Json::from(e.name)),
                ("tile", Json::from(e.tile as f64)),
                ("start", Json::from(e.start as f64)),
                ("end", Json::from(e.end as f64)),
            ])
        }))
    }
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub topology: Topology,
    pub cycles: u64,
    pub latency_ms: f64,
    pub trace: CycleTrace,
    /// Functional output (SL × d_model, heads concatenated), if operands
    /// were supplied.
    pub output: Option<Vec<f32>>,
    /// Useful MACs issued by all PEs.
    pub macs: u64,
    /// Off-chip beats issued.
    pub hbm_beats: u64,
}

impl SimResult {
    /// Mean PE utilization: useful MACs / (PE slots × active cycles).
    pub fn pe_utilization(&self, pe_count: u64) -> f64 {
        if self.cycles == 0 || pe_count == 0 {
            return 0.0;
        }
        self.macs as f64 / (pe_count as f64 * self.cycles as f64)
    }
}

/// The simulator: one synthesized build, reprogrammable per run.
pub struct Simulator {
    pub config: SimConfig,
    pub controller: Controller,
}

impl Simulator {
    pub fn new(config: SimConfig) -> Self {
        let controller = Controller::new(config.build.clone());
        Simulator { config, controller }
    }

    /// The banked on-chip arrays one head instantiates for `topo`, with
    /// the partition factors HLS needs for conflict-free parallel access
    /// (Section IV.A: "data required simultaneously by a DSP are stored
    /// in separate BRAMs").  Used by the feasibility check below and by
    /// the resource ablations.
    pub fn head_bram_pool(topo: &Topology) -> crate::fpga::BramPool {
        Self::head_bram_pool_path(topo, ExecPath::Reference)
    }

    /// [`Self::head_bram_pool`] for an explicit attention datapath.  The
    /// fused tile stream never materializes the SL×SL score matrix — only
    /// an SL×TS stripe plus the per-row online-softmax state — so its `s`
    /// bank (and the V read pattern, TS-wide instead of SL-wide) is
    /// accounted at the stripe size.  Accounting SL×SL for `FusedTiled`
    /// would charge BRAM the path never instantiates.
    pub fn head_bram_pool_path(topo: &Topology, path: ExecPath) -> crate::fpga::BramPool {
        use crate::fpga::BramBank;
        let (sl, dk, ts) = (topo.seq_len as u64, topo.d_k() as u64, topo.tile_size as u64);
        let mut pool = crate::fpga::BramPool::default();
        // Weight tiles: partitioned along the tile width (inner unroll);
        // two-port banks need a factor of TS/2 for TS reads per cycle.
        for name in ["wq", "wk", "wv"] {
            pool.add(BramBank::new(name, dk * ts, 8, (ts as u32 / 2).max(1)));
        }
        // Input tile: shared by the three MAC chains, same partitioning.
        pool.add(BramBank::new("x", sl * ts, 8, (ts as u32 / 2).max(1)));
        // Q/K buffers: QK_PM's unrolled dot product reads d_k in parallel.
        pool.add(BramBank::new("q", sl * dk, 8, (dk as u32 / 2).max(1)));
        pool.add(BramBank::new("k", sl * dk, 8, (dk as u32 / 2).max(1)));
        match path {
            ExecPath::Reference => {
                // V + score: SV_PM reads SL values of V and S per cycle.
                pool.add(BramBank::new("v", sl * dk, 8, (sl as u32 / 2).max(1)));
                pool.add(BramBank::new("s", sl * sl, 8, (sl as u32 / 2).max(1)));
            }
            ExecPath::FusedTiled => {
                // The fused SV stage consumes one TS-wide column tile per
                // cycle, so V and the SL×TS score stripe partition by TS.
                pool.add(BramBank::new("v", sl * dk, 8, (ts as u32 / 2).max(1)));
                pool.add(BramBank::new("s", sl * ts, 8, (ts as u32 / 2).max(1)));
                // Online-softmax running state: (max, sum) per row, f32.
                pool.add(BramBank::new("mrow", sl * 2, 32, 1));
            }
        }
        pool
    }

    /// [`Self::head_bram_pool_path`] for an explicit [`KernelTier`]: the
    /// path variant above keeps the paper's uniform 8-bit fixed grid
    /// (Table I) and stays the default accounting; this variant charges
    /// each tier the operand widths its datapath actually stages.
    /// `Scalar`/`Simd` hold widened i16 weight/input tiles and stream f32
    /// Q/K/V through attention; `SimdInt8` narrows the weight/input side
    /// to i8 but still streams f32 attention operands; `SimdInt8Attn` on
    /// the fused path banks i8 Q/K/V — a quarter of the f32 stream, so
    /// roughly half the pool — which is what lets more heads (or a wider
    /// tile) fit on chip, the paper's memory-utilization argument carried
    /// through the attention stage (DESIGN.md §17).
    pub fn head_bram_pool_tier(
        topo: &Topology,
        path: ExecPath,
        tier: KernelTier,
    ) -> crate::fpga::BramPool {
        use crate::fpga::BramBank;
        let (sl, dk, ts) = (topo.seq_len as u64, topo.d_k() as u64, topo.tile_size as u64);
        // Weight/input tiles: i8 where the tier stages raw i8, widened
        // i16 otherwise (the scalar/simd staging copies).
        let ww = if tier.stages_i8() { 8 } else { 16 };
        // Attention operands: the int8-attention tier's fused stream
        // quantizes Q/K/V to i8 at projection output; every other tier
        // (and the reference path, which SimdInt8Attn serves in f32)
        // streams f32.
        let aw = if tier == KernelTier::SimdInt8Attn && path == ExecPath::FusedTiled {
            8
        } else {
            32
        };
        let mut pool = crate::fpga::BramPool::default();
        for name in ["wq", "wk", "wv"] {
            pool.add(BramBank::new(name, dk * ts, ww, (ts as u32 / 2).max(1)));
        }
        pool.add(BramBank::new("x", sl * ts, ww, (ts as u32 / 2).max(1)));
        pool.add(BramBank::new("q", sl * dk, aw, (dk as u32 / 2).max(1)));
        pool.add(BramBank::new("k", sl * dk, aw, (dk as u32 / 2).max(1)));
        match path {
            ExecPath::Reference => {
                pool.add(BramBank::new("v", sl * dk, aw, (sl as u32 / 2).max(1)));
                // Scores are f32 post-softmax weights on every tier.
                pool.add(BramBank::new("s", sl * sl, 32, (sl as u32 / 2).max(1)));
            }
            ExecPath::FusedTiled => {
                pool.add(BramBank::new("v", sl * dk, aw, (ts as u32 / 2).max(1)));
                // The stripe holds i32 accumulators / f32 absorbed
                // weights — 32-bit either way.
                pool.add(BramBank::new("s", sl * ts, 32, (ts as u32 / 2).max(1)));
                pool.add(BramBank::new("mrow", sl * 2, 32, 1));
            }
        }
        pool
    }

    /// Check that every module's parallel access pattern is conflict-free
    /// on the two-port banks (an II=1 schedule is otherwise impossible —
    /// the precondition of every latency formula here).
    pub fn check_bram_ports(topo: &Topology) -> Result<(), String> {
        Self::check_bram_ports_path(topo, ExecPath::Reference)
    }

    /// [`Self::check_bram_ports`] for an explicit attention datapath: the
    /// fused SV stage reads TS (not SL) operands per cycle, matched
    /// against the stripe-sized banks above.
    pub fn check_bram_ports_path(topo: &Topology, path: ExecPath) -> Result<(), String> {
        let pool = Self::head_bram_pool_path(topo, path);
        let sv_reads = match path {
            ExecPath::Reference => topo.seq_len as u32,
            ExecPath::FusedTiled => topo.tile_size as u32,
        };
        let worst = [
            ("QKV_PM tile reads", topo.tile_size as u32),
            ("QK_PM dot reads", topo.d_k() as u32),
            ("SV_PM dot reads", sv_reads),
        ];
        for (what, reads) in worst {
            for bank in &pool.banks {
                // Each pattern touches specific arrays; the conservative
                // check is against the matching partition class.
                if bank.partition * crate::fpga::bram::PORTS_PER_BANK >= reads {
                    continue;
                }
                // Only flag arrays actually read by this pattern width.
                let relevant = match what {
                    "QKV_PM tile reads" => matches!(bank.name.as_str(), "wq" | "wk" | "wv" | "x"),
                    "QK_PM dot reads" => matches!(bank.name.as_str(), "q" | "k"),
                    _ => matches!(bank.name.as_str(), "v" | "s"),
                };
                if relevant {
                    return Err(format!(
                        "{what}: {reads} parallel reads exceed {} ports on '{}'",
                        bank.partition * 2,
                        bank.name
                    ));
                }
            }
        }
        Ok(())
    }

    /// Timing-only run (no functional datapath) on the reference
    /// (score-materializing) attention schedule.
    pub fn run_timing(&mut self, topo: &Topology) -> Result<SimResult, CtrlError> {
        self.run_inner(topo, None, ExecPath::Reference)
    }

    /// Timing-only run on an explicit [`ExecPath`].  `Reference` keeps
    /// the paper's two sequential whole-matrix S/SV phases (eqs. 11-12);
    /// `FusedTiled` replays the tile-streaming schedule the fused
    /// execute path actually runs (DESIGN.md §12): per-tile `S(t)`/
    /// `SV(t)` events where the SV accumulation of tile t overlaps the
    /// score stripe of tile t+1 under the online softmax.
    pub fn run_timing_path(
        &mut self,
        topo: &Topology,
        path: ExecPath,
    ) -> Result<SimResult, CtrlError> {
        self.run_inner(topo, None, path)
    }

    /// Full run: timing + functional output from the int8 datapath.
    pub fn run(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<SimResult, CtrlError> {
        self.run_inner(topo, Some(inputs), ExecPath::Reference)
    }

    fn run_inner(
        &mut self,
        topo: &Topology,
        inputs: Option<&MhaInputs>,
        path: ExecPath,
    ) -> Result<SimResult, CtrlError> {
        self.controller.program(topo)?;
        self.controller.start()?;

        let sl = topo.seq_len as u64;
        let dm = topo.d_model as u64;
        let dk = topo.d_k() as u64;
        let ts = topo.tile_size as u64;
        let n_tiles = topo.n_tiles() as u64;

        let mut axi = AxiMaster::default();
        let mut trace = CycleTrace::default();
        let mut now = 0u64;
        let whole = u32::MAX;
        let push = |trace: &mut CycleTrace, name, tile, start, len| -> u64 {
            trace.events.push(PhaseEvent { name, tile, start, end: start + len });
            start + len
        };

        // Control phase: µB decodes the descriptor, writes registers,
        // sequences the start signal (calibrated C0, DESIGN.md §6).
        now = push(&mut trace, "CTRL", whole, now, self.config.control_overhead);
        // LI — whole input matrix (eq. 5).
        let li = axi.load_matrix(sl, dm);
        now = push(&mut trace, "LI", whole, now, li);
        // LB — per-head bias vectors, heads in parallel (eq. 6).
        let lb = axi.load_vector(dk);
        now = push(&mut trace, "LB", whole, now, lb);

        // Tile loop: loads then compute, optionally double-buffered.
        let qkv = QkvPm::new(sl as usize, dk as usize, ts as usize, n_tiles as usize);
        let mut compute_end = now;
        let mut load_end = now;
        for t in 0..n_tiles {
            // eq. 7: input tile, eq. 8: weight tile (literal shapes).
            let lia = AxiMaster::default().load_matrix(sl, ts);
            let lwa = AxiMaster::default().load_matrix(sl, dk);
            axi.beats += sl * ts + sl * dk;
            axi.busy_cycles += lia + lwa;
            let load_start = if self.config.double_buffer {
                // Loads for tile t proceed while tile t-1 computes.
                load_end.max(now)
            } else {
                compute_end.max(now)
            };
            let lia_end = push(&mut trace, "LIA", t as u32, load_start, lia);
            load_end = push(&mut trace, "LWA", t as u32, lia_end, lwa);
            let sa = qkv.cycles_per_tile();
            let sa_start = load_end.max(compute_end);
            compute_end = push(&mut trace, "SA", t as u32, sa_start, sa);
        }
        now = compute_end.max(load_end);

        // BA — bias addition (eq. 10).
        now = push(&mut trace, "BA", whole, now, qkv.bias_cycles());
        // S — QK_PM + softmax (eq. 11).
        let scale = match self.config.scale_mode {
            ScaleMode::SqrtDk => 1.0 / (dk as f32).sqrt(),
            ScaleMode::DModel => 1.0 / dm as f32,
        };
        let softmax = match self.config.softmax_lut_bits {
            Some(bits) => SoftmaxUnit::lut(bits),
            None => SoftmaxUnit::exact(),
        };
        let qk = if self.config.causal {
            QkPm::causal(sl as usize, dk as usize, scale, softmax)
        } else {
            QkPm::new(sl as usize, dk as usize, scale, softmax)
        };
        let sv = SvPm::new(sl as usize, dk as usize);
        match path {
            ExecPath::Reference => {
                now = push(&mut trace, "S", whole, now, qk.cycles());
                // SV — SV_PM (eq. 12).
                now = push(&mut trace, "SV", whole, now, sv.cycles());
            }
            ExecPath::FusedTiled => {
                // Tile-streaming attention (DESIGN.md §12): the key/value
                // range is walked in TS-wide column tiles.  S(t) fills the
                // SL×tw score stripe; because the stripe lives banked in
                // BRAM and rows carry independent online-softmax state,
                // the row and column loops flatten into one II=1 pipeline
                // (SL·tw trips, dot depth d_k) instead of re-filling the
                // d_k-deep pipeline per row as the materializing QK_PM
                // does.  SV(t) folds the stripe into the SL×d_k
                // accumulator (SL·d_k trips, tw-deep reduction).  The SV
                // unit lags the score unit by one tile: SV(t) overlaps
                // S(t+1), the online-softmax rescale breaking the
                // S→softmax→SV whole-matrix dependency eqs. 11-12 assume.
                let n_col = sl.div_ceil(ts);
                let mut s_end = now;
                let mut sv_end = now;
                for t in 0..n_col {
                    let tw = ts.min(sl - t * ts);
                    let s_len = crate::fpga::PipelinedLoop::new(sl * tw, 1, dk).latency();
                    let sv_len = crate::fpga::PipelinedLoop::new(sl * dk, 1, tw).latency();
                    s_end = push(&mut trace, "S", t as u32, s_end, s_len);
                    let sv_start = s_end.max(sv_end);
                    sv_end = push(&mut trace, "SV", t as u32, sv_start, sv_len);
                }
                now = sv_end;
            }
        }

        // Functional datapath (all heads; fabric runs them in parallel,
        // we compute them sequentially — same result).
        let output = inputs.map(|inp| {
            let prepared = PreparedWeights::prepare(&self.config, topo, inp);
            let x = prepared.quantize_input(&inp.x);
            prepared.execute(&x)
        });

        let macs = (qkv.macs(dm as usize) + qk.macs() + sv.macs()) * topo.heads as u64;
        self.controller.finish(now);

        Ok(SimResult {
            topology: topo.clone(),
            cycles: now,
            latency_ms: self.config.build.cycles_to_ms(now),
            trace,
            output,
            macs,
            hbm_beats: axi.beats,
        })
    }
}

/// One head's weights and biases, quantized once — the host-side
/// analogue of weight tiles staged in BRAM.  Scalar/Simd tiers stage the
/// pre-widened i16 copies (the i8 vectors stay empty); the i8-staging
/// tiers (`SimdInt8`, `SimdInt8Attn`) stage raw i8 weights only (half
/// the bytes, no widening pass) and leave the i16 copies empty.
///
/// Alongside the flat copies, the SIMD tiers stage packed block-major
/// copies ([`PackedBi8`]/[`PackedBi16`], DESIGN.md §17) — the
/// cache-blocked projection GEMM's operand home.  The flat copy remains
/// authoritative for the fault model: injection flips flat cells and the
/// packed mirror is rebuilt from them, so the two never disagree.
#[derive(Clone, Debug)]
pub struct PreparedHead {
    pub wq16: Vec<i16>,
    pub wk16: Vec<i16>,
    pub wv16: Vec<i16>,
    pub wq8: Vec<i8>,
    pub wk8: Vec<i8>,
    pub wv8: Vec<i8>,
    /// Packed block-major mirrors of the staged copies: `Simd` packs the
    /// widened i16 weights, the i8-staging tiers pack the raw i8
    /// weights, `Scalar` packs nothing (it stays the flat-kernel
    /// oracle).
    pub pwq8: Option<PackedBi8>,
    pub pwk8: Option<PackedBi8>,
    pub pwv8: Option<PackedBi8>,
    pub pwq16: Option<PackedBi16>,
    pub pwk16: Option<PackedBi16>,
    pub pwv16: Option<PackedBi16>,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
    /// ABFT column-sum folds of the *pristine* quantized weights
    /// ([`crate::fixed::abft`]), computed before any fault injection
    /// touches the staged copies.  Empty when integrity checks are off.
    pub cq: Vec<i64>,
    pub ck: Vec<i64>,
    pub cv: Vec<i64>,
    /// Armed accumulator upsets per projection (Q, K, V), drawn at
    /// prepare time by the device's [`FaultPlan`] and applied after the
    /// projection GEMM on every invocation.
    pub acc_faults: [Option<AccFault>; 3],
}

impl PreparedHead {
    /// (Re)build the packed block-major copies from the flat staged
    /// copies.  Called at prepare time *after* any fault plan has
    /// corrupted the flat staging, and again by the fault hooks after
    /// they flip a staged cell — packed and flat always agree, so the
    /// ABFT verify sees the same corrupted operands whichever GEMM
    /// driver runs.
    fn repack(&mut self, tier: KernelTier, dm: usize, dk: usize) {
        match tier {
            KernelTier::Scalar => {}
            KernelTier::Simd => {
                self.pwq16 = Some(PackedBi16::pack(&self.wq16, dm, dk));
                self.pwk16 = Some(PackedBi16::pack(&self.wk16, dm, dk));
                self.pwv16 = Some(PackedBi16::pack(&self.wv16, dm, dk));
            }
            KernelTier::SimdInt8 | KernelTier::SimdInt8Attn => {
                self.pwq8 = Some(PackedBi8::pack(&self.wq8, dm, dk));
                self.pwk8 = Some(PackedBi8::pack(&self.wk8, dm, dk));
                self.pwv8 = Some(PackedBi8::pack(&self.wv8, dm, dk));
            }
        }
    }
}

/// Topology-programmed weight state for the functional datapath: built
/// once per (topology, weight set), then executed against any number of
/// inputs.  Plain owned data (`Send + Sync`), so a batch path can share
/// one instance across worker threads via `Arc`.
///
/// Bit-identity contract: every execute flavor — allocating
/// ([`Self::execute`]), workspace ([`Self::execute_into`]) and
/// head-parallel ([`Self::execute_parallel`]) — runs the exact same
/// per-head pipeline ([`Self::run_head`]: exact-integer widened GEMM, the
/// same f32 dequant/softmax/SV op order), and each head writes a disjoint
/// `d_k`-wide output stripe, so outputs are byte-for-byte identical
/// however heads or requests are grouped or scheduled (DESIGN.md §10).
///
/// The contract is per [`ExecPath`] (DESIGN.md §12): `Reference` (the
/// default for every flavor above) is the bit-identity oracle;
/// `FusedTiled` — selected via the `*_path` variants — streams
/// attention over SL×TS column tiles with an online softmax and is
/// *tolerance-equivalent* to `Reference`
/// ([`super::fused::tolerance`]), itself bit-deterministic across
/// flavors, lanes and repeats for a fixed path.
///
/// Orthogonally, the contract is per [`KernelTier`] (DESIGN.md §14),
/// fixed at prepare time: `Scalar` is the oracle; `Simd` and `SimdInt8`
/// swap in the AVX2 kernels and are *tier-tolerance-equivalent* to it
/// ([`super::fused::tier_tolerance`]) — their integer projections stay
/// bit-identical to scalar, only the order-pinned f32 score dot
/// reassociates.  `Simd` and `SimdInt8` outputs are bit-identical to
/// *each other* (exact integer GEMMs feeding the same f32 code).  The
/// flavor bit-identity above holds within every (path, tier) pair.
///
/// `SimdInt8Attn` (DESIGN.md §17) extends the i8 operand stream through
/// the fused attention stage itself: Q/K/V are quantized to i8 at
/// projection output under per-head, per-request activation scales, the
/// score GEMM runs int8×int8→i32, and the SV fold streams i8 V tiles
/// through a dequantizing axpy.  Its fused path is *quantization-
/// tolerance-equivalent* to the f32 fused stream
/// ([`super::fused::attn_quant_tolerance`], bound via
/// [`Self::attn_quant_bound`]) and still bit-deterministic across
/// flavors, lanes and repeats; its `Reference` path runs the same f32
/// modules as `SimdInt8` and is bit-identical to it.
#[derive(Clone, Debug)]
pub struct PreparedWeights {
    pub topology: Topology,
    heads: Vec<PreparedHead>,
    /// Kernel tier every execute flavor runs (clamped to host support at
    /// prepare time, so attribution is honest on non-AVX2 hosts).
    tier: KernelTier,
    /// Product of the x and w quantization grid steps.
    scale2: f32,
    /// Score module (scale + softmax realization + masking), fixed at
    /// prepare time so warm executes rebuild nothing — a LUT softmax
    /// would otherwise re-allocate its table per request.
    qk: QkPm,
    sv: SvPm,
    /// Fused tile-streaming attention (same scale/softmax/masking, the
    /// build's TS as tile width), also fixed at prepare time.
    fused: FusedAttnPm,
}

impl PreparedWeights {
    /// Quantize + widen every head's weights for `topo` under `config`'s
    /// numerics (scale mode, softmax realization, masking), on the
    /// `Scalar` oracle tier.
    pub fn prepare(config: &SimConfig, topo: &Topology, inp: &MhaInputs) -> Self {
        Self::prepare_with_tier(config, topo, inp, KernelTier::Scalar)
    }

    /// [`Self::prepare`] on an explicit [`KernelTier`] (DESIGN.md §14).
    /// The tier is clamped to host support here — a SIMD-tier request
    /// on a non-AVX2 host prepares (and reports) `Scalar` — and fixed
    /// for the lifetime of the prepared weights, so every request
    /// against them runs the same kernels.  The i8-staging tiers
    /// (`SimdInt8`, `SimdInt8Attn`) stage raw i8 weights and skip the
    /// i16 widening copies entirely; the SIMD tiers additionally stage
    /// packed block-major copies for the cache-blocked projection GEMM
    /// (DESIGN.md §17).
    pub fn prepare_with_tier(
        config: &SimConfig,
        topo: &Topology,
        inp: &MhaInputs,
        tier: KernelTier,
    ) -> Self {
        let tier = tier.clamp_available();
        let (dmn, h, dkn) = (topo.d_model, topo.heads, topo.d_k());
        let quant = Quantizer::grid64();
        let score_scale = match config.scale_mode {
            ScaleMode::SqrtDk => 1.0 / (dkn as f32).sqrt(),
            ScaleMode::DModel => 1.0 / dmn as f32,
        };
        let int8 = tier.stages_i8();
        let mut heads: Vec<PreparedHead> = (0..h)
            .map(|head| {
                let wslice = |w: &[f32]| {
                    let w8 = quant.quantize_vec(&w[head * dkn * dmn..(head + 1) * dkn * dmn]);
                    // Fold the pristine operands before staging: the fault
                    // plan below only ever corrupts the staged copies, so
                    // the checksum is the ground truth injection is
                    // verified against.
                    let fold = if config.integrity_checks {
                        fold_weights_i8(&w8, dkn, dmn)
                    } else {
                        Vec::new()
                    };
                    if int8 {
                        (w8, Vec::new(), fold)
                    } else {
                        let w16 = widen_i16(&w8);
                        (Vec::new(), w16, fold)
                    }
                };
                let bslice = |b: &[f32]| {
                    b[head * dkn..(head + 1) * dkn]
                        .iter()
                        .map(|&v| quant.fake_quant(v))
                        .collect::<Vec<f32>>()
                };
                let (wq8, wq16, cq) = wslice(&inp.wq);
                let (wk8, wk16, ck) = wslice(&inp.wk);
                let (wv8, wv16, cv) = wslice(&inp.wv);
                PreparedHead {
                    wq16,
                    wk16,
                    wv16,
                    wq8,
                    wk8,
                    wv8,
                    pwq8: None,
                    pwk8: None,
                    pwv8: None,
                    pwq16: None,
                    pwk16: None,
                    pwv16: None,
                    bq: bslice(&inp.bq),
                    bk: bslice(&inp.bk),
                    bv: bslice(&inp.bv),
                    cq,
                    ck,
                    cv,
                    acc_faults: [None; 3],
                }
            })
            .collect();
        // Seeded SEU injection (DESIGN.md §15): corrupt the staged
        // copies only, after the pristine folds above were taken.  Draw
        // order is fixed (head-major, projection-minor, flip before
        // stripe), so a plan is byte-reproducible for a given epoch.
        if let Some(plan) = config.fault_plan {
            if plan.active() {
                let mut rng = plan.rng();
                let stripe_len = topo.seq_len * dkn;
                for hp in &mut heads {
                    for proj in 0..3 {
                        if rng.chance(plan.weight_flip_rate) {
                            let (w8, w16) = match proj {
                                0 => (&mut hp.wq8, &mut hp.wq16),
                                1 => (&mut hp.wk8, &mut hp.wk16),
                                _ => (&mut hp.wv8, &mut hp.wv16),
                            };
                            super::fault::flip_weight_bank(w8, w16, &mut rng);
                        }
                        if rng.chance(plan.stripe_rate) {
                            hp.acc_faults[proj] = Some(AccFault::draw(stripe_len, &mut rng));
                        }
                    }
                }
            }
        }
        // Pack the block-major GEMM copies only now, after the fault
        // plan above has (possibly) corrupted the flat staging — the
        // packed mirror must carry the same faults the verify is
        // expected to catch.
        for hp in &mut heads {
            hp.repack(tier, dmn, dkn);
        }
        let softmax = match config.softmax_lut_bits {
            Some(bits) => SoftmaxUnit::lut(bits),
            None => SoftmaxUnit::exact(),
        };
        let qk = if config.causal {
            QkPm::causal(topo.seq_len, dkn, score_scale, softmax.clone())
        } else {
            QkPm::new(topo.seq_len, dkn, score_scale, softmax.clone())
        };
        let fused = FusedAttnPm::new(
            topo.seq_len,
            dkn,
            topo.tile_size,
            score_scale,
            softmax,
            config.causal,
        );
        PreparedWeights {
            topology: topo.clone(),
            heads,
            tier,
            scale2: quant.scale * quant.scale,
            qk: qk.with_tier(tier),
            sv: SvPm::new(topo.seq_len, dkn).with_tier(tier),
            fused: fused.with_tier(tier),
        }
    }

    /// The kernel tier every execute flavor runs (already clamped to
    /// host support at prepare time).
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Number of prepared heads.
    pub fn head_count(&self) -> usize {
        self.heads.len()
    }

    /// Corrupt one staged weight cell of head `head`'s projection `proj`
    /// (0=Q, 1=K, 2=V): flip `bit` (0..8) at element `pos`, mirrored
    /// into whichever staged copy the tier keeps — the deterministic
    /// single-fault hook the property suite drives exhaustively (the
    /// seeded [`FaultPlan`] draws the same flip randomly).
    pub fn inject_weight_fault(&mut self, head: usize, proj: usize, pos: usize, bit: u32) {
        let (dmn, dkn) = (self.topology.d_model, self.topology.d_k());
        let tier = self.tier;
        let hp = &mut self.heads[head];
        let (w8, w16) = match proj {
            0 => (&mut hp.wq8, &mut hp.wq16),
            1 => (&mut hp.wk8, &mut hp.wk16),
            _ => (&mut hp.wv8, &mut hp.wv16),
        };
        super::fault::flip_bit(w8, w16, pos, bit);
        // Mirror the corruption into the packed block-major copy the
        // cache-blocked GEMM actually reads — otherwise the injected
        // fault would be invisible to the datapath (and to the ABFT
        // verify the property suite drives).
        hp.repack(tier, dmn, dkn);
    }

    /// Arm one accumulator upset on head `head`'s projection `proj`,
    /// applied after that projection's GEMM on every invocation (the
    /// test-hook twin of the plan's `stripe_rate` draws).
    pub fn arm_acc_fault(&mut self, head: usize, proj: usize, fault: AccFault) {
        self.heads[head].acc_faults[proj] = Some(fault);
    }

    /// Do two requests carry identical weight operands?  (A batch path
    /// may only share prepared buffers across requests whose weights are
    /// identical; `x` is free to differ.)
    pub fn same_weights(a: &MhaInputs, b: &MhaInputs) -> bool {
        a.wq == b.wq
            && a.wk == b.wk
            && a.wv == b.wv
            && a.bq == b.bq
            && a.bk == b.bk
            && a.bv == b.bv
    }

    /// Quantize one request's input operand for [`Self::execute`].
    pub fn quantize_input(&self, x: &[f32]) -> FxMatrix {
        FxMatrix::from_f32(x, self.topology.seq_len, self.topology.d_model, &Quantizer::grid64())
    }

    /// The extended quantization tolerance of the `SimdInt8Attn` fused
    /// path against the f32 fused stream for request `x`, maxed over
    /// heads ([`super::fused::attn_quant_tolerance`], DESIGN.md §17).
    /// Runs the projections once to recover the per-head operand maxima
    /// that `run_into_quant` fits its activation scales from — the exact
    /// quantities the bound is parameterized by — so tests and benches
    /// get a sound, finite oracle without reaching into lane scratch.
    pub fn attn_quant_bound(&self, x: &FxMatrix) -> f32 {
        let topo = &self.topology;
        let (sln, dmn, dkn) = (topo.seq_len, topo.d_model, topo.d_k());
        assert_eq!(x.rows, sln, "input rows != SL");
        assert_eq!(x.cols, dmn, "input cols != d_model");
        let mut ws = Workspace::new();
        ws.ensure(topo, 1, ExecPath::FusedTiled, self.tier);
        if !self.tier.stages_i8() {
            widen_i16_into(&x.data, &mut ws.x16);
        }
        let Workspace { x16, lanes, .. } = &mut ws;
        let lane = &mut lanes[0];
        let amax = |xs: &[f32]| xs.iter().fold(0f32, |m, v| m.max(v.abs()));
        let mut bound = 0f32;
        for head in 0..self.heads.len() {
            self.run_head(head, &x.data, x16, lane, ExecPath::FusedTiled);
            let tol = super::fused::attn_quant_tolerance(
                self.fused.softmax.kind,
                sln,
                dmn,
                dkn,
                self.fused.scale,
                amax(&lane.q),
                amax(&lane.k),
                amax(&lane.v),
            );
            bound = bound.max(tol);
        }
        bound
    }

    /// Run one request through the functional datapath (all heads) against
    /// the prepared weights.  Allocating wrapper over
    /// [`Self::execute_into`]; serving paths hold a [`Workspace`] instead.
    pub fn execute(&self, x: &FxMatrix) -> Vec<f32> {
        self.execute_path(x, ExecPath::Reference)
    }

    /// [`Self::execute`] on an explicit attention datapath.
    pub fn execute_path(&self, x: &FxMatrix, path: ExecPath) -> Vec<f32> {
        let mut ws = Workspace::new();
        self.execute_into_path(x, &mut ws, path);
        ws.take_output()
    }

    /// Serial execute into a reusable workspace: heads run one after
    /// another through lane 0.  A warm call (workspace already sized for
    /// this or a larger topology) performs zero heap allocations.
    pub fn execute_into(&self, x: &FxMatrix, ws: &mut Workspace) {
        self.execute_into_path(x, ws, ExecPath::Reference)
    }

    /// [`Self::execute_into`] on an explicit attention datapath
    /// (DESIGN.md §12): `Reference` materializes SL×SL scores and is the
    /// bit-identity oracle; `FusedTiled` streams SL×TS column tiles with
    /// an online softmax and never sizes an SL×SL buffer in `ws`.
    pub fn execute_into_path(&self, x: &FxMatrix, ws: &mut Workspace, path: ExecPath) {
        let topo = &self.topology;
        let (sln, dmn, dkn) = (topo.seq_len, topo.d_model, topo.d_k());
        assert_eq!(x.rows, sln, "input rows != SL");
        assert_eq!(x.cols, dmn, "input cols != d_model");
        ws.ensure(topo, 1, path, self.tier);
        if !self.tier.stages_i8() {
            widen_i16_into(&x.data, &mut ws.x16);
        }
        let Workspace { x16, lanes, out, .. } = ws;
        let x16: &[i16] = x16.as_slice();
        let x8: &[i8] = &x.data;
        let lane = &mut lanes[0];
        for head in 0..self.heads.len() {
            self.run_head(head, x8, x16, lane, path);
            // Concatenate along features: out[:, head*dk..(head+1)*dk].
            for i in 0..sln {
                out[i * dmn + head * dkn..i * dmn + (head + 1) * dkn]
                    .copy_from_slice(&lane.o[i * dkn..(i + 1) * dkn]);
            }
        }
    }

    /// Head-parallel execute: heads are dealt round-robin onto `lanes`
    /// scratch lanes and run concurrently on `pool`, each writing its
    /// disjoint `d_k`-wide stripe of every output row.  Bit-identical to
    /// [`Self::execute_into`]: the per-head pipeline is the same code and
    /// stripe writes never overlap, so scheduling cannot reorder any
    /// floating-point operation *within* a head (DESIGN.md §10).
    pub fn execute_parallel(
        &self,
        x: &FxMatrix,
        ws: &mut Workspace,
        pool: &PoolHandle,
        lanes: usize,
    ) {
        self.execute_parallel_path(x, ws, pool, lanes, ExecPath::Reference)
    }

    /// [`Self::execute_parallel`] on an explicit attention datapath.
    /// Head parallelism composes with the fused path unchanged: each
    /// lane streams its heads' tiles independently, so for a fixed path
    /// the output is bit-identical to the serial flavor of that path.
    pub fn execute_parallel_path(
        &self,
        x: &FxMatrix,
        ws: &mut Workspace,
        pool: &PoolHandle,
        lanes: usize,
        path: ExecPath,
    ) {
        let topo = &self.topology;
        let (sln, dmn, dkn, h) = (topo.seq_len, topo.d_model, topo.d_k(), topo.heads);
        let lanes = lanes.clamp(1, h);
        if lanes <= 1 {
            return self.execute_into_path(x, ws, path);
        }
        assert_eq!(x.rows, sln, "input rows != SL");
        assert_eq!(x.cols, dmn, "input cols != d_model");
        ws.ensure(topo, lanes, path, self.tier);
        if !self.tier.stages_i8() {
            widen_i16_into(&x.data, &mut ws.x16);
        }
        let Workspace { x16, lanes: scratch, out, .. } = ws;
        let x16: &[i16] = x16.as_slice();
        let x8: &[i8] = &x.data;
        let out_ptr = StripePtr(out.as_mut_ptr());
        let f = |lane_idx: usize, lane: &mut HeadScratch| {
            for head in (lane_idx..h).step_by(lanes) {
                self.run_head(head, x8, x16, lane, path);
                // SAFETY: each head owns the disjoint column stripe
                // [head·d_k, (head+1)·d_k) of every output row, and each
                // head is processed by exactly one lane (head ≡ lane_idx
                // mod lanes), so no two lanes write the same element; the
                // pointer outlives the jobs because scoped_mut joins every
                // job before returning.
                unsafe {
                    for i in 0..sln {
                        std::ptr::copy_nonoverlapping(
                            lane.o.as_ptr().add(i * dkn),
                            out_ptr.0.add(i * dmn + head * dkn),
                            dkn,
                        );
                    }
                }
            }
        };
        pool.scoped_mut(&mut scratch[..lanes], &f);
    }

    /// One head through QKV → scores → SV, entirely inside `lane`.  The
    /// single source of per-head arithmetic — every execute flavor calls
    /// this, which is what makes them bit-identical for a fixed `path`
    /// and tier.  The projections dispatch on the tier (all three GEMMs
    /// produce identical i32 accumulators — exact integer arithmetic);
    /// the attention stage dispatches on the path (reference modules vs
    /// the fused tile stream), with the tier threaded into each module's
    /// f32 kernels at prepare time.
    fn run_head(
        &self,
        head: usize,
        x8: &[i8],
        x16: &[i16],
        lane: &mut HeadScratch,
        path: ExecPath,
    ) {
        let topo = &self.topology;
        let (sln, dmn, dkn) = (topo.seq_len, topo.d_model, topo.d_k());
        let hp = &self.heads[head];
        // Projection GEMM by projection index (0=Q, 1=K, 2=V): the
        // scalar oracle keeps the flat widened kernel; the SIMD tiers
        // run the cache-blocked drivers over the packed block-major
        // copies staged at prepare time (bit-identical accumulators —
        // exact integer arithmetic in any block order).
        let gemm = |proj: usize, acc: &mut [i32]| {
            let (w16, p16, p8) = match proj {
                0 => (&hp.wq16, &hp.pwq16, &hp.pwq8),
                1 => (&hp.wk16, &hp.pwk16, &hp.pwk8),
                _ => (&hp.wv16, &hp.pwv16, &hp.pwv8),
            };
            match self.tier {
                KernelTier::Scalar => matmul_i32_widened_into(x16, w16, sln, dmn, dkn, acc),
                KernelTier::Simd => {
                    let pb = p16.as_ref().expect("Simd tier stages packed i16");
                    matmul_i32_widened_blocked_into(x16, pb, sln, acc)
                }
                KernelTier::SimdInt8 | KernelTier::SimdInt8Attn => {
                    let pb = p8.as_ref().expect("i8 tiers stage packed i8");
                    matmul_i32_i8_blocked_into(x8, pb, sln, acc)
                }
            }
        };
        // ABFT row verify against the pristine fold (exact integer
        // arithmetic, so the check is tier-independent); a no-op when
        // integrity checks were off at prepare time (empty fold).
        let verify = |acc: &[i32], fold: &[i64]| -> u32 {
            if fold.is_empty() {
                return 0;
            }
            if self.tier.stages_i8() {
                verify_rows_i8(acc, x8, fold, sln, dkn)
            } else {
                verify_rows_i16(acc, x16, fold, sln, dkn)
            }
        };
        gemm(0, &mut lane.acc);
        if let Some(f) = hp.acc_faults[0] {
            lane.acc[f.pos] ^= f.mask;
        }
        lane.faults += verify(&lane.acc, &hp.cq);
        dequant_into(&lane.acc, &hp.bq, self.scale2, dkn, &mut lane.q);
        gemm(1, &mut lane.acc);
        if let Some(f) = hp.acc_faults[1] {
            lane.acc[f.pos] ^= f.mask;
        }
        lane.faults += verify(&lane.acc, &hp.ck);
        dequant_into(&lane.acc, &hp.bk, self.scale2, dkn, &mut lane.k);
        gemm(2, &mut lane.acc);
        if let Some(f) = hp.acc_faults[2] {
            lane.acc[f.pos] ^= f.mask;
        }
        lane.faults += verify(&lane.acc, &hp.cv);
        dequant_into(&lane.acc, &hp.bv, self.scale2, dkn, &mut lane.v);
        match path {
            ExecPath::Reference => {
                self.qk.run_into(&lane.q, &lane.k, &mut lane.s);
                self.sv.run_into(&lane.s, &lane.v, &mut lane.o);
            }
            ExecPath::FusedTiled if self.tier == KernelTier::SimdInt8Attn => {
                // The int8 attention stage (DESIGN.md §17): quantize
                // Q/K/V under per-head activation scales fitted for this
                // request, score in int8×int8→i32, dequantize once per
                // score row into the online-softmax absorb, stream i8 V
                // tiles through the dequantizing axpy.
                self.fused.run_into_quant(
                    &lane.q,
                    &lane.k,
                    &lane.v,
                    &mut lane.q8,
                    &mut lane.k8,
                    &mut lane.v8,
                    &mut lane.s32,
                    &mut lane.stripe,
                    &mut lane.rows,
                    &mut lane.o,
                );
            }
            ExecPath::FusedTiled => {
                self.fused.run_into(
                    &lane.q,
                    &lane.k,
                    &lane.v,
                    &mut lane.stripe,
                    &mut lane.rows,
                    &mut lane.o,
                );
            }
        }
    }
}

/// `Send + Sync` wrapper for the shared output pointer of the
/// head-parallel path; lanes write disjoint stripes (see the SAFETY note
/// in [`PreparedWeights::execute_parallel`]).
struct StripePtr(*mut f32);
unsafe impl Send for StripePtr {}
unsafe impl Sync for StripePtr {}

/// Dequantize an i32 GEMM accumulator into f32 with per-feature bias —
/// identical element order and arithmetic to the pre-workspace path.
fn dequant_into(acc: &[i32], bias: &[f32], scale2: f32, dk: usize, out: &mut [f32]) {
    for (idx, (o, &a)) in out.iter_mut().zip(acc).enumerate() {
        *o = a as f32 * scale2 + bias[idx % dk];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::LatencyModel;

    fn t1() -> Topology {
        Topology::new(64, 768, 8, 64)
    }

    #[test]
    fn sim_agrees_with_analytical_model_exactly() {
        // Same structure, same constants → identical totals (sequential
        // mode).  This is the §VII "model validates experiment" loop.
        let model = LatencyModel::default();
        for topo in [
            t1(),
            Topology::new(64, 768, 4, 64),
            Topology::new(64, 512, 8, 64),
            Topology::new(128, 768, 8, 64),
            Topology::new(16, 768, 8, 64),
        ] {
            let mut sim = Simulator::new(SimConfig::u55c());
            let got = sim.run_timing(&topo).unwrap().cycles;
            let want = model.predict(&topo).total_cycles();
            assert_eq!(got, want, "{topo}");
        }
    }

    #[test]
    fn headline_latency_reproduced() {
        let mut sim = Simulator::new(SimConfig::u55c());
        let r = sim.run_timing(&t1()).unwrap();
        assert!((r.latency_ms - 0.94).abs() < 0.01, "{}", r.latency_ms);
    }

    #[test]
    fn double_buffer_is_faster_and_bounded() {
        let mut seq = Simulator::new(SimConfig::u55c());
        let base = seq.run_timing(&t1()).unwrap().cycles;
        let mut db = Simulator::new(SimConfig { double_buffer: true, ..SimConfig::u55c() });
        let over = db.run_timing(&t1()).unwrap().cycles;
        assert!(over < base);
        // Overlap can at most hide the smaller of loads/compute per tile.
        let min_possible = base
            - LatencyModel::with_overlap(1.0).predict(&t1()).phases.overlap_saved;
        assert!(over >= min_possible, "over={over} min={min_possible}");
    }

    #[test]
    fn trace_phases_cover_total() {
        let mut sim = Simulator::new(SimConfig::u55c());
        let r = sim.run_timing(&t1()).unwrap();
        assert_eq!(r.trace.total(), r.cycles);
        // Sequential mode: phase cycles sum to the total.
        let sum: u64 = r.trace.events.iter().map(PhaseEvent::cycles).sum();
        assert_eq!(sum, r.cycles);
        for name in ["CTRL", "LI", "LB", "LIA", "LWA", "SA", "BA", "S", "SV"] {
            assert!(r.trace.phase_cycles(name) > 0, "missing {name}");
        }
    }

    #[test]
    fn fused_timing_beats_reference_at_long_sl() {
        // The headline ISSUE-9 acceptance: the fused tile stream must
        // model strictly faster than the materializing reference from
        // SL=256 up — the regime the auto policy routes to it.  Billing
        // fused executions at reference latency (the pre-fix behavior)
        // is exactly the mis-modeling arXiv 2208.03646 flags at long SL.
        for sl in [256usize, 512, 1024] {
            let topo = Topology::new(sl, 768, 8, 64);
            let reference = Simulator::new(SimConfig::u55c_long())
                .run_timing_path(&topo, ExecPath::Reference)
                .unwrap();
            let fused = Simulator::new(SimConfig::u55c_long())
                .run_timing_path(&topo, ExecPath::FusedTiled)
                .unwrap();
            assert!(
                fused.cycles < reference.cycles,
                "SL={sl}: fused {} cycles not below reference {}",
                fused.cycles,
                reference.cycles
            );
            assert!(fused.latency_ms < reference.latency_ms, "SL={sl}");
        }
    }

    #[test]
    fn fused_trace_has_per_tile_sv_overlap() {
        let topo = Topology::new(512, 768, 8, 64);
        let mut sim = Simulator::new(SimConfig::u55c_long());
        let r = sim.run_timing_path(&topo, ExecPath::FusedTiled).unwrap();
        let s: Vec<&PhaseEvent> = r.trace.events.iter().filter(|e| e.name == "S").collect();
        let sv: Vec<&PhaseEvent> = r.trace.events.iter().filter(|e| e.name == "SV").collect();
        let n_col = 512 / 64;
        assert_eq!(s.len(), n_col, "one S stripe per column tile");
        assert_eq!(sv.len(), n_col, "one SV fold per column tile");
        for (t, (se, sve)) in s.iter().zip(&sv).enumerate() {
            assert_eq!(se.tile, t as u32);
            assert_eq!(sve.tile, t as u32);
            // Dependency order within a tile: the stripe exists before
            // it is folded, and folds retire in tile order.
            assert!(sve.start >= se.end, "tile {t}: SV started before its S finished");
        }
        // The online-softmax overlap: SV(0) runs concurrently with S(1).
        assert!(
            sv[0].start < s[1].end && s[1].start < sv[0].end,
            "SV(0) [{}, {}) does not overlap S(1) [{}, {})",
            sv[0].start,
            sv[0].end,
            s[1].start,
            s[1].end
        );
        // And the timeline is genuinely concurrent: summed phase cycles
        // exceed the critical-path total (impossible in a sequential
        // schedule, where trace_phases_cover_total pins equality).
        let sum: u64 = r.trace.events.iter().map(PhaseEvent::cycles).sum();
        assert!(sum > r.trace.total(), "no overlap anywhere in the fused trace");
    }

    #[test]
    fn reference_path_timing_unchanged_by_path_dispatch() {
        // run_timing == run_timing_path(Reference): the ExecPath-aware
        // refactor must not perturb the validated reference schedule.
        let topo = t1();
        let a = Simulator::new(SimConfig::u55c()).run_timing(&topo).unwrap();
        let b = Simulator::new(SimConfig::u55c())
            .run_timing_path(&topo, ExecPath::Reference)
            .unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.trace.events, b.trace.events);
    }

    #[test]
    fn compute_only_matches_table4() {
        let mut sim = Simulator::new(SimConfig::u55c());
        let r = sim.run_timing(&t1()).unwrap();
        let ms = self::ms(&sim, r.trace.compute_only());
        assert!((ms - 0.494).abs() / 0.494 < 0.10, "{ms}");
    }

    fn ms(sim: &Simulator, cycles: u64) -> f64 {
        sim.config.build.cycles_to_ms(cycles)
    }

    #[test]
    fn functional_output_matches_tiny_reference() {
        // 2-head toy case verified against sim::modules' float math.
        let topo = Topology::new(4, 32, 2, 16);
        let inputs = MhaInputs::generate(&topo);
        let mut sim = Simulator::new(Simulator::toy_config());
        let r = sim.run(&topo, &inputs).unwrap();
        let out = r.output.unwrap();
        assert_eq!(out.len(), 4 * 32);
        assert!(out.iter().all(|v| v.is_finite()));
        // Output rows are convex combinations of V rows -> bounded by
        // the value projection range; |v| <= dk * max|x||w| + |b| is loose
        // but finite. Just pin determinism:
        let r2 = Simulator::new(Simulator::toy_config()).run(&topo, &inputs).unwrap();
        assert_eq!(out, r2.output.unwrap());
    }

    impl Simulator {
        /// Small synthesized build admitting toy topologies (tests only).
        pub fn toy_config() -> SimConfig {
            let mut c = SimConfig::u55c();
            c.build.tile_size = 16;
            c.build.max_topology = Topology::new(128, 768, 8, 16);
            c
        }
    }

    #[test]
    fn prepared_path_matches_module_path() {
        // The prepared-weight datapath (program once, execute many) must
        // agree bit-for-bit with the per-head module path — the invariant
        // the batched serving path rests on.
        use super::super::modules::HeadParams;
        let topo = Topology::new(8, 64, 2, 16);
        let inputs = MhaInputs::generate(&topo);
        let cfg = SimConfig::u55c();
        let prepared = PreparedWeights::prepare(&cfg, &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let got = prepared.execute(&x);

        let (sln, dmn, h, dkn) = (topo.seq_len, topo.d_model, topo.heads, topo.d_k());
        let quant = Quantizer::grid64();
        let scale2 = quant.scale * quant.scale;
        let xq = FxMatrix::from_f32(&inputs.x, sln, dmn, &quant);
        let qkv = QkvPm::new(sln, dkn, topo.tile_size, topo.n_tiles());
        let qk = QkPm::new(sln, dkn, 1.0 / (dkn as f32).sqrt(), SoftmaxUnit::exact());
        let sv = SvPm::new(sln, dkn);
        let mut want = vec![0f32; sln * dmn];
        for head in 0..h {
            let wslice = |w: &[f32]| {
                FxMatrix::from_f32(&w[head * dkn * dmn..(head + 1) * dkn * dmn], dkn, dmn, &quant)
            };
            let bslice = |b: &[f32]| {
                b[head * dkn..(head + 1) * dkn]
                    .iter()
                    .map(|&v| quant.fake_quant(v))
                    .collect::<Vec<f32>>()
            };
            let params = HeadParams {
                wq: wslice(&inputs.wq),
                wk: wslice(&inputs.wk),
                wv: wslice(&inputs.wv),
                bq: bslice(&inputs.bq),
                bk: bslice(&inputs.bk),
                bv: bslice(&inputs.bv),
            };
            let (q, k, v) = qkv.run(&xq, &params, scale2);
            let s = qk.run(&q, &k);
            let o = sv.run(&s, &v);
            for i in 0..sln {
                want[i * dmn + head * dkn..i * dmn + (head + 1) * dkn]
                    .copy_from_slice(&o[i * dkn..(i + 1) * dkn]);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn execute_flavors_bit_identical() {
        use crate::exec::ThreadPool;
        let topo = Topology::new(6, 64, 4, 16);
        let inputs = MhaInputs::generate(&topo);
        for (causal, lut) in [(false, None), (true, None), (false, Some(8))] {
            let mut cfg = SimConfig::u55c();
            cfg.causal = causal;
            cfg.softmax_lut_bits = lut;
            let prepared = PreparedWeights::prepare(&cfg, &topo, &inputs);
            let x = prepared.quantize_input(&inputs.x);
            let want = prepared.execute(&x);
            let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let mut ws = Workspace::new();
            prepared.execute_into(&x, &mut ws);
            assert_eq!(bits(ws.output()), bits(&want), "serial workspace diverged");
            for threads in [1, 3] {
                let pool = ThreadPool::new(threads);
                for lanes in [1, 2, 3, 4, 9] {
                    let mut wsp = Workspace::new();
                    prepared.execute_parallel(&x, &mut wsp, &pool.handle(), lanes);
                    assert_eq!(
                        bits(wsp.output()),
                        bits(&want),
                        "head-parallel diverged (threads={threads}, lanes={lanes})"
                    );
                }
            }
        }
    }

    #[test]
    fn warm_execute_reuses_every_buffer() {
        let topo = Topology::new(8, 64, 2, 16);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&SimConfig::u55c(), &topo, &inputs);
        let x1 = prepared.quantize_input(&inputs.x);
        let mut inp2 = inputs.clone();
        inp2.x = crate::testdata::gen_matrix(42, topo.seq_len, topo.d_model);
        let x2 = prepared.quantize_input(&inp2.x);
        let mut ws = Workspace::new();
        prepared.execute_into(&x1, &mut ws);
        let fp = ws.footprint();
        prepared.execute_into(&x2, &mut ws);
        assert_eq!(ws.footprint(), fp, "warm request reallocated a buffer");
        prepared.execute_into(&x1, &mut ws);
        assert_eq!(ws.footprint(), fp);
        assert_eq!(ws.output(), prepared.execute(&x1));
    }

    #[test]
    fn fused_path_matches_reference_within_tolerance() {
        // The tentpole numerics policy (DESIGN.md §12): fused is
        // tolerance-equivalent to the reference oracle for both softmax
        // realizations, masked and dense, across head counts.
        use super::super::fused::assert_within_tolerance;
        let topo = Topology::new(12, 64, 4, 16);
        let inputs = MhaInputs::generate(&topo);
        for (causal, lut) in [(false, None), (true, None), (false, Some(8)), (true, Some(8))] {
            let mut cfg = Simulator::toy_config();
            cfg.causal = causal;
            cfg.softmax_lut_bits = lut;
            let prepared = PreparedWeights::prepare(&cfg, &topo, &inputs);
            let x = prepared.quantize_input(&inputs.x);
            let want = prepared.execute(&x);
            let got = prepared.execute_path(&x, ExecPath::FusedTiled);
            let kind = prepared.fused.softmax.kind;
            assert_within_tolerance(
                kind,
                topo.seq_len,
                &want,
                &got,
                &format!("causal={causal} lut={lut:?}"),
            );
        }
    }

    #[test]
    fn fused_flavors_bit_identical_to_each_other() {
        // For a fixed path the flavor contract is unchanged: serial
        // workspace, head-parallel (any lanes/threads) and repeat runs
        // of the fused path are byte-for-byte identical.
        use crate::exec::ThreadPool;
        let topo = Topology::new(10, 64, 4, 16);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&Simulator::toy_config(), &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let want = prepared.execute_path(&x, ExecPath::FusedTiled);
        assert_eq!(
            bits(&prepared.execute_path(&x, ExecPath::FusedTiled)),
            bits(&want),
            "fused repeat run diverged"
        );
        let mut ws = Workspace::new();
        prepared.execute_into_path(&x, &mut ws, ExecPath::FusedTiled);
        assert_eq!(bits(ws.output()), bits(&want), "fused serial workspace diverged");
        for threads in [1, 3] {
            let pool = ThreadPool::new(threads);
            for lanes in [2, 4, 9] {
                let mut wsp = Workspace::new();
                prepared.execute_parallel_path(
                    &x,
                    &mut wsp,
                    &pool.handle(),
                    lanes,
                    ExecPath::FusedTiled,
                );
                assert_eq!(
                    bits(wsp.output()),
                    bits(&want),
                    "fused head-parallel diverged (threads={threads}, lanes={lanes})"
                );
            }
        }
    }

    #[test]
    fn fused_workspace_is_sl_times_ts_not_sl_squared() {
        // The acceptance contract: a fused-only workspace never sizes an
        // SL×SL buffer, its footprint is O(SL×TS), and warm fused
        // requests allocate nothing.
        let topo = Topology::new(128, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&SimConfig::u55c(), &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let mut fused_ws = Workspace::new();
        prepared.execute_into_path(&x, &mut fused_ws, ExecPath::FusedTiled);
        assert_eq!(fused_ws.reference_score_capacity(), 0, "fused allocated SL×SL");
        let fp = fused_ws.footprint();
        let fused_bytes = fused_ws.footprint_bytes();
        prepared.execute_into_path(&x, &mut fused_ws, ExecPath::FusedTiled);
        assert_eq!(fused_ws.footprint(), fp, "warm fused request reallocated");
        let mut ref_ws = Workspace::new();
        prepared.execute_into(&x, &mut ref_ws);
        let ref_bytes = ref_ws.footprint_bytes();
        assert!(
            fused_bytes < ref_bytes,
            "fused footprint {fused_bytes} not below reference {ref_bytes}"
        );
        // The gap is the score scratch itself — SL×SL vs SL×TS floats
        // (+ SL online rows).  Allow slack for allocator capacity
        // rounding, but the bulk of the SL² buffer must be gone.
        let (sl, ts) = (topo.seq_len, topo.tile_size);
        let saved = ref_bytes - fused_bytes;
        let score_gap = 4 * (sl * sl) - (4 * sl * ts + 8 * sl);
        assert!(
            saved * 10 >= score_gap * 8,
            "footprint delta {saved} B is not the score scratch (expected ~{score_gap} B)"
        );
    }

    #[test]
    fn long_build_admits_long_sequences() {
        let cfg = SimConfig::u55c_long();
        assert!(cfg.build.admits(&Topology::new(1024, 768, 8, 64)).is_ok());
        assert!(cfg.build.admits(&Topology::new(512, 256, 4, 64)).is_ok());
        assert!(cfg.build.admits(&Topology::new(2048, 768, 8, 64)).is_err());
        // Timing still schedules (same loop algebra, longer loops).
        let mut sim = Simulator::new(cfg);
        let r = sim.run_timing(&Topology::new(512, 768, 8, 64)).unwrap();
        assert!(r.cycles > 0);
    }

    #[test]
    fn same_weights_detects_divergence() {
        let topo = Topology::new(4, 32, 2, 16);
        let a = MhaInputs::generate(&topo);
        let mut b = a.clone();
        assert!(PreparedWeights::same_weights(&a, &b));
        b.x[0] += 1.0; // inputs may differ
        assert!(PreparedWeights::same_weights(&a, &b));
        b.wq[0] += 1.0; // weights may not
        assert!(!PreparedWeights::same_weights(&a, &b));
    }

    #[test]
    fn bram_pool_and_port_checks() {
        // Every Table I topology schedules conflict-free with the
        // partitioning the architecture prescribes.
        for topo in [
            t1(),
            Topology::new(64, 768, 2, 64),
            Topology::new(128, 768, 8, 64),
            Topology::new(64, 768, 8, 16),
        ] {
            Simulator::check_bram_ports(&topo).unwrap();
            let pool = Simulator::head_bram_pool(&topo);
            assert!(pool.total_banks18k() > 0);
        }
        // Under-partitioned access patterns are detected: a degenerate
        // 1-wide tile cannot feed a 96-wide QK dot from 1 bank... the
        // partition tracks the pattern here, so force a conflict by
        // checking the pool's generic port math instead.
        let pool = Simulator::head_bram_pool(&t1());
        assert!(pool.worst_access_cycles(10_000) > 1);
    }

    #[test]
    fn fused_bram_pool_banks_the_stripe_not_sl_squared() {
        // Satellite of DESIGN.md §14: the fused path only ever holds an
        // SL×TS score stripe (+ per-row online state), so its BRAM
        // accounting must not charge the SL×SL array the reference path
        // instantiates.  At SL=1024 that is the difference between an
        // infeasible 1 MiB bank and a 64 KiB stripe.
        let topo = Topology::new(1024, 768, 8, 64);
        let reference = Simulator::head_bram_pool_path(&topo, ExecPath::Reference);
        let fused = Simulator::head_bram_pool_path(&topo, ExecPath::FusedTiled);
        let elems = |pool: &crate::fpga::BramPool, name: &str| {
            pool.banks.iter().find(|b| b.name == name).unwrap().elems
        };
        assert_eq!(elems(&reference, "s"), 1024 * 1024);
        assert_eq!(elems(&fused, "s"), 1024 * 64);
        assert_eq!(elems(&fused, "mrow"), 1024 * 2);
        assert!(fused.total_banks18k() < reference.total_banks18k());
        // The default accounting stays the reference path.
        assert_eq!(Simulator::head_bram_pool(&topo).total_banks18k(), reference.total_banks18k());
        // Both paths schedule conflict-free, including the long build.
        for topo in [t1(), Topology::new(128, 768, 8, 64), topo] {
            Simulator::check_bram_ports_path(&topo, ExecPath::Reference).unwrap();
            Simulator::check_bram_ports_path(&topo, ExecPath::FusedTiled).unwrap();
        }
    }

    #[test]
    fn kernel_tiers_agree_within_tier_tolerance() {
        // DESIGN.md §14 acceptance: SIMD tiers are tier-tolerance-
        // equivalent to the scalar oracle on the full MHA (both exec
        // paths), bit-stable across repeats, and Simd ≡ SimdInt8 exactly
        // (exact integer GEMMs feeding the same f32 code).  On non-AVX2
        // hosts the clamp must reproduce the oracle bit-for-bit.
        use super::super::fused::tier_tolerance;
        let topo = Topology::new(12, 64, 4, 16);
        let inputs = MhaInputs::generate(&topo);
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for path in [ExecPath::Reference, ExecPath::FusedTiled] {
            let cfg = Simulator::toy_config();
            let scalar =
                PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::Scalar);
            assert_eq!(scalar.tier(), KernelTier::Scalar);
            let x = scalar.quantize_input(&inputs.x);
            let want = scalar.execute_path(&x, path);
            let kind = scalar.fused.softmax.kind;
            let mag = want.iter().fold(0f32, |m, v| m.max(v.abs()));
            let tol = tier_tolerance(kind, topo.seq_len, topo.d_k(), mag);
            let mut outs = Vec::new();
            for tier in [KernelTier::Simd, KernelTier::SimdInt8] {
                let p = PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, tier);
                assert_eq!(p.tier(), tier.clamp_available());
                let got = p.execute_path(&x, path);
                if p.tier() == KernelTier::Scalar {
                    assert_eq!(bits(&got), bits(&want), "clamped tier diverged ({path:?})");
                } else {
                    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                        assert!(
                            (w - g).abs() <= tol,
                            "{path:?} {tier} [{i}]: {w} vs {g} (tol {tol})"
                        );
                    }
                    assert_eq!(bits(&p.execute_path(&x, path)), bits(&got), "{path:?} {tier}");
                }
                outs.push(got);
            }
            assert_eq!(bits(&outs[0]), bits(&outs[1]), "Simd vs SimdInt8 diverged ({path:?})");
        }
    }

    #[test]
    fn tier_flavors_bit_identical() {
        // The flavor contract holds within every (path, tier) pair:
        // serial workspace and head-parallel execution reproduce the
        // allocating flavor byte-for-byte.
        use crate::exec::ThreadPool;
        let topo = Topology::new(10, 64, 4, 16);
        let inputs = MhaInputs::generate(&topo);
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for tier in KernelTier::ALL {
            let p =
                PreparedWeights::prepare_with_tier(&Simulator::toy_config(), &topo, &inputs, tier);
            let x = p.quantize_input(&inputs.x);
            for path in [ExecPath::Reference, ExecPath::FusedTiled] {
                let want = p.execute_path(&x, path);
                let mut ws = Workspace::new();
                p.execute_into_path(&x, &mut ws, path);
                assert_eq!(bits(ws.output()), bits(&want), "serial tier={tier} path={path:?}");
                let pool = ThreadPool::new(3);
                for lanes in [2, 4] {
                    let mut wsp = Workspace::new();
                    p.execute_parallel_path(&x, &mut wsp, &pool.handle(), lanes, path);
                    assert_eq!(
                        bits(wsp.output()),
                        bits(&want),
                        "tier={tier} path={path:?} lanes={lanes}"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_tier_stages_i8_weights_and_skips_widening() {
        let topo = Topology::new(8, 64, 2, 16);
        let inputs = MhaInputs::generate(&topo);
        let cfg = SimConfig::u55c();
        let p = PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8);
        if p.tier() != KernelTier::SimdInt8 {
            return; // non-AVX2 host: the clamp path is covered above
        }
        for hp in &p.heads {
            assert_eq!(hp.wq16.len(), 0, "int8 tier staged a widened copy");
            assert_eq!(hp.wq8.len(), topo.d_k() * topo.d_model);
            assert_eq!(hp.wk8.len(), topo.d_k() * topo.d_model);
            assert_eq!(hp.wv8.len(), topo.d_k() * topo.d_model);
        }
        // ... and never sizes the widened input in the workspace.
        let x = p.quantize_input(&inputs.x);
        let mut ws = Workspace::new();
        p.execute_into(&x, &mut ws);
        assert_eq!(ws.x16.len(), 0, "int8 tier widened the input");
        // The scalar staging is the converse.
        let s = PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::Scalar);
        assert_eq!(s.heads[0].wq8.len(), 0);
        assert_eq!(s.heads[0].wq16.len(), topo.d_k() * topo.d_model);
        // Packed staging follows the flat staging: the scalar oracle
        // packs nothing, Simd packs i16, the i8 tiers pack i8.
        assert!(s.heads[0].pwq16.is_none() && s.heads[0].pwq8.is_none());
        if p.tier() == KernelTier::SimdInt8 {
            let pb = p.heads[0].pwq8.as_ref().expect("int8 tier packs i8");
            assert_eq!(pb.bytes(), topo.d_k() * topo.d_model);
            assert!(p.heads[0].pwq16.is_none());
            let sp = PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::Simd);
            let pb16 = sp.heads[0].pwq16.as_ref().expect("Simd tier packs i16");
            assert_eq!(pb16.bytes(), 2 * topo.d_k() * topo.d_model);
            assert!(sp.heads[0].pwq8.is_none());
        }
    }

    #[test]
    fn int8_attn_fused_within_extended_quant_tolerance() {
        // DESIGN.md §17 acceptance: the SimdInt8Attn fused path tracks
        // the f32 fused stream within the parametric quantization bound
        // (finite, per-request), its Reference path is bit-identical to
        // SimdInt8, and the fused path is bit-deterministic on repeats.
        let topo = Topology::new(32, 64, 4, 16);
        let inputs = MhaInputs::generate(&topo);
        let cfg = Simulator::toy_config();
        let f32p = PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8);
        let i8p =
            PreparedWeights::prepare_with_tier(&cfg, &topo, &inputs, KernelTier::SimdInt8Attn);
        if i8p.tier() != KernelTier::SimdInt8Attn {
            return; // non-AVX2 host: the clamp path is covered above
        }
        let x = f32p.quantize_input(&inputs.x);
        let want = f32p.execute_path(&x, ExecPath::FusedTiled);
        let got = i8p.execute_path(&x, ExecPath::FusedTiled);
        let tol = i8p.attn_quant_bound(&x);
        assert!(tol.is_finite() && tol > 0.0, "bound degenerate: {tol}");
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert!((w - g).abs() <= tol, "[{i}]: {w} vs {g} (tol {tol})");
        }
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&i8p.execute_path(&x, ExecPath::FusedTiled)),
            bits(&got),
            "int8-attn fused repeat diverged"
        );
        // Reference path under the new tier: same f32 modules as
        // SimdInt8, byte-for-byte.
        assert_eq!(
            bits(&i8p.execute_path(&x, ExecPath::Reference)),
            bits(&f32p.execute_path(&x, ExecPath::Reference)),
            "int8-attn reference path diverged from SimdInt8"
        );
    }

    #[test]
    fn int8_attn_tier_pool_banks_i8_operands() {
        // The acceptance criterion's BRAM half: under the int8-attention
        // tier the fused pool banks Q/K/V at 8 bits — a quarter of the
        // f32 stream every other tier holds — so the pool shrinks.
        let topo = Topology::new(512, 768, 8, 64);
        let width = |pool: &crate::fpga::BramPool, name: &str| {
            pool.banks.iter().find(|b| b.name == name).unwrap().width_bits
        };
        let f32_pool =
            Simulator::head_bram_pool_tier(&topo, ExecPath::FusedTiled, KernelTier::SimdInt8);
        let i8_pool =
            Simulator::head_bram_pool_tier(&topo, ExecPath::FusedTiled, KernelTier::SimdInt8Attn);
        for name in ["q", "k", "v"] {
            assert_eq!(width(&f32_pool, name), 32, "{name}: f32 stream");
            assert_eq!(width(&i8_pool, name), 8, "{name}: i8 stream");
        }
        // Both i8-staging tiers narrow the weight/input tiles; the
        // widened tiers hold i16.
        assert_eq!(width(&f32_pool, "wq"), 8);
        assert_eq!(width(&i8_pool, "wq"), 8);
        let simd_pool =
            Simulator::head_bram_pool_tier(&topo, ExecPath::FusedTiled, KernelTier::Simd);
        assert_eq!(width(&simd_pool, "wq"), 16);
        // The stripe stays 32-bit (i32 accumulators / f32 absorb) on
        // every tier, and the reference path keeps the f32 stream even
        // under SimdInt8Attn (it runs the f32 modules there).
        assert_eq!(width(&i8_pool, "s"), 32);
        let ref_pool =
            Simulator::head_bram_pool_tier(&topo, ExecPath::Reference, KernelTier::SimdInt8Attn);
        assert_eq!(width(&ref_pool, "q"), 32);
        assert!(
            i8_pool.total_banks18k() < f32_pool.total_banks18k(),
            "i8 attention pool {} banks not below f32 {}",
            i8_pool.total_banks18k(),
            f32_pool.total_banks18k()
        );
        // The paper-convention accounting is untouched by the tier
        // axis: head_bram_pool_path still banks the uniform 8-bit grid.
        let paper = Simulator::head_bram_pool_path(&topo, ExecPath::FusedTiled);
        for bank in &paper.banks {
            if bank.name != "mrow" {
                assert_eq!(bank.width_bits, 8, "{}: paper pool widened", bank.name);
            }
        }
    }

    #[test]
    fn causal_config_changes_output_not_timing() {
        let topo = Topology::new(16, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let dense = Simulator::new(SimConfig::u55c()).run(&topo, &inputs).unwrap();
        let mut cfg = SimConfig::u55c();
        cfg.causal = true;
        let masked = Simulator::new(cfg).run(&topo, &inputs).unwrap();
        // Dense schedule: the mask is free in fabric time.
        assert_eq!(dense.cycles, masked.cycles);
        assert_ne!(dense.output, masked.output);
    }

    #[test]
    fn rejects_unsynthesizable_topology() {
        let mut sim = Simulator::new(SimConfig::u55c());
        assert!(sim.run_timing(&Topology::new(64, 1024, 8, 64)).is_err());
        assert!(sim.run_timing(&Topology::new(64, 768, 8, 32)).is_err());
    }

    #[test]
    fn mac_count_matches_closed_form() {
        let mut sim = Simulator::new(SimConfig::u55c());
        let r = sim.run_timing(&t1()).unwrap();
        // per head: 3·SL·dm·dk (QKV) + 2·SL²·dk (QK + SV), ×8 heads
        let want = 8 * (3 * 64 * 768 * 96 + 2 * 64 * 64 * 96) as u64;
        assert_eq!(r.macs, want);
    }

    #[test]
    fn hbm_traffic_accounted() {
        let mut sim = Simulator::new(SimConfig::u55c());
        let r = sim.run_timing(&t1()).unwrap();
        // LI + LB + 12×(LIA + LWA) beats
        let want = 64 * 768 + 96 + 12 * (64 * 64 + 64 * 96);
        assert_eq!(r.hbm_beats, want as u64);
    }
}
