//! The softmax unit at the tail of `QK_PM`.
//!
//! HLS synthesizes the non-linearity out of LUTs and FFs (Section IV.A.2);
//! we model both the *numerics* (an exp lookup table over a clipped,
//! max-normalized domain — matching `python/compile/kernels/softmax.py`
//! and `ref.lut_softmax` exactly) and an exact-exponential mode used when
//! bit-matching the float oracle.

/// Softmax realization selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SoftmaxKind {
    /// Exact exponential (matches the float oracle / PJRT artifact).
    Exact,
    /// 2^bits-entry LUT over [x_min, 0] (the fabric realization).
    Lut { bits: u32 },
}

/// One row's running online-softmax state for the streaming (fused)
/// attention path: the invariant after absorbing any prefix of a row's
/// scores is `m = max(prefix)` and `l = Σ exp_unit(score − m)` over the
/// prefix, up to the rescale arithmetic documented on
/// [`SoftmaxUnit::absorb_tile`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OnlineRow {
    /// Running maximum of all scores absorbed so far.
    pub m: f32,
    /// Running denominator: Σ un-normalized weights under `m`.
    pub l: f32,
}

impl OnlineRow {
    pub fn new() -> Self {
        OnlineRow { m: f32::NEG_INFINITY, l: 0.0 }
    }
}

impl Default for OnlineRow {
    fn default() -> Self {
        Self::new()
    }
}

/// The QK_PM softmax unit.
#[derive(Clone, Debug)]
pub struct SoftmaxUnit {
    pub kind: SoftmaxKind,
    /// Domain floor of the LUT (paper-scale scores rarely exceed ~8).
    pub x_min: f32,
    table: Vec<f32>,
}

impl SoftmaxUnit {
    pub fn exact() -> Self {
        SoftmaxUnit { kind: SoftmaxKind::Exact, x_min: -8.0, table: Vec::new() }
    }

    pub fn lut(bits: u32) -> Self {
        let x_min = -8.0f32;
        let n = 1usize << bits;
        let step = -x_min / (n as f32 - 1.0);
        let table = (0..n).map(|i| (x_min + i as f32 * step).exp()).collect();
        SoftmaxUnit { kind: SoftmaxKind::Lut { bits }, x_min, table }
    }

    fn exp(&self, z: f32) -> f32 {
        match self.kind {
            SoftmaxKind::Exact => z.exp(),
            SoftmaxKind::Lut { bits } => {
                let n = 1usize << bits;
                let step = -self.x_min / (n as f32 - 1.0);
                let zc = z.clamp(self.x_min, 0.0);
                let idx = ((zc - self.x_min) / step).floor() as usize;
                self.table[idx.min(n - 1)]
            }
        }
    }

    /// Streaming (online-softmax) absorb of one score tile into `row`:
    /// updates the running max/denominator and replaces `scores` in
    /// place with the tile's un-normalized weights
    /// `exp_unit(score − m_new)` under this unit's exp realization.
    ///
    /// Returns the rescale factor `α = exp(m_old − m_new)` the caller
    /// must apply to any partial accumulator (output stripe) built under
    /// the old maximum.  `α` uses the *exact* exponential regardless of
    /// the LUT realization: the α chain telescopes, so the effective
    /// final weight of any score is its unit-exp at the then-current max
    /// times an exact `exp(m_then − m_final)` — within one LUT
    /// quantization step of the batch pass's `exp_unit(score − m_final)`
    /// (the tolerance bound in `sim::fused::tolerance`, DESIGN.md §12).
    ///
    /// Before anything is absorbed `row.m` is `−∞`, so the first tile's
    /// α is `exp(−∞) = 0.0` — it rescales an all-zero accumulator, which
    /// is exact.  An empty tile returns `α = 1` and changes nothing.
    pub fn absorb_tile(&self, row: &mut OnlineRow, scores: &mut [f32]) -> f32 {
        let tile_max = scores.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let m_new = row.m.max(tile_max);
        if m_new == f32::NEG_INFINITY {
            // Nothing absorbed yet and an empty tile: avoid the −∞ − −∞
            // NaN; there is nothing to rescale.
            return 1.0;
        }
        let alpha = (row.m - m_new).exp();
        let mut sum = 0.0f32;
        for v in scores.iter_mut() {
            *v = self.exp(*v - m_new);
            sum += *v;
        }
        row.l = row.l * alpha + sum;
        row.m = m_new;
        alpha
    }

    /// In-place row softmax over a row-major `rows × cols` matrix.
    pub fn rows(&self, data: &mut [f32], rows: usize, cols: usize) {
        assert_eq!(data.len(), rows * cols);
        for r in 0..rows {
            let row = &mut data[r * cols..(r + 1) * cols];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = self.exp(*v - max);
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }

    /// LUT storage cost in LUT4 equivalents (drives the resource model's
    /// per-SL softmax term).
    pub fn lut_cost(&self) -> usize {
        match self.kind {
            SoftmaxKind::Exact => 0,
            SoftmaxKind::Lut { bits } => (1usize << bits) * 32 / 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn softmax_ref(row: &[f32]) -> Vec<f32> {
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let e: Vec<f32> = row.iter().map(|&v| (v - max).exp()).collect();
        let s: f32 = e.iter().sum();
        e.iter().map(|&v| v / s).collect()
    }

    #[test]
    fn exact_matches_reference() {
        let unit = SoftmaxUnit::exact();
        let mut m = vec![0.5, -1.0, 2.0, 0.0, 0.0, 0.0, 3.0, -3.0];
        let want0 = softmax_ref(&m[0..4]);
        let want1 = softmax_ref(&m[4..8]);
        unit.rows(&mut m, 2, 4);
        for (g, w) in m[0..4].iter().zip(&want0) {
            assert!((g - w).abs() < 1e-6);
        }
        for (g, w) in m[4..8].iter().zip(&want1) {
            assert!((g - w).abs() < 1e-6);
        }
    }

    #[test]
    fn rows_are_stochastic() {
        for unit in [SoftmaxUnit::exact(), SoftmaxUnit::lut(8)] {
            let mut m: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32 - 6.0).collect();
            unit.rows(&mut m, 8, 8);
            for r in 0..8 {
                let sum: f32 = m[r * 8..(r + 1) * 8].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
                assert!(m[r * 8..(r + 1) * 8].iter().all(|&v| v >= 0.0));
            }
        }
    }

    #[test]
    fn lut_error_shrinks_with_bits() {
        let mut exact = vec![1.5f32, -0.5, 0.25, -2.0];
        SoftmaxUnit::exact().rows(&mut exact, 1, 4);
        let err = |bits: u32| {
            let mut m = vec![1.5f32, -0.5, 0.25, -2.0];
            SoftmaxUnit::lut(bits).rows(&mut m, 1, 4);
            m.iter().zip(&exact).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max)
        };
        assert!(err(10) <= err(6));
        assert!(err(10) < 5e-3);
    }

    #[test]
    fn lut_matches_python_lut_softmax_grid() {
        // Same construction as kernels/softmax.py: floor-indexed table
        // over [-8, 0] with 2^bits-1 steps -> spot-check a value.
        let unit = SoftmaxUnit::lut(8);
        let step = 8.0 / 255.0;
        let z = -1.234f32;
        let idx = ((z + 8.0) / step).floor() as usize;
        let want = (-8.0 + idx as f32 * step).exp();
        assert!((unit.exp(z) - want).abs() < 1e-7);
    }

    #[test]
    fn shift_invariance() {
        let unit = SoftmaxUnit::exact();
        let mut a = vec![0.1f32, 0.9, -0.4, 0.0];
        let mut b: Vec<f32> = a.iter().map(|v| v + 5.0).collect();
        unit.rows(&mut a, 1, 4);
        unit.rows(&mut b, 1, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn lut_cost_scales() {
        assert_eq!(SoftmaxUnit::exact().lut_cost(), 0);
        assert!(SoftmaxUnit::lut(10).lut_cost() > SoftmaxUnit::lut(8).lut_cost());
    }

    /// Normalized probabilities out of the streaming absorb: weights are
    /// un-normalized at absorb time; dividing by the final `l` and the
    /// telescoped α chain recovers the row softmax.
    fn online_probs(unit: &SoftmaxUnit, row: &[f32], tile: usize) -> Vec<f32> {
        let mut state = OnlineRow::new();
        let mut weights = vec![0f32; row.len()];
        let mut alphas: Vec<(usize, f32)> = Vec::new(); // (tile start, α)
        let mut j0 = 0;
        while j0 < row.len() {
            let tw = tile.min(row.len() - j0);
            weights[j0..j0 + tw].copy_from_slice(&row[j0..j0 + tw]);
            let alpha = unit.absorb_tile(&mut state, &mut weights[j0..j0 + tw]);
            alphas.push((j0, alpha));
            j0 += tw;
        }
        // Apply each later tile's α to every earlier weight (what the
        // fused SV accumulator does incrementally), then normalize.
        for &(start, alpha) in &alphas {
            for w in &mut weights[..start] {
                *w *= alpha;
            }
        }
        weights.iter().map(|&w| w / state.l).collect()
    }

    #[test]
    fn online_absorb_matches_batch_rows_exact() {
        let unit = SoftmaxUnit::exact();
        let row: Vec<f32> = (0..13).map(|i| ((i * 29) % 17) as f32 / 3.0 - 2.5).collect();
        let mut want = row.clone();
        unit.rows(&mut want, 1, 13);
        for tile in [1usize, 3, 4, 13, 64] {
            let got = online_probs(&unit, &row, tile);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6, "tile={tile}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn online_absorb_matches_batch_rows_lut_within_step() {
        // The LUT realization: streaming weights are exp_lut at the
        // then-current max, rescaled exactly — within one LUT step of
        // the batch pass per element (relative e^step − 1).
        let unit = SoftmaxUnit::lut(8);
        let step = 8.0f32 / 255.0;
        let rel = step.exp() - 1.0;
        let row: Vec<f32> = (0..16).map(|i| ((i * 23) % 19) as f32 / 4.0 - 2.0).collect();
        let mut want = row.clone();
        unit.rows(&mut want, 1, 16);
        for tile in [2usize, 5, 16] {
            let got = online_probs(&unit, &row, tile);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 2.0 * rel * w.max(1e-3) + 1e-6, "tile={tile}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn absorb_tile_edge_cases() {
        let unit = SoftmaxUnit::exact();
        let mut row = OnlineRow::new();
        // Empty tile on a fresh row: no-op, α = 1.
        assert_eq!(unit.absorb_tile(&mut row, &mut []), 1.0);
        assert_eq!(row, OnlineRow::new());
        // First real tile: α = exp(−∞) = 0 (rescales the zero
        // accumulator), state becomes (max, Σ exp(v − max)).
        let mut t = [0.5f32, -0.5];
        assert_eq!(unit.absorb_tile(&mut row, &mut t), 0.0);
        assert_eq!(row.m, 0.5);
        assert!((row.l - (1.0 + (-1.0f32).exp())).abs() < 1e-6);
        // A tile that does not raise the max: α = 1 exactly.
        let mut t2 = [-1.0f32];
        assert_eq!(unit.absorb_tile(&mut row, &mut t2), 1.0);
        // A masked-only tile (−1e9 scores, the causal convention): the
        // max is unchanged and exact weights vanish.
        let mut t3 = [-1e9f32, -1e9];
        assert_eq!(unit.absorb_tile(&mut row, &mut t3), 1.0);
        assert_eq!(t3, [0.0, 0.0]);
    }
}
