//! The softmax unit at the tail of `QK_PM`.
//!
//! HLS synthesizes the non-linearity out of LUTs and FFs (Section IV.A.2);
//! we model both the *numerics* (an exp lookup table over a clipped,
//! max-normalized domain — matching `python/compile/kernels/softmax.py`
//! and `ref.lut_softmax` exactly) and an exact-exponential mode used when
//! bit-matching the float oracle.

/// Softmax realization selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SoftmaxKind {
    /// Exact exponential (matches the float oracle / PJRT artifact).
    Exact,
    /// 2^bits-entry LUT over [x_min, 0] (the fabric realization).
    Lut { bits: u32 },
}

/// The QK_PM softmax unit.
#[derive(Clone, Debug)]
pub struct SoftmaxUnit {
    pub kind: SoftmaxKind,
    /// Domain floor of the LUT (paper-scale scores rarely exceed ~8).
    pub x_min: f32,
    table: Vec<f32>,
}

impl SoftmaxUnit {
    pub fn exact() -> Self {
        SoftmaxUnit { kind: SoftmaxKind::Exact, x_min: -8.0, table: Vec::new() }
    }

    pub fn lut(bits: u32) -> Self {
        let x_min = -8.0f32;
        let n = 1usize << bits;
        let step = -x_min / (n as f32 - 1.0);
        let table = (0..n).map(|i| (x_min + i as f32 * step).exp()).collect();
        SoftmaxUnit { kind: SoftmaxKind::Lut { bits }, x_min, table }
    }

    fn exp(&self, z: f32) -> f32 {
        match self.kind {
            SoftmaxKind::Exact => z.exp(),
            SoftmaxKind::Lut { bits } => {
                let n = 1usize << bits;
                let step = -self.x_min / (n as f32 - 1.0);
                let zc = z.clamp(self.x_min, 0.0);
                let idx = ((zc - self.x_min) / step).floor() as usize;
                self.table[idx.min(n - 1)]
            }
        }
    }

    /// In-place row softmax over a row-major `rows × cols` matrix.
    pub fn rows(&self, data: &mut [f32], rows: usize, cols: usize) {
        assert_eq!(data.len(), rows * cols);
        for r in 0..rows {
            let row = &mut data[r * cols..(r + 1) * cols];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = self.exp(*v - max);
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }

    /// LUT storage cost in LUT4 equivalents (drives the resource model's
    /// per-SL softmax term).
    pub fn lut_cost(&self) -> usize {
        match self.kind {
            SoftmaxKind::Exact => 0,
            SoftmaxKind::Lut { bits } => (1usize << bits) * 32 / 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn softmax_ref(row: &[f32]) -> Vec<f32> {
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let e: Vec<f32> = row.iter().map(|&v| (v - max).exp()).collect();
        let s: f32 = e.iter().sum();
        e.iter().map(|&v| v / s).collect()
    }

    #[test]
    fn exact_matches_reference() {
        let unit = SoftmaxUnit::exact();
        let mut m = vec![0.5, -1.0, 2.0, 0.0, 0.0, 0.0, 3.0, -3.0];
        let want0 = softmax_ref(&m[0..4]);
        let want1 = softmax_ref(&m[4..8]);
        unit.rows(&mut m, 2, 4);
        for (g, w) in m[0..4].iter().zip(&want0) {
            assert!((g - w).abs() < 1e-6);
        }
        for (g, w) in m[4..8].iter().zip(&want1) {
            assert!((g - w).abs() < 1e-6);
        }
    }

    #[test]
    fn rows_are_stochastic() {
        for unit in [SoftmaxUnit::exact(), SoftmaxUnit::lut(8)] {
            let mut m: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32 - 6.0).collect();
            unit.rows(&mut m, 8, 8);
            for r in 0..8 {
                let sum: f32 = m[r * 8..(r + 1) * 8].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
                assert!(m[r * 8..(r + 1) * 8].iter().all(|&v| v >= 0.0));
            }
        }
    }

    #[test]
    fn lut_error_shrinks_with_bits() {
        let mut exact = vec![1.5f32, -0.5, 0.25, -2.0];
        SoftmaxUnit::exact().rows(&mut exact, 1, 4);
        let err = |bits: u32| {
            let mut m = vec![1.5f32, -0.5, 0.25, -2.0];
            SoftmaxUnit::lut(bits).rows(&mut m, 1, 4);
            m.iter().zip(&exact).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max)
        };
        assert!(err(10) <= err(6));
        assert!(err(10) < 5e-3);
    }

    #[test]
    fn lut_matches_python_lut_softmax_grid() {
        // Same construction as kernels/softmax.py: floor-indexed table
        // over [-8, 0] with 2^bits-1 steps -> spot-check a value.
        let unit = SoftmaxUnit::lut(8);
        let step = 8.0 / 255.0;
        let z = -1.234f32;
        let idx = ((z + 8.0) / step).floor() as usize;
        let want = (-8.0 + idx as f32 * step).exp();
        assert!((unit.exp(z) - want).abs() < 1e-7);
    }

    #[test]
    fn shift_invariance() {
        let unit = SoftmaxUnit::exact();
        let mut a = vec![0.1f32, 0.9, -0.4, 0.0];
        let mut b: Vec<f32> = a.iter().map(|v| v + 5.0).collect();
        unit.rows(&mut a, 1, 4);
        unit.rows(&mut b, 1, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn lut_cost_scales() {
        assert_eq!(SoftmaxUnit::exact().lut_cost(), 0);
        assert!(SoftmaxUnit::lut(10).lut_cost() > SoftmaxUnit::lut(8).lut_cost());
    }
}
