//! Cycle-approximate simulator of the FAMOUS accelerator.
//!
//! Simulates the architecture of Fig. 3 — `h` parallel sets of
//! {`QKV_PM`, `QK_PM` (+ scale/softmax), `SV_PM`} processing modules fed
//! by AXI/HBM loads under MicroBlaze control — with two coupled facets:
//!
//! * **Timing**: every phase is scheduled on a cycle timeline built from
//!   the same HLS loop structure the analytical model uses (outer loop
//!   un-pipelined, second loop II=1, innermost fully unrolled).  The
//!   engine emits a [`CycleTrace`] of phase events, so benches can plot
//!   per-phase attributions and the Table IV "compute-only" convention
//!   falls out naturally.
//! * **Function**: the datapath actually computes the attention output
//!   through the int8/DSP48 model in [`crate::fixed`] (exact integer QKV
//!   accumulation, f32 score scaling, exact or LUT softmax), validated
//!   against the python oracle's golden vectors.
//!
//! The simulator and [`crate::analytical`] share calibration constants;
//! `engine` tests pin their agreement so the paper's "analytical model
//! validates the experiment" claim is reproduced by construction *and*
//! checked.

pub mod axi;
pub mod controller;
pub mod engine;
pub mod fault;
pub mod fused;
pub mod modules;
pub mod softmax_unit;
pub mod workspace;

pub use crate::fixed::KernelTier;
pub use controller::{ControlRegs, Controller, CtrlError};
pub use engine::{
    CycleTrace, PhaseEvent, PreparedHead, PreparedWeights, SimConfig, SimResult, Simulator,
};
pub use fault::{AccFault, FaultPlan};
pub use fused::{tier_tolerance, ExecPath, FusedAttnPm};
pub use softmax_unit::{OnlineRow, SoftmaxKind, SoftmaxUnit};
pub use workspace::{HeadScratch, Workspace, SHRINK_WINDOW};
