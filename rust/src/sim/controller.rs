//! MicroBlaze-style control plane (Fig. 5 & 6).
//!
//! The paper programs (h, d_model, SL) at runtime: the host extracts the
//! topology from a trained model, the µB writes AXI-lite control
//! registers, raises `start`, and reads an AXI-TIMER spanning start→stop.
//! This module models that register file and the admission checks the
//! fabric's synthesized maxima impose.

use crate::config::{AcceleratorConfig, ConfigError, Topology};

/// The AXI-lite register image the µB writes before `start`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ControlRegs {
    pub seq_len: u32,
    pub d_model: u32,
    pub heads: u32,
    /// Derived by the host software: d_model / heads.
    pub d_k: u32,
    /// Derived: d_model / tile_size (tile loop bound).
    pub n_tiles: u32,
    pub start: bool,
}

/// Control-plane errors (reported to the host over AXI-lite).
#[derive(Clone, Debug, PartialEq)]
pub enum CtrlError {
    Rejected(ConfigError),
    /// start raised while a run is in flight.
    Busy,
}

impl std::fmt::Display for CtrlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CtrlError::Rejected(e) => write!(f, "control rejected: {e}"),
            CtrlError::Busy => write!(f, "accelerator busy"),
        }
    }
}

/// The accelerator-side controller: validates and latches register writes,
/// counts reconfigurations, and models the AXI-TIMER.
#[derive(Clone, Debug)]
pub struct Controller {
    pub build: AcceleratorConfig,
    regs: Option<ControlRegs>,
    busy: bool,
    /// Number of distinct reprogram events (telemetry for the batcher:
    /// the coordinator tries to minimize these).
    pub reconfigurations: u64,
    /// AXI-TIMER value of the last completed run, in cycles.
    pub last_timer: u64,
}

impl Controller {
    pub fn new(build: AcceleratorConfig) -> Self {
        Controller { build, regs: None, busy: false, reconfigurations: 0, last_timer: 0 }
    }

    /// Program a topology (µB register writes).  Validates against the
    /// synthesized maxima — the runtime-programmability contract.
    pub fn program(&mut self, topo: &Topology) -> Result<ControlRegs, CtrlError> {
        if self.busy {
            return Err(CtrlError::Busy);
        }
        self.build.admits(topo).map_err(CtrlError::Rejected)?;
        let regs = ControlRegs {
            seq_len: topo.seq_len as u32,
            d_model: topo.d_model as u32,
            heads: topo.heads as u32,
            d_k: topo.d_k() as u32,
            n_tiles: topo.n_tiles() as u32,
            start: false,
        };
        if self.regs.map(|r| (r.seq_len, r.d_model, r.heads)) != Some((regs.seq_len, regs.d_model, regs.heads))
        {
            self.reconfigurations += 1;
        }
        self.regs = Some(regs);
        Ok(regs)
    }

    /// Current register image (None before first program()).
    pub fn regs(&self) -> Option<ControlRegs> {
        self.regs
    }

    /// Raise start; the engine calls `finish(cycles)` when done.
    pub fn start(&mut self) -> Result<(), CtrlError> {
        if self.busy {
            return Err(CtrlError::Busy);
        }
        if self.regs.is_none() {
            return Err(CtrlError::Rejected(ConfigError::InvalidTopology(
                "start before programming".into(),
            )));
        }
        self.busy = true;
        Ok(())
    }

    /// Stop signal from the fabric: latch the AXI-TIMER reading.
    pub fn finish(&mut self, cycles: u64) {
        debug_assert!(self.busy, "finish without start");
        self.busy = false;
        self.last_timer = cycles;
    }

    pub fn is_busy(&self) -> bool {
        self.busy
    }

    /// Timer reading converted to ms at the build clock (what the host
    /// prints over UARTlite in the paper's setup).
    pub fn last_latency_ms(&self) -> f64 {
        self.build.cycles_to_ms(self.last_timer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctrl() -> Controller {
        Controller::new(AcceleratorConfig::u55c_ts64())
    }

    #[test]
    fn program_derives_fields() {
        let mut c = ctrl();
        let regs = c.program(&Topology::new(64, 768, 8, 64)).unwrap();
        assert_eq!(regs.d_k, 96);
        assert_eq!(regs.n_tiles, 12);
        assert_eq!(c.reconfigurations, 1);
    }

    #[test]
    fn reprogram_same_topology_is_free() {
        let mut c = ctrl();
        let t = Topology::new(64, 768, 8, 64);
        c.program(&t).unwrap();
        c.program(&t).unwrap();
        assert_eq!(c.reconfigurations, 1);
        c.program(&Topology::new(32, 768, 8, 64)).unwrap();
        assert_eq!(c.reconfigurations, 2);
    }

    #[test]
    fn rejects_beyond_synthesized_max() {
        let mut c = ctrl();
        let err = c.program(&Topology::new(256, 768, 8, 64)).unwrap_err();
        assert!(matches!(err, CtrlError::Rejected(ConfigError::ExceedsSynthesizedMax { .. })));
    }

    #[test]
    fn busy_protocol() {
        let mut c = ctrl();
        c.program(&Topology::new(64, 768, 8, 64)).unwrap();
        c.start().unwrap();
        assert!(c.is_busy());
        assert_eq!(c.start(), Err(CtrlError::Busy));
        assert!(matches!(
            c.program(&Topology::new(32, 768, 8, 64)),
            Err(CtrlError::Busy)
        ));
        c.finish(400_000);
        assert!(!c.is_busy());
        assert!((c.last_latency_ms() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn start_before_program_rejected() {
        let mut c = ctrl();
        assert!(c.start().is_err());
    }
}
