//! Operation counting and throughput metrics.
//!
//! The paper's GOP numbers follow two conventions (Section VI, Table II):
//! for (64, 512) topologies 0.11 GOP matches *attention-only* counting
//! (QKV projections + QKᵀ + SV, 2 ops per MAC); for (64, 768) the quoted
//! 0.308 GOP additionally includes the output projection (our
//! `with_projection` = 0.315 G, −2% off the quoted value).  Both
//! conventions are provided; tables state which one they use, and
//! comparative GOPS always reuse the paper's own GOP so speedup ratios
//! are like-for-like (DESIGN.md §5).

use crate::config::Topology;

/// Multiply-accumulate based operation counts (1 MAC = 2 ops).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpCount {
    pub ops: u64,
}

impl OpCount {
    /// QKV projections + QKᵀ + SV: `6·SL·d² + 4·SL²·d` ops.
    pub fn attention_only(topo: &Topology) -> OpCount {
        let sl = topo.seq_len as u64;
        let d = topo.d_model as u64;
        OpCount { ops: 6 * sl * d * d + 4 * sl * sl * d }
    }

    /// Attention plus the output projection: `+ 2·SL·d²` ops.
    pub fn with_projection(topo: &Topology) -> OpCount {
        let sl = topo.seq_len as u64;
        let d = topo.d_model as u64;
        OpCount { ops: Self::attention_only(topo).ops + 2 * sl * d * d }
    }

    /// The GOP value the paper itself quotes for this topology's
    /// (SL, d_model), where published; falls back to attention_only.
    /// Used when reproducing the paper's GOPS columns so ratios match.
    pub fn paper_convention(topo: &Topology) -> f64 {
        match (topo.seq_len, topo.d_model) {
            (64, 768) => 0.308,
            (64, 512) => 0.11,
            _ => Self::attention_only(topo).giga(),
        }
    }

    pub fn giga(&self) -> f64 {
        self.ops as f64 / 1e9
    }
}

/// Throughput in giga-operations per second from an op count + latency.
pub fn gops(ops_giga: f64, latency_ms: f64) -> f64 {
    assert!(latency_ms > 0.0);
    ops_giga / (latency_ms * 1e-3)
}

/// Simple latency statistics over repeated measurements (for the measured
/// CPU baseline and the coordinator's telemetry).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples_ms.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().copied().fold(0.0, f64::max)
    }

    /// Sum of all samples (busy-time accounting for fleet makespans).
    pub fn sum(&self) -> f64 {
        self.samples_ms.iter().sum()
    }

    /// Fold another stats object into this one (fleet aggregation: the
    /// cluster layer merges per-device `CoordinatorStats` latencies into
    /// one distribution for cluster-wide percentiles).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
    }

    /// Percentile by nearest-rank (p in [0,100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * s.len() as f64).ceil().max(1.0) as usize;
        s[rank.min(s.len()) - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attention_only_matches_paper_512() {
        // (64,512): 6·64·512² + 4·64²·512 = 0.109 G ≈ paper's 0.11.
        let t = Topology::new(64, 512, 8, 64);
        let g = OpCount::attention_only(&t).giga();
        assert!((g - 0.11).abs() / 0.11 < 0.02, "{g}");
    }

    #[test]
    fn with_projection_matches_paper_768() {
        // (64,768): attention-only 0.239 G; +projection 0.315 ≈ 0.308.
        let t = Topology::new(64, 768, 8, 64);
        assert!((OpCount::attention_only(&t).giga() - 0.239).abs() < 0.001);
        let g = OpCount::with_projection(&t).giga();
        assert!((g - 0.308).abs() / 0.308 < 0.03, "{g}");
    }

    #[test]
    fn paper_convention_table() {
        let t768 = Topology::new(64, 768, 8, 64);
        let t512 = Topology::new(64, 512, 8, 64);
        assert_eq!(OpCount::paper_convention(&t768), 0.308);
        assert_eq!(OpCount::paper_convention(&t512), 0.11);
    }

    #[test]
    fn headline_gops_reproduced() {
        // 0.308 GOP at 0.94 ms = 328 GOPS (the paper's headline).
        let g = gops(0.308, 0.94);
        assert!((g - 328.0).abs() < 1.0, "{g}");
    }

    #[test]
    fn stats_basics() {
        let mut s = LatencyStats::default();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.percentile(50.0), 2.0);
        assert_eq!(s.percentile(100.0), 4.0);
    }

    #[test]
    fn stats_empty_safe() {
        let s = LatencyStats::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        assert_eq!(s.sum(), 0.0);
    }

    #[test]
    fn merge_concatenates_distributions() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        for v in [1.0, 2.0] {
            a.record(v);
        }
        for v in [3.0, 4.0] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert!((a.sum() - 10.0).abs() < 1e-12);
        assert_eq!(a.percentile(100.0), 4.0);
        assert_eq!(a.min(), 1.0);
    }
}
