//! Comparison baselines for Tables II–IV.
//!
//! Two kinds (DESIGN.md §2):
//! * [`cpu`] — a real, measured attention implementation on this host
//!   (naive + cache-blocked), the honest "general-purpose platform"
//!   comparator we can actually run.
//! * [`platforms`] — the published datapoints of every platform the paper
//!   compares against (CPUs, GPUs, ASICs, FPGA accelerators), carried as
//!   data so the tables can be regenerated with like-for-like ratios.

pub mod cpu;
pub mod platforms;

pub use cpu::CpuAttention;
pub use platforms::{
    PlatformPoint, ASIC_TABLE3, FAMOUS_TABLE2, FPGA_TABLE4, PLATFORMS_TABLE2,
};
