//! Published platform datapoints from the paper's Tables II, III and IV.
//!
//! These are *data*, not measurements we can rerun: the paper's
//! comparisons are against published numbers of other systems.  Carrying
//! them verbatim lets the benches regenerate each table and recompute the
//! speedup ratios against our modeled FAMOUS numbers.

/// One platform's published operating point.
#[derive(Clone, Debug, PartialEq)]
pub struct PlatformPoint {
    pub name: &'static str,
    /// "seq_len, d_model, heads" as the paper writes topologies.
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    /// Published workload size in GOP (the paper's own convention).
    pub gop: f64,
    pub latency_ms: f64,
    pub gops: f64,
    /// Source row label (publication venue/device details).
    pub note: &'static str,
}

impl PlatformPoint {
    /// Speedup of a FAMOUS latency (same topology class) over this point.
    pub fn speedup_vs(&self, famous_latency_ms: f64) -> f64 {
        self.latency_ms / famous_latency_ms
    }
}

/// Table II — CPU/GPU comparison points.
pub const PLATFORMS_TABLE2: &[PlatformPoint] = &[
    PlatformPoint { name: "Intel E5-2698 v4 CPU", seq_len: 64, d_model: 768, heads: 12, gop: 0.308, latency_ms: 1.1, gops: 280.0, note: "[34]" },
    PlatformPoint { name: "NVIDIA V100 GPU", seq_len: 64, d_model: 512, heads: 4, gop: 0.11, latency_ms: 1.5578, gops: 71.0, note: "[44]" },
    PlatformPoint { name: "Intel Xeon Gold 5220R CPU", seq_len: 64, d_model: 512, heads: 8, gop: 0.11, latency_ms: 1.96, gops: 56.0, note: "[35]" },
    PlatformPoint { name: "NVIDIA P100 GPU", seq_len: 64, d_model: 512, heads: 4, gop: 0.11, latency_ms: 0.496, gops: 221.0, note: "[35]" },
];

/// FAMOUS's Table II own points (for ratio checks).
pub const FAMOUS_TABLE2: &[PlatformPoint] = &[
    PlatformPoint { name: "FAMOUS (U55C)", seq_len: 64, d_model: 768, heads: 8, gop: 0.308, latency_ms: 0.94, gops: 328.0, note: "this work" },
    PlatformPoint { name: "FAMOUS (U55C)", seq_len: 64, d_model: 512, heads: 8, gop: 0.11, latency_ms: 0.597, gops: 184.0, note: "this work" },
];

/// Table III — ASIC accelerators (sparse designs at ~1 GHz).
pub struct AsicPoint {
    pub name: &'static str,
    pub sparse: bool,
    pub tech: &'static str,
    pub gops: f64,
}

pub const ASIC_TABLE3: &[AsicPoint] = &[
    AsicPoint { name: "A^3", sparse: true, tech: "ASIC (40 nm)", gops: 221.0 },
    AsicPoint { name: "Sanger", sparse: true, tech: "ASIC (55 nm)", gops: 529.0 },
    AsicPoint { name: "SpAtten", sparse: true, tech: "ASIC (55 nm)", gops: 360.0 },
    AsicPoint { name: "SALO", sparse: true, tech: "ASIC (45 nm)", gops: 704.0 },
    AsicPoint { name: "FAMOUS", sparse: false, tech: "FPGA", gops: 328.0 },
];

/// Table IV — FPGA accelerators, compute-only attention latency,
/// normalized by the paper to 8 attention heads.
pub struct FpgaPoint {
    pub name: &'static str,
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    pub fpga: &'static str,
    pub data_format: &'static str,
    pub method: &'static str,
    pub dsps: u64,
    pub brams: u64,
    pub gops: f64,
    pub latency_ms: f64,
    pub note: &'static str,
}

pub const FPGA_TABLE4: &[FpgaPoint] = &[
    FpgaPoint { name: "Calabash", seq_len: 64, d_model: 768, heads: 12, fpga: "Xilinx VU9P", data_format: "16 bit fix", method: "HDL", dsps: 4227, brams: 640, gops: 1288.0, latency_ms: 0.239, note: "QKV computation time ignored" },
    FpgaPoint { name: "Lu et al.", seq_len: 64, d_model: 512, heads: 8, fpga: "Xilinx VU13P", data_format: "8 bit fix", method: "HDL", dsps: 129, brams: 498, gops: 128.0, latency_ms: 0.8536, note: "adjusted to 8 heads" },
    FpgaPoint { name: "Ye et al.", seq_len: 64, d_model: 512, heads: 4, fpga: "Alveo U250", data_format: "16 bit fix", method: "HDL", dsps: 4189, brams: 1781, gops: 171.0, latency_ms: 0.642, note: "" },
    FpgaPoint { name: "Li et al.", seq_len: 64, d_model: 512, heads: 4, fpga: "Xilinx VU37P", data_format: "8 bit fix", method: "HLS", dsps: 1260, brams: 448, gops: 72.0, latency_ms: 1.5264, note: "" },
    FpgaPoint { name: "Peng et al.", seq_len: 32, d_model: 800, heads: 4, fpga: "Alveo U200", data_format: "-", method: "HLS", dsps: 623, brams: 0, gops: 97.0, latency_ms: 1.706, note: "attention extracted from full transformer" },
    FpgaPoint { name: "FAMOUS", seq_len: 64, d_model: 768, heads: 8, fpga: "Alveo U55C", data_format: "8 bit fix", method: "HLS", dsps: 4157, brams: 3148, gops: 623.0, latency_ms: 0.494, note: "compute-only" },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_speedups_match_paper_claims() {
        // Section VI: 3.28× vs Xeon Gold, 2.6× vs V100, 1.17× vs E5.
        let famous_512 = 0.597;
        let famous_768 = 0.94;
        let xeon = &PLATFORMS_TABLE2[2];
        assert!((xeon.speedup_vs(famous_512) - 3.28).abs() < 0.03);
        let v100 = &PLATFORMS_TABLE2[1];
        assert!((v100.speedup_vs(famous_512) - 2.6).abs() < 0.03);
        let e5 = &PLATFORMS_TABLE2[0];
        assert!((e5.speedup_vs(famous_768) - 1.17).abs() < 0.01);
    }

    #[test]
    fn table3_famous_is_only_dense() {
        let dense: Vec<_> = ASIC_TABLE3.iter().filter(|p| !p.sparse).collect();
        assert_eq!(dense.len(), 1);
        assert_eq!(dense[0].name, "FAMOUS");
    }

    #[test]
    fn table4_famous_beats_all_but_calabash() {
        // "1.3× faster than the fastest state-of-the-art FPGA-based
        // accelerator" (excluding Calabash, which ignores QKV time).
        let famous = FPGA_TABLE4.last().unwrap();
        for p in FPGA_TABLE4.iter().filter(|p| p.name != "FAMOUS" && p.name != "Calabash") {
            assert!(p.latency_ms > famous.latency_ms, "{}", p.name);
        }
        let fastest_other = FPGA_TABLE4
            .iter()
            .filter(|p| p.name != "FAMOUS" && p.name != "Calabash")
            .map(|p| p.latency_ms)
            .fold(f64::INFINITY, f64::min);
        let ratio = fastest_other / famous.latency_ms;
        assert!((ratio - 1.3).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn gop_values_self_consistent() {
        // gops ≈ gop / latency for the published rows (±12% — the paper's
        // own rounding).
        for p in PLATFORMS_TABLE2 {
            let implied = p.gop / (p.latency_ms * 1e-3);
            assert!(
                (implied - p.gops).abs() / p.gops < 0.12,
                "{}: implied {implied:.1} vs {}",
                p.name,
                p.gops
            );
        }
    }
}
