//! Measured CPU attention baseline.
//!
//! The paper compares against Intel Xeon CPUs running dense MHA at f32.
//! This is the equivalent computation on the present host: a naive
//! textbook implementation and a cache-blocked one (the fair software
//! baseline), both single-threaded by default with an optional
//! thread-pool parallel mode.  Used by the Table II bench to put a *real*
//! measured number beside the paper's published platform points.

use crate::config::Topology;
use crate::exec::ThreadPool;
use crate::testdata::MhaInputs;
use std::sync::Arc;
use std::time::Instant;

/// f32 CPU MHA with selectable kernel.
pub struct CpuAttention {
    pub block: usize,
    pool: Option<Arc<ThreadPool>>,
}

impl CpuAttention {
    pub fn naive() -> Self {
        CpuAttention { block: 0, pool: None }
    }

    pub fn blocked(block: usize) -> Self {
        CpuAttention { block, pool: None }
    }

    pub fn parallel(block: usize) -> Self {
        CpuAttention { block, pool: Some(Arc::new(ThreadPool::default_size())) }
    }

    /// Run MHA; returns (output, wall-clock ms).
    pub fn run(&self, topo: &Topology, inp: &MhaInputs) -> (Vec<f32>, f64) {
        let t0 = Instant::now();
        let out = match &self.pool {
            Some(pool) => self.run_parallel(topo, inp, pool),
            None => {
                let mut out = vec![0f32; topo.seq_len * topo.d_model];
                for head in 0..topo.heads {
                    self.run_head(topo, inp, head, &mut out);
                }
                out
            }
        };
        (out, t0.elapsed().as_secs_f64() * 1e3)
    }

    fn run_parallel(&self, topo: &Topology, inp: &MhaInputs, pool: &Arc<ThreadPool>) -> Vec<f32> {
        let heads: Vec<usize> = (0..topo.heads).collect();
        // Each head writes a disjoint column stripe; compute stripes then merge.
        let cfg = CpuAttention { block: self.block, pool: None };
        let topo2 = topo.clone();
        let inp2 = MhaInputs {
            x: inp.x.clone(),
            wq: inp.wq.clone(),
            wk: inp.wk.clone(),
            wv: inp.wv.clone(),
            bq: inp.bq.clone(),
            bk: inp.bk.clone(),
            bv: inp.bv.clone(),
        };
        let shared = Arc::new((cfg, topo2, inp2));
        let stripes = pool.parallel_map(heads, move |head| {
            let (cfg, topo, inp) = &*shared.clone();
            let mut out = vec![0f32; topo.seq_len * topo.d_model];
            cfg.run_head(topo, inp, head, &mut out);
            (head, out)
        });
        let dk = topo.d_k();
        let dm = topo.d_model;
        let mut out = vec![0f32; topo.seq_len * dm];
        for (head, stripe) in stripes {
            for i in 0..topo.seq_len {
                let a = i * dm + head * dk;
                out[a..a + dk].copy_from_slice(&stripe[a..a + dk]);
            }
        }
        out
    }

    fn run_head(&self, topo: &Topology, inp: &MhaInputs, head: usize, out: &mut [f32]) {
        let (sl, dm, dk) = (topo.seq_len, topo.d_model, topo.d_k());
        let wr = head * dk * dm..(head + 1) * dk * dm;
        let br = head * dk..(head + 1) * dk;
        let q = self.proj(&inp.x, &inp.wq[wr.clone()], &inp.bq[br.clone()], sl, dm, dk);
        let k = self.proj(&inp.x, &inp.wk[wr.clone()], &inp.bk[br.clone()], sl, dm, dk);
        let v = self.proj(&inp.x, &inp.wv[wr], &inp.bv[br], sl, dm, dk);
        // scores + softmax
        let scale = 1.0 / (dk as f32).sqrt();
        let mut s = vec![0f32; sl * sl];
        for i in 0..sl {
            for j in 0..sl {
                let mut acc = 0f32;
                for l in 0..dk {
                    acc += q[i * dk + l] * k[j * dk + l];
                }
                s[i * sl + j] = acc * scale;
            }
        }
        for i in 0..sl {
            let row = &mut s[i * sl..(i + 1) * sl];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            for vv in row.iter_mut() {
                *vv = (*vv - m).exp();
                sum += *vv;
            }
            for vv in row.iter_mut() {
                *vv /= sum;
            }
        }
        // SV, written into the head's column stripe
        for i in 0..sl {
            for j in 0..dk {
                let mut acc = 0f32;
                for l in 0..sl {
                    acc += s[i * sl + l] * v[l * dk + j];
                }
                out[i * dm + head * dk + j] = acc;
            }
        }
    }

    /// x (sl×dm) @ w (dk×dm)ᵀ + b, naive or blocked over the reduction.
    fn proj(&self, x: &[f32], w: &[f32], b: &[f32], sl: usize, dm: usize, dk: usize) -> Vec<f32> {
        let mut out = vec![0f32; sl * dk];
        if self.block == 0 {
            for i in 0..sl {
                for j in 0..dk {
                    let mut acc = 0f32;
                    for l in 0..dm {
                        acc += x[i * dm + l] * w[j * dm + l];
                    }
                    out[i * dk + j] = acc + b[j];
                }
            }
        } else {
            let bs = self.block;
            for l0 in (0..dm).step_by(bs) {
                let l1 = (l0 + bs).min(dm);
                for i in 0..sl {
                    let xrow = &x[i * dm..(i + 1) * dm];
                    for j in 0..dk {
                        let wrow = &w[j * dm..(j + 1) * dm];
                        let mut acc = 0f32;
                        for l in l0..l1 {
                            acc += xrow[l] * wrow[l];
                        }
                        out[i * dk + j] += acc;
                    }
                }
            }
            for i in 0..sl {
                for j in 0..dk {
                    out[i * dk + j] += b[j];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new(8, 64, 4, 16)
    }

    #[test]
    fn naive_and_blocked_agree() {
        let t = topo();
        let inp = MhaInputs::generate(&t);
        let (a, _) = CpuAttention::naive().run(&t, &inp);
        let (b, _) = CpuAttention::blocked(16).run(&t, &inp);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn parallel_agrees_with_serial() {
        let t = Topology::new(16, 128, 4, 32);
        let inp = MhaInputs::generate(&t);
        let (a, _) = CpuAttention::blocked(32).run(&t, &inp);
        let (b, _) = CpuAttention::parallel(32).run(&t, &inp);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matches_simulator_datapath() {
        // The CPU f32 baseline and the accelerator's int8 datapath see
        // the same grid-aligned inputs -> outputs agree to fp tolerance.
        let t = Topology::new(8, 64, 2, 16);
        let inp = MhaInputs::generate(&t);
        let (cpu_out, _) = CpuAttention::naive().run(&t, &inp);
        let mut sim = crate::sim::Simulator::new({
            let mut c = crate::sim::SimConfig::u55c();
            c.build.tile_size = 16;
            c.build.max_topology = crate::config::Topology::new(128, 768, 8, 16);
            c
        });
        let sim_out = sim.run(&t, &inp).unwrap().output.unwrap();
        for (x, y) in cpu_out.iter().zip(&sim_out) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn reports_positive_latency() {
        let t = topo();
        let inp = MhaInputs::generate(&t);
        let (_, ms) = CpuAttention::naive().run(&t, &inp);
        assert!(ms > 0.0);
    }
}
