//! Algorithm-based fault tolerance (ABFT) for the integer GEMMs.
//!
//! Huang–Abraham style checksums, specialized to the accelerator's
//! projection GEMMs (`out[i][j] = Σ_l x[i][l] · w[j][l]`, DESIGN.md
//! §15).  At `PreparedWeights::prepare` time we fold the *pristine*
//! quantized weights into a column-sum vector
//!
//! ```text
//! fold[l] = Σ_j w[j][l]          (i64, length = d_model)
//! ```
//!
//! and per invocation verify, for every output row `i`,
//!
//! ```text
//! Σ_j acc[i][j]  ==  Σ_l x[i][l] · fold[l]
//! ```
//!
//! Both sides are exact integer arithmetic, so the check is *exact* —
//! zero false positives — across all [`crate::fixed::KernelTier`]s (the
//! i16-widened and int8 datapaths stage the same quantized values).  A
//! single corrupted weight `w[j0][l0] += δ` shifts row `i`'s left side
//! by `x[i][l0] · δ`: it is caught whenever any input row has a nonzero
//! value in column `l0`, and when no row does, the corruption is
//! provably harmless (the output is bit-identical to the clean run).
//! A corrupted accumulator entry shifts exactly one row sum and is
//! always caught.
//!
//! Cost: `O(m·(n+k))` per verified GEMM against the GEMM's `O(m·n·k)`
//! — about `1/n + 1/k` relative overhead (≈1–2% at the paper shapes).
//! Bounds: `|x|·|w| ≤ 2^7·2^15` per term and `k ≤ 2^12` at every
//! admissible topology, so row sums stay far below `i64::MAX` and the
//! fold below `2^27` per entry — no wrap even with corrupted operands.

/// Column-sum fold of a row-major `rows × cols` i8 weight matrix:
/// `fold[l] = Σ_j w[j*cols + l]`.  Computed from the pristine operands
/// *before* any fault injection touches the staged copies.
pub fn fold_weights_i8(w: &[i8], rows: usize, cols: usize) -> Vec<i64> {
    assert_eq!(w.len(), rows * cols, "weight matrix shape mismatch");
    let mut fold = vec![0i64; cols];
    for row in w.chunks_exact(cols) {
        for (f, &v) in fold.iter_mut().zip(row) {
            *f += v as i64;
        }
    }
    fold
}

/// Verify an `m × n` i32 accumulator against the fold of its weight
/// operand, using the i16-widened input (`m × k`).  Returns the number
/// of rows whose checksum disagrees (0 = clean).
pub fn verify_rows_i16(acc: &[i32], x16: &[i16], fold: &[i64], m: usize, n: usize) -> u32 {
    let k = fold.len();
    // `>=`: callers may hand high-water-mark scratch buffers that are
    // larger than the active `m × n` / `m × k` shapes.
    debug_assert!(acc.len() >= m * n);
    debug_assert!(x16.len() >= m * k);
    let mut bad = 0u32;
    for i in 0..m {
        let got: i64 = acc[i * n..(i + 1) * n].iter().map(|&v| v as i64).sum();
        let want: i64 =
            x16[i * k..(i + 1) * k].iter().zip(fold).map(|(&x, &f)| x as i64 * f).sum();
        if got != want {
            bad += 1;
        }
    }
    bad
}

/// [`verify_rows_i16`] for the int8 tier's un-widened input operand.
/// The staged i8 input holds the same values as the widened copy, so
/// the two verifiers are interchangeable on clean data.
pub fn verify_rows_i8(acc: &[i32], x8: &[i8], fold: &[i64], m: usize, n: usize) -> u32 {
    let k = fold.len();
    debug_assert!(acc.len() >= m * n);
    debug_assert!(x8.len() >= m * k);
    let mut bad = 0u32;
    for i in 0..m {
        let got: i64 = acc[i * n..(i + 1) * n].iter().map(|&v| v as i64).sum();
        let want: i64 =
            x8[i * k..(i + 1) * k].iter().zip(fold).map(|(&x, &f)| x as i64 * f).sum();
        if got != want {
            bad += 1;
        }
    }
    bad
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{matmul_i32_widened_into, widen_i16};

    fn gemm(x8: &[i8], w8: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let x16 = widen_i16(x8);
        let w16 = widen_i16(w8);
        let mut acc = vec![0i32; m * n];
        matmul_i32_widened_into(&x16, &w16, m, k, n, &mut acc);
        acc
    }

    fn operands(m: usize, k: usize, n: usize) -> (Vec<i8>, Vec<i8>) {
        let x: Vec<i8> = (0..m * k).map(|i| ((i * 37 + 11) % 255) as i8).collect();
        let w: Vec<i8> = (0..n * k).map(|i| ((i * 53 + 7) % 251) as i8).collect();
        (x, w)
    }

    #[test]
    fn clean_gemm_verifies_on_both_input_widths() {
        let (m, k, n) = (5, 16, 7);
        let (x, w) = operands(m, k, n);
        let acc = gemm(&x, &w, m, k, n);
        let fold = fold_weights_i8(&w, n, k);
        assert_eq!(verify_rows_i16(&acc, &widen_i16(&x), &fold, m, n), 0);
        assert_eq!(verify_rows_i8(&acc, &x, &fold, m, n), 0);
    }

    #[test]
    fn every_single_accumulator_flip_is_caught() {
        let (m, k, n) = (4, 8, 6);
        let (x, w) = operands(m, k, n);
        let clean = gemm(&x, &w, m, k, n);
        let fold = fold_weights_i8(&w, n, k);
        for pos in 0..clean.len() {
            for bit in [0u32, 7, 19, 30] {
                let mut acc = clean.clone();
                acc[pos] ^= 1i32 << bit;
                assert_eq!(
                    verify_rows_i16(&acc, &widen_i16(&x), &fold, m, n),
                    1,
                    "flip at {pos} bit {bit} escaped"
                );
            }
        }
    }

    #[test]
    fn weight_fault_caught_or_provably_harmless() {
        let (m, k, n) = (4, 8, 6);
        let (mut x, w) = operands(m, k, n);
        // Zero an input column: a fault confined to that weight column
        // is masked — and must leave the output bit-identical.
        for row in 0..m {
            x[row * k + 3] = 0;
        }
        let clean = gemm(&x, &w, m, k, n);
        let fold = fold_weights_i8(&w, n, k); // fold of the pristine weights
        for l in 0..k {
            let mut wf = w.clone();
            wf[2 * k + l] ^= 0x11; // corrupt w[2][l]
            let acc = gemm(&x, &wf, m, k, n);
            let bad = verify_rows_i16(&acc, &widen_i16(&x), &fold, m, n);
            if l == 3 {
                assert_eq!(bad, 0, "masked fault flagged");
                assert_eq!(acc, clean, "masked fault changed the output");
            } else {
                assert!(bad > 0, "fault in live column {l} escaped");
            }
        }
    }
}
