//! DSP48E2 MAC model: int8×int8 multiply into a 48-bit accumulator.
//!
//! One `Dsp48Mac` is the datapath of one PE (Section IV.A: "A PE is
//! comprised of a DSP48 performing multiplication and accumulation").
//! The 48-bit accumulator means FAMOUS never rounds *inside* a dot
//! product — a property the functional simulator relies on and the
//! property tests pin down.

/// Accumulator width of a DSP48E2 slice.
pub const ACC_BITS: u32 = 48;
const ACC_MAX: i64 = (1 << (ACC_BITS - 1)) - 1;
const ACC_MIN: i64 = -(1 << (ACC_BITS - 1));

/// A single DSP48 multiply-accumulate unit.
#[derive(Clone, Debug, Default)]
pub struct Dsp48Mac {
    acc: i64,
    /// Sticky flag: set if the accumulator ever left the 48-bit range.
    overflowed: bool,
    /// Number of MAC operations issued (drives PE utilization stats).
    pub ops: u64,
}

impl Dsp48Mac {
    pub fn new() -> Self {
        Self::default()
    }

    /// One MAC step: `acc += a*b` with 48-bit wraparound semantics.
    pub fn mac(&mut self, a: i8, b: i8) {
        let prod = a as i64 * b as i64; // |prod| <= 2^14: exact
        self.acc += prod;
        self.ops += 1;
        if self.acc > ACC_MAX || self.acc < ACC_MIN {
            self.overflowed = true;
            // Model hardware wraparound (two's complement truncation).
            self.acc = ((self.acc as u64) << (64 - ACC_BITS)) as i64 >> (64 - ACC_BITS);
        }
    }

    pub fn value(&self) -> i64 {
        self.acc
    }

    pub fn overflowed(&self) -> bool {
        self.overflowed
    }

    pub fn reset(&mut self) {
        self.acc = 0;
        self.overflowed = false;
    }

    /// Dot product of two int8 slices on a fresh accumulator.
    pub fn dot(a: &[i8], b: &[i8]) -> i64 {
        assert_eq!(a.len(), b.len(), "dot length mismatch");
        let mut m = Dsp48Mac::new();
        for (&x, &y) in a.iter().zip(b) {
            m.mac(x, y);
        }
        m.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_dot() {
        assert_eq!(Dsp48Mac::dot(&[1, 2, 3], &[4, 5, 6]), 32);
    }

    #[test]
    fn accumulates_across_calls() {
        let mut m = Dsp48Mac::new();
        m.mac(10, 10);
        m.mac(-5, 3);
        assert_eq!(m.value(), 85);
        assert_eq!(m.ops, 2);
        m.reset();
        assert_eq!(m.value(), 0);
    }

    #[test]
    fn never_overflows_for_realistic_reductions() {
        // Worst case int8 reduction: 128*128 per term. Even d_model=4096
        // terms stay < 2^26 — far inside 48 bits. (The invariant the
        // proptest in rust/tests exercises broadly.)
        let mut m = Dsp48Mac::new();
        for _ in 0..4096 {
            m.mac(-128, -128);
        }
        assert_eq!(m.value(), 4096 * 16384);
        assert!(!m.overflowed());
    }

    #[test]
    fn overflow_detection_and_wrap() {
        // Seed the accumulator just below the 48-bit edge, then push over.
        let mut m = Dsp48Mac { acc: ACC_MAX - 100, ..Dsp48Mac::new() };
        m.mac(127, 127);
        assert!(m.overflowed());
        // Wrapped value is still within 48-bit range.
        assert!(m.value() <= ACC_MAX && m.value() >= ACC_MIN);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn dot_length_mismatch_panics() {
        Dsp48Mac::dot(&[1, 2], &[1]);
    }
}
