//! Integer GEMM kernels for the functional datapath.
//!
//! `matmul_i32` is the reference; `matmul_i32_tiled` reproduces the FAMOUS
//! column-tiled schedule (Fig. 4) and must agree exactly (integer
//! arithmetic — the tiling invariant).  `FxMatrix` is a small row-major
//! int8 matrix wrapper used across the simulator.

use super::Quantizer;

/// Row-major int8 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct FxMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl FxMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        FxMatrix { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_f32(data: &[f32], rows: usize, cols: usize, q: &Quantizer) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        FxMatrix { rows, cols, data: q.quantize_vec(data) }
    }

    pub fn at(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: i8) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn to_f32(&self, q: &Quantizer) -> Vec<f32> {
        q.dequantize_vec(&self.data)
    }
}

/// `a (m×k) @ b^T (n×k) -> (m×n)` in exact i32 arithmetic.
///
/// `b` is stored row-major as (n × k) — i.e. we compute `a @ b.T`, the
/// orientation Algorithm 1 uses (`w_q[k][j]` indexed by output row then
/// reduction column).
pub fn matmul_i32(a: &FxMatrix, b: &FxMatrix) -> Vec<i32> {
    assert_eq!(a.cols, b.cols, "reduction dim mismatch: {} vs {}", a.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = 0i32;
            for l in 0..k {
                acc += arow[l] as i32 * brow[l] as i32;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Same contraction with the FAMOUS schedule: reduce over column tiles of
/// width `ts` (a narrower tail tile when `ts` does not divide the
/// reduction dim), accumulating partials — bit-identical to `matmul_i32`.
pub fn matmul_i32_tiled(a: &FxMatrix, b: &FxMatrix, ts: usize) -> Vec<i32> {
    assert_eq!(a.cols, b.cols, "reduction dim mismatch");
    assert!(ts > 0, "tile size must be positive");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut out = vec![0i32; m * n];
    let mut base = 0;
    while base < k {
        let width = ts.min(k - base);
        for i in 0..m {
            let arow = &a.row(i)[base..base + width];
            for j in 0..n {
                let brow = &b.row(j)[base..base + width];
                let mut acc = 0i32;
                for l in 0..width {
                    acc += arow[l] as i32 * brow[l] as i32;
                }
                out[i * n + j] += acc;
            }
        }
        base += ts;
    }
    out
}

/// Widen an int8 operand buffer to i16 (the one-time prep the fast GEMM
/// kernel wants; exposed so batch paths can widen weights once and reuse
/// them across requests).
pub fn widen_i16(data: &[i8]) -> Vec<i16> {
    let mut out = Vec::new();
    widen_i16_into(data, &mut out);
    out
}

/// Widen into a caller-owned buffer — the workspace path: no allocation
/// when `dst` already has the capacity (warm requests, `sim::Workspace`).
pub fn widen_i16_into(src: &[i8], dst: &mut Vec<i16>) {
    dst.resize(src.len(), 0);
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as i16;
    }
}

/// The fast GEMM inner kernel over pre-widened operands, writing into a
/// caller-owned buffer: `a16` is (m×k) row-major, `b16` is (n×k)
/// row-major (we compute `a @ b.T`).
///
/// Output columns are register-blocked four wide: one pass over an `a`
/// row feeds four independent i32 accumulator chains (i16×i16→i32
/// multiply-adds LLVM lowers to `pmaddwd`-class SIMD), so `a16` is
/// streamed n/4 times instead of n.  Integer addition is order-free, so
/// any blocking stays bit-identical to [`matmul_i32`].  Measured numbers
/// in EXPERIMENTS.md §Perf.
pub fn matmul_i32_widened_into(
    a16: &[i16],
    b16: &[i16],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a16.len(), m * k, "a16 shape mismatch");
    assert_eq!(b16.len(), n * k, "b16 shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    for i in 0..m {
        let arow = &a16[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b16[j * k..(j + 1) * k];
            let b1 = &b16[(j + 1) * k..(j + 2) * k];
            let b2 = &b16[(j + 2) * k..(j + 3) * k];
            let b3 = &b16[(j + 3) * k..(j + 4) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            // zip over equal-length slices: bounds checks vanish and the
            // four chains vectorize independently.
            for ((((&x, &y0), &y1), &y2), &y3) in arow.iter().zip(b0).zip(b1).zip(b2).zip(b3) {
                let x = x as i32;
                a0 += x * y0 as i32;
                a1 += x * y1 as i32;
                a2 += x * y2 as i32;
                a3 += x * y3 as i32;
            }
            orow[j] = a0;
            orow[j + 1] = a1;
            orow[j + 2] = a2;
            orow[j + 3] = a3;
            j += 4;
        }
        while j < n {
            let brow = &b16[j * k..(j + 1) * k];
            orow[j] = arow.iter().zip(brow).map(|(&x, &y)| x as i32 * y as i32).sum();
            j += 1;
        }
    }
}

/// Allocating wrapper over [`matmul_i32_widened_into`] — bit-identical to
/// [`matmul_i32`].
pub fn matmul_i32_widened(a16: &[i16], b16: &[i16], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    matmul_i32_widened_into(a16, b16, m, k, n, &mut out);
    out
}

/// Vectorization-friendly GEMM: operands are widened to i16 once, so the
/// inner product is an i16×i16→i32 multiply-add chain LLVM lowers to
/// `pmaddwd`-class SIMD (~6× the naive i8 loop; EXPERIMENTS.md §Perf).
/// Bit-identical to [`matmul_i32`] — integer arithmetic, no rounding.
pub fn matmul_i32_fast(a: &FxMatrix, b: &FxMatrix) -> Vec<i32> {
    assert_eq!(a.cols, b.cols, "reduction dim mismatch: {} vs {}", a.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    matmul_i32_widened(&widen_i16(&a.data), &widen_i16(&b.data), m, k, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::XorShift64;

    fn rand_mat(seed: u64, rows: usize, cols: usize) -> FxMatrix {
        let mut rng = XorShift64::new(seed);
        let data = (0..rows * cols).map(|_| rng.range_i64(-128, 127) as i8).collect();
        FxMatrix { rows, cols, data }
    }

    #[test]
    fn known_product() {
        // a = [[1,2],[3,4]], b rows are the columns of the classic b.
        let a = FxMatrix { rows: 2, cols: 2, data: vec![1, 2, 3, 4] };
        let b = FxMatrix { rows: 2, cols: 2, data: vec![5, 7, 6, 8] };
        // a @ b.T where b.T = [[5,6],[7,8]]
        assert_eq!(matmul_i32(&a, &b), vec![19, 22, 43, 50]);
    }

    #[test]
    fn fast_equals_direct() {
        let a = rand_mat(3, 9, 37); // odd k exercises the tail loop
        let b = rand_mat(4, 7, 37);
        assert_eq!(matmul_i32_fast(&a, &b), matmul_i32(&a, &b));
        let a = rand_mat(5, 16, 768);
        let b = rand_mat(6, 96, 768);
        assert_eq!(matmul_i32_fast(&a, &b), matmul_i32(&a, &b));
    }

    #[test]
    fn tiled_equals_direct_all_tile_sizes() {
        let a = rand_mat(1, 7, 24);
        let b = rand_mat(2, 5, 24);
        let want = matmul_i32(&a, &b);
        // Dividing and non-dividing tile widths: 5/7/9/25/100 exercise
        // the tail tile (cols % ts != 0, including ts > cols).
        for ts in [1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 24, 25, 100] {
            assert_eq!(matmul_i32_tiled(&a, &b, ts), want, "ts={ts}");
        }
    }

    #[test]
    fn widened_kernel_matches_direct() {
        let a = rand_mat(7, 6, 19);
        let b = rand_mat(8, 4, 19);
        let got = matmul_i32_widened(&widen_i16(&a.data), &widen_i16(&b.data), 6, 19, 4);
        assert_eq!(got, matmul_i32(&a, &b));
    }

    #[test]
    fn blocked_kernel_matches_direct_all_widths() {
        // n = 1..9 exercises empty/partial/multiple 4-wide blocks + tails.
        for n in 1..=9 {
            let a = rand_mat(11 + n as u64, 5, 23);
            let b = rand_mat(29 + n as u64, n, 23);
            let mut out = vec![0i32; 5 * n];
            matmul_i32_widened_into(&widen_i16(&a.data), &widen_i16(&b.data), 5, 23, n, &mut out);
            assert_eq!(out, matmul_i32(&a, &b), "n={n}");
        }
    }

    #[test]
    fn widen_into_reuses_capacity() {
        let src: Vec<i8> = (0..64).map(|v| v as i8 - 32).collect();
        let mut dst = Vec::new();
        widen_i16_into(&src, &mut dst);
        assert_eq!(dst, widen_i16(&src));
        let (ptr, cap) = (dst.as_ptr() as usize, dst.capacity());
        widen_i16_into(&src[..32], &mut dst);
        assert_eq!(dst, widen_i16(&src[..32]));
        widen_i16_into(&src, &mut dst);
        assert_eq!((dst.as_ptr() as usize, dst.capacity()), (ptr, cap), "re-widen reallocated");
    }

    #[test]
    fn from_f32_quantizes() {
        let q = Quantizer::grid64();
        let m = FxMatrix::from_f32(&[0.5, -0.25, 1.0, 0.0], 2, 2, &q);
        assert_eq!(m.data, vec![32, -16, 64, 0]);
        assert_eq!(m.to_f32(&q), vec![0.5, -0.25, 1.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "reduction dim mismatch")]
    fn mismatched_dims_panic() {
        let a = rand_mat(1, 2, 3);
        let b = rand_mat(2, 2, 4);
        matmul_i32(&a, &b);
    }

    #[test]
    fn accessors() {
        let mut m = FxMatrix::zeros(2, 3);
        m.set(1, 2, 7);
        assert_eq!(m.at(1, 2), 7);
        assert_eq!(m.row(1), &[0, 0, 7]);
    }
}
