//! Explicit-SIMD kernel tier for the hot inner kernels (DESIGN.md §14).
//!
//! The scalar kernels in [`super::matrix`] and `sim::modules` stay
//! verbatim as the bit-identity oracle; this module adds `core::arch`
//! x86_64 implementations behind runtime feature detection
//! (`is_x86_feature_detected!("avx2")`) plus a true int8×int8→i32 GEMM
//! that skips the i16 widening pass entirely — the software datapath
//! finally matching the paper's 8-bit fixed-point story instead of
//! widening every operand first.
//!
//! The numerics contract, pinned by tests and DESIGN.md §14:
//!
//! * **Integer kernels are bit-identical across every tier.**  Integer
//!   addition is associative and commutative, `_mm256_madd_epi16` forms
//!   its products at 32 bits (i16×i16 cannot overflow an i32 pair-sum),
//!   and the i8 operands sign-extend exactly — so any lane order gives
//!   the same sums.  Property-tested over random shapes, tail sizes and
//!   pointer alignments in `tests/properties.rs`.
//! * **The f32 axpy/scale kernels are bit-identical too**: they
//!   vectorize across *independent* output accumulators with exactly
//!   one multiply and one add (never FMA) per element — the same
//!   rounding sequence as the scalar loop, in lanes.
//! * **The f32 dot kernel is NOT bit-identical** — 8-lane partial sums
//!   reassociate the reduction — but its order is pinned: lane-strided
//!   partials reduced by the fixed tree in [`hsum`], then the ordered
//!   scalar tail.  Deterministic for a given length, like the scalar
//!   4-wide chains it replaces.
//!
//! Tier selection ([`KernelTier`]) is resolved once per process
//! ([`KernelTier::effective`]) so batched and sequential serving run the
//! same kernels; `FAMOUS_KERNEL_TIER` forces a tier (clamped to what the
//! host supports — the scalar fallback keeps non-AVX2 hosts green).

use std::sync::OnceLock;

use super::matrix::matmul_i32_widened_into;

/// Environment variable forcing the effective tier (`scalar`, `simd`,
/// `simd-int8`, `simd-int8-attn`).  Read once; unknown values fall back
/// to detection.
pub const TIER_ENV: &str = "FAMOUS_KERNEL_TIER";

/// Which implementation of the hot inner kernels a prepared model runs.
///
/// Ordered by ambition: `Scalar` is the verbatim oracle, `Simd` swaps in
/// the AVX2 kernels over the existing widened-i16 operands, `SimdInt8`
/// additionally feeds the projections straight from int8 (no widening
/// pass), and `SimdInt8Attn` carries the int8 operand stream through the
/// fused attention stage itself (i8 Q/K/V staging, int8 score GEMM,
/// dequantizing SV axpy).  SIMD tiers silently clamp to `Scalar` on
/// hosts without AVX2.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelTier {
    /// The scalar reference kernels — always available, bit-identity
    /// oracle for the integer tiers.
    #[default]
    Scalar,
    /// AVX2 kernels over the same widened-i16 operands.
    Simd,
    /// AVX2 kernels plus the int8×int8→i32 projection GEMM (widening-
    /// multiply pairs; the i16 copy of `x` and the weights is skipped).
    SimdInt8,
    /// `SimdInt8` plus int8 Q/K/V staging for the fused attention stage:
    /// per-head symmetric quantization at projection output, the score
    /// GEMM as int8×int8→i32, and i8 V tiles streamed through a
    /// dequantizing axpy.  Changes fused-path numerics (bounded by
    /// `sim::fused::attn_quant_tolerance`), so it is opt-in — never
    /// picked by [`KernelTier::detect`].
    SimdInt8Attn,
}

impl KernelTier {
    pub const ALL: [KernelTier; 4] = [
        KernelTier::Scalar,
        KernelTier::Simd,
        KernelTier::SimdInt8,
        KernelTier::SimdInt8Attn,
    ];

    /// Number of tiers (dense index arrays — telemetry dispatch counts).
    pub const COUNT: usize = Self::ALL.len();

    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Simd => "simd",
            KernelTier::SimdInt8 => "simd-int8",
            KernelTier::SimdInt8Attn => "simd-int8-attn",
        }
    }

    /// Dense index into `[_; KernelTier::COUNT]` arrays, matching the
    /// [`Self::ALL`] order.
    pub fn index(self) -> usize {
        match self {
            KernelTier::Scalar => 0,
            KernelTier::Simd => 1,
            KernelTier::SimdInt8 => 2,
            KernelTier::SimdInt8Attn => 3,
        }
    }

    /// Parse a tier name (the `FAMOUS_KERNEL_TIER` syntax).
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelTier::Scalar),
            "simd" | "avx2" => Some(KernelTier::Simd),
            "simd-int8" | "simd_int8" | "int8" => Some(KernelTier::SimdInt8),
            "simd-int8-attn" | "simd_int8_attn" | "int8-attn" | "int8_attn" => {
                Some(KernelTier::SimdInt8Attn)
            }
            _ => None,
        }
    }

    /// Whether this tier's kernels can run on the current host.
    pub fn is_available(self) -> bool {
        match self {
            KernelTier::Scalar => true,
            KernelTier::Simd | KernelTier::SimdInt8 | KernelTier::SimdInt8Attn => avx2_available(),
        }
    }

    /// Whether this tier stages the projection weights as raw i8 (no
    /// widened-i16 copy) and runs the int8×int8→i32 projection GEMM.
    pub fn stages_i8(self) -> bool {
        matches!(self, KernelTier::SimdInt8 | KernelTier::SimdInt8Attn)
    }

    /// Clamp to an available tier: unavailable SIMD tiers fall back to
    /// `Scalar` (the automatic non-AVX2 fallback — attribution stays
    /// honest because callers store the clamped tier).
    pub fn clamp_available(self) -> KernelTier {
        if self.is_available() {
            self
        } else {
            KernelTier::Scalar
        }
    }

    /// Best tier the host supports *without changing numerics*.
    /// `SimdInt8Attn` is deliberately excluded: quantizing the attention
    /// operands moves fused-path outputs (within
    /// `sim::fused::attn_quant_tolerance`), so it must be requested
    /// explicitly via [`TIER_ENV`] or `TierPolicy::Force`.
    pub fn detect() -> KernelTier {
        if avx2_available() {
            KernelTier::SimdInt8
        } else {
            KernelTier::Scalar
        }
    }

    /// Process-wide effective tier for `TierPolicy::Auto`: the
    /// [`TIER_ENV`] override when set (clamped to availability), else
    /// [`KernelTier::detect`].  Cached on first use so every request in
    /// a process — batched, head-parallel or sequential — runs the same
    /// kernels and serving stays deterministic.
    pub fn effective() -> KernelTier {
        static EFFECTIVE: OnceLock<KernelTier> = OnceLock::new();
        *EFFECTIVE.get_or_init(|| match std::env::var(TIER_ENV) {
            Ok(v) => KernelTier::parse(&v).unwrap_or_else(KernelTier::detect).clamp_available(),
            Err(_) => KernelTier::detect(),
        })
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Runtime AVX2 check (false on non-x86_64 targets — the scalar tier is
/// the only one there).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// ---------------------------------------------------------------- int8 GEMM

/// Scalar int8×int8→i32 GEMM — the bit-identity oracle for the int8
/// datapath: `a8` (m×k) row-major against `b8` (n×k) row-major,
/// computing `a @ b.T` exactly like [`super::matmul_i32`], with no i16
/// widening pass and no intermediate rounding.
pub fn matmul_i32_i8_scalar_into(
    a8: &[i8],
    b8: &[i8],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a8.len(), m * k, "a8 shape mismatch");
    assert_eq!(b8.len(), n * k, "b8 shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    for i in 0..m {
        let arow = &a8[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b8[j * k..(j + 1) * k];
            *o = arow.iter().zip(brow).map(|(&x, &y)| x as i32 * y as i32).sum();
        }
    }
}

/// True int8×int8→i32 GEMM (the `SimdInt8` projection kernel): AVX2
/// widening-multiply pairs when the host has them, the scalar oracle
/// otherwise — bit-identical either way (integer addition is order-
/// free).  Widening pairs (`_mm256_cvtepi8_epi16` + `_mm256_madd_epi16`)
/// are used instead of a `maddubs` signed/unsigned split: `maddubs`
/// saturates its i16 pair-sums, which would break exactness for signed
/// operands, while the pairwise madd forms 32-bit products and cannot
/// overflow.
pub fn matmul_i32_i8_into(a8: &[i8], b8: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above; all
        // memory access inside is bounds-guarded slice access.
        unsafe { matmul_i32_i8_avx2(a8, b8, m, k, n, out) };
        return;
    }
    matmul_i32_i8_scalar_into(a8, b8, m, k, n, out);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_i32_i8_avx2(a8: &[i8], b8: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    use std::arch::x86_64::*;
    assert_eq!(a8.len(), m * k, "a8 shape mismatch");
    assert_eq!(b8.len(), n * k, "b8 shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    for i in 0..m {
        let arow = &a8[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        // Columns blocked four wide like the scalar oracle: one widening
        // load of the `a` vector feeds four independent madd chains.
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b8[j * k..(j + 1) * k];
            let b1 = &b8[(j + 1) * k..(j + 2) * k];
            let b2 = &b8[(j + 2) * k..(j + 3) * k];
            let b3 = &b8[(j + 3) * k..(j + 4) * k];
            let mut s0 = _mm256_setzero_si256();
            let mut s1 = _mm256_setzero_si256();
            let mut s2 = _mm256_setzero_si256();
            let mut s3 = _mm256_setzero_si256();
            let mut l = 0;
            while l + 16 <= k {
                // Sign-extending 16×i8 → 16×i16 loads, then the pairwise
                // i16×i16→i32 madd: products form at 32 bits, so no
                // intermediate can overflow and lane order is free.
                let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(arow.as_ptr().add(l).cast()));
                let v0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b0.as_ptr().add(l).cast()));
                let v1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b1.as_ptr().add(l).cast()));
                let v2 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b2.as_ptr().add(l).cast()));
                let v3 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b3.as_ptr().add(l).cast()));
                s0 = _mm256_add_epi32(s0, _mm256_madd_epi16(av, v0));
                s1 = _mm256_add_epi32(s1, _mm256_madd_epi16(av, v1));
                s2 = _mm256_add_epi32(s2, _mm256_madd_epi16(av, v2));
                s3 = _mm256_add_epi32(s3, _mm256_madd_epi16(av, v3));
                l += 16;
            }
            let mut r0 = hsum_epi32(s0);
            let mut r1 = hsum_epi32(s1);
            let mut r2 = hsum_epi32(s2);
            let mut r3 = hsum_epi32(s3);
            while l < k {
                let x = arow[l] as i32;
                r0 += x * b0[l] as i32;
                r1 += x * b1[l] as i32;
                r2 += x * b2[l] as i32;
                r3 += x * b3[l] as i32;
                l += 1;
            }
            orow[j] = r0;
            orow[j + 1] = r1;
            orow[j + 2] = r2;
            orow[j + 3] = r3;
            j += 4;
        }
        while j < n {
            let brow = &b8[j * k..(j + 1) * k];
            let mut acc = _mm256_setzero_si256();
            let mut l = 0;
            while l + 16 <= k {
                let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(arow.as_ptr().add(l).cast()));
                let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(brow.as_ptr().add(l).cast()));
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
                l += 16;
            }
            let mut sum = hsum_epi32(acc);
            while l < k {
                sum += arow[l] as i32 * brow[l] as i32;
                l += 1;
            }
            orow[j] = sum;
            j += 1;
        }
    }
}

// ------------------------------------------------- cache-blocked GEMM (B packed)
//
// The flat kernels above stream B in row-major DRAM order on every call:
// at d_model = 768 one i8 weight matrix is 576 KiB — past typical L2 —
// so every projection re-reads B from L3/DRAM.  The blocked drivers walk
// a B that was repacked ONCE (at weight-prepare time) into block-major
// panels sized to stay L2-resident: `jc` (NC columns) outer, `pc` (KC of
// the k dimension) inner, each (jc, pc) block holding `ncb` rows of
// `kcb` contiguous i8/i16 values.  The drivers then run an
// (mc × kc × nc) loop nest accumulating per-`pc` partial dots — exact
// integer sums, so blocked output is bit-identical to the flat kernels
// in any block order (a tested invariant).

/// k-dimension block: KC × NC i8 ≤ 24 KiB per panel, re-used across all
/// m rows while resident.
pub const GEMM_KC: usize = 256;
/// Column block (B rows in the a·bᵀ convention).
pub const GEMM_NC: usize = 96;
/// Row block of A walked per resident panel.
pub const GEMM_MC: usize = 128;

/// B (n×k row-major, the `a @ b.T` convention of [`matmul_i32_i8_into`])
/// repacked once into block-major panels for [`matmul_i32_i8_blocked_into`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedBi8 {
    pub k: usize,
    pub n: usize,
    data: Vec<i8>,
}

impl PackedBi8 {
    /// Pack `b8` (n×k row-major).  Layout: for each `jc` column block
    /// (NC wide), for each `pc` k-block (KC deep), `ncb` rows of `kcb`
    /// contiguous values — the exact order the blocked driver consumes.
    pub fn pack(b8: &[i8], k: usize, n: usize) -> PackedBi8 {
        assert_eq!(b8.len(), n * k, "b8 shape mismatch");
        let mut data = Vec::with_capacity(n * k);
        let mut jc = 0;
        while jc < n {
            let ncb = GEMM_NC.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kcb = GEMM_KC.min(k - pc);
                for j in 0..ncb {
                    let row = &b8[(jc + j) * k + pc..(jc + j) * k + pc + kcb];
                    data.extend_from_slice(row);
                }
                pc += kcb;
            }
            jc += ncb;
        }
        PackedBi8 { k, n, data }
    }

    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// [`PackedBi8`]'s widened-i16 sibling, packed in the identical block
/// order for [`matmul_i32_widened_blocked_into`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedBi16 {
    pub k: usize,
    pub n: usize,
    data: Vec<i16>,
}

impl PackedBi16 {
    pub fn pack(b16: &[i16], k: usize, n: usize) -> PackedBi16 {
        assert_eq!(b16.len(), n * k, "b16 shape mismatch");
        let mut data = Vec::with_capacity(n * k);
        let mut jc = 0;
        while jc < n {
            let ncb = GEMM_NC.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kcb = GEMM_KC.min(k - pc);
                for j in 0..ncb {
                    let row = &b16[(jc + j) * k + pc..(jc + j) * k + pc + kcb];
                    data.extend_from_slice(row);
                }
                pc += kcb;
            }
            jc += ncb;
        }
        PackedBi16 { k, n, data }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 2
    }
}

/// Cache-blocked int8×int8→i32 GEMM over a pre-packed B: bit-identical
/// to [`matmul_i32_i8_into`] (exact integer partial sums), but each
/// KC×NC panel of B is read from its packed contiguous home and re-used
/// across MC rows of A while L2-resident.
pub fn matmul_i32_i8_blocked_into(a8: &[i8], pb: &PackedBi8, m: usize, out: &mut [i32]) {
    let (k, n) = (pb.k, pb.n);
    assert_eq!(a8.len(), m * k, "a8 shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    let mut off = 0;
    let mut jc = 0;
    while jc < n {
        let ncb = GEMM_NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kcb = GEMM_KC.min(k - pc);
            let block = &pb.data[off..off + ncb * kcb];
            let first = pc == 0;
            let mut ic = 0;
            while ic < m {
                let mcb = GEMM_MC.min(m - ic);
                for i in ic..ic + mcb {
                    let arow = &a8[i * k + pc..i * k + pc + kcb];
                    let orow = &mut out[i * n + jc..i * n + jc + ncb];
                    panel_i8(arow, block, kcb, ncb, orow, first);
                }
                ic += mcb;
            }
            off += ncb * kcb;
            pc += kcb;
        }
        jc += ncb;
    }
}

/// Cache-blocked sibling of [`matmul_i32_widened_simd_into`] over a
/// pre-packed i16 B — bit-identical to the flat widened kernels.
pub fn matmul_i32_widened_blocked_into(a16: &[i16], pb: &PackedBi16, m: usize, out: &mut [i32]) {
    let (k, n) = (pb.k, pb.n);
    assert_eq!(a16.len(), m * k, "a16 shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    let mut off = 0;
    let mut jc = 0;
    while jc < n {
        let ncb = GEMM_NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kcb = GEMM_KC.min(k - pc);
            let block = &pb.data[off..off + ncb * kcb];
            let first = pc == 0;
            let mut ic = 0;
            while ic < m {
                let mcb = GEMM_MC.min(m - ic);
                for i in ic..ic + mcb {
                    let arow = &a16[i * k + pc..i * k + pc + kcb];
                    let orow = &mut out[i * n + jc..i * n + jc + ncb];
                    panel_i16(arow, block, kcb, ncb, orow, first);
                }
                ic += mcb;
            }
            off += ncb * kcb;
            pc += kcb;
        }
        jc += ncb;
    }
}

/// One A row against one packed panel — AVX2 when the host has it, the
/// scalar loop otherwise (bit-identical either way).
fn panel_i8(arow: &[i8], block: &[i8], kcb: usize, ncb: usize, orow: &mut [i32], first: bool) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { panel_i8_avx2(arow, block, kcb, ncb, orow, first) };
        return;
    }
    panel_i8_scalar(arow, block, kcb, ncb, orow, first);
}

fn panel_i16(arow: &[i16], block: &[i16], kcb: usize, ncb: usize, orow: &mut [i32], first: bool) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { panel_i16_avx2(arow, block, kcb, ncb, orow, first) };
        return;
    }
    panel_i16_scalar(arow, block, kcb, ncb, orow, first);
}

/// One A row against one packed panel, scalar: `ncb` dots of length
/// `kcb`, stored on the first k-block and accumulated thereafter.
fn panel_i8_scalar(arow: &[i8], block: &[i8], kcb: usize, ncb: usize, orow: &mut [i32], first: bool) {
    for (j, o) in orow.iter_mut().enumerate().take(ncb) {
        let brow = &block[j * kcb..(j + 1) * kcb];
        let dot: i32 = arow.iter().zip(brow).map(|(&x, &y)| x as i32 * y as i32).sum();
        if first {
            *o = dot;
        } else {
            *o += dot;
        }
    }
}

fn panel_i16_scalar(
    arow: &[i16],
    block: &[i16],
    kcb: usize,
    ncb: usize,
    orow: &mut [i32],
    first: bool,
) {
    for (j, o) in orow.iter_mut().enumerate().take(ncb) {
        let brow = &block[j * kcb..(j + 1) * kcb];
        let dot: i32 = arow.iter().zip(brow).map(|(&x, &y)| x as i32 * y as i32).sum();
        if first {
            *o = dot;
        } else {
            *o += dot;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn panel_i8_avx2(
    arow: &[i8],
    block: &[i8],
    kcb: usize,
    ncb: usize,
    orow: &mut [i32],
    first: bool,
) {
    use std::arch::x86_64::*;
    // Same 4-col / 16-lane shape as `matmul_i32_i8_avx2`, B rows from
    // the packed panel.
    let mut j = 0;
    while j + 4 <= ncb {
        let b0 = &block[j * kcb..(j + 1) * kcb];
        let b1 = &block[(j + 1) * kcb..(j + 2) * kcb];
        let b2 = &block[(j + 2) * kcb..(j + 3) * kcb];
        let b3 = &block[(j + 3) * kcb..(j + 4) * kcb];
        let mut s0 = _mm256_setzero_si256();
        let mut s1 = _mm256_setzero_si256();
        let mut s2 = _mm256_setzero_si256();
        let mut s3 = _mm256_setzero_si256();
        let mut l = 0;
        while l + 16 <= kcb {
            let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(arow.as_ptr().add(l).cast()));
            let v0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b0.as_ptr().add(l).cast()));
            let v1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b1.as_ptr().add(l).cast()));
            let v2 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b2.as_ptr().add(l).cast()));
            let v3 = _mm256_cvtepi8_epi16(_mm_loadu_si128(b3.as_ptr().add(l).cast()));
            s0 = _mm256_add_epi32(s0, _mm256_madd_epi16(av, v0));
            s1 = _mm256_add_epi32(s1, _mm256_madd_epi16(av, v1));
            s2 = _mm256_add_epi32(s2, _mm256_madd_epi16(av, v2));
            s3 = _mm256_add_epi32(s3, _mm256_madd_epi16(av, v3));
            l += 16;
        }
        let mut r0 = hsum_epi32(s0);
        let mut r1 = hsum_epi32(s1);
        let mut r2 = hsum_epi32(s2);
        let mut r3 = hsum_epi32(s3);
        while l < kcb {
            let x = arow[l] as i32;
            r0 += x * b0[l] as i32;
            r1 += x * b1[l] as i32;
            r2 += x * b2[l] as i32;
            r3 += x * b3[l] as i32;
            l += 1;
        }
        if first {
            orow[j] = r0;
            orow[j + 1] = r1;
            orow[j + 2] = r2;
            orow[j + 3] = r3;
        } else {
            orow[j] += r0;
            orow[j + 1] += r1;
            orow[j + 2] += r2;
            orow[j + 3] += r3;
        }
        j += 4;
    }
    while j < ncb {
        let brow = &block[j * kcb..(j + 1) * kcb];
        let mut acc = _mm256_setzero_si256();
        let mut l = 0;
        while l + 16 <= kcb {
            let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(arow.as_ptr().add(l).cast()));
            let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(brow.as_ptr().add(l).cast()));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
            l += 16;
        }
        let mut sum = hsum_epi32(acc);
        while l < kcb {
            sum += arow[l] as i32 * brow[l] as i32;
            l += 1;
        }
        if first {
            orow[j] = sum;
        } else {
            orow[j] += sum;
        }
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn panel_i16_avx2(
    arow: &[i16],
    block: &[i16],
    kcb: usize,
    ncb: usize,
    orow: &mut [i32],
    first: bool,
) {
    use std::arch::x86_64::*;
    let mut j = 0;
    while j + 4 <= ncb {
        let b0 = &block[j * kcb..(j + 1) * kcb];
        let b1 = &block[(j + 1) * kcb..(j + 2) * kcb];
        let b2 = &block[(j + 2) * kcb..(j + 3) * kcb];
        let b3 = &block[(j + 3) * kcb..(j + 4) * kcb];
        let mut s0 = _mm256_setzero_si256();
        let mut s1 = _mm256_setzero_si256();
        let mut s2 = _mm256_setzero_si256();
        let mut s3 = _mm256_setzero_si256();
        let mut l = 0;
        while l + 16 <= kcb {
            let av = _mm256_loadu_si256(arow.as_ptr().add(l).cast());
            let v0 = _mm256_loadu_si256(b0.as_ptr().add(l).cast());
            let v1 = _mm256_loadu_si256(b1.as_ptr().add(l).cast());
            let v2 = _mm256_loadu_si256(b2.as_ptr().add(l).cast());
            let v3 = _mm256_loadu_si256(b3.as_ptr().add(l).cast());
            s0 = _mm256_add_epi32(s0, _mm256_madd_epi16(av, v0));
            s1 = _mm256_add_epi32(s1, _mm256_madd_epi16(av, v1));
            s2 = _mm256_add_epi32(s2, _mm256_madd_epi16(av, v2));
            s3 = _mm256_add_epi32(s3, _mm256_madd_epi16(av, v3));
            l += 16;
        }
        let mut r0 = hsum_epi32(s0);
        let mut r1 = hsum_epi32(s1);
        let mut r2 = hsum_epi32(s2);
        let mut r3 = hsum_epi32(s3);
        while l < kcb {
            let x = arow[l] as i32;
            r0 += x * b0[l] as i32;
            r1 += x * b1[l] as i32;
            r2 += x * b2[l] as i32;
            r3 += x * b3[l] as i32;
            l += 1;
        }
        if first {
            orow[j] = r0;
            orow[j + 1] = r1;
            orow[j + 2] = r2;
            orow[j + 3] = r3;
        } else {
            orow[j] += r0;
            orow[j + 1] += r1;
            orow[j + 2] += r2;
            orow[j + 3] += r3;
        }
        j += 4;
    }
    while j < ncb {
        let brow = &block[j * kcb..(j + 1) * kcb];
        let mut acc = _mm256_setzero_si256();
        let mut l = 0;
        while l + 16 <= kcb {
            let av = _mm256_loadu_si256(arow.as_ptr().add(l).cast());
            let bv = _mm256_loadu_si256(brow.as_ptr().add(l).cast());
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
            l += 16;
        }
        let mut sum = hsum_epi32(acc);
        while l < kcb {
            sum += arow[l] as i32 * brow[l] as i32;
            l += 1;
        }
        if first {
            orow[j] = sum;
        } else {
            orow[j] += sum;
        }
        j += 1;
    }
}

// ---------------------------------------------------------- widened GEMM

/// AVX2 tier of [`matmul_i32_widened_into`] — bit-identical to the
/// scalar 4-wide blocked kernel (integer sums), falling back to it on
/// hosts without AVX2.
pub fn matmul_i32_widened_simd_into(
    a16: &[i16],
    b16: &[i16],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { matmul_i32_widened_avx2(a16, b16, m, k, n, out) };
        return;
    }
    matmul_i32_widened_into(a16, b16, m, k, n, out);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn matmul_i32_widened_avx2(
    a16: &[i16],
    b16: &[i16],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    use std::arch::x86_64::*;
    assert_eq!(a16.len(), m * k, "a16 shape mismatch");
    assert_eq!(b16.len(), n * k, "b16 shape mismatch");
    assert_eq!(out.len(), m * n, "out shape mismatch");
    for i in 0..m {
        let arow = &a16[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        // Columns blocked four wide like the scalar oracle: one load of
        // the `a` vector feeds four independent madd chains.
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b16[j * k..(j + 1) * k];
            let b1 = &b16[(j + 1) * k..(j + 2) * k];
            let b2 = &b16[(j + 2) * k..(j + 3) * k];
            let b3 = &b16[(j + 3) * k..(j + 4) * k];
            let mut s0 = _mm256_setzero_si256();
            let mut s1 = _mm256_setzero_si256();
            let mut s2 = _mm256_setzero_si256();
            let mut s3 = _mm256_setzero_si256();
            let mut l = 0;
            while l + 16 <= k {
                let av = _mm256_loadu_si256(arow.as_ptr().add(l).cast());
                let v0 = _mm256_loadu_si256(b0.as_ptr().add(l).cast());
                let v1 = _mm256_loadu_si256(b1.as_ptr().add(l).cast());
                let v2 = _mm256_loadu_si256(b2.as_ptr().add(l).cast());
                let v3 = _mm256_loadu_si256(b3.as_ptr().add(l).cast());
                s0 = _mm256_add_epi32(s0, _mm256_madd_epi16(av, v0));
                s1 = _mm256_add_epi32(s1, _mm256_madd_epi16(av, v1));
                s2 = _mm256_add_epi32(s2, _mm256_madd_epi16(av, v2));
                s3 = _mm256_add_epi32(s3, _mm256_madd_epi16(av, v3));
                l += 16;
            }
            let mut r0 = hsum_epi32(s0);
            let mut r1 = hsum_epi32(s1);
            let mut r2 = hsum_epi32(s2);
            let mut r3 = hsum_epi32(s3);
            while l < k {
                let x = arow[l] as i32;
                r0 += x * b0[l] as i32;
                r1 += x * b1[l] as i32;
                r2 += x * b2[l] as i32;
                r3 += x * b3[l] as i32;
                l += 1;
            }
            orow[j] = r0;
            orow[j + 1] = r1;
            orow[j + 2] = r2;
            orow[j + 3] = r3;
            j += 4;
        }
        while j < n {
            let brow = &b16[j * k..(j + 1) * k];
            let mut acc = _mm256_setzero_si256();
            let mut l = 0;
            while l + 16 <= k {
                let av = _mm256_loadu_si256(arow.as_ptr().add(l).cast());
                let bv = _mm256_loadu_si256(brow.as_ptr().add(l).cast());
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
                l += 16;
            }
            let mut sum = hsum_epi32(acc);
            while l < k {
                sum += arow[l] as i32 * brow[l] as i32;
                l += 1;
            }
            orow[j] = sum;
            j += 1;
        }
    }
}

// ------------------------------------------------------------- f32 kernels

/// Dot product with the tier's pinned order.  `Scalar` delegates to the
/// caller's own loop (callers keep their scalar code verbatim and only
/// route here for SIMD tiers); the AVX2 tier reduces 8 lane-strided
/// partial sums with the fixed `hsum_ps` tree, then the ordered scalar
/// tail — deterministic, documented in DESIGN.md §14, but not bit-equal
/// to a sequential scalar sum.
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        return unsafe { dot_f32_avx2(a, b) };
    }
    dot_f32_scalar(a, b)
}

/// Sequential-order scalar dot (the non-AVX2 fallback for [`dot_f32`]).
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let len = a.len().min(b.len());
    let mut acc = _mm256_setzero_ps();
    let mut l = 0;
    while l + 8 <= len {
        let av = _mm256_loadu_ps(a.as_ptr().add(l));
        let bv = _mm256_loadu_ps(b.as_ptr().add(l));
        // mul then add, never FMA: one rounding per op, the pinned order.
        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        l += 8;
    }
    let mut sum = hsum_ps(acc);
    while l < len {
        sum += a[l] * b[l];
        l += 1;
    }
    sum
}

/// `o[j] += w * v[j]` over independent output accumulators.  Exactly one
/// multiply and one add per element in every tier (no FMA, no
/// reordering across `j`), so the AVX2 tier is bit-identical to the
/// scalar loop — the rescaled-axpy contract `sim::fused` relies on.
pub fn axpy_f32(tier: KernelTier, w: f32, v: &[f32], o: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if tier != KernelTier::Scalar && avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { axpy_f32_avx2(w, v, o) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = tier;
    for (oo, &vv) in o.iter_mut().zip(v) {
        *oo += w * vv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32_avx2(w: f32, v: &[f32], o: &mut [f32]) {
    use std::arch::x86_64::*;
    let len = o.len().min(v.len());
    let wv = _mm256_set1_ps(w);
    let mut l = 0;
    while l + 8 <= len {
        let vv = _mm256_loadu_ps(v.as_ptr().add(l));
        let ov = _mm256_loadu_ps(o.as_ptr().add(l));
        _mm256_storeu_ps(o.as_mut_ptr().add(l), _mm256_add_ps(ov, _mm256_mul_ps(wv, vv)));
        l += 8;
    }
    while l < len {
        o[l] += w * v[l];
        l += 1;
    }
}

/// `o[j] *= alpha` element-wise — one multiply per element in every
/// tier, bit-identical across tiers (the online-softmax rescale and the
/// final 1/l normalization in `sim::fused`).
pub fn scale_f32(tier: KernelTier, alpha: f32, o: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if tier != KernelTier::Scalar && avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { scale_f32_avx2(alpha, o) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = tier;
    for oo in o.iter_mut() {
        *oo *= alpha;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_f32_avx2(alpha: f32, o: &mut [f32]) {
    use std::arch::x86_64::*;
    let av = _mm256_set1_ps(alpha);
    let mut l = 0;
    while l + 8 <= o.len() {
        let ov = _mm256_loadu_ps(o.as_ptr().add(l));
        _mm256_storeu_ps(o.as_mut_ptr().add(l), _mm256_mul_ps(ov, av));
        l += 8;
    }
    while l < o.len() {
        o[l] *= alpha;
        l += 1;
    }
}

// ------------------------------------------------- int8 attention staging

/// Symmetric f32 → i8 quantization into a resident buffer, matching
/// `fixed::Quantizer` semantics exactly: round half away from zero,
/// clamp to [−128, 127].  Scalar in every tier — quantization happens
/// once per Q/K/V row per request and is not a hot loop; keeping one
/// implementation keeps the rounding bit-identical across tiers.
pub fn quantize_i8_into(src: &[f32], scale: f32, dst: &mut [i8]) {
    assert_eq!(src.len(), dst.len(), "quantize shape mismatch");
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = (s / scale).round().clamp(-128.0, 127.0) as i8;
    }
}

/// `o[j] += w * (v8[j] as f32)` — the dequantizing SV axpy of the
/// `SimdInt8Attn` fused path: the caller folds the V quantization scale
/// into `w`, so the i8 tile streams straight into the f32 output
/// accumulators.  i8 → f32 conversion is exact and each element gets
/// exactly one multiply and one add (never FMA), so the AVX2 tier is
/// bit-identical to the scalar loop — same contract as [`axpy_f32`].
pub fn axpy_i8_f32(tier: KernelTier, w: f32, v8: &[i8], o: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if tier != KernelTier::Scalar && avx2_available() {
        // SAFETY: AVX2 presence checked at runtime just above.
        unsafe { axpy_i8_f32_avx2(w, v8, o) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = tier;
    for (oo, &vv) in o.iter_mut().zip(v8) {
        *oo += w * vv as f32;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_i8_f32_avx2(w: f32, v8: &[i8], o: &mut [f32]) {
    use std::arch::x86_64::*;
    let len = o.len().min(v8.len());
    let wv = _mm256_set1_ps(w);
    let mut l = 0;
    while l + 8 <= len {
        // 8×i8 sign-extend → 8×i32 → exact f32 lanes.
        let iv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(v8.as_ptr().add(l).cast()));
        let vv = _mm256_cvtepi32_ps(iv);
        let ov = _mm256_loadu_ps(o.as_ptr().add(l));
        _mm256_storeu_ps(o.as_mut_ptr().add(l), _mm256_add_ps(ov, _mm256_mul_ps(wv, vv)));
        l += 8;
    }
    while l < len {
        o[l] += w * v8[l] as f32;
        l += 1;
    }
}

// --------------------------------------------------------- fixed-tree sums

/// Fixed-tree horizontal sum of 8 i32 lanes: (low ½ + high ½), then
/// (pairs), then (adjacent) — the integer tree order is irrelevant to
/// the result (exact arithmetic) but kept explicit for symmetry with
/// [`hsum_ps`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: std::arch::x86_64::__m256i) -> i32 {
    use std::arch::x86_64::*;
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01_00_11_10>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
    _mm_cvtsi128_si32(s)
}

/// Fixed-tree horizontal sum of 8 f32 lanes — THE pinned reduction order
/// of the SIMD dot tier (DESIGN.md §14): lanes (i, i+4) first, then
/// (i, i+2), then (0, 1).  Any change here changes f32 results and must
/// be treated as a numerics change, not a refactor.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_ps(v: std::arch::x86_64::__m256) -> f32 {
    use std::arch::x86_64::*;
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<0b00_00_00_01>(s, s));
    _mm_cvtss_f32(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{matmul_i32, widen_i16, FxMatrix};
    use crate::rng::XorShift64;

    fn rand_mat(seed: u64, rows: usize, cols: usize) -> FxMatrix {
        let mut rng = XorShift64::new(seed);
        let data = (0..rows * cols).map(|_| rng.range_i64(-128, 127) as i8).collect();
        FxMatrix { rows, cols, data }
    }

    #[test]
    fn tier_names_roundtrip() {
        for tier in KernelTier::ALL {
            assert_eq!(KernelTier::parse(tier.name()), Some(tier));
            assert_eq!(format!("{tier}"), tier.name());
        }
        assert_eq!(KernelTier::parse("AVX2"), Some(KernelTier::Simd));
        assert_eq!(KernelTier::parse("nonsense"), None);
    }

    #[test]
    fn detection_is_consistent() {
        // detect() must itself be available, effective() must be an
        // available tier, and scalar is always available.
        assert!(KernelTier::detect().is_available());
        assert!(KernelTier::effective().is_available());
        assert!(KernelTier::Scalar.is_available());
        assert_eq!(KernelTier::Scalar.clamp_available(), KernelTier::Scalar);
        if !avx2_available() {
            assert_eq!(KernelTier::SimdInt8.clamp_available(), KernelTier::Scalar);
            assert_eq!(KernelTier::SimdInt8Attn.clamp_available(), KernelTier::Scalar);
        }
        // The attention-int8 tier changes fused-path numerics, so
        // detection must never pick it on its own.
        assert_ne!(KernelTier::detect(), KernelTier::SimdInt8Attn);
        // Dense indices match the ALL order and stay in range.
        for (i, tier) in KernelTier::ALL.iter().enumerate() {
            assert_eq!(tier.index(), i);
        }
        assert_eq!(KernelTier::COUNT, KernelTier::ALL.len());
        // The env override, when present and parseable, wins (the CI
        // kernel-tier matrix relies on this).
        if let Ok(v) = std::env::var(TIER_ENV) {
            if let Some(want) = KernelTier::parse(&v) {
                assert_eq!(KernelTier::effective(), want.clamp_available());
            }
        }
    }

    #[test]
    fn int8_gemm_matches_reference_including_tails() {
        // k = 37 exercises two full 16-lane blocks + a 5-wide tail;
        // k = 16 exactly one block; k = 7 tail-only.
        for (m, k, n) in [(5, 37, 6), (3, 16, 4), (2, 7, 9), (1, 1, 1)] {
            let a = rand_mat(100 + k as u64, m, k);
            let b = rand_mat(200 + k as u64, n, k);
            let want = matmul_i32(&a, &b);
            let mut got = vec![0i32; m * n];
            matmul_i32_i8_scalar_into(&a.data, &b.data, m, k, n, &mut got);
            assert_eq!(got, want, "scalar i8 oracle m={m} k={k} n={n}");
            got.fill(0);
            matmul_i32_i8_into(&a.data, &b.data, m, k, n, &mut got);
            assert_eq!(got, want, "dispatched i8 gemm m={m} k={k} n={n}");
        }
    }

    #[test]
    fn int8_gemm_saturation_extremes() {
        // All-rails operands: the largest-magnitude products the int8
        // datapath can form ((-128)² = 16384), long reduction — checks
        // accumulator headroom, not just random values.
        let k = 768;
        let a = FxMatrix { rows: 1, cols: k, data: vec![-128; k] };
        let b = FxMatrix { rows: 1, cols: k, data: vec![-128; k] };
        let mut got = vec![0i32; 1];
        matmul_i32_i8_into(&a.data, &b.data, 1, k, 1, &mut got);
        assert_eq!(got[0], 16384 * k as i32);
        assert_eq!(got, matmul_i32(&a, &b));
    }

    #[test]
    fn blocked_gemm_bit_identical_to_flat() {
        // Shapes straddling the block boundaries: k crosses GEMM_KC,
        // n crosses GEMM_NC, m crosses GEMM_MC, plus tail-only smalls.
        for (m, k, n) in
            [(3, 300, 100), (130, 260, 97), (5, 37, 6), (1, 1, 1), (2, GEMM_KC, GEMM_NC)]
        {
            let a = rand_mat(500 + (m * k) as u64, m, k);
            let b = rand_mat(600 + (k * n) as u64, n, k);
            let mut want = vec![0i32; m * n];
            matmul_i32_i8_into(&a.data, &b.data, m, k, n, &mut want);
            let pb = PackedBi8::pack(&b.data, k, n);
            assert_eq!(pb.bytes(), n * k, "packing is a permutation, not a copy+pad");
            let mut got = vec![0i32; m * n];
            matmul_i32_i8_blocked_into(&a.data, &pb, m, &mut got);
            assert_eq!(got, want, "i8 blocked m={m} k={k} n={n}");

            let (a16, b16) = (widen_i16(&a.data), widen_i16(&b.data));
            let mut want16 = vec![0i32; m * n];
            matmul_i32_widened_into(&a16, &b16, m, k, n, &mut want16);
            assert_eq!(want16, want, "widened flat agrees with i8 flat");
            let pb16 = PackedBi16::pack(&b16, k, n);
            let mut got16 = vec![0i32; m * n];
            matmul_i32_widened_blocked_into(&a16, &pb16, m, &mut got16);
            assert_eq!(got16, want, "i16 blocked m={m} k={k} n={n}");
        }
    }

    #[test]
    fn quantize_i8_matches_quantizer_semantics() {
        let src = [0.0f32, 0.06, -0.06, 0.049, 12.9, -12.9, 0.05];
        let mut dst = [0i8; 7];
        quantize_i8_into(&src, 0.1, &mut dst);
        // round-half-away, clamp to i8 rails: 0.05/0.1 = 0.5 -> 1.
        assert_eq!(dst, [0, 1, -1, 0, 127, -128, 1]);
    }

    #[test]
    fn dequantizing_axpy_bit_identical_across_tiers() {
        let mut rng = XorShift64::new(23);
        for len in [1usize, 7, 8, 9, 16, 31, 96] {
            let v8: Vec<i8> = (0..len).map(|_| rng.range_i64(-128, 127) as i8).collect();
            let base: Vec<f32> =
                (0..len).map(|_| rng.range_i64(-1000, 1000) as f32 / 123.0).collect();
            let w = 0.0137f32;
            let mut scalar = base.clone();
            axpy_i8_f32(KernelTier::Scalar, w, &v8, &mut scalar);
            for tier in [KernelTier::Simd, KernelTier::SimdInt8, KernelTier::SimdInt8Attn] {
                let mut simd = base.clone();
                axpy_i8_f32(tier, w, &v8, &mut simd);
                assert_eq!(
                    scalar.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    simd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "i8 axpy len={len} tier={tier}"
                );
            }
        }
    }

    #[test]
    fn widened_simd_gemm_matches_scalar_blocked() {
        for (m, k, n) in [(4, 33, 7), (6, 64, 12), (1, 15, 3)] {
            let a = rand_mat(300 + k as u64, m, k);
            let b = rand_mat(400 + k as u64, n, k);
            let (a16, b16) = (widen_i16(&a.data), widen_i16(&b.data));
            let mut want = vec![0i32; m * n];
            matmul_i32_widened_into(&a16, &b16, m, k, n, &mut want);
            let mut got = vec![0i32; m * n];
            matmul_i32_widened_simd_into(&a16, &b16, m, k, n, &mut got);
            assert_eq!(got, want, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn axpy_and_scale_bit_identical_across_tiers() {
        let mut rng = XorShift64::new(9);
        for len in [1usize, 7, 8, 9, 16, 31, 64] {
            let v: Vec<f32> =
                (0..len).map(|_| rng.range_i64(-1000, 1000) as f32 / 321.0).collect();
            let base: Vec<f32> =
                (0..len).map(|_| rng.range_i64(-1000, 1000) as f32 / 123.0).collect();
            let w = 0.737f32;
            for tier in [KernelTier::Simd, KernelTier::SimdInt8] {
                let mut scalar = base.clone();
                axpy_f32(KernelTier::Scalar, w, &v, &mut scalar);
                let mut simd = base.clone();
                axpy_f32(tier, w, &v, &mut simd);
                assert_eq!(
                    scalar.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    simd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "axpy len={len} tier={tier}"
                );
                scale_f32(KernelTier::Scalar, 0.423, &mut scalar);
                scale_f32(tier, 0.423, &mut simd);
                assert_eq!(
                    scalar.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    simd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "scale len={len} tier={tier}"
                );
            }
        }
    }

    #[test]
    fn simd_dot_close_to_scalar_and_deterministic() {
        let mut rng = XorShift64::new(17);
        for len in [1usize, 5, 8, 13, 64, 96, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.range_i64(-64, 64) as f32 / 64.0).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.range_i64(-64, 64) as f32 / 64.0).collect();
            let scalar = dot_f32_scalar(&a, &b);
            let simd = dot_f32(&a, &b);
            let tol = 8.0 * len as f32 * f32::EPSILON * scalar.abs().max(1.0);
            assert!((scalar - simd).abs() <= tol, "len={len}: {scalar} vs {simd}");
            // Pinned order: repeated evaluation is bit-stable.
            assert_eq!(simd.to_bits(), dot_f32(&a, &b).to_bits(), "len={len}");
        }
    }
}
