//! Fixed-point substrate: the accelerator's 8-bit DSP48 datapath.
//!
//! The paper quantizes all operands to 8-bit fixed point (Table I, "8bit
//! fixed") and MACs them on DSP48E2 slices, which multiply up to 27×18-bit
//! operands into a 48-bit accumulator — so int8×int8 products accumulate
//! *exactly*; quantization error enters only at the operand snap.  This
//! module reproduces that datapath bit-for-bit:
//!
//! * [`Fx`] — a Q-format value: integer mantissa + fractional bits.
//! * [`Quantizer`] — float ⇄ int8-grid conversion (round-half-away,
//!   saturating), matching `python/compile/kernels/quant.py`.
//! * [`Dsp48Mac`] — a MAC unit with the DSP48's 48-bit accumulator and
//!   overflow detection.
//! * [`matmul_i32`] / [`FxMatrix`] — the functional GEMM used by the
//!   simulator's datapath mode.
//! * [`simd`] / [`KernelTier`] — explicit-SIMD implementations of the hot
//!   kernels behind runtime AVX2 detection, including the true
//!   int8×int8→i32 GEMM (no i16 widening pass); the scalar kernels above
//!   stay the bit-identity oracle (DESIGN.md §14).
//! * [`abft`] — Huang–Abraham checksum fold/verify for the projection
//!   GEMMs: exact integer detection of corrupted staged operands across
//!   all kernel tiers (DESIGN.md §15).

pub mod abft;
mod mac;
mod matrix;
pub mod simd;

pub use abft::{fold_weights_i8, verify_rows_i16, verify_rows_i8};
pub use mac::Dsp48Mac;
pub use matrix::{
    matmul_i32, matmul_i32_fast, matmul_i32_tiled, matmul_i32_widened, matmul_i32_widened_into,
    widen_i16, widen_i16_into, FxMatrix,
};
pub use simd::{
    axpy_i8_f32, matmul_i32_i8_blocked_into, matmul_i32_i8_into, matmul_i32_i8_scalar_into,
    matmul_i32_widened_blocked_into, matmul_i32_widened_simd_into, quantize_i8_into, KernelTier,
    PackedBi16, PackedBi8, TIER_ENV,
};

/// A fixed-point value: `value = mantissa * 2^-frac_bits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fx {
    pub mantissa: i32,
    pub frac_bits: u32,
}

impl Fx {
    pub fn from_f32(v: f32, frac_bits: u32, int_bits: u32) -> Fx {
        let scale = (1i64 << frac_bits) as f32;
        let raw = (v * scale).round() as i64;
        let max = (1i64 << (int_bits + frac_bits - 1)) - 1;
        let min = -(1i64 << (int_bits + frac_bits - 1));
        Fx { mantissa: raw.clamp(min, max) as i32, frac_bits }
    }

    pub fn to_f32(self) -> f32 {
        self.mantissa as f32 / (1i64 << self.frac_bits) as f32
    }
}

/// Symmetric int8 quantizer with grid step `scale` (round-half-away-from-
/// zero, saturating at ±127/−128) — the operand snap in front of the MACs.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub scale: f32,
}

impl Quantizer {
    pub fn new(scale: f32) -> Self {
        assert!(scale > 0.0, "quantizer scale must be positive");
        Quantizer { scale }
    }

    /// The grid used by the cross-language testdata (1/64).
    pub fn grid64() -> Self {
        Quantizer::new(crate::testdata::GRID_SCALE)
    }

    /// Pick a scale covering `|x|max` like `quant.pick_scale` (python).
    pub fn fit(data: &[f32]) -> Self {
        let amax = data.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-8);
        Quantizer::new(amax / 127.0)
    }

    /// Snap to the int8 grid, returning the integer level.
    pub fn quantize(&self, v: f32) -> i8 {
        // `f32::round` rounds half away from zero — same as numpy's
        // np.round for the .5 cases we care about? (numpy rounds half to
        // even; the testdata grid never produces exact .5 values, so the
        // two conventions agree on every exchanged value.)
        let q = (v / self.scale).round();
        q.clamp(-128.0, 127.0) as i8
    }

    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }

    /// quantize → dequantize: the value the datapath actually sees.
    pub fn fake_quant(&self, v: f32) -> f32 {
        self.dequantize(self.quantize(v))
    }

    pub fn quantize_vec(&self, data: &[f32]) -> Vec<i8> {
        data.iter().map(|&v| self.quantize(v)).collect()
    }

    pub fn dequantize_vec(&self, data: &[i8]) -> Vec<f32> {
        data.iter().map(|&q| self.dequantize(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fx_roundtrip_exact_on_grid() {
        for level in -128i32..=127 {
            let v = level as f32 / 64.0;
            let fx = Fx::from_f32(v, 6, 2);
            assert_eq!(fx.mantissa, level);
            assert_eq!(fx.to_f32(), v);
        }
    }

    #[test]
    fn fx_saturates() {
        let fx = Fx::from_f32(100.0, 6, 2);
        assert_eq!(fx.mantissa, 127);
        let fx = Fx::from_f32(-100.0, 6, 2);
        assert_eq!(fx.mantissa, -128);
    }

    #[test]
    fn quantizer_roundtrip_on_grid() {
        let q = Quantizer::grid64();
        for level in -128i8..=127 {
            let v = level as f32 / 64.0;
            assert_eq!(q.quantize(v), level);
            assert_eq!(q.fake_quant(v), v);
        }
    }

    #[test]
    fn quantizer_saturates() {
        let q = Quantizer::grid64();
        assert_eq!(q.quantize(10.0), 127);
        assert_eq!(q.quantize(-10.0), -128);
    }

    #[test]
    fn quantizer_error_bounded_by_half_step() {
        let q = Quantizer::new(0.05);
        for i in 0..100 {
            let v = -3.0 + i as f32 * 0.0617;
            if v.abs() < 127.0 * 0.05 {
                assert!((q.fake_quant(v) - v).abs() <= 0.025 + 1e-6);
            }
        }
    }

    #[test]
    fn fit_covers_range() {
        let data = [-3.7f32, 0.1, 2.5];
        let q = Quantizer::fit(&data);
        assert_eq!(q.quantize(-3.7), -127);
    }

    #[test]
    fn fit_zero_input_no_panic() {
        let q = Quantizer::fit(&[0.0, 0.0]);
        assert!(q.scale > 0.0);
        assert_eq!(q.quantize(0.0), 0);
    }
}
