//! Property-testing substrate (no proptest crate in the offline image).
//!
//! A deliberately small harness with the proptest essentials: value
//! generators over a seeded [`XorShift64`], a runner that executes N random
//! cases, and greedy input shrinking on failure.  Used by the coordinator/
//! fixed-point/tiling invariant tests (DESIGN.md §7).
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath)
//! use famous::proptest_lite::{run, Gen};
//! run("addition commutes", 256, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::XorShift64;

/// Per-case value source.  Records drawn scalars so the runner can replay
/// and shrink a failing case.
pub struct Gen {
    rng: XorShift64,
    /// Values drawn this case (as i64 bit-patterns for replay).
    trace: Vec<i64>,
    /// When replaying a shrunk trace, draws come from here instead.
    replay: Option<Vec<i64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: XorShift64::new(seed), trace: Vec::new(), replay: None, cursor: 0 }
    }

    fn replaying(values: Vec<i64>) -> Self {
        Gen {
            rng: XorShift64::new(0),
            trace: Vec::new(),
            replay: Some(values),
            cursor: 0,
        }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut XorShift64) -> i64) -> i64 {
        let v = match &self.replay {
            Some(vals) => {
                // Exhausted traces fall back to zero — shrinking only ever
                // shortens value magnitude, not trace length semantics.
                let v = vals.get(self.cursor).copied().unwrap_or(0);
                self.cursor += 1;
                v
            }
            None => fresh(&mut self.rng),
        };
        self.trace.push(v);
        v
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.draw(|r| r.range_i64(lo, hi));
        v.clamp(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.i64_in(lo as i64, hi as i64) as usize
    }

    pub fn i8_any(&mut self) -> i8 {
        self.i64_in(-128, 127) as i8
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        // Draw a 53-bit integer and map: keeps replay/shrink integral.
        let raw = self.draw(|r| (r.next_f64() * (1u64 << 53) as f64) as i64);
        lo + (raw as f64 / (1u64 << 53) as f64) * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.i64_in(0, 1) == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    pub fn vec_i8(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.i8_any()).collect()
    }
}

/// Outcome of a property run (exposed for harness self-tests).
#[derive(Debug)]
pub enum Outcome {
    Pass { cases: usize },
    Fail { case: usize, shrunk_trace: Vec<i64>, message: String },
}

/// Run `cases` random cases of `prop`; panic with the shrunk counterexample
/// on failure.  Deterministic per (name, case index).
pub fn run(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    match run_collect(name, cases, &prop) {
        Outcome::Pass { .. } => {}
        Outcome::Fail { case, shrunk_trace, message } => panic!(
            "property '{name}' failed on case {case}: {message}\n  shrunk trace: {shrunk_trace:?}"
        ),
    }
}

/// Like [`run`] but returns the outcome instead of panicking.
pub fn run_collect(
    name: &str,
    cases: usize,
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
) -> Outcome {
    let name_seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = name_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = check(prop, &mut g) {
            let trace = g.trace.clone();
            let (shrunk_trace, message) = shrink(prop, trace, msg);
            return Outcome::Fail { case, shrunk_trace, message };
        }
    }
    Outcome::Pass { cases }
}

fn check(
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
    g: &mut Gen,
) -> Result<(), String> {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(g)));
    match result {
        Ok(()) => Ok(()),
        Err(e) => Err(panic_message(&e)),
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Greedy shrink: repeatedly try halving each drawn value toward zero,
/// keeping any mutation that still fails.
fn shrink(
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
    mut trace: Vec<i64>,
    mut message: String,
) -> (Vec<i64>, String) {
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // silence shrink probes
    let mut improved = true;
    let mut budget = 2000usize;
    while improved && budget > 0 {
        improved = false;
        for i in 0..trace.len() {
            if trace[i] == 0 {
                continue;
            }
            for candidate in [0, trace[i] / 2, trace[i] - trace[i].signum()] {
                if candidate == trace[i] {
                    continue;
                }
                budget = budget.saturating_sub(1);
                let mut t = trace.clone();
                t[i] = candidate;
                let mut g = Gen::replaying(t.clone());
                if let Err(msg) = check(prop, &mut g) {
                    trace = t;
                    message = msg;
                    improved = true;
                    break;
                }
            }
        }
    }
    std::panic::set_hook(hook);
    (trace, message)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run("abs is non-negative", 100, |g| {
            let v = g.i64_in(-1000, 1000);
            assert!(v.abs() >= 0);
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let out = run_collect("find big", 500, &|g: &mut Gen| {
            let v = g.i64_in(0, 1000);
            assert!(v < 900, "v too big: {v}");
        });
        match out {
            Outcome::Fail { shrunk_trace, .. } => {
                // Shrinking drives v down to the smallest failing value.
                assert_eq!(shrunk_trace, vec![900]);
            }
            Outcome::Pass { .. } => panic!("should have failed"),
        }
    }

    #[test]
    fn deterministic_across_runs() {
        use std::sync::atomic::{AtomicI64, Ordering};
        let collect = || {
            let sum = AtomicI64::new(0);
            run("collect", 10, |g| {
                sum.fetch_add(g.i64_in(0, 100), Ordering::SeqCst);
            });
            sum.load(Ordering::SeqCst)
        };
        assert_eq!(collect(), collect());
    }

    #[test]
    fn generators_in_bounds() {
        run("gen bounds", 200, |g| {
            assert!((0..=10).contains(&g.usize_in(0, 10)));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let _ = g.bool();
            let v = g.vec_i8(5);
            assert_eq!(v.len(), 5);
            let xs = [1, 2, 3];
            assert!(xs.contains(g.pick(&xs)));
        });
    }
}
