//! The latency model proper: phase equations + calibration constants.

use crate::config::Topology;
use crate::fpga::hls::{LoopNest, PipelinedLoop};
use crate::jsonlite::Json;

/// Per-phase cycle attribution (eqs. 5–12 plus the calibrated overhead).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseCycles {
    /// LI — load all inputs (eq. 5).
    pub li: u64,
    /// LB — load biases (eq. 6).
    pub lb: u64,
    /// LIA — per-head input-tile loads, all tiles (eq. 7 × n_tiles).
    pub lia: u64,
    /// LWA — per-head weight-tile loads, all tiles (eq. 8 × n_tiles).
    pub lwa: u64,
    /// SA — QKV_PM compute, all tiles (eq. 9 × n_tiles).
    pub sa: u64,
    /// BA — bias addition (eq. 10).
    pub ba: u64,
    /// S — QK_PM score compute + softmax hand-off (eq. 11).
    pub s: u64,
    /// SV — SV_PM weighted values (eq. 12).
    pub sv: u64,
    /// Calibrated fixed control overhead (µB + AXI-lite; DESIGN.md §6).
    pub overhead: u64,
    /// Cycles saved by load/compute overlap (subtracted from the total;
    /// non-zero only when the model's `gamma` ablation knob is set).
    pub overlap_saved: u64,
}

impl PhaseCycles {
    /// Total latency in cycles (eq. 13 + overhead − overlap).
    pub fn total(&self) -> u64 {
        (self.li + self.lb + self.lia + self.lwa + self.sa + self.ba + self.s + self.sv
            + self.overhead)
            .saturating_sub(self.overlap_saved)
    }

    /// Compute-only latency: "excluding the latency associated with load
    /// and store operations" — the Table IV convention.
    pub fn compute_only(&self) -> u64 {
        self.sa + self.ba + self.s + self.sv + self.overhead
    }

    /// Pure load cycles (AXI/HBM traffic phases).
    pub fn load_only(&self) -> u64 {
        self.li + self.lb + self.lia + self.lwa
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("li", Json::from(self.li as f64)),
            ("lb", Json::from(self.lb as f64)),
            ("lia", Json::from(self.lia as f64)),
            ("lwa", Json::from(self.lwa as f64)),
            ("sa", Json::from(self.sa as f64)),
            ("ba", Json::from(self.ba as f64)),
            ("s", Json::from(self.s as f64)),
            ("sv", Json::from(self.sv as f64)),
            ("overhead", Json::from(self.overhead as f64)),
            ("total", Json::from(self.total() as f64)),
        ])
    }
}

/// Full prediction for one topology.
#[derive(Clone, Debug)]
pub struct LatencyBreakdown {
    pub topology: Topology,
    pub phases: PhaseCycles,
    pub clock_hz: f64,
}

impl LatencyBreakdown {
    pub fn total_cycles(&self) -> u64 {
        self.phases.total()
    }

    pub fn total_ms(&self) -> f64 {
        self.phases.total() as f64 / self.clock_hz * 1e3
    }

    pub fn compute_only_ms(&self) -> f64 {
        self.phases.compute_only() as f64 / self.clock_hz * 1e3
    }
}

/// Calibration constants (module docs in `analytical/mod.rs` explain the
/// provenance of each value).
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyModel {
    /// PD_L: AXI setup 7 + addr 1 + load 1 + store 1 + float→fixed 3.
    pub pd_l: u64,
    /// Extra terms in PD_MHA beyond d_model/TS: load 1 + mul 2 + add 1 +
    /// store 1.
    pub pd_mha_const: u64,
    /// PD_BA: load + add + store.
    pub pd_ba: u64,
    /// Fixed control overhead C0 (fitted on Table I test 1 only).
    pub c0: u64,
    /// Load/compute overlap in the tile loop, 0..=1 (0 = the paper's
    /// sequential equations; 1 = perfect double buffering).
    pub gamma: f64,
    /// Fabric clock for ms conversion.
    pub clock_hz: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            pd_l: 13,
            pd_mha_const: 5,
            pd_ba: 3,
            c0: 72_020,
            gamma: 0.0,
            clock_hz: 400e6,
        }
    }
}

impl LatencyModel {
    /// Ablation constructor: same constants, different overlap factor.
    pub fn with_overlap(gamma: f64) -> Self {
        // Under full overlap the fixed overhead absorbs the un-overlapped
        // pipeline fill; refit of C0 on test 1 gives 158_036 (DESIGN.md §6).
        let c0 = if gamma > 0.0 { (72_020.0 + gamma * 86_016.0) as u64 } else { 72_020 };
        LatencyModel { gamma, c0, ..LatencyModel::default() }
    }

    /// Predict the phase breakdown for one topology (eqs. 5–13).
    pub fn predict(&self, topo: &Topology) -> LatencyBreakdown {
        let sl = topo.seq_len as u64;
        let dm = topo.d_model as u64;
        let dk = topo.d_k() as u64;
        let ts = topo.tile_size as u64;
        let n_tiles = topo.n_tiles() as u64;

        // eq. 5: LI = [(d_model−1)·1 + PD_L] · SL
        let li = LoopNest::new(PipelinedLoop::new(dm, 1, self.pd_l), sl).latency();
        // eq. 6: LB = (d_k−1)·1 + PD_L
        let lb = PipelinedLoop::new(dk, 1, self.pd_l).latency();
        // eq. 7 × n_tiles: LIA = [(TS−1)·1 + PD_L] · SL, per tile
        let lia_tile = LoopNest::new(PipelinedLoop::new(ts, 1, self.pd_l), sl).latency();
        let lia = lia_tile * n_tiles;
        // eq. 8 × n_tiles: LWA = [(d_k−1)·1 + PD_L] · SL, per tile
        let lwa_tile = LoopNest::new(PipelinedLoop::new(dk, 1, self.pd_l), sl).latency();
        let lwa = lwa_tile * n_tiles;
        // eq. 9 × n_tiles: SA = [(d_k−1)·1 + PD_MHA] · SL, PD_MHA = n_tiles + 5
        let pd_mha = n_tiles + self.pd_mha_const;
        let sa_tile = LoopNest::new(PipelinedLoop::new(dk, 1, pd_mha), sl).latency();
        let sa = sa_tile * n_tiles;
        // eq. 10: BA = [(d_k−1)·1 + PD_BA] · SL
        let ba = LoopNest::new(PipelinedLoop::new(dk, 1, self.pd_ba), sl).latency();
        // eq. 11: S = [(SL−1)·1 + PD_S] · SL, PD_S = d_k
        let s = LoopNest::new(PipelinedLoop::new(sl, 1, dk), sl).latency();
        // eq. 12: SV = [(d_k−1)·1 + PD_SV] · SL, PD_SV = SL
        let sv = LoopNest::new(PipelinedLoop::new(dk, 1, sl), sl).latency();

        // gamma ablation: per tile, overlap hides min(loads, compute).
        let overlap_saved = if self.gamma > 0.0 {
            let per_tile = (lia_tile + lwa_tile).min(sa_tile);
            (self.gamma * (per_tile * n_tiles) as f64) as u64
        } else {
            0
        };

        LatencyBreakdown {
            topology: topo.clone(),
            phases: PhaseCycles {
                li,
                lb,
                lia,
                lwa,
                sa,
                ba,
                s,
                sv,
                overhead: self.c0,
                overlap_saved,
            },
            clock_hz: self.clock_hz,
        }
    }

    /// Residual vs a measured latency: (predicted − measured)/measured.
    pub fn residual_vs_ms(&self, topo: &Topology, measured_ms: f64) -> f64 {
        (self.predict(topo).total_ms() - measured_ms) / measured_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::{row_is_reliable, TABLE1};

    fn t1() -> Topology {
        Topology::new(64, 768, 8, 64)
    }

    #[test]
    fn phase_values_match_hand_computation_test1() {
        // Worked numbers from DESIGN.md §6 (PD_L=13, PD_MHA=17).
        let p = LatencyModel::default().predict(&t1()).phases;
        assert_eq!(p.li, 49_920);
        assert_eq!(p.lb, 108);
        assert_eq!(p.lia, 4_864 * 12);
        assert_eq!(p.lwa, 6_912 * 12);
        assert_eq!(p.sa, 7_168 * 12);
        assert_eq!(p.ba, 6_272);
        assert_eq!(p.s, 10_176);
        assert_eq!(p.sv, 10_176);
    }

    #[test]
    fn test1_calibrated_to_measured() {
        // C0 was fitted on this row; it must land exactly.
        let ms = LatencyModel::default().predict(&t1()).total_ms();
        assert!((ms - 0.94).abs() < 0.005, "{ms}");
    }

    #[test]
    fn runtime_rows_within_tolerance() {
        // Tests 2-7 share the constants fitted on test 1; the model must
        // hold within ±15% (the paper's own model is ±5% on 2 points).
        let m = LatencyModel::default();
        for row in TABLE1.iter().filter(|r| {
            row_is_reliable(r.test) && r.test <= 7 && r.d_model % r.heads == 0
        }) {
            let resid = m.residual_vs_ms(&row.topology(), row.latency_ms);
            assert!(
                resid.abs() < 0.15,
                "test {}: resid {:.1}%",
                row.test,
                resid * 100.0
            );
        }
    }

    #[test]
    fn latency_orderings_match_table1() {
        // The *shape* claims: fewer heads -> slower; smaller d_model ->
        // faster; longer sequence -> slower; smaller tile -> slower.
        let m = LatencyModel::default();
        let ms = |sl, dm, h, ts| m.predict(&Topology::new(sl, dm, h, ts)).total_ms();
        assert!(ms(64, 768, 8, 64) < ms(64, 768, 4, 64));
        assert!(ms(64, 768, 4, 64) < ms(64, 768, 2, 64));
        assert!(ms(64, 256, 8, 64) < ms(64, 512, 8, 64));
        assert!(ms(64, 512, 8, 64) < ms(64, 768, 8, 64));
        assert!(ms(32, 768, 8, 64) < ms(64, 768, 8, 64));
        assert!(ms(64, 768, 8, 64) < ms(128, 768, 8, 64));
        assert!(ms(64, 768, 8, 64) < ms(64, 768, 8, 32));
        assert!(ms(64, 768, 8, 32) < ms(64, 768, 8, 16));
    }

    #[test]
    fn compute_only_matches_table4_convention() {
        // Table IV reports FAMOUS at 0.494 ms compute-only for test 1's
        // topology; our compute_only() should land within 10%.
        let b = LatencyModel::default().predict(&t1());
        let ms = b.compute_only_ms();
        assert!((ms - 0.494).abs() / 0.494 < 0.10, "{ms}");
    }

    #[test]
    fn paper_prediction_agreement() {
        // The paper's own model says 0.98 ms (test 1) and 1.9 ms (test 6);
        // ours must be within 15% of those predictions too.
        let m = LatencyModel::default();
        let p1 = m.predict(&t1()).total_ms();
        assert!((p1 - 0.98).abs() / 0.98 < 0.15, "{p1}");
        let p6 = m.predict(&Topology::new(128, 768, 8, 64)).total_ms();
        assert!((p6 - 1.9).abs() / 1.9 < 0.15, "{p6}");
    }

    #[test]
    fn overlap_ablation_helps_small_tiles() {
        // gamma=1 (full double-buffering) must bring the TS=32 rebuild
        // (test 9) much closer to its measurement than gamma=0 does.
        let seq = Topology::new(64, 768, 8, 32);
        let g0 = LatencyModel::default().residual_vs_ms(&seq, 1.155).abs();
        let g1 = LatencyModel::with_overlap(1.0).residual_vs_ms(&seq, 1.155).abs();
        assert!(g1 < g0, "g0={g0:.3} g1={g1:.3}");
        assert!(g1 < 0.10, "g1={g1:.3}");
    }

    #[test]
    fn totals_are_consistent() {
        let p = LatencyModel::default().predict(&t1()).phases;
        assert_eq!(
            p.total(),
            p.li + p.lb + p.lia + p.lwa + p.sa + p.ba + p.s + p.sv + p.overhead
        );
        assert!(p.compute_only() < p.total());
        assert_eq!(p.load_only(), p.li + p.lb + p.lia + p.lwa);
    }
}
