//! Section VII analytical latency model (eqs. 3–14).
//!
//! The paper decomposes total latency into eight phase terms, each an
//! instance of the pipelined-loop algebra in [`crate::fpga::hls`]:
//!
//! | term | meaning                                   | eq. |
//! |------|-------------------------------------------|-----|
//! | LI   | load all inputs from HBM                  | 5   |
//! | LB   | load all biases                           | 6   |
//! | LIA  | load input tile per attention head        | 7   |
//! | LWA  | load weight tile per attention head       | 8   |
//! | SA   | QKV computation in `QKV_PM`               | 9   |
//! | BA   | bias addition                             | 10  |
//! | S    | score computation in `QK_PM`              | 11  |
//! | SV   | weighted values in `SV_PM`                | 12  |
//!
//! Pipeline depths come from the paper's text: `PD_L` = 7 (AXI setup) +
//! 1 (addr) + 1 (load) + 1 (store) + 3 (float→fixed) = 13 cc;
//! `PD_MHA` = d_model/TS + load(1) + mul(2) + add(1) + store(1);
//! `PD_BA` = 3; `PD_S` = d_k; `PD_SV` = SL.
//!
//! ## Calibration (DESIGN.md §6)
//!
//! The poster's equations as printed do **not** reduce to its own
//! Table I: a literal sum gives 0.24 ms for test 1 vs 0.94 ms measured
//! (the paper's own model text quotes 0.98 ms, so repetition factors were
//! evidently compressed out of the printed equations).  We apply the
//! smallest structural completion that explains the data:
//!
//! * the per-head tile phases (LIA, LWA, SA) repeat once per tile
//!   (`d_model/TS` times — the Fig. 4 schedule);
//! * one fixed control overhead `C0` (µB instruction generation, AXI-lite
//!   handshakes, start/stop timing) fitted on test 1 **only**: 72 020 cc;
//! * an optional load/compute overlap factor `gamma` (double-buffering
//!   ablation; default 0 = the paper's sequential reading).
//!
//! One constant set must explain all rows; per-test residuals are
//! recorded in EXPERIMENTS.md (typ. ±5%, worst +63% on the TS=16 rebuild,
//! where real hardware evidently overlaps loads with compute — see the
//! `gamma` ablation bench).

mod model;

pub use model::{LatencyBreakdown, LatencyModel, PhaseCycles};

/// Paper-published Table I measurements for residual reporting
/// (test id, topology fields, device, latency ms, GOPS).
pub struct PaperRow {
    pub test: u32,
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    pub tile_size: usize,
    pub device: &'static str,
    pub latency_ms: f64,
    pub gops: f64,
}

impl PaperRow {
    pub fn topology(&self) -> crate::config::Topology {
        crate::config::Topology::new(self.seq_len, self.d_model, self.heads, self.tile_size)
    }
}

/// Table I as published.  Test 8's row is garbled in the source scan
/// (latency "13", GOPS "16"); we carry it for completeness but exclude it
/// from residual statistics (flagged by `row_is_reliable`).
pub const TABLE1: &[PaperRow] = &[
    PaperRow { test: 1, seq_len: 64, d_model: 768, heads: 8, tile_size: 64, device: "u55c", latency_ms: 0.94, gops: 328.0 },
    PaperRow { test: 2, seq_len: 64, d_model: 768, heads: 4, tile_size: 64, device: "u55c", latency_ms: 1.401, gops: 220.0 },
    PaperRow { test: 3, seq_len: 64, d_model: 768, heads: 2, tile_size: 64, device: "u55c", latency_ms: 2.281, gops: 135.0 },
    PaperRow { test: 4, seq_len: 64, d_model: 512, heads: 8, tile_size: 64, device: "u55c", latency_ms: 0.597, gops: 184.0 },
    PaperRow { test: 5, seq_len: 64, d_model: 256, heads: 8, tile_size: 64, device: "u55c", latency_ms: 0.352, gops: 312.0 },
    PaperRow { test: 6, seq_len: 128, d_model: 768, heads: 8, tile_size: 64, device: "u55c", latency_ms: 2.0, gops: 314.0 },
    PaperRow { test: 7, seq_len: 32, d_model: 768, heads: 8, tile_size: 64, device: "u55c", latency_ms: 0.534, gops: 285.0 },
    PaperRow { test: 8, seq_len: 16, d_model: 768, heads: 8, tile_size: 64, device: "u55c", latency_ms: 1.3, gops: 16.0 },
    PaperRow { test: 9, seq_len: 64, d_model: 768, heads: 8, tile_size: 32, device: "u55c", latency_ms: 1.155, gops: 267.0 },
    PaperRow { test: 10, seq_len: 64, d_model: 768, heads: 8, tile_size: 16, device: "u55c", latency_ms: 1.563, gops: 197.0 },
    PaperRow { test: 11, seq_len: 64, d_model: 768, heads: 6, tile_size: 64, device: "u200", latency_ms: 0.977, gops: 315.0 },
    PaperRow { test: 12, seq_len: 64, d_model: 512, heads: 6, tile_size: 64, device: "u200", latency_ms: 0.604, gops: 182.0 },
];

/// Test 8's published numbers are OCR-garbled (see TABLE1 docs).
pub fn row_is_reliable(test: u32) -> bool {
    test != 8
}

/// The paper's own analytical-model predictions quoted in Section VII
/// (test id, predicted ms at 400 MHz).
pub const PAPER_PREDICTIONS: &[(u32, f64)] = &[(1, 0.98), (6, 1.9)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_is_complete() {
        assert_eq!(TABLE1.len(), 12);
        assert!(TABLE1.iter().all(|r| r.latency_ms > 0.0));
        assert_eq!(TABLE1.iter().filter(|r| r.device == "u200").count(), 2);
    }

    #[test]
    fn reliability_flags() {
        assert!(!row_is_reliable(8));
        assert!(row_is_reliable(1));
    }

    #[test]
    fn topologies_well_formed_where_divisible() {
        for r in TABLE1 {
            if r.d_model % r.heads == 0 {
                assert!(r.topology().validate().is_ok(), "test {}", r.test);
            }
        }
    }
}
