//! `FamousAccelerator` — the device-level façade.
//!
//! One instance models one programmed FPGA card: a synthesized build
//! (SimConfig), a functional engine ([`crate::runtime::Backend`] — PJRT
//! artifacts or the int8 simulator datapath), the cycle-level timing
//! model, and the structural resource estimate.  `run()` is the analogue
//! of one µB-triggered accelerator invocation: program registers, stream
//! operands, compute, read the timer.

use crate::config::Topology;
use crate::fpga::resources::{ResourceEstimate, ResourceModel, Utilization};
use crate::jsonlite::Json;
use crate::metrics::OpCount;
use crate::runtime::{Backend, SimBackend};
use crate::sim::{SimConfig, SimResult, Simulator};
use crate::testdata::MhaInputs;
use anyhow::{bail, Result};

/// Outcome of one accelerator invocation.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub topology: Topology,
    /// Functional output (SL × d_model), from the configured backend.
    pub output: Vec<f32>,
    /// Modeled fabric latency.
    pub latency_ms: f64,
    pub cycles: u64,
    /// GOPS under the paper's op-count convention for this topology.
    pub gops: f64,
    /// GOPS under the strict attention-only convention.
    pub gops_attention_only: f64,
    /// Full phase trace (for per-phase attribution and Table IV's
    /// compute-only view).
    pub sim: SimResult,
}

impl RunReport {
    pub fn compute_only_ms(&self, clock_hz: f64) -> f64 {
        self.sim.trace.compute_only() as f64 / clock_hz * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("topology", self.topology.to_json()),
            ("latency_ms", Json::from(self.latency_ms)),
            ("cycles", Json::from(self.cycles as f64)),
            ("gops", Json::from(self.gops)),
        ])
    }
}

/// The accelerator: build + backend + telemetry.
pub struct FamousAccelerator {
    pub config: SimConfig,
    // NOTE: not Send — the PJRT client is Rc-based; the server constructs
    // the accelerator on its worker thread (see coordinator::server).
    backend: Box<dyn Backend>,
    pub resource_model: ResourceModel,
    /// Completed invocations.
    pub runs: u64,
}

impl FamousAccelerator {
    pub fn new(config: SimConfig, backend: Box<dyn Backend>) -> Self {
        FamousAccelerator { config, backend, resource_model: ResourceModel::default(), runs: 0 }
    }

    /// Accelerator whose functional engine is the PJRT runtime over
    /// `artifacts/` (the production configuration).
    pub fn with_pjrt(config: SimConfig, artifacts_dir: &str) -> Result<Self> {
        let rt = crate::runtime::Runtime::load(artifacts_dir)?;
        Ok(Self::new(config, Box::new(rt)))
    }

    /// Accelerator whose functional engine is the int8 simulator datapath
    /// (no artifacts needed; independent cross-check of the PJRT path).
    pub fn with_sim_datapath(config: SimConfig) -> Self {
        let backend = SimBackend::new(config.clone());
        Self::new(config, Box::new(backend))
    }

    /// Resource estimate of this build (synthesis-time).
    pub fn resources(&self) -> ResourceEstimate {
        // Resources are set by the synthesized maxima at the paper's
        // synthesis point (SL=64 convention; analytical/mod.rs docs).
        let mut synth = self.config.build.max_topology.clone();
        synth.seq_len = synth.seq_len.min(64);
        self.resource_model.estimate(&synth)
    }

    pub fn utilization(&self) -> Utilization {
        self.resources().utilization(&self.config.build.device)
    }

    /// One invocation: admission check → timing sim → functional compute.
    pub fn run(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<RunReport> {
        if let Err(e) = self.config.build.admits(topo) {
            bail!("admission: {e}");
        }
        let mut sim = Simulator::new(self.config.clone());
        let sim_result = sim.run_timing(topo).map_err(|e| anyhow::anyhow!("sim: {e}"))?;
        let output = self.backend.run_mha(topo, inputs)?;
        let expected = topo.seq_len * topo.d_model;
        if output.len() != expected {
            bail!("backend returned {} elements, expected {expected}", output.len());
        }
        self.runs += 1;
        let latency_ms = sim_result.latency_ms;
        Ok(RunReport {
            topology: topo.clone(),
            gops: OpCount::paper_convention(topo) / (latency_ms * 1e-3),
            gops_attention_only: OpCount::attention_only(topo).giga() / (latency_ms * 1e-3),
            latency_ms,
            cycles: sim_result.cycles,
            output,
            sim: sim_result,
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accel() -> FamousAccelerator {
        FamousAccelerator::with_sim_datapath(SimConfig::u55c())
    }

    #[test]
    fn headline_run() {
        let mut a = accel();
        let topo = Topology::new(64, 768, 8, 64);
        let r = a.run(&topo, &MhaInputs::generate(&topo)).unwrap();
        assert_eq!(r.output.len(), 64 * 768);
        assert!((r.latency_ms - 0.94).abs() < 0.01);
        assert!((r.gops - 328.0).abs() < 5.0, "{}", r.gops);
        assert_eq!(a.runs, 1);
    }

    #[test]
    fn admission_rejects_oversized() {
        let mut a = accel();
        let topo = Topology::new(64, 1536, 8, 64);
        assert!(a.run(&topo, &MhaInputs::generate(&topo)).is_err());
        assert_eq!(a.runs, 0);
    }

    #[test]
    fn resources_match_paper_build() {
        let a = accel();
        let r = a.resources();
        assert!((r.dsp as f64 - 4157.0).abs() / 4157.0 < 0.01);
        let u = a.utilization();
        assert!((u.lut_pct - 98.0).abs() < 2.5);
    }

    #[test]
    fn compute_only_view() {
        let mut a = accel();
        let topo = Topology::new(64, 768, 8, 64);
        let r = a.run(&topo, &MhaInputs::generate(&topo)).unwrap();
        let co = r.compute_only_ms(a.config.build.clock_hz);
        assert!(co < r.latency_ms);
        assert!((co - 0.494).abs() / 0.494 < 0.10, "{co}");
    }

    #[test]
    fn gops_scales_down_with_fewer_heads() {
        // Table I tests 1-3 shape: fewer runtime heads -> lower GOPS.
        let mut a = accel();
        let g8 = {
            let t = Topology::new(64, 768, 8, 64);
            a.run(&t, &MhaInputs::generate(&t)).unwrap().gops
        };
        let g4 = {
            let t = Topology::new(64, 768, 4, 64);
            a.run(&t, &MhaInputs::generate(&t)).unwrap().gops
        };
        let g2 = {
            let t = Topology::new(64, 768, 2, 64);
            a.run(&t, &MhaInputs::generate(&t)).unwrap().gops
        };
        assert!(g8 > g4 && g4 > g2);
    }
}
