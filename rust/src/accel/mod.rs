//! `FamousAccelerator` — the device-level façade.
//!
//! One instance models one programmed FPGA card: a synthesized build
//! (SimConfig), a functional engine ([`crate::runtime::Backend`] — PJRT
//! artifacts or the int8 simulator datapath), the cycle-level timing
//! model, and the structural resource estimate.
//!
//! Invocation is split the way the paper's control plane is (Fig. 6):
//!
//! * **program** — topology-dependent and cached.  [`Self::program`]
//!   produces a [`ProgramImage`] (control-register image, timing
//!   `SimResult` with the full phase trace, op counts) and stores it in
//!   a topology-keyed LRU [`ProgramCache`].  Repeat topologies skip
//!   `Simulator::run_timing` entirely — the software analogue of "one
//!   register reprogramming, no re-synthesis"; the `timing_sims_run`
//!   counter proves it.
//! * **execute** — per request.  [`Self::run`] executes one request
//!   against the programmed image; [`Self::run_batch`] executes a whole
//!   same-topology batch through the backend's batched entry point.  On
//!   the sim datapath both are head-parallel and allocation-free when
//!   warm: requests execute into resident `sim::Workspace` arenas with
//!   the heads fanned out across the shared worker pool, mirroring the
//!   fabric's `h` concurrent head pipelines (DESIGN.md §10).  Outputs
//!   stay bit-identical to the serial path in every mode.

use crate::config::Topology;
use crate::fpga::resources::{ResourceEstimate, ResourceModel, Utilization};
use crate::jsonlite::Json;
use crate::metrics::OpCount;
use crate::runtime::{Backend, PathCounters, SimBackend};
use crate::sim::{ControlRegs, ExecPath, SimConfig, SimResult, Simulator};
use crate::testdata::MhaInputs;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::rc::Rc;

/// Everything the program phase derives from a topology: the register
/// image the µB would write, the modeled timing (with per-phase trace),
/// and the op-count conventions.  Immutable once built; shared by every
/// request of the same topology via `Rc`.
#[derive(Clone, Debug)]
pub struct ProgramImage {
    pub topology: Topology,
    /// The AXI-lite register image (control words) for this topology.
    pub regs: ControlRegs,
    /// Timing-only simulation result (full phase trace, no output).
    pub sim: SimResult,
    /// GOP under the paper's op-count convention.
    pub gop_paper: f64,
    /// GOP under the strict attention-only convention.
    pub gop_attention: f64,
}

impl ProgramImage {
    pub fn latency_ms(&self) -> f64 {
        self.sim.latency_ms
    }

    pub fn cycles(&self) -> u64 {
        self.sim.cycles
    }

    /// Modeled GOPS of one invocation (paper convention).
    pub fn gops(&self) -> f64 {
        self.gop_paper / (self.latency_ms() * 1e-3)
    }

    /// Modeled GOPS under attention-only counting.
    pub fn gops_attention_only(&self) -> f64 {
        self.gop_attention / (self.latency_ms() * 1e-3)
    }
}

/// Topology-keyed LRU cache of program images.  Capacity 0 disables
/// caching (every `program()` re-runs the timing sim — the pre-split
/// behavior, kept for benchmarking the win).
#[derive(Debug, Default)]
pub struct ProgramCache {
    capacity: usize,
    /// Front = least recently used, back = most recently used.
    entries: VecDeque<(Topology, Rc<ProgramImage>)>,
}

/// Default number of programmed topologies kept per device (the paper's
/// serving mixes use a handful; 16 covers every Table I shape at once).
pub const DEFAULT_PROGRAM_CACHE: usize = 16;

impl ProgramCache {
    pub fn new(capacity: usize) -> Self {
        ProgramCache { capacity, entries: VecDeque::new() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch `topo`'s image, marking it most recently used.
    pub fn get(&mut self, topo: &Topology) -> Option<Rc<ProgramImage>> {
        let pos = self.entries.iter().position(|(t, _)| t == topo)?;
        let entry = self.entries.remove(pos).expect("position valid");
        let image = Rc::clone(&entry.1);
        self.entries.push_back(entry);
        Some(image)
    }

    /// Insert a freshly built image, evicting the least recently used
    /// entry at capacity.  Returns the shared handle.
    pub fn insert(&mut self, image: ProgramImage) -> Rc<ProgramImage> {
        let image = Rc::new(image);
        if self.capacity == 0 {
            return image;
        }
        if let Some(pos) = self.entries.iter().position(|(t, _)| t == &image.topology) {
            self.entries.remove(pos);
        } else if self.entries.len() >= self.capacity {
            self.entries.pop_front();
        }
        self.entries.push_back((image.topology.clone(), Rc::clone(&image)));
        image
    }

    /// Cached topologies, LRU first (telemetry / tests).
    pub fn topologies(&self) -> Vec<Topology> {
        self.entries.iter().map(|(t, _)| t.clone()).collect()
    }

    /// Drop every cached image.  Required after mutating the owning
    /// accelerator's `config` timing knobs — images are keyed by
    /// topology only and would otherwise serve stale timing.
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

/// Per-path timing summary distilled from a cached phase trace: the
/// modeled service time plus per-phase occupancy, without the full
/// event list.  This is what virtual-time consumers (the discrete-event
/// fleet simulator, DESIGN.md §16) draw per-request service times from
/// — a cache lookup, never a per-request timing simulation.
#[derive(Clone, Debug)]
pub struct TraceSummary {
    pub topology: Topology,
    pub path: ExecPath,
    /// Critical-path total of the trace (== `SimResult::cycles`).
    pub cycles: u64,
    /// Modeled fabric latency at this build's clock.
    pub latency_ms: f64,
    /// Summed occupancy per phase name, in order of first appearance
    /// (per-tile events fold into their phase, so a fused trace's
    /// overlapped tiles sum to more than `cycles`).
    pub phases: Vec<(&'static str, u64)>,
}

impl TraceSummary {
    fn from_sim(path: ExecPath, sim: &SimResult) -> Self {
        let mut phases: Vec<(&'static str, u64)> = Vec::new();
        for e in &sim.trace.events {
            match phases.iter_mut().find(|(n, _)| *n == e.name) {
                Some((_, c)) => *c += e.cycles(),
                None => phases.push((e.name, e.cycles())),
            }
        }
        TraceSummary {
            topology: sim.topology.clone(),
            path,
            cycles: sim.cycles,
            latency_ms: sim.latency_ms,
            phases,
        }
    }

    /// Summed occupancy of one phase (0 when absent).
    pub fn phase_cycles(&self, name: &str) -> u64 {
        self.phases.iter().find(|(n, _)| *n == name).map(|(_, c)| *c).unwrap_or(0)
    }
}

/// Outcome of one accelerator invocation.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub topology: Topology,
    /// Functional output (SL × d_model), from the configured backend.
    pub output: Vec<f32>,
    /// Modeled fabric latency.
    pub latency_ms: f64,
    pub cycles: u64,
    /// GOPS under the paper's op-count convention for this topology.
    pub gops: f64,
    /// GOPS under the strict attention-only convention.
    pub gops_attention_only: f64,
    /// Full phase trace (for per-phase attribution and Table IV's
    /// compute-only view).
    pub sim: SimResult,
}

impl RunReport {
    pub fn compute_only_ms(&self, clock_hz: f64) -> f64 {
        self.sim.trace.compute_only() as f64 / clock_hz * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("topology", self.topology.to_json()),
            ("latency_ms", Json::from(self.latency_ms)),
            ("cycles", Json::from(self.cycles as f64)),
            ("gops", Json::from(self.gops)),
        ])
    }
}

/// The accelerator: build + backend + program cache + telemetry.
pub struct FamousAccelerator {
    /// Synthesized build + timing knobs.  Cached program images are
    /// keyed by topology only: if you mutate timing-relevant fields
    /// (double_buffer, control_overhead, ...) after programming, call
    /// `programs.clear()` or stale timing will be served.
    pub config: SimConfig,
    // NOTE: not Send — the PJRT client is Rc-based; the server constructs
    // the accelerator on its worker thread (see coordinator::server).
    backend: Box<dyn Backend>,
    pub resource_model: ResourceModel,
    /// Program images by topology (public so benches/tests can resize).
    pub programs: ProgramCache,
    /// Completed invocations.
    pub runs: u64,
    /// Timing simulations actually executed (program-cache misses).
    pub timing_sims_run: u64,
    /// Program requests served from the cache.
    pub program_cache_hits: u64,
    /// Memoized fused-path timing summaries ([`Self::trace_summary`]).
    /// Kept beside — not inside — the `ProgramCache`: a `ProgramImage`
    /// carries the register image of the build's *programmed* schedule
    /// (reference timing), while these are alternate-path replays of the
    /// same topology.
    fused_timings: Vec<TraceSummary>,
}

impl FamousAccelerator {
    pub fn new(config: SimConfig, backend: Box<dyn Backend>) -> Self {
        FamousAccelerator {
            config,
            backend,
            resource_model: ResourceModel::default(),
            programs: ProgramCache::new(DEFAULT_PROGRAM_CACHE),
            runs: 0,
            timing_sims_run: 0,
            program_cache_hits: 0,
            fused_timings: Vec::new(),
        }
    }

    /// Accelerator whose functional engine is the PJRT runtime over
    /// `artifacts/` (the production configuration).
    pub fn with_pjrt(config: SimConfig, artifacts_dir: &str) -> Result<Self> {
        let rt = crate::runtime::Runtime::load(artifacts_dir)?;
        Ok(Self::new(config, Box::new(rt)))
    }

    /// Accelerator whose functional engine is the int8 simulator datapath
    /// (no artifacts needed; independent cross-check of the PJRT path).
    pub fn with_sim_datapath(config: SimConfig) -> Self {
        let backend = SimBackend::new(config.clone());
        Self::new(config, Box::new(backend))
    }

    /// Resource estimate of this build (synthesis-time).
    pub fn resources(&self) -> ResourceEstimate {
        // Resources are set by the synthesized maxima at the paper's
        // synthesis point (SL=64 convention; analytical/mod.rs docs).
        let mut synth = self.config.build.max_topology.clone();
        synth.seq_len = synth.seq_len.min(64);
        self.resource_model.estimate(&synth)
    }

    pub fn utilization(&self) -> Utilization {
        self.resources().utilization(&self.config.build.device)
    }

    /// Program phase: admission check, then the topology's image from the
    /// cache — or one timing simulation on a miss.
    pub fn program(&mut self, topo: &Topology) -> Result<Rc<ProgramImage>> {
        if let Err(e) = self.config.build.admits(topo) {
            bail!("admission: {e}");
        }
        if let Some(image) = self.programs.get(topo) {
            self.program_cache_hits += 1;
            return Ok(image);
        }
        let mut sim = Simulator::new(self.config.clone());
        let sim_result = sim.run_timing(topo).map_err(|e| anyhow::anyhow!("sim: {e}"))?;
        self.timing_sims_run += 1;
        let regs = sim.controller.regs().expect("run_timing programmed the controller");
        let image = ProgramImage {
            topology: topo.clone(),
            regs,
            gop_paper: OpCount::paper_convention(topo),
            gop_attention: OpCount::attention_only(topo).giga(),
            sim: sim_result,
        };
        Ok(self.programs.insert(image))
    }

    /// Per-path timing summary for `topo` (DESIGN.md §16).  `Reference`
    /// is served straight off the cached [`ProgramImage`] (a cache miss
    /// runs the one timing sim `program` would run anyway); `FusedTiled`
    /// replays the tile-streaming schedule once per topology and is
    /// memoized thereafter.  Either way, repeat calls are lookups —
    /// the property that lets a discrete-event simulator price millions
    /// of requests without millions of timing sims.
    pub fn trace_summary(&mut self, topo: &Topology, path: ExecPath) -> Result<TraceSummary> {
        if path == ExecPath::Reference {
            let image = self.program(topo)?;
            return Ok(TraceSummary::from_sim(path, &image.sim));
        }
        if let Some(s) = self.fused_timings.iter().find(|s| &s.topology == topo) {
            return Ok(s.clone());
        }
        if let Err(e) = self.config.build.admits(topo) {
            bail!("admission: {e}");
        }
        let mut sim = Simulator::new(self.config.clone());
        let r = sim.run_timing_path(topo, path).map_err(|e| anyhow::anyhow!("sim: {e}"))?;
        self.timing_sims_run += 1;
        let s = TraceSummary::from_sim(path, &r);
        self.fused_timings.push(s.clone());
        Ok(s)
    }

    fn report(&self, image: &ProgramImage, output: Vec<f32>) -> RunReport {
        RunReport {
            topology: image.topology.clone(),
            gops: image.gops(),
            gops_attention_only: image.gops_attention_only(),
            latency_ms: image.latency_ms(),
            cycles: image.cycles(),
            output,
            sim: image.sim.clone(),
        }
    }

    /// One invocation: program (cached) → execute → report.
    pub fn run(&mut self, topo: &Topology, inputs: &MhaInputs) -> Result<RunReport> {
        let image = self.program(topo)?;
        let output = self.backend.run_mha(topo, inputs)?;
        let expected = topo.seq_len * topo.d_model;
        if output.len() != expected {
            bail!("backend returned {} elements, expected {expected}", output.len());
        }
        self.runs += 1;
        Ok(self.report(&image, output))
    }

    /// One programmed image, a whole same-topology batch of executions
    /// through the backend's batched entry point.  Reports come back in
    /// request order and are bit-identical to serial [`Self::run`] calls.
    pub fn run_batch(&mut self, topo: &Topology, inputs: &[&MhaInputs]) -> Result<Vec<RunReport>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let image = self.program(topo)?;
        let outputs = self.backend.run_mha_batch(topo, inputs)?;
        if outputs.len() != inputs.len() {
            bail!("backend returned {} outputs for {} requests", outputs.len(), inputs.len());
        }
        let expected = topo.seq_len * topo.d_model;
        let mut reports = Vec::with_capacity(outputs.len());
        for output in outputs {
            if output.len() != expected {
                bail!("backend returned {} elements, expected {expected}", output.len());
            }
            self.runs += 1;
            reports.push(self.report(&image, output));
        }
        Ok(reports)
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Fused-vs-reference dispatch attribution of the functional engine
    /// (DESIGN.md §12).  All zeros for engines with a single datapath
    /// (PJRT).
    pub fn path_counters(&self) -> PathCounters {
        self.backend.path_counters()
    }

    /// Per-request ABFT verdicts of the most recent run/run_batch call
    /// (`true` = corrupt), request order; empty for engines without an
    /// integrity layer (DESIGN.md §15).
    pub fn last_integrity(&self) -> Vec<bool> {
        self.backend.last_integrity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accel() -> FamousAccelerator {
        FamousAccelerator::with_sim_datapath(SimConfig::u55c())
    }

    #[test]
    fn headline_run() {
        let mut a = accel();
        let topo = Topology::new(64, 768, 8, 64);
        let r = a.run(&topo, &MhaInputs::generate(&topo)).unwrap();
        assert_eq!(r.output.len(), 64 * 768);
        assert!((r.latency_ms - 0.94).abs() < 0.01);
        assert!((r.gops - 328.0).abs() < 5.0, "{}", r.gops);
        assert_eq!(a.runs, 1);
        assert_eq!(a.timing_sims_run, 1);
    }

    #[test]
    fn admission_rejects_oversized() {
        let mut a = accel();
        let topo = Topology::new(64, 1536, 8, 64);
        assert!(a.run(&topo, &MhaInputs::generate(&topo)).is_err());
        assert_eq!(a.runs, 0);
        assert_eq!(a.timing_sims_run, 0);
    }

    #[test]
    fn resources_match_paper_build() {
        let a = accel();
        let r = a.resources();
        assert!((r.dsp as f64 - 4157.0).abs() / 4157.0 < 0.01);
        let u = a.utilization();
        assert!((u.lut_pct - 98.0).abs() < 2.5);
    }

    #[test]
    fn compute_only_view() {
        let mut a = accel();
        let topo = Topology::new(64, 768, 8, 64);
        let r = a.run(&topo, &MhaInputs::generate(&topo)).unwrap();
        let co = r.compute_only_ms(a.config.build.clock_hz);
        assert!(co < r.latency_ms);
        assert!((co - 0.494).abs() / 0.494 < 0.10, "{co}");
    }

    #[test]
    fn gops_scales_down_with_fewer_heads() {
        // Table I tests 1-3 shape: fewer runtime heads -> lower GOPS.
        let mut a = accel();
        let g8 = {
            let t = Topology::new(64, 768, 8, 64);
            a.run(&t, &MhaInputs::generate(&t)).unwrap().gops
        };
        let g4 = {
            let t = Topology::new(64, 768, 4, 64);
            a.run(&t, &MhaInputs::generate(&t)).unwrap().gops
        };
        let g2 = {
            let t = Topology::new(64, 768, 2, 64);
            a.run(&t, &MhaInputs::generate(&t)).unwrap().gops
        };
        assert!(g8 > g4 && g4 > g2);
    }

    #[test]
    fn repeat_topology_skips_timing_sim() {
        let mut a = accel();
        let topo = Topology::new(32, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let r1 = a.run(&topo, &inputs).unwrap();
        let r2 = a.run(&topo, &inputs).unwrap();
        assert_eq!(a.timing_sims_run, 1, "second run must hit the cache");
        assert_eq!(a.program_cache_hits, 1);
        assert_eq!(r1.latency_ms, r2.latency_ms);
        assert_eq!(r1.output, r2.output);
    }

    #[test]
    fn program_exposes_control_words() {
        let mut a = accel();
        let topo = Topology::new(64, 768, 8, 64);
        let image = a.program(&topo).unwrap();
        assert_eq!(image.regs.d_k, 96);
        assert_eq!(image.regs.n_tiles, 12);
        assert_eq!(image.cycles(), image.sim.trace.total());
        assert!((image.gops() - 328.0).abs() < 5.0);
    }

    #[test]
    fn cache_lru_eviction_at_capacity() {
        let mut a = accel();
        a.programs = ProgramCache::new(2);
        let t1 = Topology::new(16, 768, 8, 64);
        let t2 = Topology::new(32, 768, 8, 64);
        let t3 = Topology::new(64, 768, 8, 64);
        a.program(&t1).unwrap();
        a.program(&t2).unwrap();
        assert_eq!(a.timing_sims_run, 2);
        a.program(&t1).unwrap(); // refresh t1 -> t2 becomes LRU
        assert_eq!(a.program_cache_hits, 1);
        a.program(&t3).unwrap(); // evicts t2
        assert_eq!(a.timing_sims_run, 3);
        assert_eq!(a.programs.topologies(), vec![t1.clone(), t3.clone()]);
        a.program(&t2).unwrap(); // miss again: was evicted
        assert_eq!(a.timing_sims_run, 4);
    }

    #[test]
    fn zero_capacity_cache_disables_caching() {
        let mut a = accel();
        a.programs = ProgramCache::new(0);
        let topo = Topology::new(32, 768, 8, 64);
        a.program(&topo).unwrap();
        a.program(&topo).unwrap();
        assert_eq!(a.timing_sims_run, 2);
        assert_eq!(a.program_cache_hits, 0);
        assert!(a.programs.is_empty());
    }

    #[test]
    fn batch_run_counts_and_matches_serial() {
        let topo = Topology::new(16, 768, 8, 64);
        let inputs: Vec<MhaInputs> = (0..3)
            .map(|i| {
                let mut inp = MhaInputs::generate(&topo);
                inp.x = crate::testdata::gen_matrix(50 + i, topo.seq_len, topo.d_model);
                inp
            })
            .collect();
        let mut serial = accel();
        let want: Vec<Vec<f32>> =
            inputs.iter().map(|inp| serial.run(&topo, inp).unwrap().output).collect();
        let mut batched = accel();
        let refs: Vec<&MhaInputs> = inputs.iter().collect();
        let reports = batched.run_batch(&topo, &refs).unwrap();
        assert_eq!(batched.runs, 3);
        assert_eq!(batched.timing_sims_run, 1, "one program for the whole batch");
        for (r, w) in reports.iter().zip(&want) {
            assert_eq!(&r.output, w);
            assert!((r.latency_ms - reports[0].latency_ms).abs() < 1e-12);
        }
    }
}
