//! Tiny benchmarking helpers shared by the `benches/` targets (criterion
//! is unavailable in the offline image; see DESIGN.md §2).

use std::time::Instant;

/// Wall-clock statistics of repeated runs of `f`, in milliseconds.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "mean {:.4} ms  min {:.4} ms  max {:.4} ms  ({} iters)",
            self.mean_ms, self.min_ms, self.max_ms, self.iters
        )
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench(warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchStats {
        iters,
        mean_ms: mean,
        min_ms: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_ms: samples.iter().copied().fold(0.0, f64::max),
    }
}

/// Keep a value from being optimized away.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min_ms <= s.mean_ms && s.mean_ms <= s.max_ms);
    }
}
