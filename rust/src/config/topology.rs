//! Workload topology: the paper's (SL, d_model, h) triple plus the tile
//! size of the build it runs on.

use super::ConfigError;
use crate::jsonlite::Json;

/// One MHA workload shape. Matches `python/compile/topologies.Topology`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Topology {
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    pub tile_size: usize,
}

impl Topology {
    pub fn new(seq_len: usize, d_model: usize, heads: usize, tile_size: usize) -> Self {
        Topology { seq_len, d_model, heads, tile_size }
    }

    /// Per-head projection width `d_k = d_model / h` (eq. 2).
    pub fn d_k(&self) -> usize {
        self.d_model / self.heads
    }

    /// Number of weight/input column tiles `d_model / TS` (Fig. 4).
    pub fn n_tiles(&self) -> usize {
        self.d_model / self.tile_size
    }

    /// Artifact name — must match `topologies.Topology.name` in python.
    pub fn name(&self) -> String {
        format!(
            "mha_sl{}_d{}_h{}_ts{}",
            self.seq_len, self.d_model, self.heads, self.tile_size
        )
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: String| Err(ConfigError::InvalidTopology(m));
        if self.seq_len == 0 || self.d_model == 0 || self.heads == 0 || self.tile_size == 0 {
            return err(format!("zero dimension in {self:?}"));
        }
        if self.d_model % self.heads != 0 {
            return err(format!(
                "d_model={} not divisible by heads={}",
                self.d_model, self.heads
            ));
        }
        if self.d_model % self.tile_size != 0 {
            return err(format!(
                "d_model={} not divisible by tile_size={}",
                self.d_model, self.tile_size
            ));
        }
        Ok(())
    }

    /// Total multiply-add operation count conventions — see
    /// `crate::metrics::ops` for the two GOP conventions in the paper.
    pub fn output_elems(&self) -> usize {
        self.seq_len * self.d_model
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("seq_len", Json::from(self.seq_len as f64)),
            ("d_model", Json::from(self.d_model as f64)),
            ("heads", Json::from(self.heads as f64)),
            ("tile_size", Json::from(self.tile_size as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let get = |k: &str| -> Result<usize, String> {
            j.get(k)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| format!("topology missing field {k}"))
        };
        Ok(Topology::new(get("seq_len")?, get("d_model")?, get("heads")?, get("tile_size")?))
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "(SL={}, d_model={}, h={}, TS={})",
            self.seq_len, self.d_model, self.heads, self.tile_size
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities() {
        let t = Topology::new(64, 768, 8, 64);
        assert_eq!(t.d_k(), 96);
        assert_eq!(t.n_tiles(), 12);
        assert_eq!(t.name(), "mha_sl64_d768_h8_ts64");
    }

    #[test]
    fn validation_catches_indivisible() {
        assert!(Topology::new(64, 512, 6, 64).validate().is_err());
        assert!(Topology::new(64, 768, 8, 40).validate().is_err());
        assert!(Topology::new(0, 768, 8, 64).validate().is_err());
        assert!(Topology::new(64, 768, 8, 64).validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let t = Topology::new(32, 256, 4, 32);
        let j = t.to_json();
        assert_eq!(Topology::from_json(&j).unwrap(), t);
    }
}
